"""Serve a small LM with continuous batching.

    PYTHONPATH=src python examples/serve_lm.py

Spins up the ServeEngine (fixed slot pool over one static KV cache),
feeds it more requests than slots, and drains: slots free as requests
finish and queued requests are admitted — the TPU-static reduction of a
vLLM-style scheduler.  Greedy decoding is validated against a
reference forward pass over the full sequence.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine, Request


def main():
    cfg = get("tinyllama-1.1b").scaled(n_layers=2, d_model=128,
                                       n_heads=4, d_ff=256, vocab=512)
    params = tf.init_lm(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_slots=4, max_len=96)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab, 8).tolist(),
                    max_new_tokens=12)
            for i in range(10)]          # 10 requests, 4 slots
    eng.run_until_drained(reqs)
    assert all(r.done for r in reqs)
    print(f"served {len(reqs)} requests over "
          f"{eng.b} slots; generated "
          f"{sum(len(r.generated) for r in reqs)} tokens")

    # validate slot 0's greedy continuation against a full forward pass
    r = reqs[0]
    toks = list(r.prompt)
    for _ in range(3):
        logits, _ = tf.forward(params, cfg,
                               jnp.asarray([toks], jnp.int32),
                               attn_path="dense")
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert toks[len(r.prompt):] == r.generated[:3], \
        (toks[len(r.prompt):], r.generated[:3])
    print("continuous-batching output matches full-sequence forward ✓")


if __name__ == "__main__":
    main()
