"""End-to-end training driver: a ~100M-parameter LM with checkpointing,
an injected mid-run failure, and bit-identical resume.

    PYTHONPATH=src python examples/train_lm.py            # quick (CPU)
    PYTHONPATH=src python examples/train_lm.py --full     # ~100M params

Demonstrates the production path: mesh + logical sharding rules,
gradient accumulation, atomic checkpoints, restart-after-failure, and
the loss actually going down on the synthetic stream.
"""
import argparse
import dataclasses
import shutil

import jax

from repro.configs import get
from repro.launch.train import build_step_and_state
from repro.launch.mesh import make_host_mesh
from repro.launch import sharding as shlib
from repro.data.tokens import synthetic_lm_batches
from repro.train.trainer import Trainer, TrainerConfig


def run(cfg, steps, batch, seq, ckpt_dir, fail_at=None, resume=False):
    mesh = make_host_mesh()
    with shlib.use_rules(mesh), mesh:
        step, state = build_step_and_state(cfg, total=steps * 10,
                                           num_microbatches=2)
        data = synthetic_lm_batches(cfg.vocab, batch, seq)

        def failure_hook(s):
            if fail_at is not None and s == fail_at:
                raise RuntimeError(f"injected failure at step {s}")

        tr = Trainer(TrainerConfig(total_steps=steps,
                                   checkpoint_every=10,
                                   ckpt_dir=ckpt_dir, log_every=10),
                     step, state, data,
                     failure_hook=failure_hook)
        if resume:
            tr.try_resume()
        return tr.run()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, a few hundred steps (slow on "
                         "a 1-core CPU; the TPU-shaped run)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro-example-ckpt")
    args = ap.parse_args()

    base = get("tinyllama-1.1b")
    if args.full:
        # ~100M params: 12 layers, d_model 768, vocab 32000
        cfg = dataclasses.replace(
            base, name="lm-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_ff=2048, vocab=32000, head_dim=None)
        steps, batch, seq = 300, 8, 512
    else:
        cfg = base.scaled(n_layers=4, d_model=256, n_heads=8,
                          d_ff=512, vocab=2048)
        steps, batch, seq = 60, 8, 128

    n_params = cfg.param_count()
    print(f"config {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{steps} steps")
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    # ---- run with an injected failure at 60% of the way
    fail_at = int(steps * 0.6)
    try:
        run(cfg, steps, batch, seq, args.ckpt_dir, fail_at=fail_at)
        raise AssertionError("failure was not injected?")
    except RuntimeError as e:
        print(f"[expected] {e} — restarting from checkpoint")

    # ---- restart: resumes from the last checkpoint and finishes
    report = run(cfg, steps, batch, seq, args.ckpt_dir, resume=True)
    losses = [m["loss"] for m in report["history"]]
    print(f"finished at step {report['final_step']}; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss did not decrease"
    print("loss decreased ✓  checkpoint/restart exercised ✓")


if __name__ == "__main__":
    main()
