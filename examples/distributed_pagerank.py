"""Distributed PCPM PageRank over 8 (forced-host) devices.

    PYTHONPATH=src python examples/distributed_pagerank.py

The paper's §VII generalization as a first-class feature: vertices are
sharded over a device mesh; each vertex's rank crosses the interconnect
ONCE per destination shard (the PNG dedup) via a single all-to-all of
compressed update buffers, instead of once per cross-shard edge
(the edge-cut / distributed-BVGAS baseline).  Prints the wire-byte
reduction and validates both engines against the dense oracle.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs import generators
from repro.core.distributed import (build_sharded_png,
                                    pcpm_all_to_all_spmv, edge_cut_spmv,
                                    pad_to_shards, distributed_pagerank)
from repro.core.pagerank import pagerank_reference


def main():
    n_shards = jax.device_count()
    mesh = jax.make_mesh((n_shards,), ("shards",))
    g = generators.rmat(12, 16, seed=3)
    print(f"graph n={g.num_nodes:,} m={g.num_edges:,} "
          f"shards={n_shards}")

    layout = build_sharded_png(g, n_shards)
    d_v = 4
    print(f"wire updates (PCPM):    {layout.wire_updates:,} "
          f"({layout.wire_updates * d_v / 1e6:.2f} MB/iter)")
    print(f"wire edges  (edge-cut): {layout.wire_edges:,} "
          f"({layout.wire_edges * 2 * d_v / 1e6:.2f} MB/iter)")
    print(f"wire compression r = {layout.wire_compression:.2f}x")

    # SpMV correctness for both engines
    A = np.zeros((g.num_nodes, g.num_nodes))
    np.add.at(A, (g.src, g.dst), 1.0)
    x = np.random.default_rng(0).random(g.num_nodes).astype(np.float32)
    xp = jnp.asarray(pad_to_shards(x, layout))
    y_pcpm = np.asarray(pcpm_all_to_all_spmv(layout, mesh, "shards")(xp))
    y_ec = np.asarray(edge_cut_spmv(g, n_shards, mesh, "shards")(xp))
    np.testing.assert_allclose(y_pcpm[:g.num_nodes], A.T @ x,
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(y_ec[:g.num_nodes], A.T @ x,
                               rtol=2e-4, atol=1e-5)
    print("both distributed engines match the dense oracle ✓")

    # one donated fused while_loop dispatch for the whole run, with the
    # psum residual deciding the tol exit on device (DESIGN.md §6)
    res = distributed_pagerank(g, mesh, "shards", num_iterations=60,
                               tol=1e-6, layout=layout)
    ref = pagerank_reference(g, num_iterations=res.iterations)
    np.testing.assert_allclose(np.asarray(res.ranks), ref, rtol=1e-3,
                               atol=1e-7)
    print(f"sharded fused PageRank matches the dense oracle ✓ "
          f"(converged at iteration {res.iterations}, final residual "
          f"{res.residuals[-1]:.2e})")

    res_d = distributed_pagerank(g, mesh, "shards", num_iterations=30,
                                 dangling="redistribute", layout=layout)
    print(f"with dangling redistribution: total mass = "
          f"{float(np.asarray(res_d.ranks).sum()):.6f}")


if __name__ == "__main__":
    main()
