"""Continuous-batching PageRank query serving demo (DESIGN.md §7/§8).

    PYTHONPATH=src python examples/serve_pagerank.py [--scale 12]

Registers two graphs in a GraphRegistry — one built in-process, one
warm-loaded from the graphs/io.py npz format TOGETHER with its
persisted GraphPlan (so the server process pays an npz read, not an
edge re-sort) — then fires a mixed workload at each: uniform-teleport
queries, personalized queries with per-request tolerances (so slots
converge at different times and the scheduler back-fills freed columns
mid-flight), and on-device top-k queries that ship only k ids+scores
to the host.  Prints the per-query results and the latency/throughput
summary from serve/metrics.py.

The finale is a LIVE GRAPH UPDATE (DESIGN.md §9): with queries still
in flight, an edge batch lands on the kron graph —
``scheduler.apply_delta`` patches the plan's dirty partitions, swaps
the stepper (one re-lower; the admit/extract executables survive), and
the in-flight columns keep iterating straight into the NEW graph's
answers while fresh queries are admitted behind them.

``--push`` runs the forward-push query demo instead (DESIGN.md §11):
the same scheduler front door, but loose-tolerance top-k personalized
queries are routed to the host-side forward-push backend — no device
slot, no batching wait — while tight-tolerance queries on the SAME
scheduler still take the masked chunk stepper.  Prints the per-route
throughput and the top-k agreement between the two routes.

``--gateway`` runs the async front-door demo instead (DESIGN.md §13):
``Session.gateway()`` probes the measured stepper cost and autotunes
the slot-pool size, four submitter threads get futures back
immediately (push-eligible traffic on the worker pool, full-vector
queries interleaved on the device thread), a repeated query is served
bit-identically from the warm-result cache, and a live edge delta
invalidates exactly the dead cache entries while traffic continues.

``--chaos`` runs the resilience demo instead (DESIGN.md §10): the
same serving pool under injected faults — a NaN poisons a slot column
mid-flight (quarantined + re-admitted from its clean seed), a device
step throws (retried), the pool is snapshotted, "killed", and restored
mid-flight — and every answer still matches the fault-free run.

``--observe`` runs the observability demo instead (DESIGN.md §14):
the gateway storm again, but with a flight recorder + metrics
registry attached — every query leaves a well-nested span tree
(intake → backlog → slot/push → terminal → resolve), the plan build
and solve are traced, and the measured-vs-model communication
accountant counts every executed device pass.  Writes the trace
JSONL, a Prometheus metrics snapshot, and the stats JSON into
``--out`` (artifacts a CI run uploads).
"""
import argparse
import json
import os
import tempfile

import numpy as np

import repro
from repro.graphs import generators, io as graph_io
from repro.serve import GraphRegistry, SlotScheduler


def chaos(args):
    from repro.reliability import (FaultInjector, FaultPlan, FaultSpec,
                                   ResilienceConfig, restore_scheduler,
                                   snapshot_scheduler)
    g = generators.rmat(args.scale, 16, seed=7)
    part_size = max(256, g.num_nodes // 64)
    kw = dict(slots=args.slots, method="pcpm", part_size=part_size,
              chunk=4)
    rng = np.random.default_rng(0)
    seeds = []
    for _ in range(args.queries):
        s = np.zeros(g.num_nodes, np.float32)
        s[rng.integers(0, g.num_nodes, size=2)] = 1.0
        seeds.append(s)

    ref = SlotScheduler(g, **kw)
    refs = [ref.submit(s, tol=1e-6, max_iters=300) for s in seeds]
    ref_by_uid = {r.uid: r for r in ref.run_until_drained()}
    print(f"fault-free: {len(refs)} queries served "
          f"(trace_count={ref.trace_count})")

    # same workload, with a NaN poisoning slot 0 mid-flight and a
    # device step exception two chunks later
    inj = FaultInjector(FaultPlan.of([
        FaultSpec("nan_slot", step=2, slot=0),
        FaultSpec("step_error", step=4),
    ]))
    sch = SlotScheduler(
        g, fault_injector=inj,
        resilience=ResilienceConfig(max_queue=4 * args.queries,
                                    max_retries=1, max_step_retries=1),
        **kw)
    uids = [sch.submit(s, tol=1e-6, max_iters=300) for s in seeds]
    for _ in range(6):              # run into both faults...
        sch.step()
    with tempfile.TemporaryDirectory() as td:     # ...then die
        path = os.path.join(td, "sched.npz")
        snapshot_scheduler(sch, path)
        print(f"chaos: snapshot with {sch.active_slots} in flight, "
              f"{sch.queued} queued, faults fired="
              f"{[f.kind for f in inj.fired]}")
        done_before = {r.uid: r for r in sch.completed}
        counters = dict(sch.metrics.counters)     # quarantine/retry
        sch = restore_scheduler(path, g, **kw)    # "new process"
    out = {r.uid: r for r in sch.run_until_drained()}
    out.update(done_before)

    worst = max(float(np.abs(ref_by_uid[a].ranks - out[b].ranks).max())
                for a, b in zip(refs, uids))
    print(f"restored: {len(out)} served, pre-crash counters="
          f"{counters}, trace_count={sch.trace_count}")
    print(f"max |chaos - fault-free| over all queries: {worst:.2e}")
    assert worst <= 1e-6, "chaos run diverged from fault-free answers"
    assert sch.trace_count == 1
    print("resilience demo OK: poisoned slot quarantined + re-served, "
          "step fault retried, restart resumed mid-flight — answers "
          "identical")


def push(args):
    import time

    g = generators.rmat(args.scale, 16, seed=7)
    part_size = max(64, g.num_nodes // 64)
    sch = SlotScheduler(g, slots=args.slots, method="pcpm",
                        part_size=part_size, chunk=4)
    rng = np.random.default_rng(0)
    seeds = []
    for _ in range(args.queries):
        s = np.zeros(g.num_nodes, np.float32)
        s[rng.integers(0, g.num_nodes)] = 1.0
        seeds.append(s)

    results = {}
    for route in ("push", "stepper"):
        # warm the route's compiled path, then time the workload
        sch.submit(seeds[0], top_k=10, tol=1e-3, max_iters=300,
                   route=route)
        sch.run_until_drained()
        t0 = time.perf_counter()
        uids = [sch.submit(s, top_k=10, tol=1e-3, max_iters=300,
                           route=route) for s in seeds]
        sch.run_until_drained()     # push results landed at submit
        dt = time.perf_counter() - t0
        done = {r.uid: r for r in sch.completed}
        results[route] = [done[u] for u in uids]
        iters = np.mean([r.iterations for r in results[route]])
        print(f"{route:8s}: {len(uids)} personalized top-10 queries "
              f"in {dt * 1e3:7.1f}ms ({len(uids) / dt:7.1f} qps, "
              f"mean {iters:.1f} {'sweeps' if route == 'push' else 'iters'})")
    agree = np.mean([
        len(set(map(int, a.top_ids)) & set(map(int, b.top_ids)))
        / len(a.top_ids)
        for a, b in zip(results["push"], results["stepper"])])
    c = sch.metrics.counters
    print(f"push_served={c['push_served']} "
          f"fallbacks={c.get('push_fallbacks', 0)} "
          f"trace_count={sch.trace_count}")
    print(f"top-10 agreement push vs stepper: {agree:.1%}")
    assert agree >= 0.9 and sch.trace_count == 1
    print("push demo OK: same front door, loose-tolerance top-k "
          "queries served host-side without touching a device slot")


def gateway(args):
    """Async front-door demo (DESIGN.md §13): autotuned slot pool,
    concurrent submitters getting futures, warm-result cache hits, and
    a live delta invalidating the cache mid-traffic."""
    import threading
    import time

    g = generators.rmat(args.scale, 16, seed=7)
    part_size = max(64, g.num_nodes // 64)
    sess = repro.open(g, repro.EngineConfig(
        method="pcpm", part_size=part_size, chunk=4, slots=args.slots))
    rng = np.random.default_rng(0)
    nodes = rng.choice(g.num_nodes, size=args.queries, replace=False)

    def one_hot(node):
        s = np.zeros(g.num_nodes, np.float32)
        s[node] = 1.0
        return s

    with sess.gateway() as gw:
        rep = gw.autotune_report
        print(f"autotune: probes(ms)="
              f"{ {b: round(t * 1e3, 2) for b, t in rep.probes.items()} } "
              f"target={rep.target_chunk_s * 1e3:.0f}ms -> B={rep.chosen} "
              f"(session default was {args.slots})")

        # N submitter threads, futures back immediately; half the
        # traffic is push-eligible top-k, half full-vector stepper
        results, lock = [], threading.Lock()

        def client(lo, hi):
            futs = [gw.submit(one_hot(nodes[i]),
                              top_k=10 if i % 2 else None,
                              tol=1e-3 if i % 2 else 1e-5,
                              max_iters=300)
                    for i in range(lo, hi)]
            got = [f.result(timeout=300) for f in futs]
            with lock:
                results.extend(got)

        t0 = time.perf_counter()
        q4 = args.queries // 4
        threads = [threading.Thread(target=client,
                                    args=(i * q4, (i + 1) * q4))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        assert all(r.error is None for r in results)
        assert len({r.uid for r in results}) == len(results)
        print(f"4 threads, {len(results)} queries in {dt * 1e3:.0f}ms "
              f"({len(results) / dt:.0f} qps), all converged, "
              f"uids unique")

        # a repeat is a warm-result hit: O(k), bit-identical arrays
        r1 = gw.submit(one_hot(nodes[1]), top_k=10,
                       tol=1e-3, max_iters=300).result(timeout=300)
        assert r1.cached and r1.top_ids is not None
        print(f"repeat query: cached={r1.cached} "
              f"(cache: {gw.stats()['cache']})")

        # live delta: plan patched between chunks, cache entries for
        # the outgoing fingerprint dropped atomically
        k = max(4, g.num_edges // 1000)
        delta = repro.GraphDelta.insert(
            np.stack([rng.integers(0, g.num_nodes, k),
                      rng.integers(0, g.num_nodes, k)], axis=1))
        dropped = gw.apply_delta(delta).result(timeout=300)
        r2 = gw.submit(one_hot(nodes[1]), top_k=10,
                       tol=1e-3, max_iters=300).result(timeout=300)
        sch = gw._schedulers["default"]
        print(f"±{k}-edge delta: {dropped} cache entries invalidated, "
              f"repeat recomputed (cached={r2.cached}), "
              f"rebinds={sch.rebind_count}")
        assert not r2.cached
        assert sch.trace_count == 1 + sch.rebind_count
        assert sch.admit_trace_count == 1
    print("gateway demo OK: futures front door, autotuned pool, "
          "warm-result cache with delta invalidation — zero retraces")


def observe(args):
    """Observability demo (DESIGN.md §14): the gateway storm with the
    flight recorder on, then dump the three artifact surfaces — trace
    JSONL, Prometheus text, stats JSON — into ``--out``."""
    import threading
    import time

    g = generators.rmat(args.scale, 16, seed=7)
    part_size = max(64, g.num_nodes // 64)
    sess = repro.open(g, repro.EngineConfig(
        method="pcpm", part_size=part_size, chunk=4, slots=args.slots,
        observe=True))
    res = sess.pagerank(tol=1e-6, num_iterations=200)  # traced solve
    rng = np.random.default_rng(0)
    nodes = rng.choice(g.num_nodes, size=args.queries, replace=False)

    def one_hot(node):
        s = np.zeros(g.num_nodes, np.float32)
        s[node] = 1.0
        return s

    with sess.gateway() as gw:
        results, lock = [], threading.Lock()

        def client(lo, hi):
            futs = [gw.submit(one_hot(nodes[i]),
                              top_k=10 if i % 2 else None,
                              tol=1e-3 if i % 2 else 1e-5,
                              max_iters=300)
                    for i in range(lo, hi)]
            got = [f.result(timeout=300) for f in futs]
            with lock:
                results.extend(got)

        t0 = time.perf_counter()
        q4 = args.queries // 4
        threads = [threading.Thread(target=client,
                                    args=(i * q4, (i + 1) * q4))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        assert all(r.error is None for r in results)
        # a repeat is a warm-result cache hit — traced as route=cached
        r1 = gw.submit(one_hot(nodes[1]), top_k=10,
                       tol=1e-3, max_iters=300).result(timeout=300)
        assert r1.cached
        prom = gw.metrics_endpoint()
        sch = gw._schedulers["default"]
        assert sch.trace_count == 1 and sch.admit_trace_count == 1

    # ---- verify span trees off the live ring, then dump artifacts
    obs = sess.obs
    recs = obs.recorder.snapshot()
    uids = {r.uid for r in results}
    roots = [r for r in recs if r.name == "query" and r.trace in uids]
    terms = [r for r in recs if r.name == "terminal" and r.trace in uids]
    assert len(roots) == len(uids), (len(roots), len(uids))
    assert len(terms) == len(uids), "exactly one terminal per query"
    for root in roots:
        kids = [r for r in recs if r.parent_id == root.span_id
                and not r.is_event]
        assert all(root.t_start <= k.t_start and k.t_end <= root.t_end
                   for k in kids), "span tree not well-nested"

    os.makedirs(args.out, exist_ok=True)
    trace_path = obs.dump(os.path.join(args.out, "trace.jsonl"))
    prom_path = os.path.join(args.out, "metrics.prom")
    with open(prom_path, "w") as f:
        f.write(prom)
    stats = sess.stats()
    stats_path = os.path.join(args.out, "stats.json")
    with open(stats_path, "w") as f:
        json.dump(stats, f, indent=1, default=str)

    comm = stats["obs"]["comm"].get("pcpm", {})
    fr = stats["obs"]["flight_recorder"]
    print(f"storm: {len(results)} queries in {dt * 1e3:.0f}ms "
          f"({len(results) / dt:.0f} qps), solve {res.iterations} iters")
    print(f"flight recorder: {fr['recorded']} recorded, "
          f"{fr['dropped']} dropped, {fr['held']} held "
          f"(capacity {fr['capacity']})")
    print(f"span trees: {len(roots)} roots, {len(terms)} terminals — "
          f"well-nested, exactly one terminal each")
    print(f"comm accountant: {comm.get('passes', 0)} passes, "
          f"{comm.get('dram_bytes', 0):.3g} B measured, "
          f"ratio_vs_model={comm.get('ratio_vs_model', 0):.2f}")
    print(f"artifacts: {trace_path} ({fr['held']} records), "
          f"{prom_path} ({len(prom.splitlines())} lines), {stats_path}")
    print("observability demo OK: traced solve + gateway storm, "
          "complete span trees, measured comm within model's regime, "
          "zero retraces")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--queries", type=int, default=24)
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection / recovery demo "
                         "(DESIGN.md §10)")
    ap.add_argument("--push", action="store_true",
                    help="run the forward-push query routing demo "
                         "(DESIGN.md §11)")
    ap.add_argument("--gateway", action="store_true",
                    help="run the async gateway demo (DESIGN.md §13)")
    ap.add_argument("--observe", action="store_true",
                    help="run the observability demo (DESIGN.md §14)")
    ap.add_argument("--out", default="obs-artifacts",
                    help="artifact directory for --observe (trace "
                         "JSONL, Prometheus snapshot, stats JSON)")
    args = ap.parse_args()
    if args.chaos:
        return chaos(args)
    if args.push:
        return push(args)
    if args.gateway:
        return gateway(args)
    if args.observe:
        return observe(args)

    kron = generators.rmat(args.scale, 16, seed=7)
    plaw = generators.power_law(1 << args.scale, 14, seed=3)
    part_size = max(256, kron.num_nodes // 64)

    reg = GraphRegistry(slots=args.slots, method="pcpm",
                        part_size=part_size, chunk=4)
    reg.add("kron", kron)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "plaw.npz")
        plan_path = os.path.join(td, "plaw.plan.npz")
        graph_io.save(path, plaw)
        # persist the preprocessing artifact next to the graph (what a
        # deployment does once, offline)
        repro.build_plan(plaw, repro.PlanConfig(
            method="pcpm", part_size=part_size)).save(plan_path)
        repro.clear_plan_cache()        # simulate a fresh server process
        # warm-loaded: plan read from npz, scheduler compiled up front
        reg.load("plaw", path, plan_path=plan_path)
    stats = repro.plan_cache_stats()
    print(f"registry: {reg.names()}  "
          f"(slots={args.slots}, trace_count="
          f"{[reg.get(n).trace_count for n in reg.names()]}, "
          f"plan builds since load={stats.plan_builds})")

    rng = np.random.default_rng(0)
    for i in range(args.queries):
        name = ("kron", "plaw")[i % 2]
        n = reg.get(name).n
        kind = i % 3
        if kind == 0:
            reg.submit(name, tol=0.0, max_iters=20)
        elif kind == 1:
            seeds = np.zeros(n, np.float32)
            seeds[rng.integers(0, n, size=2)] = 1.0
            reg.submit(name, seeds, tol=(1e-3, 1e-5)[i % 2],
                       max_iters=200)
        else:
            reg.submit(name, top_k=10, tol=1e-4, max_iters=100)

    # a delta lands mid-load: advance one chunk (queries now in
    # flight), patch the kron scheduler, keep serving
    sch = reg.get("kron")
    sch.step()
    inflight = sch.active_slots
    k = max(4, kron.num_edges // 1000)
    ridx = rng.choice(kron.num_edges, size=k, replace=False)
    delta = repro.GraphDelta.of(
        add=np.stack([rng.integers(0, kron.num_nodes, k),
                      rng.integers(0, part_size, k)], axis=1),
        remove=np.stack([kron.src[ridx], kron.dst[ridx]], axis=1))
    sch.apply_delta(delta)
    print(f"kron: applied ±{k}-edge delta with {inflight} queries "
          f"in flight (rebinds={sch.rebind_count}, admit traces="
          f"{sch.admit_trace_count})")

    out = reg.run_until_drained()
    for name, results in out.items():
        sch = reg.get(name)
        # zero retraces under load; the delta costs exactly one
        # stepper re-lower on the graph it touched
        assert sch.trace_count == 1 + sch.rebind_count
        assert sch.admit_trace_count == 1
        print(f"\n--- {name} (n={sch.n}) ---")
        for r in results:
            what = (f"top{len(r.top_ids)}: {r.top_ids[:4]}..."
                    if r.top_ids is not None
                    else f"ranks[:3]={np.round(r.ranks[:3], 6)}")
            print(f"  uid={r.uid:3d} it={r.iterations:3d} "
                  f"conv={str(r.converged):5s} "
                  f"lat={r.latency_s * 1e3:7.1f}ms  {what}")
        s = sch.metrics.summary()
        print(f"  {s['count']} queries, {s['qps']:.1f} qps, "
              f"p50={s['p50_ms']:.1f}ms p99={s['p99_ms']:.1f}ms, "
              f"mean {s['mean_iterations']:.1f} iters")


if __name__ == "__main__":
    main()
