"""Quickstart: Partition-Centric PageRank through the Session API.

    PYTHONPATH=src python examples/quickstart.py [--scale 16] [--serve]

Builds a Graph500-style Kronecker graph and opens one ``repro.open``
Session per engine: the session resolves the graph's ``GraphPlan``
(PNG compress + transpose, partitioning, gather schedules — paper
§IV-B) through the process-level plan cache, runs 20 PageRank
iterations, checks the engines agree, and prints the paper's headline
statistics: compression ratio r, modeled bytes per edge (eqs. 3-5)
and measured per-iteration time.  The pcpm and pcpm_pallas plans share
one PNG build, and re-opening a session costs zero preprocessing.

``--serve`` continues into the serving layer: ``sess.serve()`` hands
back a continuous-batching SlotScheduler (DESIGN.md §7) answering
mixed queries — personalized seeds, per-request tolerances, on-device
top-k — from the SAME plan.  The full multi-graph demo is
examples/serve_pagerank.py.

``--stream`` demos the dynamic-graph subsystem (DESIGN.md §9): edge
batches stream into the session, each one patching the plan's dirty
partitions in place of a full rebuild, and ``pagerank(warm=True)``
repairs the previous ranks with a residual push seeded at the changed
edges instead of re-iterating from scratch.

``--ingest`` demos the real-graph pipeline (DESIGN.md §12) on the
bundled SNAP-style fixture: streaming parse, external->internal id
mapping, offsite-link filtering with virtual-mass accounting,
locality relabeling (``reorder="hybrid"``), and results — top-10,
personalized serve — reported in the FILE's original ids.

Migration note (pre-Session API): the old entry points still work —

    eng = SpMVEngine(g, method="pcpm", part_size=p)   # old
    res = pagerank(g, engine=eng, num_iterations=20)
    srv = PageRankServer(g, method="pcpm", ...)
    sch = SlotScheduler(g, method="pcpm", ...)

is now spelled

    sess = repro.open(g, repro.EngineConfig(method="pcpm",
                                            part_size=p))
    res  = sess.pagerank(num_iterations=20)
    srv  = sess.server(...)
    sch  = sess.serve(...)

The old constructors are thin shims over the same plan cache and
backend registry, so both forms share plans and stay in lockstep;
prefer the Session form — one EngineConfig instead of four keyword
sets, and every workload amortizes one preprocessing pass.
"""
import argparse
import time

import numpy as np

import repro
from repro.core.comm_model import (ModelParams, pdpr_bytes, bvgas_bytes,
                                   pcpm_bytes)
from repro.core.pagerank import pagerank_reference
from repro.graphs import generators


def ingest_demo():
    """Real-graph ingest (DESIGN.md §12) end to end on the committed
    SNAP-style fixture — the path a crawl dump takes into a served
    session, with every id the caller sees in the FILE's labels."""
    import tempfile
    from pathlib import Path

    from repro.ingest import LinkFilter, NodeIdMapping, ingest_edge_list

    fixture = (Path(__file__).resolve().parent.parent
               / "tests" / "fixtures" / "web_sample.txt")
    res = ingest_edge_list(
        fixture,
        filters=[LinkFilter("offsite", lambda s, d: d < 900_000_000)],
        self_loops="drop", dedup=True)
    print(f"ingest: {res.stats.summary()}")

    # hybrid relabeling for locality; results map back transparently
    sess = res.open(part_size=16, num_iterations=60, tol=0.0,
                    reorder="hybrid", slots=2, chunk=4)
    out = sess.pagerank()
    print(f"solved {res.graph.num_nodes} nodes in {out.iterations} "
          f"iterations (plan r={sess.plan.compression_ratio:.2f}, "
          f"reorder={sess.config.reorder})")
    ids, scores = sess.top_ranked(10)
    print("top-10 (external ids):")
    for i, s in zip(ids.tolist(), scores.tolist()):
        print(f"  {i:>9d}  {s:.5f}")

    # mass that would have flowed down the filtered offsite links
    for cat, mass in res.virtual_mass(out.ranks).items():
        print(f"virtual mass [{cat}]: {mass:.4f} "
              f"({res.virtual.counts[cat]} links)")

    # personalized serve query, seeded AND answered by external id
    ext_seed = int(ids[0])
    seeds = np.zeros(res.graph.num_nodes, np.float32)
    seeds[res.idmap.to_internal(np.int64(ext_seed))] = 1.0
    sch = sess.serve()
    sch.submit(seeds, top_k=5, tol=1e-5, max_iters=100)
    sch.run_until_drained()
    (q,) = sch.completed
    print(f"personalized from {ext_seed}: top-5 external "
          f"{q.top_external.tolist()} ({q.iterations} iters)")

    # persist plan + id map side by side: a restarted server reloads
    # both and serves external ids with zero preprocessing
    with tempfile.TemporaryDirectory() as td:
        plan_p, map_p = f"{td}/web.plan.npz", f"{td}/web.idmap.npz"
        sess.plan.save(plan_p)
        res.idmap.save(map_p)
        m2 = NodeIdMapping.load(map_p)
        assert (m2.external_ids == res.idmap.external_ids).all()
        print(f"persisted plan + id map "
              f"({m2.num_nodes} external ids round-tripped)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=15)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--serve", action="store_true",
                    help="also demo the continuous-batching query "
                         "scheduler (examples/serve_pagerank.py has "
                         "the full version)")
    ap.add_argument("--stream", action="store_true",
                    help="also demo streaming edge deltas: "
                         "incremental plan patching + residual-push "
                         "warm rank updates (DESIGN.md §9)")
    ap.add_argument("--ingest", action="store_true",
                    help="demo the real-graph ingest pipeline on the "
                         "bundled fixture: parse -> id map -> filter "
                         "-> reorder -> solve/serve in external ids "
                         "(DESIGN.md §12)")
    args = ap.parse_args()

    if args.ingest:
        return ingest_demo()

    g = generators.rmat(args.scale, args.edge_factor, seed=7)
    part_size = max(256, g.num_nodes // 64)
    print(f"kron graph: n={g.num_nodes:,} m={g.num_edges:,} "
          f"part_size={part_size}")

    results = {}
    for method in ("pdpr", "bvgas", "pcpm"):
        sess = repro.open(g, repro.EngineConfig(
            method=method, part_size=part_size,
            num_iterations=args.iters))
        t0 = time.perf_counter()
        res = sess.pagerank()
        res.ranks.block_until_ready()
        dt = (time.perf_counter() - t0) / args.iters
        results[method] = np.asarray(res.ranks)
        gteps = g.num_edges / dt / 1e9
        extra = (f"  r={sess.plan.compression_ratio:.2f}"
                 if method == "pcpm" else "")
        print(f"{method:6s}: {dt * 1e3:7.1f} ms/iter "
              f"({gteps:.3f} GTEPS){extra}")

    # engines agree with each other and with the dense oracle
    for m in ("bvgas", "pcpm"):
        np.testing.assert_allclose(results[m], results["pdpr"],
                                   rtol=1e-4, atol=1e-9)
    if g.num_nodes <= 1 << 15:
        ref = pagerank_reference(g, num_iterations=args.iters)
        np.testing.assert_allclose(results["pcpm"], ref, rtol=1e-3,
                                   atol=1e-7)
    print("engines agree ✓")

    # re-opening is free: the plan cache already holds this config
    sess = repro.open(g, repro.EngineConfig(method="pcpm",
                                            part_size=part_size))
    stats = repro.plan_cache_stats()
    print(f"plan cache: {stats.plan_builds} builds, "
          f"{stats.plan_hits} hits (reopen cost zero preprocessing)")
    pm = ModelParams(g.num_nodes, g.num_edges,
                     sess.plan.partitioning.num_partitions,
                     sess.plan.compression_ratio)
    print(f"modeled bytes/edge  pdpr(worst)={pdpr_bytes(pm)/g.num_edges:.1f}"
          f"  bvgas={bvgas_bytes(pm)/g.num_edges:.1f}"
          f"  pcpm={pcpm_bytes(pm)/g.num_edges:.1f}")

    if args.serve:
        sch = sess.serve(slots=4, chunk=4)     # shares the session plan
        sch.submit(tol=0.0, max_iters=args.iters)          # uniform
        seeds = np.zeros(g.num_nodes, np.float32)
        seeds[0] = 1.0
        sch.submit(seeds, tol=1e-5, max_iters=100)         # personalized
        sch.submit(top_k=10, tol=1e-4, max_iters=100)      # top-k only
        for r in sch.run_until_drained():
            what = (f"top10 ids {r.top_ids[:4]}..."
                    if r.top_ids is not None else "full ranks")
            print(f"serve: uid={r.uid} it={r.iterations} "
                  f"conv={r.converged} {what}")
        s = sch.metrics.summary()
        print(f"serve: {s['qps']:.1f} qps, p50={s['p50_ms']:.1f}ms "
              f"(see examples/serve_pagerank.py)")

    if args.stream:
        import time as _t
        rng = np.random.default_rng(1)
        n, m = sess.graph.num_nodes, sess.graph.num_edges
        base = sess.pagerank(tol=1e-6, num_iterations=300)
        print(f"\nstream: solved cold in {base.iterations} iterations;"
              " now inserting edge batches...")
        for batch in range(3):
            # new content arrives clustered: this batch's edges land
            # in two destination partitions, so the plan patch splices
            # 2/64 partitions and leaves the rest untouched
            k = m // 1000
            band = np.flatnonzero(sess.graph.dst
                                  < 2 * part_size).astype(np.int64)
            ridx = rng.choice(band, size=k, replace=False)
            delta = repro.GraphDelta.of(
                add=np.stack([rng.integers(0, n, k),
                              rng.integers(0, 2 * part_size, k)],
                             axis=1),
                remove=np.stack([sess.graph.src[ridx],
                                 sess.graph.dst[ridx]], axis=1))
            patches0 = repro.plan_cache_stats().plan_patches
            t0 = _t.perf_counter()
            sess.apply_delta(delta)
            res = sess.pagerank(warm=True, tol=1e-6,
                                num_iterations=300)
            res.ranks.block_until_ready()
            dt = _t.perf_counter() - t0
            patched = repro.plan_cache_stats().plan_patches > patches0
            print(f"stream: batch {batch}: ±{k} edges -> plan "
                  f"{'patched' if patched else 'rebuilt'}, "
                  f"{res.iterations} push sweeps, warm update "
                  f"{dt * 1e3:.0f} ms (vs {base.iterations}-iteration "
                  "cold solve)")


if __name__ == "__main__":
    main()
