"""Quickstart: Partition-Centric PageRank in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py [--scale 16] [--serve]

Builds a Graph500-style Kronecker graph, constructs the PNG layout
(compress + transpose, paper §IV-B), runs 20 PageRank iterations with
all three engines (PDPR / BVGAS / PCPM), checks they agree, and prints
the paper's headline statistics: compression ratio r, modeled bytes per
edge (eqs. 3-5), and measured per-iteration time.

``--serve`` continues into the serving layer: a continuous-batching
SlotScheduler (DESIGN.md §7) answers a handful of mixed queries —
personalized seeds, per-request tolerances, on-device top-k — from one
AOT-compiled (n, B) stepper.  The full multi-graph demo is
examples/serve_pagerank.py.
"""
import argparse
import time

import numpy as np
import jax

from repro.graphs import generators
from repro.core.pagerank import pagerank, pagerank_reference
from repro.core.spmv import SpMVEngine
from repro.core.comm_model import (ModelParams, pdpr_bytes, bvgas_bytes,
                                   pcpm_bytes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=15)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--serve", action="store_true",
                    help="also demo the continuous-batching query "
                         "scheduler (examples/serve_pagerank.py has "
                         "the full version)")
    args = ap.parse_args()

    g = generators.rmat(args.scale, args.edge_factor, seed=7)
    part_size = max(256, g.num_nodes // 64)
    print(f"kron graph: n={g.num_nodes:,} m={g.num_edges:,} "
          f"part_size={part_size}")

    results = {}
    for method in ("pdpr", "bvgas", "pcpm"):
        eng = SpMVEngine(g, method=method, part_size=part_size)
        t0 = time.perf_counter()
        res = pagerank(g, engine=eng, num_iterations=args.iters)
        res.ranks.block_until_ready()
        dt = (time.perf_counter() - t0) / args.iters
        results[method] = np.asarray(res.ranks)
        gteps = g.num_edges / dt / 1e9
        extra = (f"  r={eng.compression_ratio:.2f}"
                 if method == "pcpm" else "")
        print(f"{method:6s}: {dt * 1e3:7.1f} ms/iter "
              f"({gteps:.3f} GTEPS){extra}")

    # engines agree with each other and with the dense oracle
    for m in ("bvgas", "pcpm"):
        np.testing.assert_allclose(results[m], results["pdpr"],
                                   rtol=1e-4, atol=1e-9)
    if g.num_nodes <= 1 << 15:
        ref = pagerank_reference(g, num_iterations=args.iters)
        np.testing.assert_allclose(results["pcpm"], ref, rtol=1e-3,
                                   atol=1e-7)
    print("engines agree ✓")

    eng = SpMVEngine(g, method="pcpm", part_size=part_size)
    pm = ModelParams(g.num_nodes, g.num_edges,
                     eng.partitioning.num_partitions,
                     eng.compression_ratio)
    print(f"modeled bytes/edge  pdpr(worst)={pdpr_bytes(pm)/g.num_edges:.1f}"
          f"  bvgas={bvgas_bytes(pm)/g.num_edges:.1f}"
          f"  pcpm={pcpm_bytes(pm)/g.num_edges:.1f}")

    if args.serve:
        from repro.serve import SlotScheduler
        sch = SlotScheduler(g, slots=4, method="pcpm",
                            part_size=part_size, chunk=4)
        sch.submit(tol=0.0, max_iters=args.iters)          # uniform
        seeds = np.zeros(g.num_nodes, np.float32)
        seeds[0] = 1.0
        sch.submit(seeds, tol=1e-5, max_iters=100)         # personalized
        sch.submit(top_k=10, tol=1e-4, max_iters=100)      # top-k only
        for r in sch.run_until_drained():
            what = (f"top10 ids {r.top_ids[:4]}..."
                    if r.top_ids is not None else "full ranks")
            print(f"serve: uid={r.uid} it={r.iterations} "
                  f"conv={r.converged} {what}")
        s = sch.metrics.summary()
        print(f"serve: {s['qps']:.1f} qps, p50={s['p50_ms']:.1f}ms "
              f"(see examples/serve_pagerank.py)")


if __name__ == "__main__":
    main()
