"""Regression suite for the serve-layer accounting bugs fixed in the
push-backend PR (DESIGN.md §11), plus an exactly-once terminal audit.

The two named bugs:

1. **Retry accounting** — quarantine re-admission used to call the
   admit path unconditionally, which (a) overwrote ``t_admit`` so the
   retried query's queue wait vanished, and (b) reset the slot's
   iteration counter so a retried query could burn
   ``(max_retries + 1) x max_iters`` device work while reporting only
   the final run's iterations.  Now ``t_admit`` is first-wins and
   consumed iterations carry across re-admissions: ``max_iters``
   bounds TOTAL work and ``QueryResult.iterations`` reports it.

2. **Sentinel leak** — a deadline lapsing after a quarantine
   re-admission but before the slot's first residual readback used to
   surface the pool-init sentinel ``residual = -1.0`` as if it were a
   measurement.  Now a query finishing without a readback reports
   ``residual is None``.

The audit class sweeps every terminal path (served, push-served,
rejected, expired, deadline-degraded, quarantine-failed, max_iters=0)
and asserts each uid resolves exactly once with a trace consistent
with its result.
"""
import collections
import sys
import threading

import numpy as np
import pytest

from repro.graphs import generators
from repro.reliability import (FaultInjector, FaultPlan, FaultSpec,
                               ResilienceConfig)
from repro.serve import SlotScheduler

SMALL = dict(method="pcpm", part_size=64, chunk=4)


@pytest.fixture(scope="module")
def g():
    return generators.rmat(8, 8, seed=1)


def _seed(g, at=3):
    s = np.zeros(g.num_nodes, np.float32)
    s[at] = 1.0
    s[(at * 7 + 1) % g.num_nodes] = 1.0
    return s


def _fake_clock(sch):
    t = [0.0]
    sch.metrics.clock = lambda: t[0]
    sch.clock = sch.metrics.clock
    return t


def _poisoned(g, *, max_retries=1, **kw):
    inj = FaultInjector(FaultPlan.of([FaultSpec("nan_slot", step=2,
                                                slot=0)]))
    return SlotScheduler(
        g, slots=1, fault_injector=inj,
        resilience=ResilienceConfig(max_retries=max_retries),
        **SMALL, **kw)


@pytest.fixture(scope="module")
def clean_iters(g):
    """Iterations the reference query needs fault-free."""
    sch = SlotScheduler(g, slots=1, **SMALL)
    u = sch.submit(_seed(g), tol=1e-6, max_iters=300)
    sch.run_until_drained()
    r = {r.uid: r for r in sch.completed}[u]
    assert r.converged
    return r.iterations


class TestRetryAccounting:
    def test_budget_spans_retries(self, g, clean_iters):
        """A quarantine retry must NOT get a fresh ``max_iters``: the
        poisoned run's iterations stay charged, so with max_iters set
        to exactly the clean-run cost the retried query runs out of
        budget and honestly reports non-convergence at max_iters —
        pre-fix it silently burned ~2x the budget and converged."""
        sch = _poisoned(g)
        u = sch.submit(_seed(g), tol=1e-6, max_iters=clean_iters)
        sch.run_until_drained()
        r = {r.uid: r for r in sch.completed}[u]
        assert sch.metrics.counters["quarantined"] == 1
        assert sch.metrics.counters["requeued"] == 1
        assert not r.converged
        assert r.iterations == clean_iters       # total, incl. burned
        assert sch.metrics.traces[u].iterations == clean_iters

    def test_retry_converges_within_enlarged_budget(self, g,
                                                    clean_iters):
        """Same fault with budget = clean cost + burned iterations:
        the retry converges, and the reported count is the TOTAL
        device work (burned + clean rerun), not just the rerun."""
        sch = _poisoned(g)
        u = sch.submit(_seed(g), tol=1e-6, max_iters=300)
        sch.run_until_drained()
        r = {r.uid: r for r in sch.completed}[u]
        assert r.converged and r.error is None
        burned = r.iterations - clean_iters
        assert burned >= SMALL["chunk"]          # >= 1 poisoned chunk
        assert sch.trace_count == 1

    def test_budget_exhausted_fails_explicitly(self, g, clean_iters):
        """If the poisoned run already consumed the whole budget there
        is nothing left to retry with — the query must fail crisply,
        not be re-admitted for zero iterations."""
        sch = _poisoned(g)
        # chunk + 1: the clean first chunk takes 4, the poisoned step
        # burns the single remaining iteration -> nothing left to retry
        u = sch.submit(_seed(g), tol=1e-6,
                       max_iters=SMALL["chunk"] + 1)
        sch.run_until_drained()
        r = {r.uid: r for r in sch.completed}[u]
        assert r.error is not None and "budget exhausted" in r.error
        assert not r.converged
        assert sch.metrics.counters["requeued"] == 0

    def test_queue_wait_first_wins(self, g):
        """``t_admit`` records the FIRST admission: a retry at t=1.0
        must not erase the queue wait measured at t=0."""
        sch = _poisoned(g)
        t = _fake_clock(sch)
        u = sch.submit(_seed(g), tol=1e-6, max_iters=300)
        sch.step()                     # clean chunk at t=0
        t[0] = 1.0                     # wall time passes mid-flight
        sch.run_until_drained()        # poison fires, retry re-admits
        tr = sch.metrics.traces[u]
        assert sch.metrics.counters["requeued"] == 1
        assert tr.queue_wait_s == 0.0  # pre-fix: 1.0 (re-admit time)
        r = {r.uid: r for r in sch.completed}[u]
        assert r.converged


class TestResidualSentinel:
    def test_deadline_before_first_readback_reports_none(self, g):
        """Deadline lapses in the same step() as a quarantine
        re-admission — the slot's residual buffer holds the -1.0 init
        sentinel because the re-admitted run never read one back.  The
        result must say ``residual is None`` (and therefore not
        converged), never leak the sentinel."""
        sch = _poisoned(g)
        t = _fake_clock(sch)
        u = sch.submit(_seed(g), tol=1e-6, max_iters=300,
                       deadline_s=0.5)
        sch.step()                     # clean chunk, residual readback
        t[0] = 1.0                     # deadline passes mid-flight
        sch.step()                     # poison -> requeue -> re-admit
        #                                -> deadline sweep, same step
        r = {r.uid: r for r in sch.completed}[u]
        assert sch.metrics.counters["deadline_hits"] == 1
        assert r.residual is None      # pre-fix: -1.0
        assert r.degraded and not r.converged and r.error is None
        assert r.top_ids is None and r.ranks is not None

    def test_zero_budget_submit_reports_none(self, g):
        """max_iters=0 serves the seed column as-is at admission: no
        readback ever happened, so residual is None, converged False,
        and the ranks are the (normalized) seed itself."""
        sch = SlotScheduler(g, slots=1, **SMALL)
        s = _seed(g)
        u = sch.submit(s, tol=1e-6, max_iters=0)
        sch.run_until_drained()
        r = {r.uid: r for r in sch.completed}[u]
        assert r.residual is None and not r.converged
        assert r.error is None and r.iterations == 0
        np.testing.assert_allclose(r.ranks, s / s.sum(), atol=1e-7)

    def test_quarantine_failure_reports_none(self, g):
        """max_retries=0: the poisoned query fails explicitly and the
        result carries residual None (the column is poisoned — there
        is no honest residual to report), never NaN."""
        sch = _poisoned(g, max_retries=0)
        u = sch.submit(_seed(g), tol=1e-6, max_iters=300)
        sch.run_until_drained()
        r = {r.uid: r for r in sch.completed}[u]
        assert r.error is not None and "quarantined" in r.error
        assert r.residual is None      # pre-fix: nan
        assert not r.converged


class TestTerminalAudit:
    def _audit(self, sch, uids):
        """Every uid resolves exactly once, trace and result agree."""
        counts = collections.Counter(r.uid for r in sch.completed)
        assert set(counts) == set(uids)
        assert all(c == 1 for c in counts.values())
        by_uid = {r.uid: r for r in sch.completed}
        for uid in uids:
            r, tr = by_uid[uid], sch.metrics.traces[uid]
            assert tr.t_done is not None
            assert tr.iterations == r.iterations
            assert tr.converged == r.converged
            assert tr.error == r.error
            assert tr.degraded == r.degraded
            if r.error is not None:
                assert not r.converged
                assert r.ranks is None and r.top_ids is None
            if r.converged:
                assert r.residual is not None and r.residual >= 0.0
        # single-home reconciliation (DESIGN.md §14): the registry
        # counters and the trace table must derive the same totals —
        # raises AssertionError naming the first drifted family
        sch.metrics.reconcile()
        return by_uid

    def test_chaos_workload_resolves_every_uid(self, g):
        """Mixed workload across every terminal path: push-served,
        stepper-served, quarantine retry, explicit rejection (queue
        cap), degenerate max_iters=0 — one result per uid, consistent
        traces, consistent counters."""
        inj = FaultInjector(FaultPlan.of([FaultSpec("nan_slot", step=3,
                                                    slot=0)]))
        sch = SlotScheduler(
            g, slots=2, fault_injector=inj,
            resilience=ResilienceConfig(max_retries=1, max_queue=4),
            **SMALL)
        uids = []
        # 2 push-served inline (loose tol + top_k) — never queue
        for i in range(2):
            uids.append(sch.submit(_seed(g, at=i), top_k=8, tol=1e-2,
                                   max_iters=300))
        # 1 degenerate zero-budget
        uids.append(sch.submit(_seed(g, at=5), tol=1e-6, max_iters=0))
        # 8 stepper queries: 2 slots + queue cap 4 -> some rejected
        for i in range(8):
            uids.append(sch.submit(_seed(g, at=10 + i), tol=1e-6,
                                   max_iters=300))
        sch.run_until_drained()
        by_uid = self._audit(sch, uids)
        c = sch.metrics.counters
        assert c["push_served"] == 2
        assert c["quarantined"] >= 1
        rejected = [r for r in by_uid.values()
                    if r.error and "rejected" in r.error]
        assert c["rejected"] == len(rejected) > 0
        served = [r for r in by_uid.values()
                  if r.error is None and r.iterations > 0]
        assert all(r.converged for r in served)
        assert sch.trace_count == 1
        assert sch.admit_trace_count == 1

    def test_concurrent_submit_storm_accounting(self, g):
        """Satellite regression for the thread-safety bug: N threads
        hammering ``submit`` must lose NO counter increments and drop
        NO terminal results.  Forced through the rejection path
        (max_queue=0, route='stepper') so every submit does the full
        metrics round trip with zero device work — pre-fix the
        ``Counter[name] += 1`` read-modify-write silently lost updates
        under preemption and ``counters['rejected']`` undercounted."""
        sch = SlotScheduler(g, slots=1,
                            resilience=ResilienceConfig(max_queue=0),
                            **SMALL)
        threads, per, uids = 8, 300, []
        lock = threading.Lock()
        reader_errors = []
        stop = threading.Event()

        def storm():
            mine = [sch.submit(None, tol=1e-6, max_iters=10,
                               route="stepper")
                    for _ in range(per)]
            with lock:
                uids.extend(mine)

        def reader():
            # pre-fix: percentile/summary iterated the LIVE traces
            # dict and died with 'dictionary changed size during
            # iteration' under any concurrent submit
            try:
                while not stop.is_set():
                    sch.metrics.percentile(50)
                    sch.metrics.summary()
            except RuntimeError as exc:
                reader_errors.append(exc)

        old = sys.getswitchinterval()
        sys.setswitchinterval(1e-6)    # maximize preemption pressure
        try:
            rd = threading.Thread(target=reader)
            rd.start()
            ts = [threading.Thread(target=storm) for _ in range(threads)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            stop.set()
            rd.join()
        finally:
            sys.setswitchinterval(old)
        assert not reader_errors
        total = threads * per
        assert len(set(uids)) == total           # no uid reuse
        assert sch.metrics.counters["rejected"] == total
        assert len(sch.completed) == total
        self._audit(sch, uids)

    def test_concurrent_mixed_storm_with_device_thread(self, g):
        """Mixed push/stepper storm: submitter threads race a single
        stepping thread (the gateway's thread-ownership shape).  Every
        uid must resolve exactly once with a consistent trace, push
        answers must come off per-thread engines, and the stepper must
        stay at one trace."""
        sch = SlotScheduler(g, slots=4, **SMALL)
        uids, lock, done = [], threading.Lock(), threading.Event()

        def submitter(i):
            mine = []
            for j in range(20):
                if (i + j) % 2:
                    mine.append(sch.submit(_seed(g, at=i * 7 + j),
                                           top_k=8, tol=1e-2,
                                           max_iters=300))
                else:
                    mine.append(sch.submit(_seed(g, at=i * 5 + j),
                                           tol=1e-5, max_iters=300))
            with lock:
                uids.extend(mine)

        ts = [threading.Thread(target=submitter, args=(i,))
              for i in range(6)]
        errors = []

        def device_loop():
            try:
                while not done.is_set() or sch.queued \
                        or sch.active_slots:
                    sch.step()
            except Exception as exc:   # noqa: BLE001
                errors.append(exc)

        dev = threading.Thread(target=device_loop)
        dev.start()
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        done.set()
        dev.join(timeout=120)
        assert not dev.is_alive() and not errors
        assert len(uids) == 120
        by_uid = self._audit(sch, uids)
        assert all(r.error is None for r in by_uid.values())
        assert sch.metrics.counters["push_served"] > 0
        assert sch.trace_count == 1
        assert sch.admit_trace_count == 1

    def test_second_stepper_thread_raises(self, g):
        """``step()`` is single-caller by contract: a second thread
        stepping concurrently must fail fast, not corrupt the pool."""
        sch = SlotScheduler(g, slots=1, **SMALL)
        sch._step_lock.acquire()       # impersonate a stepping thread
        try:
            with pytest.raises(RuntimeError, match="concurrently"):
                sch.step()
        finally:
            sch._step_lock.release()

    def test_expiry_and_deadline_paths_audit(self, g):
        """Queue expiry and in-flight deadline degradation both leave
        exactly-once, trace-consistent terminals."""
        sch = SlotScheduler(g, slots=1,
                            resilience=ResilienceConfig(max_queue=8),
                            **SMALL)
        t = _fake_clock(sch)
        u_run = sch.submit(_seed(g, at=1), tol=1e-6, max_iters=300,
                           deadline_s=0.5)
        u_exp = sch.submit(_seed(g, at=2), tol=1e-6, max_iters=300,
                           deadline_s=0.5)
        sch.step()                     # u_run admitted, u_exp queued
        t[0] = 1.0                     # both deadlines pass
        sch.run_until_drained()
        by_uid = self._audit(sch, [u_run, u_exp])
        assert "deadline" in by_uid[u_exp].error
        assert by_uid[u_run].degraded and by_uid[u_run].error is None
        assert sch.metrics.counters["expired"] == 1
        assert sch.metrics.counters["deadline_hits"] == 1
