"""Gateway subsystem suite (DESIGN.md §13): async front door
semantics under concurrency, warm-result cache correctness and
invalidation, slot-pool autotune, and multi-graph QoS (weighted-fair
interleave + budgeted plan eviction).
"""
import collections
import threading

import numpy as np
import pytest

import repro
from repro.gateway import (Gateway, GatewayConfig, WeightedFair,
                           autotune_slots)
from repro.gateway.cache import ResultCache, seed_digest
from repro.graphs import generators
from repro.reliability import ResilienceConfig
from repro.serve import GraphRegistry, SlotScheduler
from repro.stream import GraphDelta

SMALL = dict(method="pcpm", part_size=64, chunk=4)
NO_TUNE = GatewayConfig()


@pytest.fixture(scope="module")
def g():
    return generators.rmat(8, 8, seed=1)


def _seed(g, at=3):
    s = np.zeros(g.num_nodes, np.float32)
    s[at] = 1.0
    s[(at * 7 + 1) % g.num_nodes] = 1.0
    return s


def _delta(g, rng_seed=0, k=24):
    rng = np.random.default_rng(rng_seed)
    src = rng.integers(0, g.num_nodes, k).astype(np.int64)
    dst = rng.integers(0, g.num_nodes, k).astype(np.int64)
    return GraphDelta.insert(np.stack([src, dst], axis=1))


def _audit_futures(sch, results):
    """Exactly-once: every future resolved to a distinct uid whose
    trace is terminal and consistent with the result."""
    counts = collections.Counter(r.uid for r in results)
    assert all(c == 1 for c in counts.values())
    for r in results:
        tr = sch.metrics.traces[r.uid]
        assert tr.t_done is not None
        assert tr.converged == r.converged
        assert tr.error == r.error


class TestFrontDoor:
    def test_mixed_traffic_resolves(self, g):
        sch = SlotScheduler(g, slots=4, **SMALL)
        with Gateway(sch) as gw:
            futs = [gw.submit(_seed(g, at=i), top_k=8, tol=1e-2,
                              max_iters=300) for i in range(3)]
            futs += [gw.submit(None, tol=1e-6, max_iters=200)
                     for _ in range(3)]
            res = [f.result(timeout=120) for f in futs]
        assert all(r.error is None and r.converged for r in res)
        assert sch.metrics.counters["push_served"] == 3
        assert sch.trace_count == 1
        assert sch.admit_trace_count == 1
        _audit_futures(sch, res)

    def test_submit_validates_synchronously(self, g):
        sch = SlotScheduler(g, slots=1, **SMALL)
        with Gateway(sch) as gw:
            with pytest.raises(ValueError, match="max_iters"):
                gw.submit(None, max_iters=-1)
            with pytest.raises(ValueError, match="top_k"):
                gw.submit(None, top_k=0)
            with pytest.raises(ValueError, match="needs a seed"):
                gw.submit(None, route="push")

    def test_backlog_rejection_is_explicit(self, g):
        """max_pending=0: every stepper query is shed AT THE GATEWAY
        with a terminal, counted result — push-eligible traffic keeps
        flowing through the worker pool untouched."""
        sch = SlotScheduler(g, slots=1, **SMALL)
        cfg = GatewayConfig(max_pending=0, cache_entries=0)
        with Gateway(sch, config=cfg) as gw:
            r_step = gw.submit(None, tol=1e-6).result(timeout=60)
            r_push = gw.submit(_seed(g), top_k=8,
                               tol=1e-2).result(timeout=60)
        assert "gateway backlog full" in r_step.error
        assert not r_step.converged
        assert r_push.error is None and r_push.converged
        assert sch.metrics.counters["rejected"] == 1
        _audit_futures(sch, [r_step, r_push])

    def test_scheduler_queue_cap_survives_gateway(self, g):
        """PR 6 admission semantics through the async path: a bounded
        scheduler queue still sheds explicitly, and the shed results
        come back through the futures."""
        sch = SlotScheduler(
            g, slots=1, route="stepper",
            resilience=ResilienceConfig(max_queue=1), **SMALL)
        with Gateway(sch, config=GatewayConfig(cache_entries=0)) as gw:
            futs = [gw.submit(_seed(g, at=i), tol=0.0, max_iters=200)
                    for i in range(8)]
            res = [f.result(timeout=120) for f in futs]
        rejected = [r for r in res if r.error
                    and "admission queue full" in r.error]
        served = [r for r in res if r.error is None]
        assert len(rejected) + len(served) == 8
        assert sch.metrics.counters["rejected"] == len(rejected) > 0
        _audit_futures(sch, res)

    def test_deadline_expiry_through_gateway(self, g):
        """Deadlines are absolute from gateway intake: a query stuck
        behind a long-running slot expires in the queue, explicitly."""
        sch = SlotScheduler(g, slots=1, route="stepper", **SMALL)
        with Gateway(sch, config=GatewayConfig(cache_entries=0)) as gw:
            f_long = gw.submit(_seed(g, at=1), tol=0.0, max_iters=400)
            f_exp = gw.submit(_seed(g, at=2), tol=1e-6, max_iters=400,
                              deadline_s=1e-4)
            r_long = f_long.result(timeout=120)
            r_exp = f_exp.result(timeout=120)
        assert r_long.error is None
        assert r_exp.error is not None and "deadline" in r_exp.error
        assert sch.metrics.counters["expired"] == 1

    def test_priority_orders_backlog(self, g):
        """The device thread hands the whole backlog to the scheduler
        before admitting, so priorities submitted out of order still
        win — same semantics as synchronous submission."""
        sch = SlotScheduler(g, slots=1, route="stepper", **SMALL)
        gw = Gateway(sch, config=GatewayConfig(cache_entries=0))
        try:
            # occupy the single slot so the rest queue behind it
            f0 = gw.submit(_seed(g, at=0), tol=0.0, max_iters=200)
            lo = gw.submit(_seed(g, at=1), tol=0.0, max_iters=20,
                           priority=0)
            hi = gw.submit(_seed(g, at=2), tol=0.0, max_iters=20,
                           priority=5)
            res = {id(f): f.result(timeout=120)
                   for f in (f0, lo, hi)}
            tr_hi = sch.metrics.traces[res[id(hi)].uid]
            tr_lo = sch.metrics.traces[res[id(lo)].uid]
            assert tr_hi.t_admit <= tr_lo.t_admit
        finally:
            gw.close()

    def test_concurrent_submit_storm_exactly_once(self, g):
        """N submitter threads against one gateway: every future
        resolves exactly once, uids are unique, the stepper stays at
        one trace, and the accounting audit holds."""
        sch = SlotScheduler(g, slots=4, **SMALL)
        results, lock = [], threading.Lock()
        with Gateway(sch, config=GatewayConfig(cache_entries=0)) as gw:
            def storm(i):
                futs = []
                for j in range(15):
                    if (i + j) % 2:
                        futs.append(gw.submit(_seed(g, at=i * 7 + j),
                                              top_k=8, tol=1e-2,
                                              max_iters=300))
                    else:
                        futs.append(gw.submit(_seed(g, at=i * 5 + j),
                                              tol=1e-5, max_iters=300))
                got = [f.result(timeout=120) for f in futs]
                with lock:
                    results.extend(got)

            ts = [threading.Thread(target=storm, args=(i,))
                  for i in range(6)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        assert len(results) == 90
        assert len({r.uid for r in results}) == 90
        assert all(r.error is None for r in results)
        assert sch.trace_count == 1
        assert sch.admit_trace_count == 1
        _audit_futures(sch, results)

    def test_close_drains_and_rejects_after(self, g):
        sch = SlotScheduler(g, slots=2, **SMALL)
        gw = Gateway(sch)
        futs = [gw.submit(None, tol=1e-6, max_iters=200)
                for _ in range(4)]
        gw.close()                      # default drain=True
        assert all(f.done() for f in futs)
        with pytest.raises(RuntimeError, match="closed"):
            gw.submit(None)


class TestResultCache:
    def test_hit_is_bit_identical_and_o_k(self, g):
        sch = SlotScheduler(g, slots=2, **SMALL)
        with Gateway(sch) as gw:
            r1 = gw.submit(_seed(g), top_k=8,
                           tol=1e-2).result(timeout=120)
            r2 = gw.submit(_seed(g), top_k=8,
                           tol=1e-2).result(timeout=120)
        assert not r1.cached and r2.cached
        assert r2.uid != r1.uid                   # fresh uid + trace
        assert r2.top_ids is r1.top_ids           # THE same arrays
        assert r2.top_scores is r1.top_scores
        assert sch.metrics.counters["cache_hits"] == 1
        assert sch.metrics.traces[r2.uid].t_done is not None
        assert gw.cache.hits == 1

    def test_stepper_results_cache_too(self, g):
        sch = SlotScheduler(g, slots=2, route="stepper", **SMALL)
        with Gateway(sch) as gw:
            r1 = gw.submit(_seed(g), tol=1e-6).result(timeout=120)
            r2 = gw.submit(_seed(g), tol=1e-6).result(timeout=120)
        assert r2.cached and r2.ranks is r1.ranks

    def test_unconverged_and_errored_not_cached(self, g):
        sch = SlotScheduler(g, slots=1, route="stepper", **SMALL)
        with Gateway(sch) as gw:
            # tol=0 runs the budget and never converges -> uncached
            r1 = gw.submit(_seed(g), tol=0.0,
                           max_iters=8).result(timeout=120)
            r2 = gw.submit(_seed(g), tol=0.0,
                           max_iters=8).result(timeout=120)
        assert not r1.converged and not r2.cached
        assert gw.cache.hits == 0 and len(gw.cache) == 0

    def test_distinct_requests_miss(self, g):
        sch = SlotScheduler(g, slots=2, **SMALL)
        with Gateway(sch) as gw:
            gw.submit(_seed(g, at=3), top_k=8,
                      tol=1e-2).result(timeout=120)
            r = gw.submit(_seed(g, at=4), top_k=8,
                          tol=1e-2).result(timeout=120)
            r_tol = gw.submit(_seed(g, at=3), top_k=8,
                              tol=1e-3).result(timeout=120)
        assert not r.cached and not r_tol.cached

    def test_delta_invalidates_atomically(self, g):
        """apply_delta through the gateway: entries keyed on the
        outgoing plan fingerprint drop, the same request re-solves on
        the new graph, and the push path answers against the NEW CSR
        (regression for the stale internal-graph rebind bug)."""
        sch = SlotScheduler(g, slots=2, **SMALL)
        d = _delta(g)
        with Gateway(sch) as gw:
            r1 = gw.submit(_seed(g), top_k=8,
                           tol=1e-3).result(timeout=120)
            dropped = gw.apply_delta(d).result(timeout=120)
            assert dropped >= 1
            r2 = gw.submit(_seed(g), top_k=8,
                           tol=1e-3).result(timeout=120)
        assert not r2.cached                      # recomputed
        assert sch.rebind_count == 1
        assert sch.trace_count == 2               # one rebind compile
        # parity: a fresh scheduler on the post-delta graph must agree
        from repro.stream.delta import apply_delta as apply_edges
        g_new = apply_edges(g, d)
        ref = SlotScheduler(g_new, slots=2, **SMALL)
        u = ref.submit(_seed(g), top_k=8, tol=1e-3)
        ref.run_until_drained()
        r_ref = {r.uid: r for r in ref.completed}[u]
        assert list(r2.top_ids) == list(r_ref.top_ids)
        np.testing.assert_allclose(r2.top_scores, r_ref.top_scores,
                                   atol=1e-5)
        assert gw.cache.invalidated >= 1

    def test_cache_unit_lru_and_fp_invalidation(self):
        c = ResultCache(capacity=2)
        c.put(("g", "fp1", "s1", 1e-3, 8, 100, "auto"), "a")
        c.put(("g", "fp1", "s2", 1e-3, 8, 100, "auto"), "b")
        assert c.get(("g", "fp1", "s1", 1e-3, 8, 100, "auto")) == "a"
        c.put(("g", "fp2", "s3", 1e-3, 8, 100, "auto"), "c")  # evicts s2
        assert c.get(("g", "fp1", "s2", 1e-3, 8, 100, "auto")) is None
        assert c.invalidate_fp("fp1") == 1
        assert c.get(("g", "fp1", "s1", 1e-3, 8, 100, "auto")) is None
        assert c.get(("g", "fp2", "s3", 1e-3, 8, 100, "auto")) == "c"

    def test_seed_digest_stability(self, g):
        s = _seed(g)
        assert seed_digest(s) == seed_digest(s.copy())
        assert seed_digest(s) != seed_digest(_seed(g, at=4))
        assert seed_digest(None) == "uniform"


class TestAutotune:
    def test_report_sane(self, g):
        eng = repro.open(g, repro.EngineConfig(**{
            k: v for k, v in SMALL.items() if k != "chunk"})).engine
        rep = autotune_slots(eng, chunk=4, target_chunk_s=10.0,
                             candidates=(2, 4, 8), repeats=2)
        assert rep.chosen == 8            # everything under 10 s
        assert set(rep.probes) == {2, 4, 8}
        assert all(t > 0 for t in rep.probes.values())
        tight = autotune_slots(eng, chunk=4, target_chunk_s=1e-12,
                               candidates=(2, 4, 8), repeats=1)
        assert tight.chosen == 2          # nothing passes -> smallest
        assert len(tight.probes) == 1     # early stop after first miss

    def test_non_multivector_backend_defaults(self):
        class FakeBackend:
            multi_vector = False

        class FakeEngine:
            backend = FakeBackend()

        rep = autotune_slots(FakeEngine(), chunk=4, default=6)
        assert rep.chosen == 6 and rep.probes == {}

    def test_session_gateway_wires_chosen_slots(self, g):
        sess = repro.open(g, repro.EngineConfig(**SMALL, slots=2))
        cfg = GatewayConfig(target_chunk_s=10.0,
                            autotune_candidates=(2, 4, 8))
        with sess.gateway(config=cfg) as gw:
            assert gw.autotune_report is not None
            assert gw.autotune_report.chosen == 8
            sch = gw._schedulers["default"]
            assert sch.slots == 8
            r = gw.submit(None, tol=1e-6).result(timeout=120)
        assert r.converged
        # explicit slots override beats autotune
        with sess.gateway(config=cfg, slots=3) as gw2:
            assert gw2.autotune_report is None
            assert gw2._schedulers["default"].slots == 3


class TestWeightedFair:
    def test_share_proportions(self):
        fair = WeightedFair({"a": 3.0, "b": 1.0})
        picks = collections.Counter(fair.pick(["a", "b"])
                                    for _ in range(400))
        assert picks["a"] == 300 and picks["b"] == 100

    def test_rejoin_without_banked_credit(self):
        fair = WeightedFair({"a": 1.0, "b": 1.0})
        for _ in range(50):
            fair.pick(["a"])              # b idle throughout
        picks = collections.Counter(fair.pick(["a", "b"])
                                    for _ in range(40))
        # b rejoins at a's pass, not 50 turns in arrears
        assert picks["b"] <= 21

    def test_rejects_nonpositive_share(self):
        with pytest.raises(ValueError, match="share"):
            WeightedFair({"a": 0.0})


class TestRegistryQoS:
    def test_weighted_drain_and_gateway(self, g):
        g2 = generators.rmat(8, 8, seed=2)
        reg = GraphRegistry(**SMALL, slots=2)
        reg.add("one", g, share=2.0)
        reg.add("two", g2, share=1.0)
        reg.submit("one", _seed(g), tol=1e-5, max_iters=200)
        reg.submit("two", _seed(g2), tol=1e-5, max_iters=200)
        out = reg.run_until_drained()
        assert len(out["one"]) == 1 and len(out["two"]) == 1
        assert all(r.converged for rs in out.values() for r in rs)
        with reg.gateway() as gw:
            r1 = gw.submit(_seed(g), graph="one",
                           tol=1e-5).result(timeout=120)
            r2 = gw.submit(_seed(g2), graph="two",
                           tol=1e-5).result(timeout=120)
            with pytest.raises(ValueError, match="graph="):
                gw.submit(None)           # ambiguous without a name
        assert r1.converged and r2.converged

    def test_budget_evicts_lru_idle_never_busy(self, g):
        from repro.core.plan import plan_nbytes
        g2 = generators.rmat(8, 8, seed=2)
        g3 = generators.rmat(8, 8, seed=3)
        probe = GraphRegistry(**SMALL, slots=1)
        per = plan_nbytes(probe.add("probe", g).engine.plan)
        reg = GraphRegistry(memory_budget_bytes=int(2.5 * per),
                            **SMALL, slots=1)
        reg.add("a", g)
        reg.add("b", g2)
        # occupy 'a' with an in-flight query (admitted, not drained)
        reg.submit("a", _seed(g), tol=0.0, max_iters=400)
        reg.get("a").step()
        assert reg.get("a").active_slots == 1
        reg.add("c", g3)                  # over budget -> evict ONE
        assert reg.evictions == 1
        assert "b" not in reg             # LRU idle victim
        assert "a" in reg and "c" in reg  # busy + newest survive
        out = reg.run_until_drained()     # in-flight query unharmed
        assert len(out["a"]) == 1 and out["a"][0].error is None

    def test_budget_defers_when_all_busy(self, g):
        from repro.core.plan import plan_nbytes
        g2 = generators.rmat(8, 8, seed=2)
        probe = GraphRegistry(**SMALL, slots=1)
        per = plan_nbytes(probe.add("probe", g).engine.plan)
        reg = GraphRegistry(memory_budget_bytes=int(1.5 * per),
                            **SMALL, slots=1)
        reg.add("a", g)
        reg.submit("a", _seed(g), tol=0.0, max_iters=400)
        reg.get("a").step()
        reg.add("b", g2)                  # over budget, 'a' is busy
        assert "a" in reg and "b" in reg  # deferred, not dropped
        assert reg.total_plan_bytes > reg.memory_budget_bytes
        assert reg.evictions == 0

    def test_explicit_evict_refuses_busy(self, g):
        reg = GraphRegistry(**SMALL, slots=1)
        reg.add("a", g)
        reg.submit("a", _seed(g), tol=0.0, max_iters=400)
        with pytest.raises(ValueError, match="drain"):
            reg.evict("a")
        reg.run_until_drained()
        reg.evict("a")
        assert "a" not in reg and reg.evictions == 1
