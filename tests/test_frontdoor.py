"""Front-door validation: malformed graphs, deltas, and queries fail
with crisp ValueErrors at the boundary instead of corrupting plans or
producing garbage ranks deep inside a kernel (DESIGN.md §10).
"""
import numpy as np
import pytest

import repro
from repro.core.plan import PlanConfig, build_plan
from repro.graphs.formats import Graph, from_edge_list, validate_graph
from repro.serve import ServeMetrics
from repro.stream.delta import GraphDelta


def _edges(*pairs):
    e = np.array(pairs, np.int32)
    return e[:, 0], e[:, 1]


class TestGraphConstruction:
    def test_rejects_float_arrays(self):
        with pytest.raises(ValueError, match="int32"):
            Graph(2, np.array([0.0, 1.0]), np.array([1.0, 0.0]))

    def test_rejects_wrong_dims(self):
        s, d = _edges((0, 1))
        with pytest.raises(ValueError, match="1-D"):
            Graph(2, s.reshape(1, 1), d.reshape(1, 1))

    def test_rejects_length_mismatch(self):
        with pytest.raises(ValueError, match="length"):
            Graph(2, np.array([0, 1], np.int32),
                  np.array([1], np.int32))

    def test_rejects_nonpositive_num_nodes(self):
        s, d = _edges((0, 0))
        with pytest.raises(ValueError, match="num_nodes"):
            Graph(0, s, d)

    def test_from_edge_list_rejects_floats(self):
        with pytest.raises(ValueError, match="integer"):
            from_edge_list(2, np.array([[0.5, 1.0]]))

    def test_from_edge_list_rejects_bad_shape(self):
        with pytest.raises(ValueError, match=r"\(m, 2\)"):
            from_edge_list(3, np.array([[0, 1, 2]], np.int32))


class TestGraphRangeValidation:
    def test_out_of_range_ids(self):
        s, d = _edges((0, 5))       # dst 5 >= num_nodes 3
        g = Graph(3, s, d)
        with pytest.raises(ValueError, match="outside"):
            validate_graph(g)

    def test_negative_ids(self):
        s, d = _edges((-1, 1))
        g = Graph(3, s, d)
        with pytest.raises(ValueError, match="outside"):
            validate_graph(g)

    def test_build_plan_validates(self):
        s, d = _edges((0, 9))
        g = Graph(4, s, d)
        with pytest.raises(ValueError, match="outside"):
            build_plan(g, PlanConfig(method="pcpm", part_size=64))

    def test_session_validates(self):
        s, d = _edges((0, 9))
        g = Graph(4, s, d)
        with pytest.raises(ValueError, match="outside"):
            repro.open(g, method="pcpm", part_size=64)

    def test_validation_memoized(self):
        from repro.graphs import generators
        g = generators.rmat(6, 4, seed=0)
        validate_graph(g)
        assert g.__dict__.get("_validated")
        validate_graph(g)           # second call is O(1)


class TestDeltaValidation:
    def test_rejects_float_edges(self):
        with pytest.raises(ValueError, match="integer"):
            GraphDelta.insert(np.array([[0.5, 1.5]]))

    def test_rejects_bad_shape(self):
        with pytest.raises(ValueError, match=r"\(m, 2\)"):
            GraphDelta.insert(np.array([[0, 1, 2]], np.int32))

    def test_validate_out_of_range(self):
        from repro.graphs import generators
        g = generators.rmat(6, 4, seed=0)
        bad = GraphDelta.insert(
            np.array([[0, g.num_nodes + 3]], np.int32))
        with pytest.raises(ValueError, match="out of range"):
            bad.validate(g)
        neg = GraphDelta.insert(np.array([[-2, 0]], np.int32))
        with pytest.raises(ValueError, match="out of range"):
            neg.validate(g)

    def test_scheduler_apply_delta_validates(self):
        from repro.graphs import generators
        from repro.serve import SlotScheduler
        g = generators.rmat(6, 4, seed=0)
        sch = SlotScheduler(g, slots=2, method="pcpm", part_size=64,
                            chunk=4)
        bad = GraphDelta.insert(
            np.array([[0, g.num_nodes + 1]], np.int32))
        with pytest.raises(ValueError, match="out of range"):
            sch.apply_delta(bad)
        assert sch.metrics.counters["delta_failures"] == 1
        sch.submit(tol=1e-4, max_iters=100)
        assert all(r.converged for r in sch.run_until_drained())


class TestMetricsEdgeCases:
    def test_empty_recorder(self):
        m = ServeMetrics()
        assert m.percentile(50.0) is None
        assert m.percentile(99.0, of="queue") is None
        s = m.summary()
        assert s["count"] == 0 and s["served_count"] == 0
        assert s["p50_ms"] is None and s["qps"] is None

    def test_error_completions_excluded_from_latency(self):
        t = [0.0]
        m = ServeMetrics()
        m.clock = lambda: t[0]
        m.submitted(1); m.submitted(2)
        m.admitted(1); m.admitted(2)
        t[0] = 1.0
        m.completed(1, iterations=10, converged=True)
        m.completed(2, iterations=0, converged=False,
                    error="rejected: queue full")
        s = m.summary()
        assert s["count"] == 2
        assert s["served_count"] == 1 and s["error_count"] == 1
        assert s["mean_iterations"] == 10.0
        assert s["converged_frac"] == 1.0   # over served only

    def test_degraded_counted(self):
        m = ServeMetrics()
        m.submitted(1); m.admitted(1)
        m.completed(1, iterations=5, converged=True, degraded=True)
        assert m.summary()["degraded_count"] == 1

    def test_counters(self):
        m = ServeMetrics()
        m.incr("rejected"); m.incr("rejected"); m.incr("quarantined")
        assert m.summary()["counters"] == {"rejected": 2,
                                           "quarantined": 1}

    def test_single_completion_qps_not_inf(self):
        """One completion => zero span; qps must be None, not inf."""
        t = [0.0]
        m = ServeMetrics()
        m.clock = lambda: t[0]
        m.submitted(1); m.admitted(1)
        m.completed(1, iterations=3, converged=True)
        assert m.summary()["qps"] is None
