"""Plan/Session split acceptance (ISSUE 4):

- ``repro.open(g, cfg)`` serves pagerank(), spmv() and serve() from
  ONE cached GraphPlan (build count == 1);
- the backend registry resolves all five engines; a new backend plugs
  in through ``register_backend`` without touching any call site;
- the old entry points (SpMVEngine / pagerank() / PageRankServer /
  SlotScheduler) are shims over the same plan cache — both paths give
  identical results;
- ``two_phase`` is honored or rejected, never silently ignored.
"""
import numpy as np
import pytest
import jax.numpy as jnp

import repro
from repro.core import (SpMVEngine, pagerank, pagerank_reference,
                        resolve_method)
from repro.core.backends import Backend, _REGISTRY
from repro.core.plan import plan_cache_stats
from repro.graphs import generators


@pytest.fixture
def graph():
    return generators.rmat(7, 6, seed=9)


def dense_spmv(g, x):
    A = np.zeros((g.num_nodes, g.num_nodes))
    np.add.at(A, (g.src, g.dst), 1.0)
    return A.T @ x


# ----------------------------------------------------------- the facade
class TestSession:
    def test_one_plan_serves_everything(self, graph):
        """The acceptance invariant: pagerank + spmv + serve + server
        + a reopened session all come from ONE plan build."""
        cfg = repro.EngineConfig(method="pcpm", part_size=32,
                                 num_iterations=15, slots=2, chunk=4)
        before = plan_cache_stats().plan_builds
        sess = repro.open(graph, cfg)

        res = sess.pagerank()
        ref = pagerank_reference(graph, num_iterations=15)
        np.testing.assert_allclose(np.asarray(res.ranks), ref,
                                   rtol=1e-3, atol=1e-7)

        x = np.random.default_rng(0).random(
            graph.num_nodes).astype(np.float32)
        np.testing.assert_allclose(np.asarray(sess.spmv(x)),
                                   dense_spmv(graph, x), rtol=2e-4,
                                   atol=1e-5)

        sch = sess.serve()
        assert sch.engine is sess.engine          # shared plan, shared
        sch.submit(tol=0.0, max_iters=15)         # device streams
        out = sch.run_until_drained()
        np.testing.assert_allclose(out[0].ranks, ref, rtol=1e-3,
                                   atol=1e-7)

        srv = sess.server(num_iterations=15)
        pr, it, _ = srv.query()
        assert it == 15

        sess2 = repro.open(graph, cfg)
        assert sess2.plan is sess.plan
        assert plan_cache_stats().plan_builds == before + 1

    def test_overrides_and_defaults(self, graph):
        sess = repro.open(graph, method="pcpm", part_size=32, tol=1e-6,
                          num_iterations=100)
        res = sess.pagerank()
        assert res.iterations < 100 and res.residuals[-1] < 1e-6
        res5 = sess.pagerank(num_iterations=5, tol=0.0)
        assert res5.iterations == 5

    def test_python_driver_override(self, graph):
        sess = repro.open(graph, method="pcpm", part_size=32,
                          num_iterations=10)
        fused = sess.pagerank()
        py = sess.pagerank(driver="python")
        np.testing.assert_allclose(np.asarray(fused.ranks),
                                   np.asarray(py.ranks), rtol=1e-5,
                                   atol=1e-8)

    def test_plan_save_exposed(self, graph, tmp_path):
        sess = repro.open(graph, method="pcpm", part_size=32)
        path = str(tmp_path / "g.plan.npz")
        sess.plan.save(path)
        loaded = repro.GraphPlan.load(path)
        assert loaded.config == sess.plan.config

    def test_session_rejects_two_phase(self, graph):
        with pytest.raises(ValueError, match="two_phase"):
            repro.open(graph, two_phase=True)


# ----------------------------------------------------------- plan cache
class TestPlanCache:
    def test_shims_share_the_session_plan(self, graph):
        """Old constructors and the facade resolve to the SAME plan."""
        sess = repro.open(graph, method="pcpm", part_size=32)
        eng = SpMVEngine(graph, method="pcpm", part_size=32)
        assert eng.plan is sess.plan

    def test_png_deduped_across_pcpm_and_pallas(self, graph):
        """The old SpMVEngine built the identical PNG layout once per
        method; the plan cache builds it once per (graph, part_size)."""
        SpMVEngine(graph, method="pcpm", part_size=16)
        stats = plan_cache_stats()
        png_before = stats.png_builds
        SpMVEngine(graph, method="pcpm_pallas", part_size=16)
        assert stats.png_builds == png_before          # hit, not build
        assert stats.png_hits > 0

    def test_registry_schedulers_share_one_plan(self, graph):
        """GraphRegistry / repeated SlotScheduler construction reuses
        one plan per graph instead of rebuilding per scheduler."""
        from repro.serve import SlotScheduler
        a = SlotScheduler(graph, slots=2, method="pcpm", part_size=16)
        builds = plan_cache_stats().plan_builds
        b = SlotScheduler(graph, slots=4, method="pcpm", part_size=16)
        assert plan_cache_stats().plan_builds == builds
        assert a.engine.plan is b.engine.plan

    def test_equal_graphs_share_plans(self, graph):
        """The cache is content-addressed: a re-generated identical
        graph hits the same plan."""
        g2 = generators.rmat(7, 6, seed=9)
        assert g2 is not graph
        e1 = SpMVEngine(graph, method="pcpm", part_size=32)
        e2 = SpMVEngine(g2, method="pcpm", part_size=32)
        assert e1.plan is e2.plan


# ------------------------------------------------------------- registry
class TestRegistry:
    def test_all_five_backends_registered(self):
        assert set(repro.available_backends()) >= {
            "pdpr", "bvgas", "pcpm", "pcpm_pallas", "pcpm_sharded"}

    def test_unknown_method_rejected(self, graph):
        with pytest.raises(ValueError, match="unknown method"):
            SpMVEngine(graph, method="gespmm")

    def test_capability_flags(self):
        assert repro.get_backend("pcpm_sharded").supports_sharding
        assert not repro.get_backend("pcpm").supports_sharding
        assert repro.get_backend("pcpm").supports_two_phase
        assert not repro.get_backend("pdpr").supports_two_phase
        assert repro.get_backend("pcpm_pallas").multi_vector

    def test_resolve_method_sharded_fallback(self):
        assert resolve_method("pcpm", sharded=True) == "pcpm_sharded"
        assert resolve_method("pcpm", sharded=False) == "pcpm"
        assert resolve_method("pcpm_sharded",
                              sharded=True) == "pcpm_sharded"

    def test_new_backend_plugs_in_without_call_site_edits(self, graph):
        """Register a toy engine and drive it through the UNCHANGED
        SpMVEngine / pagerank() / Session call sites."""
        pcpm = repro.get_backend("pcpm")
        toy = Backend("toy_pcpm", pcpm.build_plan, pcpm.spmv_fn,
                      phase_fns=pcpm.phase_fns)
        repro.register_backend(toy)
        try:
            res = pagerank(graph, method="toy_pcpm", num_iterations=10,
                           part_size=32)
            ref = pagerank_reference(graph, num_iterations=10)
            np.testing.assert_allclose(np.asarray(res.ranks), ref,
                                       rtol=1e-3, atol=1e-7)
            sess = repro.open(graph, method="toy_pcpm", part_size=32)
            sch = sess.serve(slots=2)
            sch.submit(tol=0.0, max_iters=10)
            out = sch.run_until_drained()
            np.testing.assert_allclose(out[0].ranks, ref, rtol=1e-3,
                                       atol=1e-7)
            with pytest.raises(ValueError, match="already registered"):
                repro.register_backend(toy)
        finally:
            _REGISTRY.pop("toy_pcpm", None)


# ------------------------------------------------------------ two_phase
class TestTwoPhase:
    def test_spmv_fn_raises_instead_of_ignoring(self, graph):
        eng = SpMVEngine(graph, method="pcpm", part_size=32,
                         two_phase=True)
        with pytest.raises(ValueError, match="two_phase"):
            eng.spmv_fn()

    def test_two_phase_call_still_correct(self, graph):
        x = np.random.default_rng(1).random(
            graph.num_nodes).astype(np.float32)
        for method in ("pcpm", "bvgas"):
            eng = SpMVEngine(graph, method=method, part_size=32,
                             two_phase=True)
            np.testing.assert_allclose(np.asarray(eng(jnp.asarray(x))),
                                       dense_spmv(graph, x), rtol=2e-4,
                                       atol=1e-5)

    def test_two_phase_rejected_for_fused_only_backends(self, graph):
        for method in ("pdpr", "pcpm_pallas"):
            with pytest.raises(ValueError, match="two_phase"):
                SpMVEngine(graph, method=method, two_phase=True)

    def test_two_phase_pagerank_uses_python_driver(self, graph):
        eng = SpMVEngine(graph, method="pcpm", part_size=32,
                         two_phase=True)
        res = pagerank(graph, engine=eng, num_iterations=10)
        ref = pagerank_reference(graph, num_iterations=10)
        np.testing.assert_allclose(np.asarray(res.ranks), ref,
                                   rtol=1e-3, atol=1e-7)


# ----------------------------------------------------- deprecation shims
class TestShims:
    """The pre-split entry points keep their signatures and agree with
    the Session path bit-for-bit (same plan, same closures)."""

    def test_pagerank_shim_matches_session(self, graph):
        old = pagerank(graph, method="pcpm", num_iterations=12,
                       part_size=32)
        new = repro.open(graph, method="pcpm", part_size=32,
                         num_iterations=12).pagerank()
        np.testing.assert_array_equal(np.asarray(old.ranks),
                                      np.asarray(new.ranks))

    def test_server_shim_matches_session(self, graph):
        from repro.serve import PageRankServer
        old = PageRankServer(graph, method="pcpm", part_size=32,
                             num_iterations=10)
        pr_old, it_old, _ = old.query()
        sess = repro.open(graph, method="pcpm", part_size=32,
                          num_iterations=10)
        pr_new, it_new, _ = sess.server().query()
        assert it_old == it_new
        np.testing.assert_array_equal(np.asarray(pr_old),
                                      np.asarray(pr_new))

    def test_engine_attributes_preserved(self, graph):
        eng = SpMVEngine(graph, method="pcpm", part_size=32)
        assert eng.partitioning.part_size == 32
        assert eng.layout.compression_ratio == eng.compression_ratio > 1
        assert eng.num_nodes == graph.num_nodes
        eng_p = SpMVEngine(graph, method="pdpr")
        assert eng_p.compression_ratio == 1.0
        with pytest.raises(AttributeError):
            eng_p.layout
