"""Training/serving substrate: checkpoint atomicity + resume,
failure-injection restart, gradient compression, serving engine,
data-pipeline determinism."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models import transformer as tf
from repro.optim import AdamW, cosine_schedule
from repro.train import Trainer, TrainerConfig, checkpoint, compression
from repro.data import synthetic_lm_batches
from repro.serve import ServeEngine, Request


# ------------------------------------------------------------ checkpoint
class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.asarray([1, 2], jnp.int32)}}
        checkpoint.save(str(tmp_path), 7, tree)
        restored, step = checkpoint.restore(str(tmp_path), tree)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                      np.asarray(tree["b"]["c"]))

    def test_keep_last_n(self, tmp_path):
        tree = {"x": jnp.zeros(3)}
        for s in range(6):
            checkpoint.save(str(tmp_path), s, tree, keep=2)
        assert checkpoint.all_steps(str(tmp_path)) == [4, 5]

    def test_shape_mismatch_raises(self, tmp_path):
        checkpoint.save(str(tmp_path), 0, {"x": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            checkpoint.restore(str(tmp_path), {"x": jnp.zeros((3,))})

    def test_partial_write_never_corrupts(self, tmp_path):
        tree = {"x": jnp.ones(4)}
        checkpoint.save(str(tmp_path), 1, tree)
        # a stray tmp file (crashed writer) must be ignored
        open(os.path.join(tmp_path, ".tmp-99.npz"), "wb").write(b"junk")
        restored, step = checkpoint.restore(str(tmp_path), tree)
        assert step == 1


# -------------------------------------------------------------- trainer
def _tiny_setup(tmp_path, total_steps=12, ckpt_every=4, fail_at=None):
    cfg = get("tinyllama-1.1b").scaled(n_layers=1, d_model=32, n_heads=2,
                                       d_ff=64, vocab=64)
    params = tf.init_lm(cfg, jax.random.key(0))
    opt = AdamW(lr=1e-3)
    state = (params, opt.init(params))
    step = jax.jit(tf.make_train_step(cfg, opt))
    data = synthetic_lm_batches(cfg.vocab, 2, 16, seed=3)

    failed = {"done": False}

    def failure_hook(s):
        if fail_at is not None and s == fail_at and not failed["done"]:
            failed["done"] = True
            raise RuntimeError("injected node failure")

    tr = Trainer(TrainerConfig(total_steps=total_steps,
                               checkpoint_every=ckpt_every,
                               ckpt_dir=str(tmp_path), log_every=1000),
                 step, state, data,
                 failure_hook=failure_hook if fail_at else None,
                 log_fn=lambda *a: None)
    return tr, cfg, opt, step


class TestTrainerFaultTolerance:
    def test_failure_restart_bit_identical(self, tmp_path):
        # run A: uninterrupted
        tr_a, *_ = _tiny_setup(tmp_path / "a", total_steps=10,
                               ckpt_every=5)
        out_a = tr_a.run()
        params_a = tr_a.state[0]

        # run B: crash at step 7, then restart and resume
        tr_b, *_ = _tiny_setup(tmp_path / "b", total_steps=10,
                               ckpt_every=5, fail_at=7)
        with pytest.raises(RuntimeError):
            tr_b.run()
        tr_c, *_ = _tiny_setup(tmp_path / "b", total_steps=10,
                               ckpt_every=5)
        assert tr_c.try_resume()
        assert tr_c.step == 5
        # data iterator must be fast-forwarded to the resume point —
        # deterministic keyed data makes this a seek, not state restore
        tr_c.data = synthetic_lm_batches(64, 2, 16, seed=3, start_step=5)
        tr_c.run()
        for la, lb in zip(jax.tree.leaves(params_a),
                          jax.tree.leaves(tr_c.state[0])):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_resume_without_checkpoint_is_false(self, tmp_path):
        tr, *_ = _tiny_setup(tmp_path / "c")
        assert not tr.try_resume()


# ----------------------------------------------------------- compression
class TestCompression:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
        ef = jnp.zeros_like(x)
        q, scale, err = compression.compress(x, ef)
        assert q.dtype == jnp.int8
        x_hat = compression.decompress(q, scale)
        assert float(jnp.abs(x - x_hat).max()) <= float(scale) * 0.5 + 1e-6

    def test_error_feedback_unbiased_over_steps(self):
        """With EF, the AVERAGE of decompressed grads converges to the
        average of true grads (residual is re-injected)."""
        rng = np.random.default_rng(1)
        g_true = jnp.asarray(rng.standard_normal(256).astype(np.float32))
        ef = jnp.zeros_like(g_true)
        acc = jnp.zeros_like(g_true)
        n = 200
        for _ in range(n):
            q, s, ef = compression.compress(g_true, ef)
            acc = acc + compression.decompress(q, s)
        np.testing.assert_allclose(np.asarray(acc / n),
                                   np.asarray(g_true), atol=5e-3)

    def test_tree_api(self):
        grads = {"w": jnp.ones((4, 4)), "b": jnp.full((4,), -2.0)}
        ef = compression.init_ef_state(grads)
        out, new_ef = compression.compressed_gradients(grads, ef)
        assert jax.tree.structure(out) == jax.tree.structure(grads)
        np.testing.assert_allclose(np.asarray(out["w"]), 1.0, atol=1e-2)


# -------------------------------------------------------------- serving
class TestServeEngine:
    def test_continuous_batching_matches_sequential(self):
        cfg = get("tinyllama-1.1b").scaled(n_layers=1, d_model=32,
                                           n_heads=2, d_ff=64, vocab=64)
        params = tf.init_lm(cfg, jax.random.key(5))
        rng = np.random.default_rng(2)
        prompts = [list(map(int, rng.integers(1, 60, ln)))
                   for ln in (5, 3, 7, 4, 6)]
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
        eng.run_until_drained(reqs)
        assert all(r.done for r in reqs)

        # oracle: single-request greedy decode via full forward
        for r, prompt in zip(reqs, prompts):
            toks = list(prompt)
            for _ in range(len(r.generated)):
                logits, _ = tf.forward(params, cfg,
                                       jnp.asarray([toks], jnp.int32),
                                       attn_path="dense")
                toks.append(int(jnp.argmax(logits[0, -1])))
            assert toks[len(prompt):] == r.generated, (
                toks[len(prompt):], r.generated)

    def test_slots_reused(self):
        cfg = get("tinyllama-1.1b").scaled(n_layers=1, d_model=32,
                                           n_heads=2, d_ff=64, vocab=64)
        params = tf.init_lm(cfg, jax.random.key(6))
        reqs = [Request(uid=i, prompt=[1 + i], max_new_tokens=2)
                for i in range(6)]
        eng = ServeEngine(cfg, params, batch_slots=2, max_len=16)
        eng.run_until_drained(reqs)
        assert all(r.done for r in reqs)


# ----------------------------------------------------------------- data
def test_data_determinism_and_seek():
    it1 = synthetic_lm_batches(100, 2, 8, seed=9)
    batches = [next(it1) for _ in range(5)]
    it2 = synthetic_lm_batches(100, 2, 8, seed=9, start_step=3)
    b3 = next(it2)
    np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                  np.asarray(b3["tokens"]))
    labels = np.asarray(batches[0]["labels"])
    tokens = np.asarray(batches[0]["tokens"])
    assert (labels[:, :-1] == tokens[:, 1:]).all()
