"""Observability layer tests (DESIGN.md §14).

Four instrument groups, each with exact-semantics unit tests, then the
integration storms:

- metrics registry: counter monotonicity, le-INCLUSIVE histogram
  buckets with exact cumulative exposition, kind-conflict errors,
  Prometheus text format down to the line.
- tracer/flight recorder: explicit-parent nesting, bounded ring with
  drop accounting, exactly-once ``end()``, JSONL dump format.
- comm accounting: measured bytes from real plan geometry vs the
  paper's §V model — pcpm must land within 2x of eq. 5 (the headline
  acceptance bound), and the per-stream breakdown must reconcile.
- the serving integration: a PR 9-shaped concurrent mixed push/stepper
  storm with observability ON must yield one complete, well-nested
  span tree per query with exactly one terminal event, keep
  ``trace_count == 1``, and cost < 5% qps vs observability OFF.
"""
import json
import threading
import time

import numpy as np
import pytest

import repro
from repro.core.plan import PlanConfig, build_plan, clear_plan_cache
from repro.graphs import generators
from repro.obs import (FlightRecorder, MetricsRegistry, Observability,
                       QuerySpans, Tracer, measure_plan, vs_model)
from repro.obs.comm import CommAccountant
from repro.reliability import (FaultInjector, FaultPlan, FaultSpec,
                               ResilienceConfig)
from repro.serve import SlotScheduler
from repro.serve.metrics import ServeMetrics

SMALL = dict(method="pcpm", part_size=64, chunk=4)


@pytest.fixture(scope="module")
def g():
    return generators.rmat(8, 8, seed=1)


def _seed(g, at=3):
    s = np.zeros(g.num_nodes, np.float32)
    s[at % g.num_nodes] = 1.0
    s[(at * 7 + 1) % g.num_nodes] = 1.0
    return s


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------
class TestMetricsRegistry:
    def test_counter_monotone(self):
        reg = MetricsRegistry()
        c = reg.counter("x_total", "help", kind="a")
        c.inc()
        c.inc(3)
        assert reg.counter_value("x_total", kind="a") == 4
        with pytest.raises(ValueError, match="monotone"):
            c.inc(-1)
        assert c.value == 4

    def test_labels_are_order_insensitive(self):
        reg = MetricsRegistry()
        reg.counter("t", a="1", b="2").inc()
        reg.counter("t", b="2", a="1").inc()
        assert reg.counter_value("t", a="1", b="2") == 2
        assert len(reg.family_items("t")) == 1

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x")

    def test_unknown_reads_as_zero(self):
        assert MetricsRegistry().counter_value("nope") == 0.0
        assert MetricsRegistry().family_items("nope") == []

    def test_gauge_levels(self):
        reg = MetricsRegistry()
        ga = reg.gauge("depth")
        ga.set(5)
        ga.inc()
        ga.dec(3)
        assert ga.value == 3

    def test_histogram_le_inclusive_exact(self):
        """A value EQUAL to an upper bound lands in that bucket
        (Prometheus ``le`` semantics) and exposed counts are
        cumulative — checked against a hand-computed table."""
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
        for v in (0.1, 0.1, 0.5, 1.0, 7.0, 11.0):
            h.observe(v)
        snap = h.snapshot()
        assert snap["buckets"] == [(0.1, 2), (1.0, 4), (10.0, 5),
                                   ("+Inf", 6)]
        assert snap["count"] == 6
        assert snap["sum"] == pytest.approx(19.7)

    def test_histogram_rejects_unsorted_bounds(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="ascending"):
            reg.histogram("h", buckets=(1.0, 0.5))

    def test_prometheus_text_exact(self):
        reg = MetricsRegistry()
        reg.counter("ev_total", "events", event="a").inc(2)
        reg.gauge("depth", "queue depth").set(3)
        h = reg.histogram("lat_seconds", "latency", buckets=(0.5, 2.0))
        h.observe(0.5)
        h.observe(1.0)
        text = reg.prometheus_text()
        assert "# HELP ev_total events\n# TYPE ev_total counter\n" \
               'ev_total{event="a"} 2\n' in text
        assert "depth 3\n" in text
        assert 'lat_seconds_bucket{le="0.5"} 1' in text
        assert 'lat_seconds_bucket{le="2"} 2' in text
        assert 'lat_seconds_bucket{le="+Inf"} 2' in text
        assert "lat_seconds_sum 1.5" in text
        assert "lat_seconds_count 2" in text

    def test_render_merges_with_extra_labels(self):
        from repro.obs import render_prometheus
        r1, r2 = MetricsRegistry(), MetricsRegistry()
        r1.counter("q_total").inc(1)
        r2.counter("q_total").inc(5)
        text = render_prometheus([(r1, {"graph": "a"}),
                                  (r2, {"graph": "b"}),
                                  (r1, {"graph": "dup"})])   # deduped
        assert 'q_total{graph="a"} 1' in text
        assert 'q_total{graph="b"} 5' in text
        assert "dup" not in text

    def test_label_escaping(self):
        reg = MetricsRegistry()
        reg.counter("e_total", event='say "hi"\n').inc()
        text = reg.prometheus_text()
        assert r'event="say \"hi\"\n"' in text


# ---------------------------------------------------------------------------
# Tracer / flight recorder
# ---------------------------------------------------------------------------
class TestTracer:
    def test_explicit_parent_nesting(self):
        tr = Tracer(FlightRecorder(16))
        root = tr.start("query", trace=7)
        child = root.child("slot", slot=2)
        child.end(iterations=5)
        root.end()
        recs = tr.recorder.snapshot()
        assert [r.name for r in recs] == ["slot", "query"]  # end order
        slot, query = recs
        assert slot.parent_id == query.span_id
        assert slot.trace == query.trace == 7
        assert slot.attrs == {"slot": 2, "iterations": 5}
        assert query.t_start <= slot.t_start <= slot.t_end <= query.t_end

    def test_end_exactly_once(self):
        tr = Tracer(FlightRecorder(16))
        sp = tr.start("x")
        sp.end()
        sp.end()
        sp.end(status="error")
        assert len(tr.recorder) == 1
        assert tr.double_ends == 2

    def test_ring_bounded_with_drop_accounting(self):
        tr = Tracer(FlightRecorder(4))
        for i in range(10):
            tr.event("e", i=i)
        recs = tr.recorder.snapshot()
        assert len(recs) == 4
        assert [r.attrs["i"] for r in recs] == [6, 7, 8, 9]  # oldest out
        assert tr.recorder.recorded == 10
        assert tr.recorder.dropped == 6

    def test_span_contextmanager_error_status(self):
        tr = Tracer(FlightRecorder(16))
        with pytest.raises(RuntimeError):
            with tr.span("risky"):
                raise RuntimeError("boom")
        (rec,) = tr.recorder.snapshot()
        assert rec.status == "error"
        assert "boom" in rec.attrs["error"]

    def test_jsonl_dump_format(self, tmp_path):
        tr = Tracer(FlightRecorder(8))
        tr.event("a", k=1)
        with tr.span("b", trace=3):
            pass
        path = tr.recorder.dump(str(tmp_path / "f.jsonl"))
        lines = open(path).read().splitlines()
        header = json.loads(lines[0])
        assert header == {"schema": 1, "recorded": 2, "dropped": 0,
                          "capacity": 8, "held": 2}
        rows = [json.loads(ln) for ln in lines[1:]]
        assert [r["name"] for r in rows] == ["a", "b"]
        assert rows[0]["t0"] == rows[0]["t1"]          # event
        assert rows[1]["trace"] == 3
        assert set(rows[0]) == {"name", "span", "parent", "trace",
                                "t0", "t1", "status", "attrs"}

    def test_query_spans_retry_and_terminal(self):
        tr = Tracer(FlightRecorder(32))
        qs = QuerySpans(tr, tr.start("query"))
        qs.bind(42)
        qs.start_child("slot", slot=0)
        qs.start_child("slot", slot=1)     # re-admit: closes the first
        qs.finish(iterations=9)
        recs = tr.recorder.snapshot()
        by = {}
        for r in recs:
            by.setdefault(r.name, []).append(r)
        assert [r.status for r in by["slot"]] == ["retry", "ok"]
        assert len(by["terminal"]) == 1
        assert all(r.trace == 42 for r in recs)
        assert by["query"][0].status == "ok"           # root recorded

    def test_gateway_owned_root_ends_at_resolve(self):
        tr = Tracer(FlightRecorder(32))
        qs = QuerySpans(tr, tr.start("query"), gateway_owned=True)
        qs.bind(1)
        qs.finish()                        # terminal, root still open
        assert "query" not in {r.name for r in tr.recorder.snapshot()}
        qs.resolve()
        names = [r.name for r in tr.recorder.snapshot()]
        assert names.count("query") == 1 and "resolve" in names
        qs.resolve()                       # idempotent
        assert [r.name for r in tr.recorder.snapshot()
                ].count("query") == 1


# ---------------------------------------------------------------------------
# Comm accounting
# ---------------------------------------------------------------------------
class TestCommAccounting:
    def test_pcpm_measured_within_2x_of_model(self):
        """Acceptance bound: at scale 16 the DRAM-stream bytes measured
        off the real plan geometry must land within 2x of the paper's
        eq. 5 prediction (padding + the bins round trip are the honest
        gap, quantified in DESIGN.md §14)."""
        g = generators.rmat(16, 16, seed=3)
        plan = build_plan(g, PlanConfig(method="pcpm", part_size=4096))
        cmp_ = vs_model(plan)
        assert cmp_["method"] == "pcpm"
        assert 0.5 <= cmp_["ratio"] <= 2.0, cmp_
        # breakdown reconciles: stream sum == headline number
        meas = measure_plan(plan)
        assert sum(meas.dram.values()) == meas.dram_bytes
        assert meas.dram_bytes == cmp_["measured_bytes_per_iter"]

    def test_all_methods_measurable(self):
        g = generators.rmat(10, 8, seed=2)
        for method in ("pcpm", "pdpr", "bvgas"):
            plan = build_plan(g, PlanConfig(method=method,
                                            part_size=256))
            cmp_ = vs_model(plan)
            assert cmp_["measured_bytes_per_iter"] > 0
            assert cmp_["model_bytes_per_iter"] > 0
            assert np.isfinite(cmp_["ratio"])

    def test_multi_vector_amortizes_index_streams(self):
        """ncols multiplies only the VALUE streams; the index streams
        are read once per pass, so bytes/column strictly decreases —
        the multi-vector amortization the serving stack banks on."""
        g = generators.rmat(10, 8, seed=2)
        plan = build_plan(g, PlanConfig(method="pcpm", part_size=256))
        b1 = measure_plan(plan, ncols=1).dram_bytes
        b8 = measure_plan(plan, ncols=8).dram_bytes
        assert b1 < b8 < 8 * b1

    def test_accountant_accumulates_and_skips_empty(self):
        g = generators.rmat(8, 8, seed=1)
        plan = build_plan(g, PlanConfig(method="pcpm", part_size=64))
        reg = MetricsRegistry()
        acc = CommAccountant(registry=reg)
        acc.record_pass(plan, iters=0)          # no-op
        acc.record_solve(plan, 10)
        acc.record_pass(plan, iters=5)
        s = acc.summary()["pcpm"]
        assert s["passes"] == 15
        assert s["dram_bytes"] == 15 * s["bytes_per_pass"]
        assert s["ratio_vs_model"] == pytest.approx(
            s["dram_bytes"] / s["model_dram_bytes"])
        assert reg.counter_value("comm_passes_total",
                                 method="pcpm") == 15


# ---------------------------------------------------------------------------
# ServeMetrics single-home + reconciliation
# ---------------------------------------------------------------------------
class TestServeMetricsReconcile:
    def test_duplicate_terminal_raises(self):
        m = ServeMetrics()
        m.submitted(1)
        m.completed(1, iterations=3, converged=True)
        with pytest.raises(RuntimeError, match="duplicate terminal"):
            m.completed(1, iterations=3, converged=True)

    def test_counters_is_derived_view(self):
        m = ServeMetrics()
        m.incr("rejected", 2)
        assert m.counters["rejected"] == 2
        assert m.counters["never_bumped"] == 0
        # single home: the registry IS the storage
        assert m.registry.counter_value("serve_events_total",
                                        event="rejected") == 2

    def test_reconcile_catches_drift(self):
        """A counter bumped without its terminal — the double-home
        bug class this layer kills — must be NAMED by reconcile()."""
        m = ServeMetrics()
        m.submitted(1)
        m.incr("rejected")
        m.completed(1, iterations=0, converged=False,
                    error="rejected: queue full")
        m.reconcile()                       # consistent: passes
        m.incr("rejected")                  # drift: counter w/o trace
        with pytest.raises(AssertionError, match="rejected"):
            m.reconcile()

    def test_reconcile_routes(self):
        m = ServeMetrics()
        for uid, route, ev in ((1, "push", "push_served"),
                               (2, "cached", "cache_hits")):
            m.submitted(uid)
            m.incr(ev)
            m.completed(uid, iterations=1, converged=True, route=route)
        out = m.reconcile()
        assert out["push_served"] == 1 and out["cache_hits_served"] == 1


# ---------------------------------------------------------------------------
# Plan events + session wiring
# ---------------------------------------------------------------------------
class TestPlanEvents:
    def test_build_and_cache_hit_events(self, g):
        clear_plan_cache()
        obs = Observability(capacity=64)
        try:
            cfg = PlanConfig(method="pcpm", part_size=64)
            build_plan(g, cfg)
            build_plan(g, cfg)              # second call: cache hit
            names = [r.name for r in obs.recorder.snapshot()]
            assert "plan_build" in names and "plan_cache_hit" in names
            assert obs.registry.counter_value(
                "plan_events_total", event="plan_build") == 1
            assert obs.registry.counter_value(
                "plan_events_total", event="plan_cache_hit") == 1
        finally:
            obs.close()

    def test_closed_bundle_detaches(self, g):
        clear_plan_cache()
        obs = Observability(capacity=64)
        obs.close()
        build_plan(g, PlanConfig(method="pcpm", part_size=64))
        assert "plan_build" not in {r.name
                                    for r in obs.recorder.snapshot()}

    def test_patch_emits_plan_patch_event(self, g):
        from repro.stream import GraphDelta
        sess = repro.open(g, repro.EngineConfig(**SMALL, observe=True))
        rng = np.random.default_rng(0)
        delta = GraphDelta.insert(
            np.stack([rng.integers(0, g.num_nodes, 8),
                      rng.integers(0, g.num_nodes, 8)], axis=1))
        sess.apply_delta(delta)
        names = [r.name for r in sess.obs.recorder.snapshot()]
        assert "plan_patch" in names and "session_delta" in names


class TestSessionObserve:
    def test_observe_idempotent_and_stats(self, g):
        sess = repro.open(g, repro.EngineConfig(**SMALL))
        assert sess.obs is None
        obs = sess.observe()
        assert sess.observe() is obs
        res = sess.pagerank(num_iterations=5)
        st = sess.stats()
        assert st["plan_cache"]["plan_builds"] >= 1
        assert st["obs"]["comm"]["pcpm"]["passes"] == res.iterations
        assert st["obs"]["flight_recorder"]["recorded"] >= 1
        names = [r.name for r in obs.recorder.snapshot()]
        assert "solve" in names

    def test_config_observe_traces_build_and_solve(self):
        clear_plan_cache()
        g2 = generators.rmat(8, 8, seed=9)
        sess = repro.open(g2, repro.EngineConfig(**SMALL, observe=True))
        sess.pagerank(num_iterations=3)
        names = [r.name for r in sess.obs.recorder.snapshot()]
        # the bundle attaches BEFORE the plan builds, so the session's
        # own preprocessing is on the record
        assert "plan_build" in names and "solve" in names

    def test_crash_dump_on_quarantine(self, g, tmp_path):
        """PR 6's resilience path is the forensics moment: a poisoned
        slot that exhausts retries must leave a flight-recorder file
        behind."""
        obs = Observability(capacity=256, dump_dir=str(tmp_path))
        try:
            inj = FaultInjector(FaultPlan.of(
                [FaultSpec("nan_slot", step=2, slot=0)]))
            sch = SlotScheduler(
                g, slots=1, fault_injector=inj, obs=obs,
                resilience=ResilienceConfig(max_retries=0), **SMALL)
            sch.submit(_seed(g), tol=1e-6, max_iters=300)
            sch.run_until_drained()
            assert sch.metrics.counters["quarantined"] == 1
            dumps = list(tmp_path.glob("flight-*.jsonl"))
            assert len(dumps) == 1
            lines = dumps[0].read_text().splitlines()
            assert json.loads(lines[0])["schema"] == 1
            assert any(json.loads(ln)["name"] == "crash_dump"
                       for ln in lines[1:])
            assert obs.registry.counter_value("crash_dumps_total") == 1
        finally:
            obs.close()

    def test_snapshot_parks_trace_beside_state(self, g, tmp_path):
        from repro.reliability.snapshot import snapshot_scheduler
        obs = Observability(capacity=256)
        try:
            sch = SlotScheduler(g, slots=1, obs=obs, **SMALL)
            sch.submit(_seed(g), tol=1e-6, max_iters=300)
            sch.step()
            path = str(tmp_path / "state.npz")
            snapshot_scheduler(sch, path)
            trace = tmp_path / "state.npz.trace.jsonl"
            assert trace.exists()
            rows = [json.loads(ln)
                    for ln in trace.read_text().splitlines()[1:]]
            assert any(r["name"] == "snapshot" for r in rows)
        finally:
            obs.close()


# ---------------------------------------------------------------------------
# The PR 9 storm with observability on
# ---------------------------------------------------------------------------
def _storm(sch, *, threads=6, per=20):
    """Mixed push/stepper storm against a free-running device thread —
    the exact thread-ownership shape of test_serve_accounting's PR 9
    regression.  Returns (uids, elapsed_s)."""
    uids, lock, done = [], threading.Lock(), threading.Event()
    errors = []
    g = sch.g

    def submitter(i):
        mine = []
        for j in range(per):
            if (i + j) % 2:
                mine.append(sch.submit(_seed(g, at=i * 7 + j),
                                       top_k=8, tol=1e-2,
                                       max_iters=300))
            else:
                mine.append(sch.submit(_seed(g, at=i * 5 + j),
                                       tol=1e-5, max_iters=300))
        with lock:
            uids.extend(mine)

    def device_loop():
        try:
            while not done.is_set() or sch.queued or sch.active_slots:
                sch.step()
        except Exception as exc:   # noqa: BLE001
            errors.append(exc)

    t0 = time.perf_counter()
    dev = threading.Thread(target=device_loop)
    dev.start()
    ts = [threading.Thread(target=submitter, args=(i,))
          for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    done.set()
    dev.join(timeout=120)
    elapsed = time.perf_counter() - t0
    assert not dev.is_alive() and not errors
    return uids, elapsed


class TestObservedStorm:
    def test_storm_span_trees_complete_and_well_nested(self, g):
        """Every query in a concurrent mixed storm gets a COMPLETE span
        tree: one root, exactly one terminal event, every child span
        closed and nested inside the root interval — and the stepper
        still compiled exactly once."""
        obs = Observability(capacity=65536)
        try:
            sch = SlotScheduler(g, slots=4, obs=obs, **SMALL)
            uids, _ = _storm(sch)
            assert len(uids) == 120
            sch.metrics.reconcile()
            by_trace = {}
            for r in obs.recorder.snapshot():
                by_trace.setdefault(r.trace, []).append(r)
            assert obs.recorder.dropped == 0    # ring sized for storm
            for uid in uids:
                recs = by_trace[uid]
                roots = [r for r in recs if r.name == "query"]
                terms = [r for r in recs if r.name == "terminal"]
                assert len(roots) == 1, (uid, [r.name for r in recs])
                assert len(terms) == 1, (uid, [r.name for r in recs])
                root = roots[0]
                for r in recs:
                    if r.span_id == root.span_id:
                        continue
                    # well-nested: inside the root's interval, and the
                    # parent chain reaches the root
                    assert root.t_start <= r.t_start
                    assert r.t_end <= root.t_end, (uid, r.name)
                    assert r.parent_id is not None
                # every non-push query passed through queue+slot or
                # push — never both served paths
                names = {r.name for r in recs}
                assert ("push" in names) != ("slot" in names), names
            assert sch.trace_count == 1
            assert sch.admit_trace_count == 1
        finally:
            obs.close()

    def test_observed_storm_qps_within_5pct(self):
        """The acceptance bound: observability ON costs < 5% qps on a
        device-bound storm (scale 12 — chunk compute dominates, the
        regime the serving stack actually runs in; on toy graphs where
        a device step is microseconds, ~20 us of span records per
        query is a measurable slice of nothing).  Best-of-N with
        ALTERNATING trial order on shared pre-compiled schedulers, so
        neither compile time nor CPU warm-up bias either side."""
        import gc
        g_big = generators.rmat(12, 8, seed=1)
        # the production-default ring (8192) comfortably holds a storm
        # (~1k records) — an oversized ring would just hand the GC a
        # bigger live set to sweep mid-trial and measure THAT instead
        obs = Observability(capacity=8192)
        try:
            kw = dict(method="pcpm", part_size=1024, chunk=4)
            sch_off = SlotScheduler(g_big, slots=4, **kw)
            sch_on = SlotScheduler(g_big, slots=4, obs=obs, **kw)
            _storm(sch_off, threads=2, per=5)     # warm both paths
            _storm(sch_on, threads=2, per=5)
            best = {"off": 0.0, "on": 0.0}
            for i in range(4):
                pairs = [("off", sch_off), ("on", sch_on)]
                for key, sch in (pairs if i % 2 == 0
                                 else reversed(pairs)):
                    gc.collect()       # garbage from PRIOR trials is
                    #                    not this trial's overhead
                    uids, dt = _storm(sch)
                    best[key] = max(best[key], len(uids) / dt)
            assert best["on"] >= 0.95 * best["off"], best
        finally:
            obs.close()


class TestGatewayObserved:
    def test_gateway_roots_cover_resolution(self, g):
        """Gateway-owned roots end at future resolution: every uid's
        recorded root must contain its terminal event, and the three
        serve routes (stepper / cache / push) all leave exactly one
        terminal."""
        sess = repro.open(g, repro.EngineConfig(**SMALL, observe=True))
        obs = sess.obs
        gw = sess.gateway(autotune=False, slots=2)
        with gw:
            f1 = gw.submit(tol=1e-3, max_iters=300, top_k=5)
            r1 = f1.result(timeout=120)
            f2 = gw.submit(tol=1e-3, max_iters=300, top_k=5)  # cached
            r2 = f2.result(timeout=120)
            f3 = gw.submit(_seed(g), tol=1e-2, max_iters=300,
                           top_k=5)                           # push
            r3 = f3.result(timeout=120)
        assert r1.converged and r2.error is None and r3.error is None
        by = {}
        for r in obs.recorder.snapshot():
            by.setdefault(r.trace, []).append(r)
        for uid in (r1.uid, r2.uid, r3.uid):
            recs = by[uid]
            roots = [r for r in recs if r.name == "query"]
            terms = [r for r in recs if r.name == "terminal"]
            resolves = [r for r in recs if r.name == "resolve"]
            assert len(roots) == len(terms) == len(resolves) == 1
            assert roots[0].t_start <= terms[0].t_start \
                <= roots[0].t_end
        # route accounting survived the obs plumbing
        sch = next(iter(gw._schedulers.values()))
        rec = sch.metrics.reconcile()
        assert rec["cache_hits_served"] == 1
        assert rec["push_served"] == 1

    def test_metrics_endpoint_scrape(self, g):
        sess = repro.open(g, repro.EngineConfig(**SMALL, observe=True))
        gw = sess.gateway(autotune=False, slots=2)
        with gw:
            gw.submit(tol=1e-3, max_iters=300, top_k=5).result(
                timeout=120)
            text = gw.metrics_endpoint()
        assert "# TYPE serve_terminals_total counter" in text
        assert 'serve_terminals_total{graph="default"} 1' in text
        assert "gateway_cache_entries" in text
        assert "comm_passes_total" in text      # obs registry merged
        assert "trace_count" in text
