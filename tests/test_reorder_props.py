"""Property tests for graphs/reorder.py (ISSUE 8 satellite).

Every ordering must emit a VALID permutation on awkward graph shapes
(disconnected components, multi-edges, isolated nodes, no edges at
all), and relabeling must commute with PageRank: solving on the
relabeled graph then mapping back equals solving on the original —
the invariant the whole reorder-in-plan wiring rests on.
"""
import numpy as np
import pytest

from repro.core import pagerank_reference
from repro.graphs import generators
from repro.graphs.formats import Graph
from repro.graphs.reorder import (ORDERINGS, available_orderings,
                                  inverse_permutation,
                                  reorder_permutation)

ALL = sorted(ORDERINGS)


def make_graphs():
    e = lambda *pairs: np.array(pairs, dtype=np.int32)
    cases = {}
    # two components, neither reachable from the other
    ed = e((0, 1), (1, 2), (2, 0), (3, 4), (4, 3))
    cases["disconnected"] = Graph(5, ed[:, 0], ed[:, 1])
    # multi-edges and a self-loop
    ed = e((0, 1), (0, 1), (0, 1), (1, 0), (2, 2))
    cases["multi_edge"] = Graph(3, ed[:, 0], ed[:, 1])
    # nodes 5..9 appear in no edge at all
    ed = e((0, 1), (1, 2), (2, 3), (3, 4), (4, 0))
    cases["isolated_nodes"] = Graph(10, ed[:, 0], ed[:, 1])
    empty = np.array([], dtype=np.int32)
    cases["no_edges"] = Graph(4, empty, empty.copy())
    cases["single_node"] = Graph(1, empty, empty.copy())
    cases["rmat"] = generators.rmat(6, 4, seed=11)
    return cases

GRAPHS = make_graphs()


@pytest.mark.parametrize("name", ALL)
@pytest.mark.parametrize("shape", sorted(GRAPHS))
def test_valid_permutation(name, shape):
    g = GRAPHS[shape]
    perm = reorder_permutation(g, name)
    assert perm.dtype == np.int32 and perm.shape == (g.num_nodes,)
    assert sorted(perm.tolist()) == list(range(g.num_nodes))
    inv = inverse_permutation(perm)
    np.testing.assert_array_equal(perm[inv],
                                  np.arange(g.num_nodes))
    np.testing.assert_array_equal(inv[perm],
                                  np.arange(g.num_nodes))


@pytest.mark.parametrize("name", ALL)
def test_memoized_on_graph_instance(name):
    g = generators.rmat(5, 4, seed=2)
    assert reorder_permutation(g, name) is reorder_permutation(g, name)


def test_unknown_ordering_rejected():
    with pytest.raises(ValueError, match="unknown ordering"):
        reorder_permutation(GRAPHS["rmat"], "gorder")
    assert available_orderings()[0] == "none"
    assert set(available_orderings()) == {"none", *ALL}


@pytest.mark.parametrize("name", ALL)
@pytest.mark.parametrize("shape",
                         ["disconnected", "multi_edge",
                          "isolated_nodes", "rmat"])
def test_relabel_commutes_with_pagerank(name, shape):
    """pr(relabel(g))[perm] == pr(g) to 1e-6 L-inf: degree structure
    is label-invariant, so the float64 oracle on the relabeled graph,
    mapped back, must reproduce the original solve."""
    g = GRAPHS[shape]
    perm = reorder_permutation(g, name)
    pr = pagerank_reference(g, num_iterations=50)
    pr_rel = pagerank_reference(g.relabel(perm), num_iterations=50)
    # value of node u lives at slot perm[u] in the relabeled solve
    assert np.abs(pr_rel[perm] - pr).max() <= 1e-6
