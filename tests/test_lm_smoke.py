"""Per-LM-arch smoke tests: reduced config of the same family, one
forward/train/prefill/decode step on CPU; shape + finite checks."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get, LMConfig
from repro.models import transformer as tf
from repro.optim import AdamW

LM_ARCHS = ["mixtral-8x7b", "grok-1-314b", "stablelm-1.6b",
            "tinyllama-1.1b", "deepseek-67b"]


def smoke_cfg(name: str) -> LMConfig:
    return get(name).scaled()


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = smoke_cfg(arch)
    params = tf.init_lm(cfg, jax.random.key(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)),
                         dtype=jnp.int32)
    logits, aux = tf.forward(params, cfg, tokens)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "tinyllama-1.1b"])
def test_train_step_reduces_loss(arch, rng):
    cfg = smoke_cfg(arch)
    params = tf.init_lm(cfg, jax.random.key(1))
    opt = AdamW(lr=5e-3)
    opt_state = opt.init(params)
    step = jax.jit(tf.make_train_step(cfg, opt))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                         dtype=jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    for _ in range(5):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_consistency(arch, rng):
    """Greedy decode logits from (prefill + decode_step) must match the
    full-sequence forward logits position by position."""
    cfg = smoke_cfg(arch)
    params = tf.init_lm(cfg, jax.random.key(2))
    b, s = 2, 24
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                         dtype=jnp.int32)
    full_logits, _ = tf.forward(params, cfg, tokens, attn_path="dense")

    logits_p, cache = tf.prefill(params, cfg, tokens[:, :s - 1])
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(full_logits[:, s - 2], np.float32), rtol=5e-2,
        atol=6e-2)
    # pad cache to full length then decode the final token
    slots = cache["k"].shape[2]
    max_slots = min(s, cfg.window) if cfg.window else s
    pad = max_slots - slots
    if pad > 0:
        cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                 for k, v in cache.items()}
    logits_d, _ = tf.decode_step(params, cfg, cache, tokens[:, s - 1:],
                                 jnp.int32(s - 1))
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(full_logits[:, s - 1], np.float32), rtol=5e-2,
        atol=6e-2)


def test_swa_matches_window_mask(rng):
    """Mixtral-family SWA: chunked attention path == dense masked path."""
    cfg = smoke_cfg("mixtral-8x7b")
    params = tf.init_lm(cfg, jax.random.key(3))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 128)),
                         dtype=jnp.int32)
    lc, _ = tf.forward(params, cfg, tokens, attn_path="chunked")
    ld, _ = tf.forward(params, cfg, tokens, attn_path="dense")
    np.testing.assert_allclose(np.asarray(lc, np.float32),
                               np.asarray(ld, np.float32), rtol=5e-2,
                               atol=6e-2)


def test_param_count_formula():
    for arch in LM_ARCHS:
        cfg = get(arch)
        n = cfg.param_count()
        if arch == "grok-1-314b":
            assert 250e9 < n < 380e9, n
        if arch == "tinyllama-1.1b":
            assert 0.9e9 < n < 1.3e9, n
        if arch == "deepseek-67b":
            assert 55e9 < n < 75e9, n
        assert cfg.active_param_count() <= n
