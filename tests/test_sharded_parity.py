"""Parity property suite (ISSUE 2): the sharded fused PageRank loop
matches the single-device fused driver to <= 1e-6 Linf across random
graphs, shard counts {1, 2, 4, 8}, dangling policies, and node counts
not divisible by the shard count (isolated tail nodes included).

Runs in ONE subprocess with 8 forced host devices (like
test_distributed.py) so the device count never leaks into other tests;
hypothesis drives the example loop inside that subprocess.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the [test] extra")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax

    assert jax.device_count() == 8
    from hypothesis import given, settings, strategies as st
    from repro.graphs import generators
    from repro.graphs.formats import Graph
    from repro.core import SpMVEngine, pagerank

    @settings(max_examples=12, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([5, 6]),
           st.sampled_from([1, 2, 4, 8]), st.integers(0, 5),
           st.sampled_from(["none", "redistribute"]))
    def check_parity(seed, scale, shards, extra, dangling):
        base = generators.rmat(scale, 4, seed=seed % 1000)
        # tail of isolated nodes: exercises dangling + isolated nodes
        # and (usually) n not divisible by num_shards
        g = Graph(base.num_nodes + extra, base.src, base.dst)
        eng = SpMVEngine(g, method="pcpm_sharded", num_shards=shards)
        res_s = pagerank(g, engine=eng, num_iterations=12,
                         dangling=dangling)
        res_1 = pagerank(g, method="pcpm", num_iterations=12,
                         dangling=dangling)
        linf = float(np.abs(np.asarray(res_s.ranks)
                            - np.asarray(res_1.ranks)).max())
        assert linf <= 1e-6, (
            f"Linf {linf} seed={seed} scale={scale} shards={shards} "
            f"extra={extra} dangling={dangling}")
        assert res_s.iterations == res_1.iterations

    check_parity()
    print("sharded parity suite ok")
""")


def test_sharded_parity_properties():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "sharded parity suite ok" in proc.stdout
