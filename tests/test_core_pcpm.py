"""Core PCPM correctness: PNG layout invariants, engine equivalence,
PageRank vs dense oracle, paper-example graph.

Hypothesis-based property tests live in test_engine_props.py so this
module stays collectable without the [test] extra's ``hypothesis``.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.graphs import Graph, from_edge_list, generators
from repro.core import (Partitioning, build_png, block_png, SpMVEngine,
                        pagerank, pagerank_reference, comm_model,
                        pcpm_spmv_weighted, DevicePNG)


# The example graph of paper fig. 3a: 9 nodes (1-indexed in the figure;
# 0-indexed here), partitions of 3 nodes.
PAPER_EDGES = np.array([
    [6, 2], [7, 0], [7, 1], [7, 2],       # into partition 0 (nodes 0-2)
    [3, 4], [6, 3], [6, 4], [6, 5],       # into partition 1 (nodes 3-5)
    [2, 8], [7, 8],                        # into partition 2 (nodes 6-8)
], dtype=np.int32)


def paper_graph() -> Graph:
    return from_edge_list(9, PAPER_EDGES)


def dense_spmv(g: Graph, x: np.ndarray) -> np.ndarray:
    A = np.zeros((g.num_nodes, g.num_nodes))
    np.add.at(A, (g.src, g.dst), 1.0)
    return A.T @ x


# ---------------------------------------------------------------- layout
class TestPNGLayout:
    def test_paper_example_compression(self):
        g = paper_graph()
        png = build_png(g, Partitioning(9, 3))
        # fig. 5: the PNG has fewer edges than the original (10); from the
        # fig. 3b bins the unique (src, dst-partition) pairs are
        # {(7,P1),(8,P1),(4,P2),(7,P2),(3,P3),(8,P3)} -> 6 PNG edges.
        assert g.num_edges == 10
        assert png.num_updates == 6
        assert png.compression_ratio == pytest.approx(10 / 6)

    def test_update_stream_sorted_and_deduped(self):
        g = generators.rmat(8, 8, seed=1)
        part = Partitioning(g.num_nodes, 64)
        png = build_png(g, part)
        dstp = png.update_src * 0  # recompute per-update partition
        for p in range(png.num_partitions):
            s, e = png.update_offsets[p], png.update_offsets[p + 1]
            seg = png.update_src[s:e]
            assert np.all(np.diff(seg) > 0), "updates unique+sorted per bin"
        # every edge's update idx points at its own (src, dstp) pair
        for p in range(png.num_partitions):
            es, ee = png.edge_offsets[p], png.edge_offsets[p + 1]
            assert np.all(png.edge_dst[es:ee] // part.part_size == p)

    def test_edge_update_consistency(self):
        g = generators.uniform_random(200, 2000, seed=2)
        png = build_png(g, Partitioning(200, 32))
        # expanding update_src over edges must recover the edge multiset
        src_of_edge = png.update_src[png.edge_update_idx]
        got = set(zip(src_of_edge.tolist(), png.edge_dst.tolist()))
        want = set(zip(g.src.tolist(), g.dst.tolist()))
        assert got == want

    def test_blocked_view_roundtrip(self):
        g = generators.rmat(7, 6, seed=3)
        part = Partitioning(g.num_nodes, 32)
        png = build_png(g, part)
        blk = block_png(png)
        k = png.num_partitions
        # reconstruct y = A^T x from blocks
        x = np.random.default_rng(0).random(g.num_nodes).astype(np.float32)
        y = np.zeros(part.padded_nodes + 1, dtype=np.float64)
        for p in range(k):
            upd = np.concatenate([
                np.where(blk.update_src[p] >= 0,
                         x[np.maximum(blk.update_src[p], 0)], 0.0),
                [0.0]])  # extra zero row for padded edges
            vals = upd[blk.edge_update_local[p]]
            dst = np.minimum(blk.edge_dst_local[p], blk.part_size - 1)
            dst_glob = np.where(blk.edge_dst_local[p] == blk.part_size,
                                part.padded_nodes, p * blk.part_size + dst)
            np.add.at(y, dst_glob, vals)
        ref = dense_spmv(g, x)
        np.testing.assert_allclose(y[:g.num_nodes], ref, rtol=1e-5)

    def test_compression_monotone_in_part_size(self):
        g = generators.rmat(10, 16, seed=4)
        rs = [build_png(g, Partitioning(g.num_nodes, ps)).compression_ratio
              for ps in (64, 256, 1024)]
        assert rs[0] <= rs[1] <= rs[2]  # paper fig. 12

    def test_locality_reorder_raises_r(self):
        from repro.graphs import reorder
        g = generators.rmat(10, 16, seed=5)
        perm = reorder.hybrid_order(g)
        g2 = g.relabel(perm)
        ps = 128
        r0 = build_png(g, Partitioning(g.num_nodes, ps)).compression_ratio
        r1 = build_png(g2, Partitioning(g.num_nodes, ps)).compression_ratio
        assert r1 > r0  # paper table V: GOrder raises r


# ---------------------------------------------------------------- engines
class TestEngineEquivalence:
    @pytest.mark.parametrize("method", ["pdpr", "bvgas", "pcpm"])
    def test_spmv_matches_dense(self, method):
        g = generators.rmat(8, 8, seed=6)
        eng = SpMVEngine(g, method=method, part_size=64)
        x = jnp.asarray(
            np.random.default_rng(1).random(g.num_nodes, ).astype(np.float32))
        y = np.asarray(eng(x))
        ref = dense_spmv(g, np.asarray(x))
        np.testing.assert_allclose(y, ref, rtol=2e-4)

    def test_multivector_spmv(self):
        """GNN-style: x is (n, d)."""
        g = generators.uniform_random(300, 3000, seed=7)
        eng = SpMVEngine(g, method="pcpm", part_size=64)
        x = np.random.default_rng(2).random((300, 16)).astype(np.float32)
        y = np.asarray(eng(jnp.asarray(x)))
        ref = dense_spmv(g, x)
        np.testing.assert_allclose(y, ref, rtol=2e-4)

    def test_weighted_spmv(self):
        g = generators.uniform_random(100, 800, seed=8)
        part = Partitioning(100, 32)
        png = build_png(g, part)
        dev = DevicePNG.build(g, part, png)
        rng = np.random.default_rng(3)
        x = rng.random(100).astype(np.float32)
        # weights aligned with the PNG edge order
        w = rng.random(g.num_edges).astype(np.float32)
        y = np.asarray(pcpm_spmv_weighted(
            dev.update_src, dev.edge_update_idx, dev.edge_dst,
            jnp.asarray(w), jnp.asarray(x), num_nodes=100))
        A = np.zeros((100, 100))
        src_of_edge = png.update_src[png.edge_update_idx]
        np.add.at(A, (src_of_edge, png.edge_dst), w)
        np.testing.assert_allclose(y, A.T @ x, rtol=2e-4)

# --------------------------------------------------------------- pagerank
class TestPageRank:
    @pytest.mark.parametrize("method", ["pdpr", "bvgas", "pcpm"])
    def test_matches_dense_oracle(self, method):
        g = generators.rmat(7, 8, seed=9)
        res = pagerank(g, method=method, num_iterations=20, part_size=32)
        ref = pagerank_reference(g, num_iterations=20)
        np.testing.assert_allclose(np.asarray(res.ranks), ref, rtol=1e-3)

    def test_converges(self):
        g = generators.rmat(8, 8, seed=10)
        res = pagerank(g, method="pcpm", num_iterations=50, part_size=64,
                       tol=1e-5)
        assert res.residuals[-1] < res.residuals[0]
        assert res.iterations < 50

    def test_dangling_nodes(self):
        # node 3 has no out-edges
        g = from_edge_list(4, np.array([[0, 1], [1, 2], [2, 3], [0, 3]]))
        res = pagerank(g, method="pcpm", num_iterations=30, part_size=2)
        ref = pagerank_reference(g, num_iterations=30)
        np.testing.assert_allclose(np.asarray(res.ranks), ref, rtol=1e-4)

    def test_rank_sanity_hub(self):
        # star graph: everyone points at node 0
        n = 50
        e = np.stack([np.arange(1, n), np.zeros(n - 1, dtype=np.int64)], 1)
        g = from_edge_list(n, e)
        res = pagerank(g, method="pcpm", num_iterations=20, part_size=16)
        ranks = np.asarray(res.ranks)
        assert ranks[0] == ranks.max()


# ------------------------------------------------------------ comm model
class TestCommModel:
    def test_paper_kron_numbers(self):
        """§V-B: kron, d_v=4, l=64, 256KB partitions → BVGAS_ra ≈ 66.9M,
        PCPM_ra ≈ 0.26M."""
        p = comm_model.ModelParams(n=33_500_000, m=1_070_000_000, k=512,
                                   r=3.06)
        ra = comm_model.random_accesses(p)
        assert ra["bvgas"] == pytest.approx(66.9e6, rel=0.01)
        assert ra["pcpm"] == pytest.approx(0.26e6, rel=0.05)

    def test_pcpm_bounds(self):
        """§V-A: with r=1 PCPM ≈ BVGAS; with r=m/n PCPM reaches the PDPR
        lower bound m*d_i (up to the n/k² terms)."""
        p1 = comm_model.ModelParams(n=10 ** 6, m=3 * 10 ** 7, k=64, r=1.0)
        assert (comm_model.pcpm_bytes(p1)
                <= comm_model.bvgas_bytes(p1) * 1.01)
        r_opt = p1.m / p1.n
        p2 = comm_model.ModelParams(n=p1.n, m=p1.m, k=64, r=r_opt)
        lower = p1.m * p1.d_i
        assert comm_model.pcpm_bytes(p2) < 1.5 * lower

    def test_threshold_inequalities(self):
        p = comm_model.ModelParams(n=10 ** 6, m=16 * 10 ** 6, k=64, r=4.0,
                                   c_mr=0.5)
        assert comm_model.pcpm_wins_over_pdpr(p)
        # high locality: c_mr small → BVGAS loses, PCPM can still win
        p_loc = comm_model.ModelParams(n=10 ** 6, m=16 * 10 ** 6, k=64,
                                       r=8.0, c_mr=0.05)
        assert not comm_model.bvgas_wins_over_pdpr(p_loc)
        assert comm_model.pcpm_wins_over_pdpr(p_loc)
