"""Hypothesis property tests for engine equivalence (split out of
test_core_pcpm.py so that module collects without ``hypothesis``)."""
import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip(
    "hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.graphs import generators
from repro.core import SpMVEngine


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 7),
       st.sampled_from([4, 16, 64]))
def test_property_engines_agree(seed, scale, part_size):
    """Property: all engines compute the same y for random graphs,
    including empty partitions, self-loops, multi-edges."""
    g = generators.rmat(scale, 4, seed=seed)
    x = jnp.asarray(np.random.default_rng(seed).random(
        g.num_nodes).astype(np.float32))
    ys = [np.asarray(SpMVEngine(g, method=m, part_size=part_size)(x))
          for m in ("pdpr", "bvgas", "pcpm")]
    np.testing.assert_allclose(ys[0], ys[1], rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(ys[0], ys[2], rtol=2e-4, atol=1e-6)
