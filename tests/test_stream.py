"""Dynamic-graph subsystem tests (DESIGN.md §9).

- delta semantics: multiset removal, loud failure on missing edges,
  incremental fingerprint == from-scratch fingerprint;
- patch exactness: for every patchable backend, a spliced plan's
  arrays equal a from-scratch build EXACTLY (np.array_equal), for
  localized deltas (dirty-partition path) and scattered ones
  (threshold fallback);
- residual-push parity: ``update_ranks`` agrees with a cold full
  recompute to <= 1e-6 L-inf for random insert+delete deltas including
  dangling-node creation, under both dangling policies; mass is
  conserved under "redistribute";
- plan-cache hygiene: a stream of patched plans stays bounded by the
  cache limit and ``evict_plans`` releases the whole parent chain;
- the Session front door and the SlotScheduler rebind path.
"""
import numpy as np
import pytest

import repro
from repro.core import backends
from repro.core import plan as plan_mod
from repro.core.pagerank import pagerank, pagerank_reference
from repro.core.plan import (PlanConfig, build_plan, clear_plan_cache,
                             evict_plans, graph_fingerprint)
from repro.core.spmv import SpMVEngine
from repro.graphs import generators
from repro.graphs.formats import Graph
from repro.stream import (DynamicGraph, GraphDelta, apply_delta,
                          patch_plan, update_ranks)

PART = 128
PATCHABLE = ("pcpm", "pcpm_pallas", "pdpr", "bvgas")


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _graph(scale=10, ef=8, seed=3):
    return generators.rmat(scale, ef, seed=seed)


def _random_delta(g, rng, *, n_add=40, n_rem=40, dst_parts=None):
    """Random delta; ``dst_parts`` restricts destinations to the given
    partitions (localized delta, the dirty-partition regime)."""
    n, m = g.num_nodes, g.num_edges
    if dst_parts is None:
        rem_pool = np.arange(m)
        add_dst = rng.integers(0, n, size=n_add)
    else:
        in_parts = np.isin(g.dst // PART, dst_parts)
        rem_pool = np.flatnonzero(in_parts)
        p = rng.choice(dst_parts, size=n_add)
        add_dst = (p * PART + rng.integers(0, PART, size=n_add)).clip(
            0, n - 1)
    ridx = rng.choice(rem_pool, size=min(n_rem, len(rem_pool)),
                      replace=False)
    add = np.stack([rng.integers(0, n, size=n_add),
                    add_dst], axis=1).astype(np.int32)
    rem = np.stack([g.src[ridx], g.dst[ridx]], axis=1)
    return GraphDelta.of(add=add, remove=rem)


def _dangling_creation_delta(g, rng):
    """Remove EVERY out-edge of a well-connected node (creates a new
    dangling node) and insert edges out of a previously-dangling one."""
    deg = g.out_degree
    victim = int(np.argmax((deg > 0) & (deg < 8)))
    mask = g.src == victim
    rem = np.stack([g.src[mask], g.dst[mask]], axis=1)
    dangling = np.flatnonzero(deg == 0)
    add = np.empty((0, 2), dtype=np.int32)
    if len(dangling):
        u = int(dangling[0])
        add = np.array([[u, (u + 1) % g.num_nodes],
                        [u, (u + 7) % g.num_nodes]], dtype=np.int32)
    return GraphDelta.of(add=add, remove=rem)


# ---------------------------------------------------------------------------
# Delta semantics
# ---------------------------------------------------------------------------
def test_apply_delta_multiset_and_errors():
    g = Graph(4, np.array([0, 0, 1, 2], np.int32),
              np.array([1, 1, 2, 3], np.int32))
    # removing one copy of a multi-edge keeps the other
    g2 = apply_delta(g, GraphDelta.remove([[0, 1]]))
    assert g2.num_edges == 3
    assert ((g2.src == 0) & (g2.dst == 1)).sum() == 1
    # removing a non-existent edge fails loudly
    with pytest.raises(ValueError, match="cannot remove"):
        apply_delta(g, GraphDelta.remove([[3, 0]]))
    with pytest.raises(ValueError, match="cannot remove"):
        apply_delta(g, GraphDelta.remove([[0, 1], [0, 1], [0, 1]]))
    # out-of-range endpoints fail loudly
    with pytest.raises(ValueError, match="out of range"):
        apply_delta(g, GraphDelta.insert([[0, 4]]))
    # empty delta is a no-op
    g3 = apply_delta(g, GraphDelta.of())
    assert np.array_equal(g3.src, g.src)


def test_incremental_fingerprint_matches_fresh():
    rng = np.random.default_rng(0)
    g = _graph()
    graph_fingerprint(g)                       # memoize hash parts
    delta = _random_delta(g, rng)
    g2 = apply_delta(g, delta)
    fresh = Graph(g2.num_nodes, g2.src.copy(), g2.dst.copy())
    assert graph_fingerprint(g2) == graph_fingerprint(fresh)
    assert graph_fingerprint(g2) != graph_fingerprint(g)
    # permutation-invariance survives the incremental path
    perm = rng.permutation(g2.num_edges)
    shuf = Graph(g2.num_nodes, g2.src[perm], g2.dst[perm])
    assert graph_fingerprint(shuf) == graph_fingerprint(g2)


def test_dynamic_graph_tracks_dirtiness():
    rng = np.random.default_rng(1)
    g = _graph()
    dyn = DynamicGraph(g)
    d1 = _random_delta(g, rng, dst_parts=np.array([1, 2]))
    dyn.apply(d1)
    assert set(dyn.dirty_partitions(PART)) <= {1, 2}
    assert dyn.version == 1 and dyn.base_graph is g
    d2 = _random_delta(dyn.graph, rng, dst_parts=np.array([5]))
    dyn.apply(d2)
    assert set(dyn.dirty_partitions(PART)) <= {1, 2, 5}
    assert len(dyn.touched_sources()) > 0
    dyn.mark_clean()
    assert dyn.dirty_partitions(PART).size == 0
    assert dyn.base_graph is dyn.graph


# ---------------------------------------------------------------------------
# Patch exactness
# ---------------------------------------------------------------------------
def _assert_plans_equal(a, b, method):
    for field in ("csc_src", "csc_dst", "bv_src", "bv_dst"):
        x, y = getattr(a, field), getattr(b, field)
        assert (x is None) == (y is None)
        if x is not None:
            assert np.array_equal(x, y), (method, field)
    if a.png is not None:
        for f in ("update_src", "update_offsets", "edge_update_idx",
                  "edge_dst", "edge_offsets"):
            assert np.array_equal(getattr(a.png, f),
                                  getattr(b.png, f)), (method, f)
    if a.schedule is not None:
        for f in ("edge_update_idx_padded", "piece_start", "piece_end",
                  "piece_dst"):
            assert np.array_equal(getattr(a.schedule, f),
                                  getattr(b.schedule, f)), (method, f)
    if a.blocked is not None:
        for f in ("update_src", "edge_update_local", "edge_dst_local"):
            assert np.array_equal(getattr(a.blocked, f),
                                  getattr(b.blocked, f)), (method, f)


@pytest.mark.parametrize("method", PATCHABLE)
@pytest.mark.parametrize("localized", [True, False])
def test_patch_matches_scratch_build(method, localized):
    rng = np.random.default_rng(7)
    g = _graph()
    cfg = PlanConfig(method=method, part_size=PART)
    plan = build_plan(g, cfg)
    dst_parts = np.array([0, 3]) if localized else None
    delta = _random_delta(g, rng, dst_parts=dst_parts)
    g2 = apply_delta(g, delta)
    patched = patch_plan(plan, delta, g2)
    scratch = backends.get_backend(method).build_plan(g2, cfg)
    assert patched.num_edges == g2.num_edges
    assert patched.graph_fp == graph_fingerprint(g2)
    assert patched.parent_fp == graph_fingerprint(g)
    _assert_plans_equal(patched, scratch, method)
    if localized:
        # the localized delta must exercise the splice, not the
        # full-rebuild fallback
        assert repro.plan_cache_stats().plan_patches >= 1


@pytest.mark.parametrize("method", PATCHABLE)
def test_patch_dangling_and_chain(method):
    """Chained deltas (incl. dangling-node creation) stay exact."""
    rng = np.random.default_rng(11)
    g = _graph()
    cfg = PlanConfig(method=method, part_size=PART)
    plan = build_plan(g, cfg)
    cur_g = g
    for i in range(3):
        delta = (_dangling_creation_delta(cur_g, rng) if i == 1
                 else _random_delta(cur_g, rng,
                                    dst_parts=np.array([i, i + 4])))
        g2 = apply_delta(cur_g, delta)
        plan = patch_plan(plan, delta, g2)
        cur_g = g2
    scratch = backends.get_backend(method).build_plan(cur_g, cfg)
    _assert_plans_equal(plan, scratch, method)


def test_patched_plan_spmv_agrees():
    rng = np.random.default_rng(23)
    g = _graph()
    delta = _random_delta(g, rng, dst_parts=np.array([2]))
    g2 = apply_delta(g, delta)
    x = rng.random(g.num_nodes).astype(np.float32)
    ys = {}
    for method in PATCHABLE:
        plan = build_plan(g, PlanConfig(method=method, part_size=PART))
        patched = patch_plan(plan, delta, g2)
        ys[method] = np.asarray(SpMVEngine(g2, plan=patched)(x))
    for method in PATCHABLE[1:]:
        # engines reduce in different orders; tolerance is f32 rounding
        np.testing.assert_allclose(ys[method], ys["pcpm"], rtol=1e-5,
                                   atol=2e-5)


def test_png_shared_across_patched_pcpm_and_pallas():
    rng = np.random.default_rng(29)
    g = _graph()
    p1 = build_plan(g, PlanConfig(method="pcpm", part_size=PART))
    p2 = build_plan(g, PlanConfig(method="pcpm_pallas", part_size=PART))
    assert p1.png is p2.png
    delta = _random_delta(g, rng, dst_parts=np.array([1]))
    g2 = apply_delta(g, delta)
    q1 = patch_plan(p1, delta, g2)
    q2 = patch_plan(p2, delta, g2)
    assert q1.png is q2.png        # one spliced PNG serves both


# ---------------------------------------------------------------------------
# Residual-push parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dangling", ["none", "redistribute"])
def test_update_ranks_matches_cold_recompute(dangling):
    rng = np.random.default_rng(13)
    g = _graph(scale=11)
    plan = build_plan(g, PlanConfig(method="pcpm", part_size=PART))
    eng = SpMVEngine(g, plan=plan)
    prev = pagerank(g, engine=eng, num_iterations=400, tol=1e-10,
                    dangling=dangling)
    delta = _random_delta(g, rng, n_add=30, n_rem=30)
    # fold in a dangling-node creation too
    delta2 = _dangling_creation_delta(g, rng)
    delta = GraphDelta.of(
        add=np.stack([np.concatenate([delta.add_src, delta2.add_src]),
                      np.concatenate([delta.add_dst, delta2.add_dst])],
                     axis=1),
        remove=np.stack(
            [np.concatenate([delta.rem_src, delta2.rem_src]),
             np.concatenate([delta.rem_dst, delta2.rem_dst])], axis=1))
    g2 = apply_delta(g, delta)
    patched = patch_plan(plan, delta, g2)
    warm = update_ranks(patched, delta, prev.ranks, g_old=g, g_new=g2,
                        damping=0.85, dangling=dangling, tol=1e-9)
    cold = pagerank(g2, engine=SpMVEngine(g2, plan=patched),
                    num_iterations=400, tol=1e-10, dangling=dangling)
    err = np.abs(np.asarray(warm.ranks) - np.asarray(cold.ranks)).max()
    assert err <= 1e-6, err
    ref = pagerank_reference(g2, num_iterations=300, dangling=dangling)
    assert np.abs(np.asarray(warm.ranks) - ref).max() <= 1e-5
    if dangling == "redistribute":
        # mass conservation: pr + push(residual) keeps total mass 1
        assert abs(float(np.asarray(warm.ranks).sum()) - 1.0) < 1e-4


def test_update_ranks_empty_delta_is_noop():
    g = _graph()
    plan = build_plan(g, PlanConfig(method="pcpm", part_size=PART))
    prev = pagerank(g, engine=SpMVEngine(g, plan=plan),
                    num_iterations=50)
    res = update_ranks(plan, GraphDelta.of(), prev.ranks, g_old=g,
                       g_new=g)
    assert res.iterations == 0
    np.testing.assert_array_equal(np.asarray(res.ranks),
                                  np.asarray(prev.ranks))


def test_update_ranks_dense_fallback():
    """A delta heavy enough to displace > dense_threshold of the rank
    mass goes through the fused warm start and still agrees."""
    rng = np.random.default_rng(17)
    g = _graph(scale=10)
    n, m = g.num_nodes, g.num_edges
    plan = build_plan(g, PlanConfig(method="pcpm", part_size=PART))
    prev = pagerank(g, engine=SpMVEngine(g, plan=plan),
                    num_iterations=400, tol=1e-10)
    # rewire 30% of the edges
    k = m // 3
    ridx = rng.choice(m, size=k, replace=False)
    delta = GraphDelta.of(
        add=np.stack([rng.integers(0, n, k), rng.integers(0, n, k)],
                     axis=1).astype(np.int32),
        remove=np.stack([g.src[ridx], g.dst[ridx]], axis=1))
    g2 = apply_delta(g, delta)
    patched = patch_plan(plan, delta, g2)
    warm = update_ranks(patched, delta, prev.ranks, g_old=g, g_new=g2,
                        tol=1e-9, max_push=400)
    cold = pagerank(g2, engine=SpMVEngine(g2, plan=patched),
                    num_iterations=400, tol=1e-10)
    err = np.abs(np.asarray(warm.ranks) - np.asarray(cold.ranks)).max()
    assert err <= 1e-6, err


# ---------------------------------------------------------------------------
# Plan-cache hygiene under a delta stream
# ---------------------------------------------------------------------------
def test_patch_stream_stays_bounded_and_chain_evicts():
    rng = np.random.default_rng(19)
    g = _graph()
    cfg = PlanConfig(method="pcpm", part_size=PART)
    plan = build_plan(g, cfg)
    graphs = [g]
    for i in range(6):
        delta = _random_delta(graphs[-1], rng,
                              dst_parts=np.array([i % 4]))
        g2 = apply_delta(graphs[-1], delta)
        plan = patch_plan(plan, delta, g2)
        graphs.append(g2)
        assert len(plan_mod._PLAN_CACHE) <= plan_mod.MAX_CACHED_PLANS
    # the whole version chain is cached (7 graphs) ...
    assert len(plan_mod._PLAN_CACHE) == 7
    # ... and evicting ANY version releases the entire chain
    evicted = evict_plans(graphs[3])
    assert len(plan_mod._PLAN_CACHE) == 0
    assert len(plan_mod._PNG_CACHE) == 0
    assert evicted >= 7
    # a g_new inconsistent with the delta is rejected, not patched
    plan = build_plan(g, cfg)
    d_real = _random_delta(g, rng, dst_parts=np.array([0]))
    d_other = _random_delta(g, rng, dst_parts=np.array([0]))
    with pytest.raises(ValueError, match="not g_old"):
        patch_plan(plan, d_other, apply_delta(g, d_real))
    patch_plan(plan, d_real, apply_delta(g, d_real))


def test_patch_stream_respects_lru_cap(monkeypatch):
    """A stream of patched plans longer than the cache bound cannot pin
    unbounded memory."""
    rng = np.random.default_rng(31)
    monkeypatch.setattr(plan_mod, "MAX_CACHED_PLANS", 4)
    monkeypatch.setattr(plan_mod, "MAX_CACHED_PNGS", 4)
    g = _graph()
    cfg = PlanConfig(method="pcpm", part_size=PART)
    plan = build_plan(g, cfg)
    cur = g
    for i in range(10):
        delta = _random_delta(cur, rng, dst_parts=np.array([i % 4]))
        nxt = apply_delta(cur, delta)
        plan = patch_plan(plan, delta, nxt)
        cur = nxt
        assert len(plan_mod._PLAN_CACHE) <= 4
        assert len(plan_mod._PNG_CACHE) <= 4


# ---------------------------------------------------------------------------
# Capability flags
# ---------------------------------------------------------------------------
def test_supports_incremental_flags():
    for method in PATCHABLE:
        assert backends.get_backend(method).supports_incremental
    assert not backends.get_backend("pcpm_sharded").supports_incremental


def test_sharded_delta_falls_back_to_rebuild():
    """patch_plan on a backend without a patcher still produces a
    correct, chained, cached plan (full rebuild)."""
    rng = np.random.default_rng(37)
    g = _graph()
    cfg = PlanConfig(method="pcpm_sharded", part_size=PART,
                     num_shards=1)
    plan = build_plan(g, cfg)
    delta = _random_delta(g, rng, dst_parts=np.array([1]))
    g2 = apply_delta(g, delta)
    patched = patch_plan(plan, delta, g2)
    assert patched.parent_fp == graph_fingerprint(g)
    assert patched.graph_fp == graph_fingerprint(g2)
    assert repro.plan_cache_stats().plan_patches == 0   # rebuilt


# ---------------------------------------------------------------------------
# Session front door
# ---------------------------------------------------------------------------
def test_session_apply_delta_warm_parity():
    rng = np.random.default_rng(41)
    g = _graph(scale=11)
    sess = repro.open(g, repro.EngineConfig(method="pcpm",
                                            part_size=PART))
    # 1e-6 is the tightest tolerance the cold driver can VERIFY in
    # f32 (its step-diff floor is ~2e-7); the warm gate requires the
    # prior solve to have achieved the requested tol
    sess.pagerank(num_iterations=400, tol=1e-6)
    d1 = _random_delta(g, rng, dst_parts=np.array([2, 9]))
    d2 = _random_delta(apply_delta(g, d1), rng,
                       dst_parts=np.array([5]))
    sess.apply_delta(d1)
    sess.apply_delta(d2)          # two deltas accumulate
    warm = sess.pagerank(warm=True, tol=1e-6, num_iterations=400)
    cold = pagerank(sess.graph, engine=sess.engine,
                    num_iterations=400, tol=1e-10)
    err = np.abs(np.asarray(warm.ranks) - np.asarray(cold.ranks)).max()
    assert err <= 1e-6, err
    assert warm.iterations < 400       # genuinely incremental
    assert repro.plan_cache_stats().plan_patches >= 2


def test_session_warm_unconverged_prior_falls_back_cold():
    """The sparse residual seed is only exact over a CONVERGED prior
    solve — warm=True after a 20-iteration tol=0 run must not silently
    deliver 1e-4-accurate ranks while reporting a 1e-8 residual."""
    rng = np.random.default_rng(47)
    g = _graph(scale=11)
    sess = repro.open(g, repro.EngineConfig(method="pcpm",
                                            part_size=PART))
    sess.pagerank(num_iterations=20, tol=0.0)     # NOT converged
    sess.apply_delta(_random_delta(g, rng, dst_parts=np.array([1])))
    warm = sess.pagerank(warm=True, tol=1e-8, num_iterations=400)
    cold = pagerank(sess.graph, engine=sess.engine,
                    num_iterations=400, tol=1e-10)
    err = np.abs(np.asarray(warm.ranks) - np.asarray(cold.ranks)).max()
    assert err <= 1e-6, err       # fell back to an honest cold solve


def test_session_warm_without_solve_falls_back_cold():
    g = _graph()
    sess = repro.open(g, repro.EngineConfig(method="pcpm",
                                            part_size=PART,
                                            num_iterations=30))
    res = sess.pagerank(warm=True)     # no previous solve
    ref = pagerank_reference(g, num_iterations=30)
    assert np.abs(np.asarray(res.ranks) - ref).max() <= 1e-5


# ---------------------------------------------------------------------------
# Serving across a delta
# ---------------------------------------------------------------------------
def test_scheduler_apply_delta_keeps_inflight_queries():
    rng = np.random.default_rng(43)
    g = _graph(scale=11)
    n = g.num_nodes
    sess = repro.open(g, repro.EngineConfig(method="pcpm",
                                            part_size=PART))
    sch = sess.serve(slots=2, chunk=4)
    sch.submit(tol=1e-7, max_iters=500)                 # uniform
    sch.submit(top_k=5, tol=1e-7, max_iters=500)        # top-k
    sch.step()
    assert sch.active_slots == 2
    delta = _random_delta(g, rng, dst_parts=np.array([3]))
    g2 = apply_delta(g, delta)
    sch.apply_delta(delta, g_new=g2)
    out = sch.run_until_drained()
    assert len(out) == 2
    # one stepper re-lower, zero admit retraces, state carried over
    assert sch.trace_count == 2
    assert sch.admit_trace_count == 1
    assert sch.rebind_count == 1
    uni = [r for r in out if r.top_ids is None][0]
    ref = pagerank_reference(g2, num_iterations=300)
    assert np.abs(uni.ranks - ref).max() <= 1e-5
    # queries submitted after the delta reuse the same executables
    sch.submit(tol=1e-6, max_iters=200)
    sch.run_until_drained()
    assert sch.trace_count == 2 and sch.admit_trace_count == 1


# ---------------------------------------------------------------------------
# Warm starts on locality-reordered plans
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("reorder", ["degree", "hybrid"])
def test_session_reorder_warm_composes(reorder):
    """``warm=True`` on a ``reorder != none`` session composes the
    stored original-space ranks through ``reorder_perm`` (internal
    space in, gather back out) instead of cold-falling-back — the
    labeling is the ONLY difference, so parity and incrementality must
    match the unreordered warm path exactly."""
    rng = np.random.default_rng(43)
    g = _graph(scale=11)
    sess = repro.open(g, repro.EngineConfig(method="pcpm",
                                            part_size=PART,
                                            reorder=reorder))
    assert sess.plan.reorder_perm is not None
    sess.pagerank(num_iterations=400, tol=1e-6)
    # pure re-solve: the stored ranks already satisfy tol, so the warm
    # path answers in ZERO sweeps (a cold fallback would power-iterate
    # from scratch — the pre-fix behavior)
    again = sess.pagerank(warm=True, tol=1e-6, num_iterations=400)
    assert again.iterations == 0
    d1 = _random_delta(g, rng, dst_parts=np.array([2, 9]))
    d2 = _random_delta(apply_delta(g, d1), rng,
                       dst_parts=np.array([5]))
    sess.apply_delta(d1)
    sess.apply_delta(d2)
    warm = sess.pagerank(warm=True, tol=1e-6, num_iterations=400)
    cold = pagerank(sess.graph, engine=sess.engine,
                    num_iterations=400, tol=1e-10)
    err = np.abs(np.asarray(warm.ranks) - np.asarray(cold.ranks)).max()
    assert err <= 1e-6, err
    assert 0 < warm.iterations < 400   # a push, not a cold re-run


def test_session_reorder_warm_unconverged_still_falls_back():
    """The honest fallback survives the reorder composition: an
    unconverged prior on a reordered plan still cold-runs."""
    rng = np.random.default_rng(47)
    g = _graph(scale=11)
    sess = repro.open(g, repro.EngineConfig(method="pcpm",
                                            part_size=PART,
                                            reorder="hybrid"))
    sess.pagerank(num_iterations=20, tol=0.0)     # NOT converged
    sess.apply_delta(_random_delta(g, rng, dst_parts=np.array([1])))
    warm = sess.pagerank(warm=True, tol=1e-8, num_iterations=400)
    cold = pagerank(sess.graph, engine=sess.engine,
                    num_iterations=400, tol=1e-10)
    err = np.abs(np.asarray(warm.ranks) - np.asarray(cold.ranks)).max()
    assert err <= 1e-6, err
