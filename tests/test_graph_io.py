"""Round-trip tests for graphs/io.py (ISSUE 8 satellite).

save/load must preserve dtypes exactly (int32 edges — a silently
widened dtype would fail Graph's front-door validation downstream),
handle the empty-edge graph, and pair with install_plan so a graph +
plan persisted together warm-load with ZERO fresh plan builds.
"""
import numpy as np

import repro
from repro.core.plan import (graph_fingerprint, install_plan,
                             plan_cache_stats)
from repro.graphs import generators, io
from repro.graphs.formats import Graph


def test_round_trip_preserves_everything(tmp_path):
    g = generators.rmat(6, 5, seed=4)
    p = str(tmp_path / "g.npz")
    io.save(p, g)
    g2 = io.load(p)
    assert g2.num_nodes == g.num_nodes
    assert g2.src.dtype == np.int32 and g2.dst.dtype == np.int32
    np.testing.assert_array_equal(g2.src, g.src)
    np.testing.assert_array_equal(g2.dst, g.dst)
    # identical edge sets fingerprint identically (cache-key contract)
    assert graph_fingerprint(g2) == graph_fingerprint(g)


def test_empty_edge_graph_round_trip(tmp_path):
    empty = np.array([], dtype=np.int32)
    g = Graph(7, empty, empty.copy())
    p = str(tmp_path / "empty.npz")
    io.save(p, g)
    g2 = io.load(p)
    assert g2.num_nodes == 7
    assert g2.src.size == 0 and g2.src.dtype == np.int32


def test_graph_plus_plan_warm_load(tmp_path):
    """The server-restart path: persist graph AND plan, reload both in
    a 'new process', install, open a session — plan_builds stays 0."""
    g = generators.rmat(6, 5, seed=8)
    cfg = repro.EngineConfig(part_size=32, reorder="degree")
    sess = repro.open(g, cfg)
    gp, pp = str(tmp_path / "g.npz"), str(tmp_path / "g.plan.npz")
    io.save(gp, g)
    sess.plan.save(pp)

    g2 = io.load(gp)
    plan2 = io.load_plan(pp)
    np.testing.assert_array_equal(plan2.reorder_perm,
                                  sess.plan.reorder_perm)
    install_plan(g2, plan2)
    before = plan_cache_stats().plan_builds
    sess2 = repro.open(g2, cfg)
    assert plan_cache_stats().plan_builds == before
    np.testing.assert_allclose(
        np.asarray(sess2.pagerank(num_iterations=30, tol=0.0).ranks),
        np.asarray(sess.pagerank(num_iterations=30, tol=0.0).ranks),
        atol=1e-7)
