"""Distributed PCPM tests — run in a subprocess with 8 host devices so
the forced device count never leaks into other tests' jax runtime."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    assert jax.device_count() == 8
    from repro.graphs import generators
    from repro.core.distributed import (build_sharded_png,
                                        pcpm_all_to_all_spmv,
                                        edge_cut_spmv, pad_to_shards,
                                        distributed_pagerank)
    from repro.core import pagerank_reference

    mesh = jax.make_mesh((8,), ("shards",))
    g = generators.rmat(9, 8, seed=11)
    n = g.num_nodes
    A = np.zeros((n, n)); np.add.at(A, (g.src, g.dst), 1.0)

    layout = build_sharded_png(g, 8)
    assert layout.wire_compression >= 1.0
    print("wire compression r =", round(layout.wire_compression, 3))

    rng = np.random.default_rng(0)
    x = rng.random(n).astype(np.float32)
    xp = jnp.asarray(pad_to_shards(x, layout))

    # 1) PCPM distributed SpMV == dense oracle
    spmv = pcpm_all_to_all_spmv(layout, mesh, "shards")
    y = np.asarray(spmv(xp))[:n]
    np.testing.assert_allclose(y, A.T @ x, rtol=2e-4, atol=1e-5)
    print("pcpm spmv ok")

    # 2) multi-vector (GNN feature) SpMV
    xf = rng.random((n, 8)).astype(np.float32)
    yf = np.asarray(spmv(jnp.asarray(pad_to_shards(xf, layout))))[:n]
    np.testing.assert_allclose(yf, A.T @ xf, rtol=2e-4, atol=1e-5)
    print("pcpm multivector ok")

    # 3) edge-cut (BVGAS-analogue) baseline agrees
    spmv_ec = edge_cut_spmv(g, 8, mesh, "shards")
    y2 = np.asarray(spmv_ec(xp))[:n]
    np.testing.assert_allclose(y2, A.T @ x, rtol=2e-4, atol=1e-5)
    print("edge-cut spmv ok")

    # 4) wire bytes: PCPM sends fewer update values than edge-cut
    assert layout.wire_updates <= layout.wire_edges
    print("wire", layout.wire_updates, "<=", layout.wire_edges)

    # 5) distributed pagerank == dense oracle
    pr = distributed_pagerank(g, mesh, "shards", num_iterations=15)
    ref = pagerank_reference(g, num_iterations=15)
    np.testing.assert_allclose(pr, ref, rtol=1e-3, atol=1e-7)
    print("distributed pagerank ok")

    # 6) HLO actually contains an all-to-all (not a gather fallback)
    lowered = jax.jit(spmv).lower(
        jax.ShapeDtypeStruct(xp.shape, xp.dtype))
    txt = lowered.compile().as_text()
    assert "all-to-all" in txt, "expected all-to-all collective"
    print("collective check ok")
""")


@pytest.mark.parametrize("case", ["full"])
def test_distributed_pcpm(case, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for marker in ["pcpm spmv ok", "pcpm multivector ok",
                   "edge-cut spmv ok", "distributed pagerank ok",
                   "collective check ok"]:
        assert marker in proc.stdout
