"""Distributed PCPM tests — run in a subprocess with 8 host devices so
the forced device count never leaks into other tests' jax runtime."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    assert jax.device_count() == 8
    from repro.graphs import generators
    from repro.core.distributed import (build_sharded_png,
                                        pcpm_all_to_all_spmv,
                                        edge_cut_spmv, pad_to_shards,
                                        distributed_pagerank,
                                        sharded_power_iteration)
    from repro.core import SpMVEngine, pagerank, pagerank_reference
    from repro.serve import PageRankServer

    mesh = jax.make_mesh((8,), ("shards",))
    g = generators.rmat(9, 8, seed=11)
    n = g.num_nodes
    A = np.zeros((n, n)); np.add.at(A, (g.src, g.dst), 1.0)

    layout = build_sharded_png(g, 8)
    assert layout.wire_compression >= 1.0
    print("wire compression r =", round(layout.wire_compression, 3))

    rng = np.random.default_rng(0)
    x = rng.random(n).astype(np.float32)
    xp = jnp.asarray(pad_to_shards(x, layout))

    # 1) PCPM distributed SpMV (blocked local gather) == dense oracle
    spmv = pcpm_all_to_all_spmv(layout, mesh, "shards")
    y = np.asarray(spmv(xp))[:n]
    np.testing.assert_allclose(y, A.T @ x, rtol=2e-4, atol=1e-5)
    # the flat segment-sum fallback agrees with the blocked schedule
    y_flat = np.asarray(pcpm_all_to_all_spmv(
        layout, mesh, "shards", blocked=False)(xp))[:n]
    np.testing.assert_allclose(y, y_flat, rtol=1e-4, atol=1e-6)
    print("pcpm spmv ok")

    # 2) multi-vector (GNN feature) SpMV
    xf = rng.random((n, 8)).astype(np.float32)
    yf = np.asarray(spmv(jnp.asarray(pad_to_shards(xf, layout))))[:n]
    np.testing.assert_allclose(yf, A.T @ xf, rtol=2e-4, atol=1e-5)
    print("pcpm multivector ok")

    # 3) edge-cut (BVGAS-analogue) baseline agrees
    spmv_ec = edge_cut_spmv(g, 8, mesh, "shards")
    y2 = np.asarray(spmv_ec(xp))[:n]
    np.testing.assert_allclose(y2, A.T @ x, rtol=2e-4, atol=1e-5)
    print("edge-cut spmv ok")

    # 4) wire bytes: PCPM sends fewer update values than edge-cut
    assert layout.wire_updates <= layout.wire_edges
    print("wire", layout.wire_updates, "<=", layout.wire_edges)

    # 5) sharded fused pagerank == dense oracle, and matches the
    #    single-device fused driver to 1e-6 Linf
    res = distributed_pagerank(g, mesh, "shards", num_iterations=15,
                               layout=layout)
    ref = pagerank_reference(g, num_iterations=15)
    np.testing.assert_allclose(np.asarray(res.ranks), ref, rtol=1e-3,
                               atol=1e-7)
    sd = pagerank(g, method="pcpm", num_iterations=15)
    linf = float(np.abs(np.asarray(res.ranks)
                        - np.asarray(sd.ranks)).max())
    assert linf <= 1e-6, linf
    print("distributed pagerank ok")

    # 6) device-side early exit: sharded loop stops at the same
    #    iteration as the single-device fused driver (psum residual
    #    agreement)
    res_t = distributed_pagerank(g, mesh, "shards", num_iterations=80,
                                 tol=1e-6, layout=layout)
    sd_t = pagerank(g, method="pcpm", num_iterations=80, tol=1e-6)
    assert res_t.iterations == sd_t.iterations < 80, (
        res_t.iterations, sd_t.iterations)
    np.testing.assert_allclose(res_t.residuals, sd_t.residuals,
                               rtol=5e-3, atol=1e-7)
    print("early exit ok at", res_t.iterations)

    # 7) dangling regression (the seed's distributed path dropped sink
    #    mass and rebuilt the pad mask on host every iteration): a
    #    graph with sinks keeps total mass 1 under redistribution and
    #    matches the dense oracle
    g_sink = generators.rmat(8, 4, seed=21)
    assert (np.asarray(g_sink.out_degree) == 0).any(), "need sinks"
    res_d = distributed_pagerank(g_sink, mesh, "shards",
                                 num_iterations=25,
                                 dangling="redistribute")
    ref_d = pagerank_reference(g_sink, num_iterations=25,
                               dangling="redistribute")
    np.testing.assert_allclose(np.asarray(res_d.ranks), ref_d,
                               rtol=1e-3, atol=1e-7)
    mass = float(np.asarray(res_d.ranks).sum())
    assert abs(mass - 1.0) < 1e-5, mass
    # and it matches the single-device fused loop with the same policy
    sd_d = pagerank(g_sink, method="pcpm", num_iterations=25,
                    dangling="redistribute")
    assert float(np.abs(np.asarray(res_d.ranks)
                        - np.asarray(sd_d.ranks)).max()) <= 1e-6
    print("dangling redistribution ok")

    # 8) public API: SpMVEngine(method="pcpm_sharded") end-to-end
    #    through pagerank()
    eng = SpMVEngine(g, method="pcpm_sharded")
    res_e = pagerank(g, engine=eng, num_iterations=15)
    np.testing.assert_allclose(np.asarray(res_e.ranks), ref, rtol=1e-3,
                               atol=1e-7)
    # raw SpMV through the engine wrapper too
    y_e = np.asarray(eng(jnp.asarray(x)))
    np.testing.assert_allclose(y_e, A.T @ x, rtol=2e-4, atol=1e-5)
    print("pcpm_sharded engine ok")

    # 9) sharded serving: AOT-compiled on the mesh, zero retrace
    srv = PageRankServer(g, sharded=True, num_iterations=10)
    assert srv.trace_count == 1
    for _ in range(3):
        pr, it, _ = srv.query()
        assert it == 10
    assert srv.trace_count == 1
    np.testing.assert_allclose(
        np.asarray(pr), pagerank_reference(g, num_iterations=10),
        rtol=1e-3, atol=1e-7)
    print("sharded server ok")

    # 10) HLO: the loop is one while with an all-to-all inside (not a
    #     gather fallback), and spmv keeps its collective
    run = sharded_power_iteration(layout, mesh, "shards",
                                  num_iterations=5, tol=1e-6)
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("shards"))
    spec = jax.ShapeDtypeStruct((layout.padded_nodes,), jnp.float32,
                                sharding=sh)
    txt = run.lower(spec, spec, spec).compile().as_text()
    assert "all-to-all" in txt, "expected all-to-all collective"
    assert "while" in txt, "expected fused while loop"
    lowered = jax.jit(spmv).lower(
        jax.ShapeDtypeStruct(xp.shape, xp.dtype))
    assert "all-to-all" in lowered.compile().as_text()
    print("collective check ok")

    # 11) device residency: the sharded loop runs to completion without
    #     a single device->host transfer
    n_pad = layout.padded_nodes
    pr0 = jax.device_put(jnp.full((n_pad,), 1.0 / n, jnp.float32)
                         * (jnp.arange(n_pad) < n), sh)
    base = jax.device_put(jnp.full((n_pad,), 0.15 / n, jnp.float32)
                          * (jnp.arange(n_pad) < n), sh)
    from repro.core.distributed import _padded_inv_degree
    inv_deg = jax.device_put(
        jnp.asarray(_padded_inv_degree(g, layout)), sh)
    with jax.transfer_guard_device_to_host("disallow"):
        pr, it, resid = run(pr0, inv_deg, base)
        pr.block_until_ready()
    print("no host transfers ok")

    # 12) continuous-batching scheduler on the 8-shard mesh: mixed
    #     per-slot convergence, zero retraces, parity with the
    #     single-device scheduler and the dense oracle
    from repro.serve import SlotScheduler
    sch = SlotScheduler(g, slots=4, sharded=True, chunk=4)
    assert sch.sharded and sch.engine.mesh.devices.size == 8
    uid_u = sch.submit(tol=0.0, max_iters=15)
    seeds = np.zeros(n, np.float32); seeds[3] = 1.0
    uid_p = sch.submit(seeds, tol=1e-6, max_iters=200)
    uid_f = sch.submit(seeds, tol=1e-3, max_iters=200)
    uid_k = sch.submit(tol=0.0, max_iters=15, top_k=25)
    by = {r.uid: r for r in sch.run_until_drained()}
    assert sch.trace_count == 1 and sch.admit_trace_count == 1
    ref15 = pagerank_reference(g, num_iterations=15)
    assert np.abs(by[uid_u].ranks - ref15).max() <= 1e-5
    assert by[uid_f].iterations < by[uid_p].iterations  # early exit
    np.testing.assert_allclose(by[uid_k].top_scores,
                               np.sort(ref15)[-25:][::-1], atol=1e-5)
    assert (by[uid_k].top_ids < n).all()     # pad rows masked out
    # parity with the single-device scheduler at identical budgets
    sd = SlotScheduler(g, slots=4, method="pcpm", chunk=4)
    sd_u = sd.submit(tol=0.0, max_iters=15)
    sd_p = sd.submit(seeds, tol=1e-6, max_iters=200)
    sd_by = {r.uid: r for r in sd.run_until_drained()}
    assert by[uid_p].iterations == sd_by[sd_p].iterations
    assert np.abs(by[uid_u].ranks - sd_by[sd_u].ranks).max() <= 1e-6
    assert np.abs(by[uid_p].ranks - sd_by[sd_p].ranks).max() <= 1e-6
    print("sharded scheduler ok")
""")


@pytest.mark.parametrize("case", ["full"])
def test_distributed_pcpm(case, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for marker in ["pcpm spmv ok", "pcpm multivector ok",
                   "edge-cut spmv ok", "distributed pagerank ok",
                   "early exit ok", "dangling redistribution ok",
                   "pcpm_sharded engine ok", "sharded server ok",
                   "collective check ok", "no host transfers ok",
                   "sharded scheduler ok"]:
        assert marker in proc.stdout
