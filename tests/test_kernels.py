"""Per-kernel allclose vs pure-jnp oracle, interpret=True, shape sweeps."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.graphs import generators
from repro.core import Partitioning, build_png, block_png
from repro.kernels.pcpm_spmv import (pack_blocked, pcpm_spmv_pallas,
                                     pcpm_gather_pallas, pcpm_gather_ref)
from repro.kernels.embedding_bag import (embedding_bag,
                                         embedding_bag_pallas,
                                         embedding_bag_ref)
from repro.kernels.flash_attention import (attention, mha_ref,
                                           flash_attention_pallas)


RNG = np.random.default_rng(42)


# ------------------------------------------------------------- pcpm_spmv
class TestPCPMKernel:
    @pytest.mark.parametrize("scale,deg,part_size,d", [
        (6, 4, 16, 1), (7, 8, 32, 8), (8, 6, 64, 16), (7, 4, 128, 32),
    ])
    def test_spmv_matches_dense(self, scale, deg, part_size, d):
        g = generators.rmat(scale, deg, seed=scale)
        packed = pack_blocked(
            block_png(build_png(g, Partitioning(g.num_nodes, part_size))),
            g.num_nodes, edge_block=128)
        x = RNG.random((g.num_nodes, d)).astype(np.float32)
        y = np.asarray(pcpm_spmv_pallas(packed, jnp.asarray(x.squeeze()
                                        if d == 1 else x)))
        A = np.zeros((g.num_nodes, g.num_nodes))
        np.add.at(A, (g.src, g.dst), 1.0)
        ref = A.T @ x
        np.testing.assert_allclose(
            y.reshape(ref.shape[0], -1), ref.reshape(ref.shape[0], -1)
            if d > 1 else ref, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_kernel_vs_ref_direct(self, dtype):
        k, U, d, P, Eb, neb = 4, 128, 128, 64, 128, 3
        bins = jnp.asarray(RNG.random((k, U, d)), dtype=dtype)
        eu = jnp.asarray(RNG.integers(0, U + 1, (k, neb, Eb)), dtype=jnp.int32)
        ed = jnp.asarray(RNG.integers(0, P + 1, (k, neb, Eb)), dtype=jnp.int32)
        out_k = pcpm_gather_pallas(bins, eu, ed, part_size=P,
                                   interpret=True)
        out_r = pcpm_gather_ref(bins, eu, ed, part_size=P)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
            rtol=tol, atol=tol)

    def test_empty_partition(self):
        # a partition with zero edges must produce zeros
        k, U, d, P, Eb = 2, 128, 128, 8, 128
        bins = jnp.asarray(RNG.random((k, U, d)).astype(np.float32))
        eu = jnp.full((k, 1, Eb), U, dtype=jnp.int32)   # all padding
        ed = jnp.full((k, 1, Eb), P, dtype=jnp.int32)
        out = pcpm_gather_pallas(bins, eu, ed, part_size=P, interpret=True)
        assert np.allclose(np.asarray(out), 0.0)


# ---------------------------------------------------------- embedding_bag
class TestEmbeddingBag:
    @pytest.mark.parametrize("v,d,b,l", [
        (512, 128, 8, 4), (1024, 64, 32, 16), (2048, 128, 64, 8),
    ])
    def test_pallas_vs_ref(self, v, d, b, l):
        table = jnp.asarray(RNG.random((v, d)).astype(np.float32))
        idx = jnp.asarray(RNG.integers(0, v, (b, l)), dtype=jnp.int32)
        w = jnp.asarray(RNG.random((b, l)).astype(np.float32))
        out = embedding_bag(table, idx, w, path="pallas")
        ref = embedding_bag_ref(table, idx, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)

    def test_padding_indices_ignored(self):
        v, d = 512, 128
        table = jnp.asarray(RNG.random((v, d)).astype(np.float32))
        idx = jnp.asarray([[0, 1, v, v], [2, v, v, v]], dtype=jnp.int32)
        out = embedding_bag(table, idx, None, path="pallas")
        ref = np.asarray(table)[np.array([[0, 1], [2, 2]])]
        np.testing.assert_allclose(np.asarray(out)[0], ref[0].sum(0),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out)[1], np.asarray(table)[2],
                                   rtol=1e-5)

    def test_xla_path_matches(self):
        v, d, b, l = 1024, 64, 16, 8
        table = jnp.asarray(RNG.random((v, d)).astype(np.float32))
        idx = jnp.asarray(RNG.integers(0, v, (b, l)), dtype=jnp.int32)
        np.testing.assert_allclose(
            np.asarray(embedding_bag(table, idx, None, path="xla")),
            np.asarray(embedding_bag(table, idx, None, path="pallas")),
            rtol=1e-4, atol=1e-5)


# --------------------------------------------------------- flash attention
class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,d", [
        (1, 4, 4, 256, 64), (2, 8, 2, 128, 64), (1, 4, 1, 384, 128),
    ])
    def test_causal_vs_ref(self, b, hq, hkv, sq, d):
        q = jnp.asarray(RNG.standard_normal((b, sq, hq, d)),
                        dtype=jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, sq, hkv, d)),
                        dtype=jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, sq, hkv, d)),
                        dtype=jnp.float32)
        out = attention(q, k, v, causal=True, path="pallas")
        ref = attention(q, k, v, causal=True, path="xla")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("window", [64, 128, 200])
    def test_sliding_window(self, window):
        b, h, s, d = 1, 2, 384, 64
        q = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype=jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype=jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype=jnp.float32)
        out = attention(q, k, v, causal=True, window=window, path="pallas")
        ref = attention(q, k, v, causal=True, window=window, path="xla")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_unpadded_seq(self):
        """Sq not a multiple of the block size exercises kv_len masking."""
        b, h, s, d = 1, 2, 200, 64
        q = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype=jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype=jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype=jnp.float32)
        out = attention(q, k, v, causal=True, path="pallas")
        ref = attention(q, k, v, causal=True, path="xla")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_bf16(self):
        b, h, s, d = 1, 2, 256, 64
        mk = lambda: jnp.asarray(RNG.standard_normal((b, s, h, d)),
                                 dtype=jnp.bfloat16)
        q, k, v = mk(), mk(), mk()
        out = attention(q, k, v, causal=True, path="pallas")
        ref = attention(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True, path="xla")
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), rtol=5e-2, atol=5e-2)
