"""PCPM-distributed GraphCast == single-device baseline (subprocess
with 8 forced host devices, like test_distributed)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.configs import get
    from repro.graphs import generators
    from repro.core.distributed import build_sharded_png, pad_to_shards
    from repro.models.gnn import (GraphBatch, graphcast_forward,
                                  init_graphcast)
    from repro.models.gnn_dist import (DistGraph, graphcast_dist_forward,
                                       make_dist_train_step,
                                       dist_graph_shardings)
    from repro.optim import AdamW

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get("graphcast").scaled()
    g = generators.rmat(9, 8, seed=5)       # 512 nodes, 4096 edges
    n, m, df, n_out = g.num_nodes, g.num_edges, 12, 8
    rng = np.random.default_rng(0)
    feat = rng.standard_normal((n, df)).astype(np.float32)
    pos = rng.standard_normal((n, 3)).astype(np.float32)
    pos /= np.linalg.norm(pos, axis=1, keepdims=True)
    labels = rng.integers(0, n_out, n).astype(np.int32)

    params = init_graphcast(cfg, jax.random.key(1), df, n_out)

    # baseline: plain edge-list forward
    gb = GraphBatch(jnp.asarray(g.src), jnp.asarray(g.dst),
                    jnp.ones(m, jnp.float32), jnp.asarray(feat),
                    jnp.asarray(pos), jnp.ones(n, jnp.float32),
                    jnp.zeros(n, jnp.int32), 1, jnp.asarray(labels))
    ref = np.asarray(graphcast_forward(params, cfg, gb))

    # PCPM-distributed forward over 8 shards
    layout = build_sharded_png(g, 8)
    dg = DistGraph.from_png(layout, pad_to_shards(feat, layout),
                            pad_to_shards(pos, layout),
                            pad_to_shards(labels, layout))
    with mesh:
        out = np.asarray(graphcast_dist_forward(params, cfg, dg, mesh))
    np.testing.assert_allclose(out[:n], ref, rtol=2e-4, atol=2e-5)
    print("dist forward matches baseline ok")

    # one train step runs and produces finite loss/grads
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_dist_train_step(cfg, opt, mesh, n_out=n_out))
    with mesh:
        p2, s2, metrics = step(params, opt.init(params), dg)
    assert np.isfinite(float(metrics["loss"]))
    print("dist train step ok", float(metrics["loss"]))

    # the compiled program exchanges via all-to-all, not all-gather of
    # the full node tensor
    with mesh:
        txt = jax.jit(
            lambda p, d: graphcast_dist_forward(p, cfg, d, mesh)
        ).lower(params, dg).compile().as_text()
    assert "all-to-all" in txt
    print("uses all-to-all ok")
""")


def test_gnn_dist_pcpm():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for marker in ["dist forward matches baseline ok",
                   "dist train step ok", "uses all-to-all ok"]:
        assert marker in proc.stdout
