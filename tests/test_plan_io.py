"""GraphPlan serialization (ISSUE 4 satellite): a saved+loaded plan
equals a freshly built one — same schedule arrays, same SpMV output
(≤1e-6) — for every registered backend, sharded included; and
``install_plan`` warm-starts the process cache so loading replaces
building."""
import numpy as np
import pytest
import jax.numpy as jnp

import repro
from repro.core import SpMVEngine
from repro.core.plan import (PlanConfig, build_plan, graph_fingerprint,
                             install_plan, plan_cache_stats)
from repro.graphs import generators, io as graph_io


@pytest.fixture
def graph():
    return generators.rmat(7, 6, seed=17)


def _cfg(method):
    # num_shards=1 keeps the sharded backend tier-1 (single device)
    return PlanConfig(method=method, part_size=32, num_shards=1)


ALL_METHODS = ["pdpr", "bvgas", "pcpm", "pcpm_pallas", "pcpm_sharded"]


@pytest.mark.parametrize("method", ALL_METHODS)
class TestRoundTrip:
    def test_arrays_and_spmv_match_fresh_build(self, graph, method,
                                               tmp_path):
        fresh = build_plan(graph, _cfg(method))
        path = str(tmp_path / "plan.npz")
        fresh.save(path)
        loaded = repro.GraphPlan.load(path)

        assert loaded.config == fresh.config
        assert loaded.num_nodes == fresh.num_nodes
        assert loaded.num_edges == fresh.num_edges
        for key in ("csc_src", "csc_dst", "bv_src", "bv_dst"):
            a, b = getattr(fresh, key), getattr(loaded, key)
            assert (a is None) == (b is None)
            if a is not None:
                np.testing.assert_array_equal(a, b)
        if fresh.png is not None:
            for key in ("update_src", "update_offsets",
                        "edge_update_idx", "edge_dst", "edge_offsets"):
                np.testing.assert_array_equal(
                    getattr(fresh.png, key), getattr(loaded.png, key))
        if fresh.schedule is not None:
            assert loaded.schedule.block == fresh.schedule.block
            for key in ("edge_update_idx_padded", "piece_start",
                        "piece_end", "piece_dst"):
                np.testing.assert_array_equal(
                    getattr(fresh.schedule, key),
                    getattr(loaded.schedule, key))
        if fresh.blocked is not None:
            for key in ("update_src", "edge_update_local",
                        "edge_dst_local"):
                np.testing.assert_array_equal(
                    getattr(fresh.blocked, key),
                    getattr(loaded.blocked, key))
        if fresh.sharded is not None:
            assert loaded.sharded.num_shards == fresh.sharded.num_shards
            assert loaded.sharded.shard_size == fresh.sharded.shard_size
            for key in ("send_ids", "edge_upd", "edge_dst",
                        "eui_padded", "piece_start", "piece_end",
                        "piece_dst"):
                np.testing.assert_array_equal(
                    getattr(fresh.sharded, key),
                    getattr(loaded.sharded, key))

        x = np.random.default_rng(3).random(
            graph.num_nodes).astype(np.float32)
        y_fresh = np.asarray(SpMVEngine(graph, plan=fresh)(
            jnp.asarray(x)))
        y_loaded = np.asarray(SpMVEngine(graph, plan=loaded)(
            jnp.asarray(x)))
        assert np.abs(y_fresh - y_loaded).max() <= 1e-6

    def test_compression_ratio_survives(self, graph, method, tmp_path):
        fresh = build_plan(graph, _cfg(method))
        path = str(tmp_path / "plan.npz")
        fresh.save(path)
        loaded = repro.GraphPlan.load(path)
        assert loaded.compression_ratio == pytest.approx(
            fresh.compression_ratio)


class TestWarmStart:
    def test_install_plan_replaces_building(self, graph, tmp_path):
        path = str(tmp_path / "plan.npz")
        build_plan(graph, _cfg("pcpm")).save(path)
        # a "fresh process": same edges, new Graph object, empty cache
        g2 = generators.rmat(7, 6, seed=17)
        repro.clear_plan_cache()
        install_plan(g2, repro.GraphPlan.load(path))
        sess = repro.open(g2, method="pcpm", part_size=32, num_shards=1)
        assert plan_cache_stats().plan_builds == 0     # loaded, not built
        res = sess.pagerank(num_iterations=10)
        from repro.core import pagerank_reference
        np.testing.assert_allclose(
            np.asarray(res.ranks),
            pagerank_reference(graph, num_iterations=10),
            rtol=1e-3, atol=1e-7)

    def test_registry_load_with_plan_path(self, graph, tmp_path):
        from repro.serve import GraphRegistry
        gpath = str(tmp_path / "g.npz")
        ppath = str(tmp_path / "g.plan.npz")
        graph_io.save(gpath, graph)
        build_plan(graph, _cfg("pcpm")).save(ppath)
        repro.clear_plan_cache()
        reg = GraphRegistry(slots=2, chunk=4)
        sch = reg.load("g", gpath, plan_path=ppath)
        assert plan_cache_stats().plan_builds == 0     # warm-loaded
        assert sch.engine.method == "pcpm"
        assert sch.engine.partitioning.part_size == 32
        reg.submit("g", tol=0.0, max_iters=10)
        out = reg.run_until_drained()["g"]
        from repro.core import pagerank_reference
        np.testing.assert_allclose(
            out[0].ranks, pagerank_reference(graph, num_iterations=10),
            rtol=1e-3, atol=1e-7)

    def test_fingerprint_content_addressed(self, graph):
        g_same = generators.rmat(7, 6, seed=17)
        g_diff = generators.rmat(7, 6, seed=18)
        assert graph_fingerprint(graph) == graph_fingerprint(g_same)
        assert graph_fingerprint(graph) != graph_fingerprint(g_diff)

    def test_install_plan_rejects_wrong_graph(self, graph, tmp_path):
        """A plan from a different graph must never seed the cache —
        silently serving wrong preprocessing is the failure mode."""
        path = str(tmp_path / "plan.npz")
        build_plan(graph, _cfg("pcpm")).save(path)
        plan = repro.GraphPlan.load(path)
        g_other = generators.rmat(7, 6, seed=18)   # same n, other edges
        assert g_other.num_nodes == graph.num_nodes
        with pytest.raises(ValueError, match="mismatch"):
            install_plan(g_other, plan)
        g_small = generators.rmat(6, 6, seed=18)   # different n
        with pytest.raises(ValueError, match="mismatch"):
            install_plan(g_small, plan)

    def test_engine_rejects_foreign_plan(self, graph, tmp_path):
        """SpMVEngine(g, plan=...) applies the same plan/graph guard
        as install_plan."""
        path = str(tmp_path / "plan.npz")
        build_plan(graph, _cfg("pcpm")).save(path)
        plan = repro.GraphPlan.load(path)
        g_other = generators.rmat(7, 6, seed=18)
        with pytest.raises(ValueError, match="mismatch"):
            SpMVEngine(g_other, plan=plan)
        g_small = generators.rmat(6, 6, seed=18)
        with pytest.raises(ValueError, match="mismatch"):
            SpMVEngine(g_small, plan=plan)

    def test_oversized_sharded_plan_rejected(self, graph, tmp_path):
        """A sharded plan wanting more shards than this runtime has
        devices must raise (the mesh would otherwise silently truncate
        against the plan's fixed-shape shard arrays)."""
        import jax
        from repro.core.distributed import build_sharded_png
        too_many = jax.device_count() + 1
        plan = repro.GraphPlan(
            PlanConfig(method="pcpm_sharded", num_shards=too_many),
            graph.num_nodes, graph.num_edges,
            build_plan(graph, _cfg("pcpm_sharded")).partitioning,
            sharded=build_sharded_png(graph, too_many))
        path = str(tmp_path / "big.plan.npz")
        plan.save(path)
        loaded = repro.GraphPlan.load(path)
        with pytest.raises(ValueError, match="devices"):
            SpMVEngine(graph, plan=loaded)
        with pytest.raises(ValueError, match="num_shards"):
            install_plan(graph, loaded)

    def test_shard_axis_name_shares_plan(self, graph):
        """The mesh axis name is a run-layer knob — plans for the same
        graph must not duplicate per axis name."""
        p1 = build_plan(graph, PlanConfig(method="pcpm_sharded",
                                          num_shards=1))
        builds = plan_cache_stats().plan_builds
        p2 = build_plan(graph, PlanConfig(method="pcpm_sharded",
                                          num_shards=1, shard_axis="x"))
        assert p2 is p1
        assert plan_cache_stats().plan_builds == builds

    def test_irrelevant_gather_block_shares_plan(self, graph):
        """Backends that never consume gather_block normalize it out
        of the cache key — no duplicate builds for irrelevant knobs."""
        for method in ("pcpm_pallas",):
            e1 = SpMVEngine(graph, method=method, part_size=32)
            builds = plan_cache_stats().plan_builds
            e2 = SpMVEngine(graph, plan=build_plan(
                graph, PlanConfig(method=method, part_size=32,
                                  gather_block=512)))
            assert plan_cache_stats().plan_builds == builds, method
            assert e1.plan is e2.plan
        # ...but the blocked-gather engines genuinely depend on it:
        # distinct plans per block (pdpr/bvgas joined pcpm when they
        # adopted the hierarchical gather schedule)
        for method in ("pdpr", "bvgas", "pcpm"):
            p1 = build_plan(graph, PlanConfig(method=method,
                                              part_size=32))
            p2 = build_plan(graph, PlanConfig(method=method,
                                              part_size=32,
                                              gather_block=512))
            assert p1 is not p2 and p2.schedule.block == 512

    def test_evict_plans_releases_cache_entries(self, graph):
        from repro.core.plan import evict_plans
        sess = repro.open(graph, method="pcpm", part_size=32)
        assert evict_plans(graph) >= 1
        # live sessions keep serving from their plan reference
        res = sess.pagerank(num_iterations=5)
        assert res.iterations == 5
        # the next build is a rebuild, not a hit
        builds = plan_cache_stats().plan_builds
        repro.open(graph, method="pcpm", part_size=32)
        assert plan_cache_stats().plan_builds == builds + 1
