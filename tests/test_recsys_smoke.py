"""MIND smoke tests: shapes, training, retrieval sanity."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models import recsys
from repro.optim import AdamW


@pytest.fixture()
def cfg():
    return get("mind").scaled()


def make_batch(cfg, rng, b=16):
    hist = rng.integers(0, cfg.vocab, (b, cfg.hist_len))
    # pad a tail of history with out-of-vocab sentinels
    hist[:, -2:] = cfg.vocab
    return {"hist": jnp.asarray(hist, jnp.int32),
            "target": jnp.asarray(rng.integers(0, cfg.vocab, (b,)),
                                  jnp.int32)}


def test_interests_shape_finite(cfg):
    rng = np.random.default_rng(0)
    params = recsys.init_mind(cfg, jax.random.key(0))
    batch = make_batch(cfg, rng)
    caps = recsys.interests(params, cfg, batch["hist"])
    assert caps.shape == (16, cfg.n_interests, cfg.embed_dim)
    assert np.isfinite(np.asarray(caps)).all()


def test_padding_invariance(cfg):
    """Out-of-vocab (padded) history slots must not affect interests."""
    rng = np.random.default_rng(1)
    params = recsys.init_mind(cfg, jax.random.key(1))
    b = make_batch(cfg, rng)
    caps1 = recsys.interests(params, cfg, b["hist"])
    h2 = np.asarray(b["hist"]).copy()
    h2[:, -2:] = cfg.vocab + 7  # different sentinel, same validity
    caps2 = recsys.interests(params, cfg, jnp.asarray(h2))
    np.testing.assert_allclose(np.asarray(caps1), np.asarray(caps2),
                               rtol=1e-5, atol=1e-6)


def test_train_step_decreases_loss(cfg):
    rng = np.random.default_rng(2)
    params = recsys.init_mind(cfg, jax.random.key(2))
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    state = opt.init(params)
    step = jax.jit(recsys.make_train_step(cfg, opt))
    batch = make_batch(cfg, rng, b=32)
    losses = []
    for _ in range(10):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses


def test_retrieval_finds_history_items(cfg):
    """After training on a batch, retrieval should score the user's own
    target item higher than random items on average."""
    rng = np.random.default_rng(3)
    params = recsys.init_mind(cfg, jax.random.key(3))
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    state = opt.init(params)
    step = jax.jit(recsys.make_train_step(cfg, opt))
    batch = make_batch(cfg, rng, b=32)
    for _ in range(30):
        params, state, _ = step(params, state, batch)
    cand = jnp.arange(cfg.vocab, dtype=jnp.int32)
    scores, idx = recsys.retrieval_step(params, cfg, batch["hist"][:4],
                                        cand, top_k=cfg.vocab)
    # positive target should rank in the top half for most users
    ranks = []
    for i in range(4):
        pos = int(batch["target"][i])
        ranks.append(int(np.where(np.asarray(idx[i]) == pos)[0][0]))
    assert np.median(ranks) < cfg.vocab // 2, ranks


def test_retrieval_topk_shape(cfg):
    params = recsys.init_mind(cfg, jax.random.key(4))
    rng = np.random.default_rng(4)
    batch = make_batch(cfg, rng, b=2)
    cand = jnp.asarray(rng.integers(0, cfg.vocab, (500,)), jnp.int32)
    scores, idx = recsys.retrieval_step(params, cfg, batch["hist"], cand,
                                        top_k=8)
    assert scores.shape == (2, 8) and idx.shape == (2, 8)
    assert np.all(np.diff(np.asarray(scores), axis=1) <= 1e-6)
