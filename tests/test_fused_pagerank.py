"""Fused-driver + pcpm_pallas engine coverage (ISSUE 1):

- parity of the fused `lax.while_loop` driver and the Pallas engine
  against the dense oracle across part sizes (single-partition and
  empty-partition shapes included);
- d > 1 multi-vector SpMV and batched personalized serving;
- dangling nodes;
- tol-based early exit identical to the Python-loop debug driver;
- zero device->host transfers inside the fused iteration loop
  (enforced with jax's transfer guard);
- AOT-compiled serving path never retraces per request.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graphs import Graph, from_edge_list, generators
from repro.core import (SpMVEngine, fused_power_iteration, pagerank,
                        pagerank_reference)
from repro.core.pagerank import _inv_degree
from repro.serve import PageRankServer


def dense_spmv(g: Graph, x: np.ndarray) -> np.ndarray:
    A = np.zeros((g.num_nodes, g.num_nodes))
    np.add.at(A, (g.src, g.dst), 1.0)
    return A.T @ x


# --------------------------------------------------------------- parity
class TestParity:
    # part sizes straddle the node count: 512 > n for scale 7 (=128
    # nodes per rmat pow) ... part_size >= n gives partition count 1.
    @pytest.mark.parametrize("method", ["pcpm", "pcpm_pallas"])
    @pytest.mark.parametrize("part_size", [16, 64, 1 << 20])
    def test_pagerank_vs_dense_oracle(self, method, part_size):
        g = generators.rmat(7, 8, seed=9)
        res = pagerank(g, method=method, num_iterations=20,
                       part_size=part_size)
        ref = pagerank_reference(g, num_iterations=20)
        np.testing.assert_allclose(np.asarray(res.ranks), ref, rtol=1e-3)

    def test_single_partition(self):
        g = generators.rmat(6, 4, seed=3)
        eng = SpMVEngine(g, method="pcpm_pallas",
                         part_size=g.num_nodes)
        assert eng.partitioning.num_partitions == 1
        x = np.random.default_rng(0).random(g.num_nodes).astype(np.float32)
        np.testing.assert_allclose(np.asarray(eng(jnp.asarray(x))),
                                   dense_spmv(g, x), rtol=2e-4, atol=1e-5)

    def test_empty_partitions(self):
        # all edges land in partition 0; partitions 1..7 are empty
        n = 64
        e = np.stack([np.arange(1, n), np.zeros(n - 1, dtype=np.int64)], 1)
        g = from_edge_list(n, e)
        for method in ("pcpm", "pcpm_pallas"):
            eng = SpMVEngine(g, method=method, part_size=8)
            x = np.random.default_rng(1).random(n).astype(np.float32)
            np.testing.assert_allclose(
                np.asarray(eng(jnp.asarray(x))), dense_spmv(g, x),
                rtol=2e-4, atol=1e-5)

    def test_multivector_pallas(self):
        g = generators.uniform_random(300, 3000, seed=7)
        eng = SpMVEngine(g, method="pcpm_pallas", part_size=64)
        x = np.random.default_rng(2).random((300, 16)).astype(np.float32)
        y = np.asarray(eng(jnp.asarray(x)))
        np.testing.assert_allclose(y, dense_spmv(g, x), rtol=2e-4,
                                   atol=1e-5)

    def test_dangling_nodes_fused(self):
        g = from_edge_list(4, np.array([[0, 1], [1, 2], [2, 3], [0, 3]]))
        for method in ("pcpm", "pcpm_pallas"):
            res = pagerank(g, method=method, num_iterations=30,
                           part_size=2)
            ref = pagerank_reference(g, num_iterations=30)
            np.testing.assert_allclose(np.asarray(res.ranks), ref,
                                       rtol=1e-4)


# ------------------------------------------------- dangling redistribution
class TestDanglingRedistribution:
    def test_mass_conserved_and_matches_oracle(self):
        g = generators.rmat(8, 4, seed=21)     # rmat leaves sinks
        assert (np.asarray(g.out_degree) == 0).any()
        res = pagerank(g, method="pcpm", num_iterations=25,
                       dangling="redistribute")
        ref = pagerank_reference(g, num_iterations=25,
                                 dangling="redistribute")
        np.testing.assert_allclose(np.asarray(res.ranks), ref,
                                   rtol=1e-3, atol=1e-7)
        assert abs(float(np.asarray(res.ranks).sum()) - 1.0) < 1e-5

    def test_python_driver_agrees(self):
        g = generators.rmat(7, 4, seed=22)
        eng = SpMVEngine(g, method="pcpm", part_size=32)
        fused = pagerank(g, engine=eng, num_iterations=20,
                         dangling="redistribute")
        py = pagerank(g, engine=eng, num_iterations=20,
                      dangling="redistribute", driver="python")
        np.testing.assert_allclose(np.asarray(fused.ranks),
                                   np.asarray(py.ranks), rtol=1e-5,
                                   atol=1e-8)

    def test_unknown_policy_rejected(self):
        g = generators.rmat(6, 4, seed=23)
        with pytest.raises(ValueError, match="dangling"):
            pagerank(g, method="pcpm", dangling="drop-it")


# --------------------------------------- sharded engine on one device
class TestShardedSingleDevice:
    """The pcpm_sharded engine degenerates to 1 shard on the default
    single-device runtime — tier-1 coverage of the shard_map path
    without forcing host devices (the 8-device suites live in
    test_distributed.py / test_sharded_parity.py)."""

    def test_pagerank_end_to_end(self):
        g = generators.rmat(7, 8, seed=9)
        eng = SpMVEngine(g, method="pcpm_sharded")
        res = pagerank(g, engine=eng, num_iterations=20)
        ref = pagerank_reference(g, num_iterations=20)
        np.testing.assert_allclose(np.asarray(res.ranks), ref,
                                   rtol=1e-3, atol=1e-7)

    def test_pad_slots_leak_no_mass(self):
        # n chosen so the padded tail is non-empty at shard_size
        # granularity only when num_shards > 1; with 1 shard the
        # layout is pad-free, so force a ragged n via isolated tail
        g = generators.rmat(7, 6, seed=19)
        eng = SpMVEngine(g, method="pcpm_sharded")
        res = pagerank(g, engine=eng, num_iterations=30,
                       dangling="redistribute")
        mass = float(np.asarray(res.ranks).sum())
        assert abs(mass - 1.0) < 1e-5
        ref = pagerank_reference(g, num_iterations=30,
                                 dangling="redistribute")
        np.testing.assert_allclose(np.asarray(res.ranks), ref,
                                   rtol=1e-3, atol=1e-7)

    def test_spmv_matches_dense(self):
        g = generators.uniform_random(300, 3000, seed=7)
        eng = SpMVEngine(g, method="pcpm_sharded")
        x = np.random.default_rng(2).random((300, 4)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(eng(jnp.asarray(x))),
                                   dense_spmv(g, x), rtol=2e-4,
                                   atol=1e-5)

    def test_too_many_shards_rejected(self):
        g = generators.rmat(6, 4, seed=3)
        with pytest.raises(ValueError, match="num_shards"):
            SpMVEngine(g, method="pcpm_sharded",
                       num_shards=jax.device_count() + 1)


# ------------------------------------------------------------ early exit
class TestEarlyExit:
    def test_tol_exit_matches_python_driver(self):
        g = generators.rmat(8, 8, seed=10)
        eng = SpMVEngine(g, method="pcpm", part_size=64)
        fused = pagerank(g, engine=eng, num_iterations=60, tol=1e-5)
        py = pagerank(g, engine=eng, num_iterations=60, tol=1e-5,
                      driver="python")
        assert fused.iterations == py.iterations < 60
        # XLA fuses the loop body differently from the op-by-op driver;
        # identical math, f32 rounding differs in the last couple ulps.
        np.testing.assert_allclose(np.asarray(fused.ranks),
                                   np.asarray(py.ranks), rtol=1e-5,
                                   atol=1e-8)
        np.testing.assert_allclose(fused.residuals, py.residuals,
                                   rtol=5e-3, atol=1e-7)

    def test_check_every_defers_exit(self):
        g = generators.rmat(8, 8, seed=10)
        eng = SpMVEngine(g, method="pcpm", part_size=64)
        every = pagerank(g, engine=eng, num_iterations=60, tol=1e-5)
        coarse = pagerank(g, engine=eng, num_iterations=60, tol=1e-5,
                          check_every=7)
        # exit only on a check boundary, never before convergence
        assert coarse.iterations % 7 == 0 or coarse.iterations == 60
        assert coarse.iterations >= every.iterations
        assert coarse.residuals[-1] < 1e-5


# ----------------------------------------------------- device residency
class TestDeviceResidency:
    def test_no_host_transfers_inside_loop(self):
        """The fused loop must run to completion without a single
        device->host transfer — the Python driver's per-iteration
        float() sync would trip the guard."""
        g = generators.rmat(8, 8, seed=11)
        eng = SpMVEngine(g, method="pcpm", part_size=64)
        run = fused_power_iteration(eng, num_iterations=15, tol=1e-12)
        n = g.num_nodes
        pr0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
        base = jnp.full((n,), 0.15 / n, dtype=jnp.float32)
        inv_deg = _inv_degree(g)
        with jax.transfer_guard_device_to_host("disallow"):
            pr, it, res = run(pr0, inv_deg, base)
            pr.block_until_ready()
        assert int(it) == 15

    def test_loop_is_one_device_program(self):
        """Structural: the fused driver lowers to a single `while`
        primitive with no host callbacks — the whole iteration loop is
        one device dispatch (per check_every block there is only an
        on-device branch, never a host round-trip)."""
        g = generators.rmat(6, 4, seed=12)
        eng = SpMVEngine(g, method="pcpm", part_size=16)
        run = fused_power_iteration(eng, num_iterations=5, tol=1e-6,
                                    check_every=2)
        n = g.num_nodes
        pr0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
        base = jnp.full((n,), 0.15 / n, dtype=jnp.float32)
        jaxpr = jax.make_jaxpr(run.__wrapped__)(pr0, _inv_degree(g), base)
        prims = [str(e.primitive) for e in jaxpr.jaxpr.eqns]
        assert prims.count("while") == 1
        assert not any("callback" in p or "infeed" in p or "outfeed" in p
                       for p in prims)


# --------------------------------------------------------------- serving
class TestServing:
    def test_aot_no_retrace_per_request(self):
        g = generators.rmat(7, 6, seed=13)
        srv = PageRankServer(g, method="pcpm_pallas", part_size=32,
                             num_iterations=10)
        assert srv.trace_count == 1          # traced once, at lowering
        for _ in range(3):
            pr, it, _ = srv.query()
            assert it == 10
        assert srv.trace_count == 1          # zero traces per request

    def test_batched_personalized_queries(self):
        g = generators.rmat(7, 8, seed=14)
        n, d = g.num_nodes, 3
        srv = PageRankServer(g, method="pcpm", part_size=32, batch=d,
                             num_iterations=30)
        seeds = np.zeros((n, d), np.float32)
        seeds[5, 0] = seeds[17, 1] = seeds[33, 2] = 1.0
        pr, it, _ = srv.query(seeds)
        assert pr.shape == (n, d)
        # dense personalized oracle, per column
        A = np.zeros((n, n))
        np.add.at(A, (g.src, g.dst), 1.0)
        inv = np.where(g.out_degree == 0, 0.0,
                       1.0 / np.maximum(g.out_degree, 1))
        for j in range(d):
            v = seeds[:, j] / seeds[:, j].sum()
            x = v.copy()
            for _ in range(it):
                x = 0.15 * v + 0.85 * (A.T @ (x * inv))
            np.testing.assert_allclose(np.asarray(pr)[:, j], x,
                                       rtol=1e-3, atol=1e-7)
