"""GNN smoke + property tests: shapes, finiteness, training, and SO(3)
equivariance/invariance of the equivariant architectures."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models import gnn
from repro.models.equivariant import wigner_d
from repro.optim import AdamW

GNN_ARCHS = ["graphcast", "nequip", "mace", "equiformer-v2"]


def make_batch(seed=0, n=40, e=160, d_feat=12, n_graphs=1):
    return gnn.random_graph_batch(np.random.default_rng(seed), n, e,
                                  d_feat, n_graphs=n_graphs)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_forward_shapes_finite(arch):
    cfg = get(arch).scaled()
    g = make_batch()
    params = gnn.init_gnn(cfg, jax.random.key(0), 12, 8)
    out = gnn.gnn_forward(params, cfg, g)
    assert out.shape == (g.num_nodes, 8)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_batched_molecule_shape(arch):
    cfg = get(arch).scaled()
    g = make_batch(n=64, e=256, n_graphs=8)
    params = gnn.init_gnn(cfg, jax.random.key(1), 12, 4)
    out = gnn.gnn_forward(params, cfg, g)
    assert out.shape == (64, 4)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_train_step_decreases_loss(arch):
    cfg = get(arch).scaled()
    g = make_batch(seed=2)
    params = gnn.init_gnn(cfg, jax.random.key(2), 12, 8)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    state = opt.init(params)
    step = jax.jit(gnn.make_gnn_train_step(cfg, opt, n_out=8))
    losses = []
    for _ in range(8):
        params, state, m = step(params, state, g)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ["nequip", "mace", "equiformer-v2"])
def test_rotation_invariance(arch):
    """Scalar (l=0) outputs must be invariant under global rotation of
    positions — THE correctness property of the equivariant stack."""
    cfg = get(arch).scaled()
    g = make_batch(seed=3)
    params = gnn.init_gnn(cfg, jax.random.key(3), 12, 8)
    out1 = gnn.gnn_forward(params, cfg, g)

    rng = np.random.default_rng(5)
    q = rng.standard_normal(4)
    q /= np.linalg.norm(q)
    w, x, y, z = q
    rot = np.array([
        [1 - 2 * (y * y + z * z), 2 * (x * y - z * w), 2 * (x * z + y * w)],
        [2 * (x * y + z * w), 1 - 2 * (x * x + z * z), 2 * (y * z - x * w)],
        [2 * (x * z - y * w), 2 * (y * z + x * w), 1 - 2 * (x * x + y * y)]])
    g_rot = gnn.GraphBatch(
        g.edge_src, g.edge_dst, g.edge_mask, g.node_feat,
        g.positions @ jnp.asarray(rot, jnp.float32).T, g.node_mask,
        g.graph_id, g.n_graphs, g.labels)
    out2 = gnn.gnn_forward(params, cfg, g_rot)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-3, atol=1e-4)


def test_permutation_equivariance_graphcast():
    """Relabeling nodes permutes outputs correspondingly."""
    cfg = get("graphcast").scaled()
    g = make_batch(seed=4)
    params = gnn.init_gnn(cfg, jax.random.key(4), 12, 8)
    out = gnn.gnn_forward(params, cfg, g)
    perm = np.random.default_rng(6).permutation(g.num_nodes)
    inv = np.argsort(perm)
    g_p = gnn.GraphBatch(
        jnp.asarray(perm, jnp.int32)[g.edge_src],
        jnp.asarray(perm, jnp.int32)[g.edge_dst],
        g.edge_mask, g.node_feat[jnp.asarray(inv)],
        g.positions[jnp.asarray(inv)], g.node_mask[jnp.asarray(inv)],
        g.graph_id, g.n_graphs, g.labels[jnp.asarray(inv)])
    out_p = gnn.gnn_forward(params, cfg, g_p)
    np.testing.assert_allclose(np.asarray(out_p),
                               np.asarray(out)[inv], rtol=1e-4,
                               atol=1e-5)


def test_edge_mask_zeroes_padding():
    """A padded (masked) edge must not change any output."""
    cfg = get("graphcast").scaled()
    g = make_batch(seed=7)
    params = gnn.init_gnn(cfg, jax.random.key(7), 12, 8)
    out = gnn.gnn_forward(params, cfg, g)
    # append a masked edge pointing somewhere arbitrary
    g2 = gnn.GraphBatch(
        jnp.concatenate([g.edge_src, jnp.asarray([0], jnp.int32)]),
        jnp.concatenate([g.edge_dst, jnp.asarray([1], jnp.int32)]),
        jnp.concatenate([g.edge_mask, jnp.asarray([0.0])]),
        g.node_feat, g.positions, g.node_mask, g.graph_id, g.n_graphs,
        g.labels)
    out2 = gnn.gnn_forward(params, cfg, g2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-6)
