"""Chaos suite for the serving resilience layer (DESIGN.md §10).

Every fault here is injected through the deterministic, seedable
``FaultInjector`` test hook — no monkeypatching of device code — and
every test asserts the three resilience invariants:

1. queries untouched by a fault finish within 1e-6 of the fault-free
   run (blast-radius containment);
2. ``trace_count`` stays 1 — no resilience path is allowed to cost a
   retrace;
3. affected queries end in an EXPLICIT terminal state (converged after
   re-admission, or a ``QueryResult.error``) — never a hang, never a
   silently-wrong answer.
"""
import os
import tempfile

import numpy as np
import pytest

import repro
from repro.core.plan import PlanConfig, build_plan
from repro.graphs import generators
from repro.reliability import (FaultInjector, FaultPlan, FaultSpec,
                               InjectedFault, ResilienceConfig,
                               check_plan_integrity, corrupt_plan_arrays,
                               load_rank_checkpoint, restore_scheduler,
                               save_rank_checkpoint, snapshot_scheduler)
from repro.serve import SlotScheduler
from repro.stream.delta import apply_delta as apply_edges

SMALL = dict(method="pcpm", part_size=64, chunk=4)


@pytest.fixture(scope="module")
def g():
    return generators.rmat(8, 8, seed=1)


def _seeds(g, k, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        s = np.zeros(g.num_nodes, np.float32)
        s[rng.integers(0, g.num_nodes, size=2)] = 1.0
        out.append(s)
    return out


def _drain_map(sch):
    sch.run_until_drained()
    return {r.uid: r for r in sch.completed}


@pytest.fixture(scope="module")
def fault_free(g):
    """uid -> QueryResult of the fault-free run, keyed by submit order."""
    sch = SlotScheduler(g, slots=3, **SMALL)
    uids = [sch.submit(s, tol=1e-6, max_iters=300) for s in _seeds(g, 6)]
    results = _drain_map(sch)
    return [results[u] for u in uids]


class TestFaultPlan:
    def test_spec_validation(self):
        with pytest.raises(ValueError):
            FaultSpec("not_a_kind", step=1)
        with pytest.raises(ValueError):
            FaultSpec("nan_slot", step=0)

    def test_deterministic_slot_choice(self):
        plan = FaultPlan.of([FaultSpec("nan_slot", step=3)], seed=7)
        picks = [FaultInjector(plan).poisons(3, [0, 1, 2])
                 for _ in range(3)]
        assert picks[0] == picks[1] == picks[2]

    def test_exhausted(self):
        inj = FaultInjector(FaultPlan.of([FaultSpec("step_error",
                                                    step=1)]))
        with pytest.raises(InjectedFault):
            inj.check_step(1)
        assert inj.exhausted and len(inj.fired) == 1
        inj.check_step(1)          # fires once, then inert


class TestQuarantine:
    @pytest.mark.parametrize("kind", ["nan_slot", "inf_slot"])
    def test_poisoned_slot_requeued_clean(self, g, fault_free, kind):
        """A non-finite column freezes on-device, is detected at the
        host, and re-admitted from its clean seed; neighbours keep
        iterating to the fault-free answers."""
        inj = FaultInjector(FaultPlan.of([FaultSpec(kind, step=2,
                                                    slot=0)]))
        sch = SlotScheduler(g, slots=3, fault_injector=inj,
                            resilience=ResilienceConfig(max_retries=1),
                            **SMALL)
        uids = [sch.submit(s, tol=1e-6, max_iters=300)
                for s in _seeds(g, 6)]
        results = _drain_map(sch)
        assert sch.metrics.counters["quarantined"] == 1
        assert sch.metrics.counters["requeued"] == 1
        assert sch.trace_count == 1
        for ref, uid in zip(fault_free, uids):
            r = results[uid]
            assert r.error is None and r.converged
            assert np.abs(ref.ranks - r.ranks).max() <= 1e-6

    def test_no_retry_fails_explicitly(self, g, fault_free):
        inj = FaultInjector(FaultPlan.of([FaultSpec("nan_slot", step=2,
                                                    slot=0)]))
        sch = SlotScheduler(g, slots=3, fault_injector=inj,
                            resilience=ResilienceConfig(max_retries=0),
                            **SMALL)
        uids = [sch.submit(s, tol=1e-6, max_iters=300)
                for s in _seeds(g, 6)]
        results = _drain_map(sch)
        failed = [r for r in results.values() if r.error]
        assert len(failed) == 1 and "quarantined" in failed[0].error
        assert not failed[0].converged
        for ref, uid in zip(fault_free, uids):
            if results[uid].error is None:
                assert np.abs(ref.ranks
                              - results[uid].ranks).max() <= 1e-6

    @pytest.mark.skipif(
        "XLA_FLAGS" not in os.environ
        or "host_platform_device_count" not in os.environ["XLA_FLAGS"],
        reason="needs forced host devices (CI reliability job)")
    def test_sharded_quarantine(self, g):
        """Same containment on the shard_map stepper: the psum'd
        residual is replicated, so every shard freezes the poisoned
        column in the same iteration."""
        import jax
        shards = jax.device_count()
        assert shards >= 2
        kw = dict(slots=2, method="pcpm_sharded", part_size=64,
                  num_shards=shards, chunk=4)
        ref = SlotScheduler(g, **kw)
        ru = [ref.submit(s, tol=1e-6, max_iters=300)
              for s in _seeds(g, 4)]
        refm = _drain_map(ref)
        inj = FaultInjector(FaultPlan.of([FaultSpec("nan_slot", step=2,
                                                    slot=1)]))
        sch = SlotScheduler(g, fault_injector=inj,
                            resilience=ResilienceConfig(max_retries=1),
                            **kw)
        su = [sch.submit(s, tol=1e-6, max_iters=300)
              for s in _seeds(g, 4)]
        out = _drain_map(sch)
        assert sch.metrics.counters["quarantined"] == 1
        assert sch.trace_count == 1
        for a, b in zip(ru, su):
            assert np.abs(refm[a].ranks - out[b].ranks).max() <= 1e-6


class TestStepFailure:
    def test_transient_retry(self, g, fault_free):
        inj = FaultInjector(FaultPlan.of([FaultSpec("step_error",
                                                    step=2)]))
        sch = SlotScheduler(
            g, slots=3, fault_injector=inj,
            resilience=ResilienceConfig(max_step_retries=1), **SMALL)
        uids = [sch.submit(s, tol=1e-6, max_iters=300)
                for s in _seeds(g, 6)]
        results = _drain_map(sch)
        assert sch.metrics.counters["stepper_failures"] == 1
        for ref, uid in zip(fault_free, uids):
            r = results[uid]
            assert r.converged and r.error is None
            assert np.abs(ref.ranks - r.ranks).max() <= 1e-6

    def test_hard_failure_fails_inflight_keeps_serving(self, g):
        """Past the retry budget the in-flight queries fail with
        explicit errors, the pool state is rebuilt, and the queued
        queries are still served correctly."""
        inj = FaultInjector(FaultPlan.of([FaultSpec("step_error",
                                                    step=2)]))
        sch = SlotScheduler(
            g, slots=3, fault_injector=inj,
            resilience=ResilienceConfig(max_step_retries=0), **SMALL)
        for s in _seeds(g, 6):
            sch.submit(s, tol=1e-6, max_iters=300)
        results = list(_drain_map(sch).values())
        errs = [r for r in results if r.error]
        oks = [r for r in results if not r.error]
        assert len(errs) == 3 and all("stepper failure" in r.error
                                      for r in errs)
        assert len(oks) == 3 and all(r.converged for r in oks)


class TestPlanFaults:
    def test_delta_failure_leaves_scheduler_intact(self, g):
        inj = FaultInjector(FaultPlan.of([FaultSpec("delta_error",
                                                    step=1)]))
        sch = SlotScheduler(g, slots=2, fault_injector=inj, **SMALL)
        sch.submit(tol=1e-6, max_iters=300)
        delta = repro.GraphDelta.insert(np.array([[1, 2]], np.int32))
        with pytest.raises(InjectedFault):
            sch.apply_delta(delta)
        assert sch.metrics.counters["delta_failures"] == 1
        assert sch.rebind_count == 0
        assert all(r.converged for r in sch.run_until_drained())

    def test_corrupt_plan_rejected_old_plan_serves(self, g):
        """A corrupted patched plan is caught by the integrity check
        BEFORE it is installed; the delta fails explicitly and the old
        plan keeps serving."""
        inj = FaultInjector(FaultPlan.of([FaultSpec("corrupt_plan",
                                                    step=1)]))
        sch = SlotScheduler(g, slots=2, fault_injector=inj, **SMALL)
        sch.submit(tol=1e-6, max_iters=300)
        delta = repro.GraphDelta.insert(np.array([[1, 2], [3, 4]],
                                                 np.int32))
        with pytest.raises(ValueError, match="plan integrity"):
            sch.apply_delta(delta)
        assert sch.metrics.counters["delta_failures"] == 1
        assert sch.rebind_count == 0
        assert all(r.converged for r in sch.run_until_drained())

    @pytest.mark.parametrize("method", ["pdpr", "bvgas", "pcpm",
                                        "pcpm_pallas"])
    def test_integrity_accepts_real_plans(self, method):
        """No false positives: fresh AND incrementally-patched plans of
        every backend pass the integrity check, and a corrupted copy of
        each fails it."""
        from repro.stream.patch import patch_plan
        g = generators.rmat(9, 8, seed=3)
        delta = repro.GraphDelta.insert(
            np.array([[1, 2], [300, 7], [8, 450]], np.int32))
        plan = build_plan(g, PlanConfig(method=method, part_size=64))
        check_plan_integrity(plan)
        p2 = patch_plan(plan, delta, apply_edges(g, delta))
        check_plan_integrity(p2)
        with pytest.raises(ValueError, match="plan integrity"):
            check_plan_integrity(corrupt_plan_arrays(plan))


class TestOverload:
    def test_burst_bounded_queue_explicit_rejections(self, g):
        res = ResilienceConfig(max_queue=4, default_deadline_s=30.0)
        sch = SlotScheduler(g, slots=2, resilience=res, **SMALL)
        for s in _seeds(g, 12):
            sch.submit(s, tol=1e-6, max_iters=300)
        assert sch.queued <= 4      # depth bounded DURING the burst
        results = list(_drain_map(sch).values())
        rejected = [r for r in results if r.error
                    and "rejected" in r.error]
        served = [r for r in results if not r.error]
        assert len(results) == 12              # every uid terminates
        assert len(rejected) == 12 - 4         # shed load is explicit
        assert sch.metrics.counters["rejected"] == 8
        assert all(r.converged for r in served)
        # p99 of ADMITTED queries stays within the deadline
        p99 = sch.metrics.percentile(99.0)
        assert p99 is not None and p99 <= 30.0

    def test_deadline_expires_in_queue(self, g):
        t = [0.0]
        sch = SlotScheduler(g, slots=1,
                            resilience=ResilienceConfig(max_queue=8),
                            **SMALL)
        sch.metrics.clock = lambda: t[0]
        sch.clock = sch.metrics.clock
        u1 = sch.submit(_seeds(g, 1)[0], tol=1e-6, max_iters=300)
        u2 = sch.submit(_seeds(g, 1)[0], tol=1e-6, max_iters=300,
                        deadline_s=0.5)
        t[0] = 1.0                 # u2's deadline passes while queued
        results = _drain_map(sch)
        assert "deadline" in results[u2].error
        assert results[u1].converged
        assert sch.metrics.counters["expired"] == 1

    def test_degrades_before_dropping(self, g):
        """Under measured SLO pressure a tight-tolerance query is
        admitted at the degraded tolerance instead of being dropped,
        and the result is marked."""
        sch = SlotScheduler(
            g, slots=2,
            resilience=ResilienceConfig(degrade_tol=1e-3), **SMALL)
        sch._iter_s = 0.05          # prime the pressure model:
        sch._query_iters = 60.0     # predicted service 3s > deadline
        u = sch.submit(_seeds(g, 1)[0], tol=1e-8, max_iters=300,
                       deadline_s=1.0)
        results = _drain_map(sch)
        assert results[u].degraded and results[u].error is None
        assert sch.metrics.counters["degraded"] == 1

    def test_priority_order(self, g):
        sch = SlotScheduler(g, slots=1, **SMALL)
        lo = sch.submit(_seeds(g, 1)[0], tol=1e-6, max_iters=300)
        sch.step()                  # lo occupies the only slot
        a = sch.submit(_seeds(g, 2)[1], tol=1e-6, max_iters=300,
                       priority=0)
        b = sch.submit(_seeds(g, 3)[2], tol=1e-6, max_iters=300,
                       priority=5)
        results = sch.run_until_drained()
        order = [r.uid for r in results]
        assert order.index(b) < order.index(a)


class TestSnapshotRestore:
    def test_roundtrip_matches_uninterrupted(self, g, fault_free):
        """snapshot -> (process death) -> restore resumes the in-flight
        queries to the SAME iteration counts and answers as the
        uninterrupted run — power iteration is memoryless given the
        rank column, so no cold recompute and no drift."""
        sch = SlotScheduler(g, slots=3, **SMALL)
        uids = [sch.submit(s, tol=1e-6, max_iters=300)
                for s in _seeds(g, 6)]
        for _ in range(3):
            sch.step()             # some in flight, some still queued
        assert sch.active_slots == 3 and sch.queued == 3
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "sched.npz")
            snapshot_scheduler(sch, path)
            restored = restore_scheduler(path, g, slots=3, **SMALL)
        results = _drain_map(restored)
        assert restored.trace_count == 1
        for ref, uid in zip(fault_free, uids):
            r = results[uid]
            assert r.iterations == ref.iterations
            assert np.abs(ref.ranks - r.ranks).max() <= 1e-6

    def test_restore_rejects_wrong_graph(self, g):
        sch = SlotScheduler(g, slots=2, **SMALL)
        sch.submit(tol=1e-6, max_iters=300)
        sch.step()
        other = generators.rmat(8, 8, seed=99)
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "sched.npz")
            snapshot_scheduler(sch, path)
            with pytest.raises(ValueError, match="fingerprint"):
                restore_scheduler(path, other, slots=2, **SMALL)

    def test_uid_floor_survives_restart(self, g):
        sch = SlotScheduler(g, slots=2, **SMALL)
        uid = sch.submit(tol=1e-6, max_iters=300)
        sch.step()
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "sched.npz")
            snapshot_scheduler(sch, path)
            restored = restore_scheduler(path, g, slots=2, **SMALL)
        assert restored.submit(tol=1e-6, max_iters=10) > uid


class TestRankCheckpoint:
    def test_file_roundtrip(self, g):
        ranks = np.random.default_rng(0).random(g.num_nodes,
                                                ).astype(np.float32)
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "ck.npz")
            save_rank_checkpoint(path, g, ranks, residual=1e-7,
                                 damping=0.85, dangling="none")
            ck = load_rank_checkpoint(path)
        assert np.array_equal(ck.ranks, ranks)
        assert ck.residual == pytest.approx(1e-7)
        assert ck.damping == 0.85 and ck.dangling == "none"

    def test_session_warm_restart(self, g):
        sess = repro.open(g, method="pcpm", part_size=64, tol=1e-6,
                          num_iterations=200)
        cold = sess.pagerank()
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "ck.npz")
            sess.save_checkpoint(path)
            fresh = repro.open(g, method="pcpm", part_size=64,
                               tol=1e-6, num_iterations=200)
            fresh.load_checkpoint(path)
            warm = fresh.pagerank(warm=True)
        assert len(warm.residuals) < len(cold.residuals)
        assert np.abs(np.asarray(warm.ranks)
                      - np.asarray(cold.ranks)).max() <= 1e-6

    def test_session_restart_across_delta_chain(self, g):
        """Checkpoint on g, restart after g+delta: the fingerprint
        lineage is verified and the warm solve routes through the
        residual-push updater instead of a cold run."""
        delta = repro.GraphDelta.insert(np.array([[3, 9], [100, 4]],
                                                 np.int32))
        sess = repro.open(g, method="pcpm", part_size=64, tol=1e-6,
                          num_iterations=200)
        sess.pagerank()
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "ck.npz")
            sess.save_checkpoint(path)
            restarted = repro.open(g, method="pcpm", part_size=64,
                                   tol=1e-6, num_iterations=200)
            restarted.apply_delta(delta)
            restarted.load_checkpoint(path, g_old=g, delta=delta)
            warm = restarted.pagerank(warm=True)
            cold = repro.open(restarted.graph, method="pcpm",
                              part_size=64, tol=1e-6,
                              num_iterations=200).pagerank()
        assert len(warm.residuals) < len(cold.residuals)
        assert np.abs(np.asarray(warm.ranks)
                      - np.asarray(cold.ranks)).max() <= 1e-6

    def test_checkpoint_rejects_wrong_lineage(self, g):
        sess = repro.open(g, method="pcpm", part_size=64, tol=1e-6,
                          num_iterations=200)
        sess.pagerank()
        other = generators.rmat(8, 8, seed=99)
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "ck.npz")
            sess.save_checkpoint(path)
            s2 = repro.open(other, method="pcpm", part_size=64)
            with pytest.raises(ValueError, match="different graph"):
                s2.load_checkpoint(path)
            with pytest.raises(ValueError, match="delta chain"):
                s2.load_checkpoint(
                    path, g_old=g, delta=repro.GraphDelta.insert(
                        np.array([[1, 1]], np.int32)))
