"""Continuous-batching PageRank serving subsystem (ISSUE 3):

- acceptance workload: 50 mixed-convergence requests on a B=4 slot
  pool, zero retraces (trace_count == 1), served ranks vs
  pagerank_reference / the dense personalized oracle to <= 1e-5 Linf;
- per-slot early exit: a slow slot iterates past a fast slot's
  convergence, and the fast slot's frozen ranks stay pinned to the
  oracle at exactly its own iteration count;
- slot reuse after convergence; no-retrace across mixed seeds=None /
  ndarray / top-k queries;
- on-device top-k agrees with the full-vector ranks to <= 1e-6 and
  ships only (k,) ids+scores;
- GraphRegistry: several compiled graphs in one process, warm-loaded
  from graphs/io.py;
- PageRankServer uniform-batch caching (satellite);
- ServeEngine head-of-line regression (satellite): a never-fitting
  request must not starve the queue behind it.
"""
import os
import tempfile

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graphs import generators, io as graph_io
from repro.core import pagerank_reference
from repro.serve import (GraphRegistry, PageRankServer, ServeMetrics,
                         SlotScheduler)


def personalized_oracle(g, seed, iterations, damping=0.85):
    """Dense personalized-PageRank oracle for a single seed vector."""
    n = g.num_nodes
    A = np.zeros((n, n))
    np.add.at(A, (g.src, g.dst), 1.0)
    inv = np.where(g.out_degree == 0, 0.0,
                   1.0 / np.maximum(g.out_degree, 1))
    v = np.asarray(seed, dtype=np.float64)
    v = v / v.sum()
    x = v.copy()
    for _ in range(iterations):
        x = (1 - damping) * v + damping * (A.T @ (x * inv))
    return x


@pytest.fixture(scope="module")
def graph():
    return generators.rmat(7, 8, seed=9)


# ----------------------------------------------------- acceptance workload
class TestContinuousBatching:
    def test_50_request_mixed_workload_zero_retrace(self, graph):
        """The headline: 50 requests with wildly different convergence
        times share a B=4 pool; everything is served correctly with a
        single stepper trace."""
        g = graph
        n = g.num_nodes
        rng = np.random.default_rng(3)
        sch = SlotScheduler(g, slots=4, method="pcpm", part_size=32,
                            chunk=4)
        assert sch.trace_count == 1          # traced once, at lowering
        assert sch.admit_trace_count == 1

        expected = {}
        for i in range(50):
            kind = i % 4
            if kind == 0:                    # uniform, fixed iterations
                uid = sch.submit(tol=0.0, max_iters=20)
                expected[uid] = ("uniform", None, 20)
            elif kind == 1:                  # personalized, loose tol
                seeds = np.zeros(n, np.float32)
                seeds[rng.integers(0, n)] = 1.0
                uid = sch.submit(seeds, tol=1e-3, max_iters=200)
                expected[uid] = ("seeded", seeds, None)
            elif kind == 2:                  # personalized, tight tol
                seeds = np.zeros(n, np.float32)
                seeds[rng.integers(0, n, size=4)] = 1.0
                uid = sch.submit(seeds, tol=1e-6, max_iters=200)
                expected[uid] = ("seeded", seeds, None)
            else:                            # uniform top-k
                uid = sch.submit(top_k=10, tol=0.0, max_iters=20)
                expected[uid] = ("topk", None, 20)

        results = sch.run_until_drained()
        assert len(results) == 50
        assert sch.trace_count == 1          # ZERO retraces under load
        assert sch.admit_trace_count == 1

        iters_seen = set()
        ref20 = pagerank_reference(g, num_iterations=20)
        for r in results:
            kind, seeds, fixed_iters = expected[r.uid]
            if kind == "uniform":
                assert r.iterations == 20
                assert np.abs(r.ranks - ref20).max() <= 1e-5
            elif kind == "seeded":
                assert r.converged
                oracle = personalized_oracle(g, seeds, r.iterations)
                assert np.abs(r.ranks - oracle).max() <= 1e-5
                iters_seen.add(r.iterations)
            else:
                assert r.top_ids.shape == (10,)
                assert r.top_scores.shape == (10,)
                top = np.sort(ref20)[-10:][::-1]
                np.testing.assert_allclose(r.top_scores, top, atol=1e-5)
        # genuinely mixed convergence: tolerances produced different
        # per-slot iteration counts inside shared pools
        assert len(iters_seen) > 1

    def test_per_slot_early_exit(self, graph):
        """A fast (loose-tol) slot freezes while its slow neighbour
        keeps iterating in the same pool — and the frozen column is
        bit-stable at the oracle for exactly its own iteration count."""
        g = graph
        sch = SlotScheduler(g, slots=2, method="pcpm", part_size=32,
                            chunk=4)
        fast = sch.submit(tol=1e-3, max_iters=200)
        slow = sch.submit(tol=1e-6, max_iters=200)
        results = sch.run_until_drained()
        by = {r.uid: r for r in results}
        assert by[fast].converged and by[slow].converged
        # the slow slot iterated past the fast slot's convergence
        assert by[fast].iterations < by[slow].iterations
        assert results[0].uid == fast        # and completed first
        for uid in (fast, slow):
            ref = pagerank_reference(
                g, num_iterations=by[uid].iterations)
            assert np.abs(by[uid].ranks - ref).max() <= 1e-5
        assert sch.trace_count == 1

    def test_slot_reuse_after_convergence(self, graph):
        """More queries than slots: freed columns are re-admitted (no
        retrace) and every query is served."""
        sch = SlotScheduler(graph, slots=2, method="pcpm",
                            part_size=32, chunk=4)
        uids = [sch.submit(tol=0.0, max_iters=5 + 3 * i)
                for i in range(7)]
        results = sch.run_until_drained()
        assert sorted(r.uid for r in results) == sorted(uids)
        assert sch.trace_count == 1
        assert sch.admit_trace_count == 1
        ref = {it: pagerank_reference(graph, num_iterations=it)
               for it in {5 + 3 * i for i in range(7)}}
        for r, it in zip(sorted(results, key=lambda r: r.uid),
                         (5 + 3 * i for i in range(7))):
            assert r.iterations == it
            assert np.abs(r.ranks - ref[it]).max() <= 1e-5

    def test_queue_beyond_pool_drains_fifo(self, graph):
        sch = SlotScheduler(graph, slots=2, method="pcpm",
                            part_size=32, chunk=8)
        for _ in range(6):
            sch.submit(tol=0.0, max_iters=10)
        assert sch.queued == 6 and sch.active_slots == 0
        sch.step()
        assert sch.active_slots == 2 and sch.queued == 4
        sch.run_until_drained()
        assert sch.queued == 0 and sch.active_slots == 0
        assert len(sch.completed) == 6

    def test_invalid_inputs_rejected(self, graph):
        sch = SlotScheduler(graph, slots=1, method="pcpm",
                            part_size=32)
        with pytest.raises(ValueError, match="positive"):
            sch.submit(np.zeros(graph.num_nodes, np.float32))
        with pytest.raises(ValueError, match="top_k"):
            sch.submit(top_k=0)
        with pytest.raises(ValueError, match="max_iters"):
            sch.submit(max_iters=-1)
        with pytest.raises(ValueError, match="slot"):
            SlotScheduler(graph, slots=0)


# ------------------------------------------------------------- top-k path
class TestTopK:
    def test_topk_matches_full_vector(self, graph):
        """Top-k ids/scores agree with the served full vector to 1e-6,
        and only (k,) arrays come back from device."""
        g = graph
        seeds = np.zeros(g.num_nodes, np.float32)
        seeds[11] = seeds[29] = 1.0
        sch = SlotScheduler(g, slots=2, method="pcpm", part_size=32,
                            chunk=4)
        u_full = sch.submit(seeds, tol=0.0, max_iters=25)
        u_topk = sch.submit(seeds, tol=0.0, max_iters=25, top_k=16)
        by = {r.uid: r for r in sch.run_until_drained()}
        full = by[u_full].ranks
        tk = by[u_topk]
        assert tk.ranks is None              # top-k ships no n-vector
        assert tk.top_ids.shape == (16,)
        assert tk.top_scores.shape == (16,)
        np.testing.assert_allclose(tk.top_scores,
                                   np.sort(full)[-16:][::-1], atol=1e-6)
        np.testing.assert_allclose(full[tk.top_ids], tk.top_scores,
                                   atol=1e-6)

    def test_distinct_k_compiles_once_each(self, graph):
        sch = SlotScheduler(graph, slots=1, method="pcpm",
                            part_size=32)
        for _ in range(2):
            for k in (5, 9):
                sch.submit(top_k=k, tol=0.0, max_iters=5)
        sch.run_until_drained()
        assert sorted(sch._topk_cache) == [5, 9]
        assert sch.trace_count == 1


# ------------------------------------------------------- sharded serving
class TestShardedScheduler:
    """Degenerate 1-shard coverage of the sharded chunk stepper in
    tier-1 (the 8-device suite lives in test_distributed.py)."""

    def test_sharded_serving_matches_reference(self, graph):
        g = graph
        sch = SlotScheduler(g, slots=2, sharded=True, chunk=4)
        assert sch.sharded
        uid_u = sch.submit(tol=0.0, max_iters=15)
        seeds = np.zeros(g.num_nodes, np.float32)
        seeds[7] = 2.0
        uid_p = sch.submit(seeds, tol=0.0, max_iters=15, top_k=5)
        by = {r.uid: r for r in sch.run_until_drained()}
        ref = pagerank_reference(g, num_iterations=15)
        assert np.abs(by[uid_u].ranks - ref).max() <= 1e-5
        oracle = personalized_oracle(g, seeds, 15)
        np.testing.assert_allclose(by[uid_p].top_scores,
                                   np.sort(oracle)[-5:][::-1],
                                   atol=1e-5)
        # pad rows can never appear in top-k ids
        assert (by[uid_p].top_ids < g.num_nodes).all()
        assert sch.trace_count == 1


# ------------------------------------------------------------- registry
class TestGraphRegistry:
    def test_multi_graph_process(self, graph):
        g2 = generators.uniform_random(200, 2000, seed=5)
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "uni.npz")
            graph_io.save(path, g2)
            reg = GraphRegistry(slots=2, method="pcpm", part_size=32,
                                chunk=4)
            reg.add("kron", graph)
            reg.load("uni", path)            # warm-loaded + compiled
            assert reg.names() == ["kron", "uni"]
            assert "kron" in reg and len(reg) == 2
            assert reg.get("uni").trace_count == 1
            reg.submit("kron", tol=0.0, max_iters=10)
            reg.submit("uni", tol=0.0, max_iters=10)
            out = reg.run_until_drained()
        for name, g in (("kron", graph), ("uni", g2)):
            ref = pagerank_reference(g, num_iterations=10)
            assert np.abs(out[name][0].ranks - ref).max() <= 1e-5
        with pytest.raises(KeyError, match="unknown graph"):
            reg.get("nope")
        with pytest.raises(ValueError, match="already registered"):
            reg.add("kron", graph)


# -------------------------------------------------------------- metrics
class TestMetrics:
    def test_trace_lifecycle_and_summary(self):
        t = iter(np.arange(0.0, 10.0, 0.5))
        m = ServeMetrics(clock=lambda: float(next(t)))
        m.submitted(0)          # t=0.0
        m.submitted(1)          # t=0.5
        m.admitted(0)           # t=1.0
        m.admitted(1)           # t=1.5
        m.completed(0, iterations=12, converged=True)    # t=2.0
        m.completed(1, iterations=30, converged=False)   # t=2.5
        s = m.summary()
        assert s["count"] == 2
        assert s["mean_iterations"] == 21.0
        assert s["converged_frac"] == 0.5
        # span = last done (2.5) - first submit (0.0)
        assert abs(s["qps"] - 2 / 2.5) < 1e-9
        assert abs(s["p99_ms"] - 2000.0) < 1e-6   # uid0: 2.0s latency
        assert m.completed_count == 2

    def test_shared_metrics_across_schedulers(self):
        """Regression: uids are process-unique, so one ServeMetrics
        shared by several schedulers (a registry's aggregate view)
        never overwrites traces across graphs."""
        shared = ServeMetrics()
        g1 = generators.rmat(6, 4, seed=3)
        g2 = generators.uniform_random(100, 800, seed=4)
        reg = GraphRegistry(slots=2, method="pcpm", part_size=16,
                            metrics=shared)
        reg.add("a", g1)
        reg.add("b", g2)
        u1 = reg.submit("a", tol=0.0, max_iters=5)
        u2 = reg.submit("b", tol=0.0, max_iters=5)
        assert u1 != u2
        reg.run_until_drained()
        assert shared.summary()["count"] == 2

    def test_scheduler_populates_metrics(self):
        g = generators.rmat(6, 4, seed=3)
        sch = SlotScheduler(g, slots=2, method="pcpm", part_size=16)
        sch.submit(tol=0.0, max_iters=5)
        sch.submit(tol=0.0, max_iters=5)
        sch.run_until_drained()
        s = sch.metrics.summary()
        assert s["count"] == 2
        assert s["mean_iterations"] == 5.0
        assert s["qps"] > 0


# ---------------------------------------- PageRankServer uniform cache
class TestUniformBatchCache:
    def test_cached_base_buffer_reused(self, graph):
        srv = PageRankServer(graph, method="pcpm", part_size=32,
                             num_iterations=10)
        pr1, it1, _ = srv.query()
        assert srv._uniform_cache is not None
        host, base = srv._uniform_cache
        pr2, it2, _ = srv.query()
        assert srv._uniform_cache[1] is base   # device buffer reused
        assert srv._uniform_cache[0] is host   # host batch not rebuilt
        np.testing.assert_array_equal(np.asarray(pr1), np.asarray(pr2))
        assert srv.trace_count == 1
        # seeded queries bypass and do not disturb the cache
        seeds = np.random.default_rng(0).random(
            graph.num_nodes).astype(np.float32)
        srv.query(seeds)
        assert srv._uniform_cache[1] is base

    def test_cache_matches_reference(self, graph):
        srv = PageRankServer(graph, method="pcpm", part_size=32,
                             num_iterations=20)
        pr, _, _ = srv.query()
        pr2, _, _ = srv.query()
        ref = pagerank_reference(graph, num_iterations=20)
        np.testing.assert_allclose(np.asarray(pr2), ref, rtol=1e-3,
                                   atol=1e-7)


# ------------------------------------------- ServeEngine head-of-line
class TestServeEngineHeadOfLine:
    def _engine(self, batch_slots=2, max_len=16):
        from repro.configs import get
        from repro.models import transformer as tf
        from repro.serve import ServeEngine
        cfg = get("tinyllama-1.1b").scaled(n_layers=1, d_model=32,
                                           n_heads=2, d_ff=64, vocab=64)
        params = tf.init_lm(cfg, jax.random.key(5))
        return ServeEngine(cfg, params, batch_slots=batch_slots,
                           max_len=max_len)

    def test_never_fitting_head_does_not_starve_queue(self):
        """Regression: a request whose prompt+budget can never fit the
        static cache used to pin the queue head forever; now it is
        rejected and the requests behind it are served."""
        from repro.serve import Request
        eng = self._engine(max_len=16)
        huge = Request(uid=0, prompt=list(range(1, 41)),
                       max_new_tokens=4)            # 40 + 4 >> 16
        small = Request(uid=1, prompt=[3, 5], max_new_tokens=2)
        eng.run_until_drained([huge, small], max_steps=200)
        assert small.done and small.error is None
        assert len(small.generated) == 2
        assert huge.done and huge.error is not None
        assert "max_len" in huge.error
        assert not huge.generated                  # never admitted

    def test_fitting_requests_unaffected(self):
        from repro.serve import Request
        eng = self._engine(max_len=32)
        reqs = [Request(uid=i, prompt=[1 + i, 2 + i], max_new_tokens=3)
                for i in range(5)]
        eng.run_until_drained(reqs)
        assert all(r.done and r.error is None for r in reqs)
        assert all(len(r.generated) == 3 for r in reqs)

    def test_add_request_rejects_unfitting(self):
        from repro.serve import Request
        eng = self._engine(max_len=16)
        assert not eng.add_request(
            Request(uid=0, prompt=list(range(1, 20)), max_new_tokens=4))
        assert eng.active == 0
        # exact-boundary request (prompt + budget == max_len) fits and
        # completes in full
        boundary = Request(uid=1, prompt=list(range(1, 13)),
                           max_new_tokens=4)
        assert eng.fits(boundary)
        eng.run_until_drained([boundary], max_steps=100)
        assert boundary.done and boundary.error is None
        assert len(boundary.generated) == 4
