"""Property tests for the distributed (sharded) PNG layout —
the §VII generalization's structural invariants, host-side only."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core.distributed import build_sharded_png
from repro.graphs.generators import rmat, uniform_random


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(5, 9),
       st.sampled_from([2, 4, 8]), st.booleans())
def test_sharded_png_invariants(seed, scale, shards, use_rmat):
    g = (rmat(scale, 4, seed=seed % 1000) if use_rmat
         else uniform_random(1 << scale, (1 << scale) * 4,
                             seed=seed % 1000))
    lay = build_sharded_png(g, shards)

    # every edge appears exactly once across destination-shard streams
    real_edges = int((lay.edge_dst < lay.shard_size).sum())
    assert real_edges == g.num_edges

    # dedup can only help: updates <= edges, on AND off the wire
    total_updates = int((lay.send_ids >= 0).sum())
    assert total_updates <= g.num_edges
    assert lay.wire_updates <= lay.wire_edges
    assert lay.wire_compression >= 1.0

    # every real edge's receive-buffer slot points at a real update
    u = lay.send_ids.shape[2]
    flat_real = lay.send_ids.reshape(shards, -1) \
        .transpose(1, 0)  # not used; keep send layout opaque
    for s in range(shards):
        e_mask = lay.edge_dst[s] < lay.shard_size
        slots = lay.edge_upd[s][e_mask]
        assert (slots < shards * u).all()
        src_shard = slots // u
        rank = slots % u
        assert (lay.send_ids[src_shard, s, rank] >= 0).all()

    # update source ids are valid local ids
    valid = lay.send_ids[lay.send_ids >= 0]
    assert (valid < lay.shard_size).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(5, 9),
       st.sampled_from([2, 4, 8]), st.booleans())
def test_sharded_gather_schedule_invariants(seed, scale, shards,
                                            use_rmat):
    """The per-shard blocked gather schedule (DESIGN.md §3 applied
    shard-locally) must cover every real edge exactly once and keep all
    pad slots mathematically inert."""
    g = (rmat(scale, 4, seed=seed % 1000) if use_rmat
         else uniform_random(1 << scale, (1 << scale) * 4,
                             seed=seed % 1000))
    lay = build_sharded_png(g, shards)
    s, u = shards, lay.send_ids.shape[2]
    ssz = lay.shard_size
    mp = lay.eui_padded.shape[1]
    zero_slot = s * u

    # stream is padded to a whole number of blocks
    assert mp % lay.gather_block == 0
    # per-shard edge stream is sorted by local destination (the run
    # structure the blocked reduction depends on)
    for sh in range(s):
        real = lay.edge_dst[sh][lay.edge_dst[sh] < ssz]
        assert (np.diff(real) >= 0).all()

    for sh in range(s):
        st_, en, pd = (lay.piece_start[sh], lay.piece_end[sh],
                       lay.piece_dst[sh])
        real_p = pd < ssz
        # real pieces tile the real-edge prefix: disjoint, in-bounds,
        # and their sizes add up to the real edge count of the shard
        assert (st_[real_p] <= en[real_p]).all()
        assert (en[real_p] < mp).all()
        sizes = (en[real_p] - st_[real_p] + 1)
        n_real = int((lay.edge_dst[sh] < ssz).sum())
        # real pieces tile the real edges exactly (pads have the
        # sentinel dst, so they always start their own piece)
        assert int(sizes.sum()) == n_real
        # every real piece's covered slots carry real receive-buffer
        # indices (strictly below the zero slot)
        for a, b in zip(st_[real_p], en[real_p]):
            sl = lay.eui_padded[sh, a:b + 1]
            assert (sl < zero_slot).all()
        # pad pieces are inert: sentinel destination
        assert (pd[~real_p] == ssz).all()

    # pad entries of the padded stream point at the zero slot
    tail = lay.eui_padded[:, :]
    pad_mask = np.ones((s, mp), dtype=bool)
    e_max = lay.edge_dst.shape[1]
    pad_mask[:, :e_max] = lay.edge_dst == ssz
    assert (tail[pad_mask] == zero_slot).all()
