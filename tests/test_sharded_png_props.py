"""Property tests for the distributed (sharded) PNG layout —
the §VII generalization's structural invariants, host-side only."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core.distributed import build_sharded_png
from repro.graphs.generators import rmat, uniform_random


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(5, 9),
       st.sampled_from([2, 4, 8]), st.booleans())
def test_sharded_png_invariants(seed, scale, shards, use_rmat):
    g = (rmat(scale, 4, seed=seed % 1000) if use_rmat
         else uniform_random(1 << scale, (1 << scale) * 4,
                             seed=seed % 1000))
    lay = build_sharded_png(g, shards)

    # every edge appears exactly once across destination-shard streams
    real_edges = int((lay.edge_dst < lay.shard_size).sum())
    assert real_edges == g.num_edges

    # dedup can only help: updates <= edges, on AND off the wire
    total_updates = int((lay.send_ids >= 0).sum())
    assert total_updates <= g.num_edges
    assert lay.wire_updates <= lay.wire_edges
    assert lay.wire_compression >= 1.0

    # every real edge's receive-buffer slot points at a real update
    u = lay.send_ids.shape[2]
    flat_real = lay.send_ids.reshape(shards, -1) \
        .transpose(1, 0)  # not used; keep send layout opaque
    for s in range(shards):
        e_mask = lay.edge_dst[s] < lay.shard_size
        slots = lay.edge_upd[s][e_mask]
        assert (slots < shards * u).all()
        src_shard = slots // u
        rank = slots % u
        assert (lay.send_ids[src_shard, s, rank] >= 0).all()

    # update source ids are valid local ids
    valid = lay.send_ids[lay.send_ids >= 0]
    assert (valid < lay.shard_size).all()
