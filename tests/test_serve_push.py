"""Parity + routing suite for the forward-push personalized-query
backend (serve/push.py, DESIGN.md §11).

The documented accuracy contract: a push (or stepper) run stopped at
tolerance ``tol`` is within ``tol * d/(1-d)`` L1 of the exact
personalized fixed point.  Every parity check here asserts against
that bound — push vs a dense f64 oracle, push vs the masked-stepper
route, host vs device push — across the seed shapes that exercise
different code paths (hub, leaf, dangling sink, uniform).

Routing invariants: push queries are served inline and never touch
the stepper, so ``trace_count`` / ``admit_trace_count`` stay 1 when
routes interleave; a push that stops above its bound falls back to
the stepper warm-started at the estimate with its sweeps charged
against the budget.
"""
import numpy as np
import pytest

from repro.core.backends import get_backend
from repro.core.spmv import SpMVEngine
from repro.graphs import generators
from repro.serve import SlotScheduler
from repro.serve.push import PushQueryEngine
from repro.serve.topk import host_topk

DAMPING = 0.85
SMALL = dict(method="pcpm", part_size=64, chunk=4)


@pytest.fixture(scope="module")
def g():
    return generators.rmat(10, 8, seed=1)


@pytest.fixture(scope="module")
def engine(g):
    return SpMVEngine(g, method="pcpm", part_size=64)


@pytest.fixture(scope="module")
def dense_w(g):
    """Dense damped-free transition operator W[v, u] = 1/deg[u]."""
    n = g.num_nodes
    W = np.zeros((n, n), np.float64)
    np.add.at(W, (g.dst, g.src),
              1.0 / np.maximum(g.out_degree, 1)[g.src])
    return W


def personalized_oracle(W, seed, *, damping=DAMPING, iters=3000,
                        tol=1e-13):
    """f64 fixed point of x = (1-d) s + d W x (dangling='none')."""
    s = seed.astype(np.float64)
    s = s / s.sum()
    x = s.copy()
    base = (1.0 - damping) * s
    for _ in range(iters):
        x2 = base + damping * (W @ x)
        if np.abs(x2 - x).sum() < tol:
            break
        x = x2
    return x2


def seed_catalog(g):
    """One-hot hub / leaf / dangling seeds + the uniform vector."""
    n = g.num_nodes
    deg = np.asarray(g.out_degree)
    hub = int(np.argmax(deg))
    nonzero = np.nonzero(deg > 0)[0]
    leaf = int(nonzero[np.argmin(deg[nonzero])])
    sinks = np.nonzero(deg == 0)[0]
    out = {}
    for name, node in (("hub", hub), ("leaf", leaf)):
        s = np.zeros(n, np.float32)
        s[node] = 1.0
        out[name] = s
    if sinks.size:
        s = np.zeros(n, np.float32)
        s[sinks[0]] = 1.0
        out["dangling"] = s
    out["uniform"] = np.full(n, 1.0 / n, np.float32)
    return out


class TestPushParity:
    @pytest.mark.parametrize("tol", [1e-2, 1e-3, 1e-4])
    def test_host_push_vs_oracle(self, g, engine, dense_w, tol):
        eng = PushQueryEngine(g, engine)
        bound = tol * DAMPING / (1.0 - DAMPING) + 1e-5  # f32 slack
        for name, seed in seed_catalog(g).items():
            res = eng.query(seed, tol=tol, max_sweeps=400)
            assert res.converged, name
            oracle = personalized_oracle(dense_w, seed)
            err = float(np.abs(res.estimate - oracle).sum())
            assert err <= bound, (name, tol, err, bound)

    def test_device_push_matches_host(self, g, engine, dense_w):
        host = PushQueryEngine(g, engine, mode="host")
        dev = PushQueryEngine(g, engine, mode="device")
        for name, seed in seed_catalog(g).items():
            rh = host.query(seed, tol=1e-3, max_sweeps=400)
            rd = dev.query(seed, tol=1e-3, max_sweeps=400)
            assert rh.converged and rd.converged, name
            oracle = personalized_oracle(dense_w, seed)
            bound = 1e-3 * DAMPING / (1.0 - DAMPING) + 1e-5
            assert np.abs(rd.estimate - oracle).sum() <= bound, name
            # same fixed point, independent stopping points
            assert np.abs(rd.estimate - rh.estimate).sum() <= 2 * bound

    def test_dangling_seed_exact_in_zero_sweeps(self, g, engine):
        """A sink's mass never propagates: the push answers with the
        closed form (1-d)*seed at the sink, exactly, without a single
        sweep."""
        deg = np.asarray(g.out_degree)
        sinks = np.nonzero(deg == 0)[0]
        assert sinks.size, "fixture graph must have dangling nodes"
        s = np.zeros(g.num_nodes, np.float32)
        s[sinks[0]] = 1.0
        res = PushQueryEngine(g, engine).query(s, tol=1e-3)
        assert res.sweeps == 0 and res.converged
        expect = np.zeros(g.num_nodes, np.float32)
        expect[sinks[0]] = 1.0 - DAMPING
        np.testing.assert_allclose(res.estimate, expect, atol=1e-7)

    def test_push_vs_stepper_route(self, g):
        """Same query down both routes lands within 2x the documented
        bound of each other (each is within one bound of the fixed
        point)."""
        sch = SlotScheduler(g, slots=2, **SMALL)
        tol = 1e-3
        for seed in seed_catalog(g).values():
            up = sch.submit(seed, tol=tol, max_iters=400, route="push")
            us = sch.submit(seed, tol=tol, max_iters=400,
                            route="stepper")
            sch.run_until_drained()
            out = {r.uid: r for r in sch.completed}
            rp, rs = out[up], out[us]
            assert rp.converged and rs.converged
            err = float(np.abs(rp.ranks - rs.ranks).sum())
            assert err <= 2 * tol * DAMPING / (1.0 - DAMPING) + 1e-5

    def test_topk_id_agreement(self, g, engine, dense_w):
        """Push top-k ids match the oracle's top-k, modulo ids whose
        oracle score is within the error bound of the k-th score (a
        genuine tie at the resolution the tolerance buys)."""
        k, tol = 16, 1e-3
        bound = tol * DAMPING / (1.0 - DAMPING)
        eng = PushQueryEngine(g, engine)
        for name, seed in seed_catalog(g).items():
            res = eng.query(seed, tol=tol, top_k=k, max_sweeps=400)
            oracle = personalized_oracle(dense_w, seed)
            oracle_ids, oracle_scores = host_topk(oracle, k)
            kth = oracle_scores[-1]
            push_set, oracle_set = set(res.top_ids), set(oracle_ids)
            for i in oracle_ids:
                if oracle[i] > kth + 2 * bound:
                    assert i in push_set, (name, int(i))
            for i in res.top_ids:
                if i not in oracle_set:
                    assert oracle[i] >= kth - 2 * bound, (name, int(i))


class TestPushRouting:
    def test_interleaved_routes_zero_retrace(self, g):
        sch = SlotScheduler(g, slots=4, **SMALL)
        rng = np.random.default_rng(0)
        n = g.num_nodes
        uids = []
        for i in range(24):
            s = np.zeros(n, np.float32)
            s[rng.integers(0, n)] = 1.0
            tol = 1e-2 if i % 2 == 0 else 1e-6  # push / stepper mix
            uids.append(sch.submit(s, top_k=8, tol=tol, max_iters=300))
        sch.run_until_drained()
        out = {r.uid: r for r in sch.completed}
        assert len(out) == 24 and all(u in out for u in uids)
        assert all(out[u].converged for u in uids)
        assert sch.trace_count == 1
        assert sch.admit_trace_count == 1
        assert sch.metrics.counters["push_served"] == 12

    def test_auto_routes_only_loose_topk_personalized(self, g):
        sch = SlotScheduler(g, slots=2, **SMALL)
        n = g.num_nodes
        s = np.zeros(n, np.float32)
        s[3] = 1.0
        sch.submit(s, top_k=8, tol=1e-3, max_iters=300)       # push
        sch.submit(s, top_k=8, tol=1e-6, max_iters=300)       # tight
        sch.submit(s, tol=1e-3, max_iters=300)                # full vec
        sch.submit(None, top_k=8, tol=1e-3, max_iters=300)    # uniform
        sch.run_until_drained()
        assert sch.metrics.counters["push_served"] == 1
        assert all(r.converged for r in sch.completed)

    def test_fallback_resumes_on_stepper(self, g):
        """A push stopped above its bound hands the query to the
        stepper warm-started at the estimate: total iterations equal
        the pure-stepper run's (the push sweeps ARE the first stepper
        iterations), and the answer matches."""
        n = g.num_nodes
        s = np.zeros(n, np.float32)
        s[5] = 1.0
        sch = SlotScheduler(g, slots=2, push_max_sweeps=6, **SMALL)
        up = sch.submit(s, top_k=8, tol=1e-6, max_iters=300,
                        route="push")
        sch.run_until_drained()
        us = sch.submit(s, top_k=8, tol=1e-6, max_iters=300,
                        route="stepper")
        sch.run_until_drained()
        out = {r.uid: r for r in sch.completed}
        rp, rs = out[up], out[us]
        assert sch.metrics.counters["push_fallbacks"] == 1
        assert rp.converged and rs.converged
        assert np.array_equal(rp.top_ids, rs.top_ids)
        # warm start = identical iterates: the chunked stepper may
        # overshoot by at most one chunk relative to the pure run
        assert abs(rp.iterations - rs.iterations) <= SMALL["chunk"]
        assert sch.trace_count == 1

    def test_explicit_push_validation(self, g):
        sch = SlotScheduler(g, slots=2, **SMALL)
        n = g.num_nodes
        s = np.zeros(n, np.float32)
        s[0] = 1.0
        with pytest.raises(ValueError, match="needs a seed"):
            sch.submit(None, tol=1e-3, route="push")
        with pytest.raises(ValueError, match="tol > 0"):
            sch.submit(s, tol=0.0, route="push")
        with pytest.raises(ValueError, match="tol > 0"):
            sch.submit(s, tol=1e-3, max_iters=0, route="push")
        with pytest.raises(ValueError, match="route"):
            sch.submit(s, tol=1e-3, route="bogus")
        # a failed validation never allocates a uid / trace
        assert len(sch.metrics.traces) == 0

    def test_redistribute_routes_to_stepper(self, g):
        sch = SlotScheduler(g, slots=2, dangling="redistribute",
                            **SMALL)
        s = np.zeros(g.num_nodes, np.float32)
        s[0] = 1.0
        with pytest.raises(ValueError, match="dangling"):
            sch.submit(s, tol=1e-2, route="push")
        u = sch.submit(s, top_k=8, tol=1e-2, max_iters=300)  # auto
        sch.run_until_drained()
        assert sch.metrics.counters["push_served"] == 0
        assert {r.uid: r for r in sch.completed}[u].converged

    def test_capability_flags(self):
        assert get_backend("pcpm").supports_push_query
        assert get_backend("pdpr").supports_push_query
        assert get_backend("bvgas").supports_push_query
        assert get_backend("pcpm_pallas").supports_push_query
        assert not get_backend("pcpm_sharded").supports_push_query

    def test_engine_rejects_redistribute(self, g, engine):
        with pytest.raises(ValueError, match="dangling"):
            PushQueryEngine(g, engine, dangling="redistribute")


class TestHostTopk:
    def test_matches_device_tiebreak(self):
        import jax.numpy as jnp
        from repro.serve.topk import topk_ranks
        rng = np.random.default_rng(3)
        # duplicate scores force the tie-break path
        vals = rng.integers(0, 50, size=200).astype(np.float32) / 50.0
        ids_h, sc_h = host_topk(vals, 17)
        ids_d, sc_d = topk_ranks(jnp.asarray(vals), 17)
        np.testing.assert_array_equal(ids_h, np.asarray(ids_d))
        np.testing.assert_array_equal(sc_h, np.asarray(sc_d))
