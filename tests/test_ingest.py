"""Ingest subsystem acceptance (ISSUE 8).

- streaming SNAP/TSV parsing: chunked, gzip-sniffed, comment-aware,
  crisp errors with line numbers;
- NodeIdMapping: external (64-bit / string) <-> dense int32 internal,
  persisted next to the plan npz;
- pipeline: link filters, self-loop/dup policy, virtual-link mass;
- END TO END: fixture file -> parse -> id map -> filter -> reorder ->
  Session.pagerank() + serve top-k, every result in ORIGINAL external
  ids, matching the dense float64 oracle;
- reorder-in-plan wiring: distinct cache entries per ordering, plan
  save/load round-trips the permutation, warm-load via install_plan,
  scheduler parity across orderings, honest apply_delta guards.
"""
import gzip
import io
from pathlib import Path

import numpy as np
import pytest

import repro
from repro.core import pagerank_reference
from repro.core.plan import (build_plan, install_plan, plan_cache_stats)
from repro.graphs import generators
from repro.graphs.io import load_plan
from repro.ingest import (LinkFilter, NodeIdMapping, ParseError,
                          ingest_edge_list, iter_edge_chunks,
                          read_edge_list)

FIXTURE = Path(__file__).resolve().parent / "fixtures" / "web_sample.txt"
OFFSITE = LinkFilter("offsite", lambda s, d: d < 900_000_000)


def oracle_top(ref, k):
    """Top-k internal ids of a rank vector, score desc then id asc —
    the same tie-break ``Session.top_ranked`` uses."""
    part = np.argpartition(-ref, k - 1)[:k]
    return part[np.lexsort((part, -ref[part]))]


# -------------------------------------------------------------- parser
class TestParse:
    def test_fixture_streams_in_chunks(self):
        s, d = read_edge_list(FIXTURE)
        assert s.dtype == np.int64 and s.size == 295
        assert d.max() >= 900_000_000          # offsite edges present
        cs, cd = [], []
        sizes = []
        for a, b in iter_edge_chunks(FIXTURE, chunk_edges=37):
            sizes.append(a.size)
            cs.append(a)
            cd.append(b)
        assert max(sizes) == 37 and len(sizes) > 1
        np.testing.assert_array_equal(np.concatenate(cs), s)
        np.testing.assert_array_equal(np.concatenate(cd), d)

    def test_gzip_sniffed_from_magic_bytes(self):
        raw = FIXTURE.read_bytes()
        s, d = read_edge_list(FIXTURE)
        # no .gz extension anywhere — detection is content-based
        gs, gd = read_edge_list(io.BytesIO(gzip.compress(raw)))
        np.testing.assert_array_equal(gs, s)
        np.testing.assert_array_equal(gd, d)

    def test_comments_blanks_and_extra_columns(self):
        text = "# c\n% c\n\n1 2 0.5 2020\n2 3\n"
        s, d = read_edge_list(io.StringIO(text))
        assert s.tolist() == [1, 2] and d.tolist() == [2, 3]

    def test_explicit_delimiter(self):
        s, d = read_edge_list(io.StringIO("1,2\n3,,4\n"), delimiter=",")
        assert s.tolist() == [1, 3] and d.tolist() == [2, 4]

    def test_string_ids(self):
        s, d = read_edge_list(io.StringIO("a b\nb c\n"))
        assert s.dtype.kind == "U" and s.tolist() == ["a", "b"]

    def test_short_line_names_line_number(self):
        with pytest.raises(ParseError, match="line 3"):
            read_edge_list(io.StringIO("# c\n1 2\noops\n"))

    def test_mixed_dtype_names_culprit(self):
        with pytest.raises(ParseError, match="non-numeric id 'x'"):
            read_edge_list(io.StringIO("1 2\nx 4\n"))

    def test_chunk_edges_validated(self):
        with pytest.raises(ValueError, match="chunk_edges"):
            list(iter_edge_chunks(io.StringIO("1 2\n"), chunk_edges=0))


# --------------------------------------------------------------- idmap
class TestIdMap:
    def test_first_seen_dense_assignment(self):
        m = NodeIdMapping()
        out = m.map_chunk(np.array([50, 7, 50, 99]))
        assert out.tolist() == [0, 1, 0, 2] and out.dtype == np.int32
        assert m.num_nodes == 3
        assert m.external_ids.tolist() == [50, 7, 99]
        np.testing.assert_array_equal(m.to_external([2, 0]), [99, 50])

    def test_to_internal_missing_modes(self):
        m = NodeIdMapping()
        m.map_chunk(np.array([5, 6]))
        assert m.to_internal(np.array([6, 5])).tolist() == [1, 0]
        assert m.to_internal(np.array([6, 123]),
                             missing="mark").tolist() == [1, -1]
        with pytest.raises(KeyError, match="123"):
            m.to_internal(np.array([123]))
        with pytest.raises(ValueError, match="missing"):
            m.to_internal(np.array([5]), missing="bogus")

    @pytest.mark.parametrize("ids", [[10**12, 5, 7], ["a.com", "b.org"]])
    def test_persistence_round_trip(self, ids, tmp_path):
        m = NodeIdMapping()
        m.map_chunk(np.array(ids))
        p = str(tmp_path / "idmap.npz")
        m.save(p)
        m2 = NodeIdMapping.load(p)
        np.testing.assert_array_equal(m2.external_ids, m.external_ids)
        assert m2.to_internal(m.external_ids).tolist() == \
            list(range(len(ids)))

    def test_load_rejects_foreign_npz(self, tmp_path):
        p = str(tmp_path / "not_idmap.npz")
        np.savez(p, foo=np.arange(3))
        with pytest.raises(ValueError, match="not a NodeIdMapping"):
            NodeIdMapping.load(p)

    def test_identity(self):
        m = NodeIdMapping.identity(4)
        assert m.to_internal(np.array([3, 0])).tolist() == [3, 0]


# ------------------------------------------------------------ pipeline
class TestPipeline:
    def test_fixture_accounting_balances(self):
        res = ingest_edge_list(FIXTURE, filters=[OFFSITE],
                               self_loops="drop", dedup=True)
        st = res.stats
        assert st.edges_read == 295
        assert st.edges_kept == (st.edges_read - st.filtered["offsite"]
                                 - st.self_loops_removed
                                 - st.duplicates_removed)
        assert st.num_nodes == res.graph.num_nodes == res.idmap.num_nodes
        assert res.virtual.counts == {"offsite": st.filtered["offsite"]}
        # filtering BEFORE id mapping: offsite dsts never claim an id
        assert res.idmap.external_ids.max() < 900_000_000

    def test_self_loop_policies(self):
        text = "1 1\n1 2\n2 1\n"
        keep = ingest_edge_list(io.StringIO(text))
        assert keep.stats.edges_kept == 3
        drop = ingest_edge_list(io.StringIO(text), self_loops="drop")
        assert drop.stats.edges_kept == 2
        assert drop.stats.self_loops_removed == 1
        virt = ingest_edge_list(io.StringIO(text), self_loops="virtual")
        assert virt.virtual.counts == {"self_loops": 1}
        with pytest.raises(ValueError, match="self_loops"):
            ingest_edge_list(io.StringIO(text), self_loops="nuke")

    def test_dedup_counts(self):
        res = ingest_edge_list(io.StringIO("1 2\n1 2\n2 1\n"), dedup=True)
        assert res.stats.duplicates_removed == 1
        assert res.stats.edges_kept == 2

    def test_non_virtual_filter_only_counts(self):
        f = LinkFilter("spam", lambda s, d: s != 9, virtual=False)
        res = ingest_edge_list(io.StringIO("1 2\n9 2\n2 1\n"),
                               filters=[f])
        assert res.stats.filtered["spam"] == 1
        assert res.virtual.counts == {}

    def test_duplicate_filter_names_rejected(self):
        f = LinkFilter("x", lambda s, d: s == s)
        with pytest.raises(ValueError, match="duplicate filter"):
            ingest_edge_list(io.StringIO("1 2\n"), filters=[f, f])

    def test_all_filtered_raises(self):
        f = LinkFilter("all", lambda s, d: np.zeros(s.shape, bool))
        with pytest.raises(ValueError, match="empty graph"):
            ingest_edge_list(io.StringIO("1 2\n"), filters=[f])

    def test_virtual_mass_hand_computed(self):
        # kept graph: 10 <-> 20; virtual: 10 -> 999 (offsite).  Node 10
        # would split damping*pr[10] over (1 kept + 1 virtual) links.
        f = LinkFilter("offsite", lambda s, d: d < 900)
        res = ingest_edge_list(io.StringIO("10 20\n20 10\n10 999\n"),
                               filters=[f])
        ref = pagerank_reference(res.graph, num_iterations=80)
        mass = res.virtual_mass(ref)
        pr10 = ref[res.idmap.to_internal(np.int64(10))]
        assert mass["offsite"] == pytest.approx(0.85 * pr10 / 2)

    def test_virtual_source_not_in_graph_contributes_nothing(self):
        # 999 -> 5 is filtered and 999 never enters the graph: its rank
        # is unknown, so its virtual edge must carry zero mass.
        f = LinkFilter("off", lambda s, d: (s < 900) & (d < 900))
        res = ingest_edge_list(io.StringIO("1 2\n2 1\n999 5\n"),
                               filters=[f])
        ref = pagerank_reference(res.graph, num_iterations=40)
        assert res.virtual_mass(ref)["off"] == 0.0


# -------------------------------------- end-to-end external-id parity
@pytest.mark.parametrize("reorder", ["none", "hybrid"])
def test_end_to_end_fixture_parity(reorder):
    """The PR's acceptance test: fixture file -> full pipeline ->
    solve + serve, all results in the file's ORIGINAL ids, matching
    the dense float64 oracle."""
    res = ingest_edge_list(FIXTURE, filters=[OFFSITE],
                           self_loops="drop", dedup=True)
    g = res.graph
    ref = pagerank_reference(g, num_iterations=60)
    sess = res.open(method="pcpm", part_size=16, num_iterations=60,
                    tol=0.0, reorder=reorder, slots=2, chunk=4)
    out = sess.pagerank()
    np.testing.assert_allclose(np.asarray(out.ranks), ref, atol=1e-6,
                               rtol=0)

    ids, scores = sess.top_ranked(5)
    expect_ext = res.idmap.to_external(oracle_top(ref, 5))
    assert ids.tolist() == expect_ext.tolist()
    np.testing.assert_allclose(scores, ref[oracle_top(ref, 5)],
                               atol=1e-6)

    sch = sess.serve()
    uid_topk = sch.submit(top_k=5, tol=0.0, max_iters=60,
                          route="stepper")
    uid_full = sch.submit(tol=0.0, max_iters=60, route="stepper")
    done = {r.uid: r for r in sch.run_until_drained()}
    topk = done[uid_topk]
    assert topk.error is None
    assert topk.top_external is not None
    assert sorted(topk.top_external.tolist()) == \
        sorted(expect_ext.tolist())
    full = done[uid_full]
    np.testing.assert_allclose(np.asarray(full.ranks), ref, atol=1e-6,
                               rtol=0)


def test_push_route_speaks_external_ids():
    """Personalized push queries on a reordered plan return the same
    external top-k as on the unreordered plan."""
    res = ingest_edge_list(FIXTURE, filters=[OFFSITE],
                           self_loops="drop", dedup=True)
    seed = np.zeros(res.graph.num_nodes, dtype=np.float32)
    seed[res.idmap.to_internal(res.idmap.external_ids[3])] = 1.0
    tops = {}
    for reorder in ("none", "hybrid"):
        sess = res.open(part_size=16, reorder=reorder, slots=2, chunk=4)
        sch = sess.serve(route="push")
        sch.submit(seed, top_k=5, tol=1e-4, max_iters=200)
        sch.run_until_drained()
        (q,) = sch.completed
        assert q.error is None and q.top_external is not None
        tops[reorder] = sorted(q.top_external.tolist())
    assert tops["none"] == tops["hybrid"]


# ----------------------------------------- reorder-in-plan wiring
@pytest.fixture(scope="module")
def rmat():
    return generators.rmat(8, 6, seed=3)


class TestReorderPlans:
    @pytest.mark.parametrize("reorder", ["degree", "bfs", "hybrid"])
    def test_engine_parity_each_ordering(self, rmat, reorder):
        ref = pagerank_reference(rmat, num_iterations=40)
        sess = repro.open(rmat, part_size=32, num_iterations=40,
                          tol=0.0, reorder=reorder)
        np.testing.assert_allclose(np.asarray(sess.pagerank().ranks),
                                   ref, atol=1e-6, rtol=0)

    def test_distinct_cache_entries_per_ordering(self, rmat):
        # part_size distinct from every other test in this module so
        # the cache-miss accounting below starts from a clean key
        cfg = repro.EngineConfig(part_size=64)
        p_none = build_plan(rmat, cfg.plan_config())
        before = plan_cache_stats().plan_builds
        p_hyb = build_plan(rmat,
                           cfg.replace(reorder="hybrid").plan_config())
        assert plan_cache_stats().plan_builds == before + 1
        assert p_hyb is not p_none
        assert p_none.reorder_perm is None
        assert p_hyb.reorder_perm is not None
        # reordered plan is stamped with the ORIGINAL graph fingerprint
        assert p_hyb.graph_fp == p_none.graph_fp
        # cache hit on repeat — the permutation is not recomputed
        assert build_plan(rmat,
                          cfg.replace(reorder="hybrid").plan_config()) \
            is p_hyb

    def test_unknown_ordering_rejected(self, rmat):
        with pytest.raises(ValueError, match="reorder"):
            repro.open(rmat, reorder="gorder")

    def test_plan_save_load_round_trips_permutation(self, rmat,
                                                    tmp_path):
        cfg = repro.EngineConfig(part_size=32, reorder="hybrid")
        plan = build_plan(rmat, cfg.plan_config())
        p = str(tmp_path / "g.plan.npz")
        plan.save(p)
        loaded = load_plan(p)
        np.testing.assert_array_equal(loaded.reorder_perm,
                                      plan.reorder_perm)
        assert loaded.config.reorder == "hybrid"
        # warm-load: installing the persisted plan serves a session
        # with zero fresh builds
        install_plan(rmat, loaded)
        before = plan_cache_stats().plan_builds
        sess = repro.open(rmat, cfg)
        assert plan_cache_stats().plan_builds == before
        ref = pagerank_reference(rmat, num_iterations=40)
        np.testing.assert_allclose(
            np.asarray(sess.pagerank(num_iterations=40, tol=0.0).ranks),
            ref, atol=1e-6, rtol=0)

    def test_batch_server_speaks_original_ids(self, rmat):
        """PageRankServer on a reordered plan: uniform AND
        personalized queries come back in original-id order."""
        sess = repro.open(rmat, part_size=32, num_iterations=40,
                          tol=0.0, reorder="hybrid")
        srv = sess.server(batch=1)
        ref = pagerank_reference(rmat, num_iterations=40)
        pr, _, _ = srv.query()
        np.testing.assert_allclose(np.asarray(pr), ref, atol=1e-6,
                                   rtol=0)
        seeds = np.zeros(rmat.num_nodes, np.float32)
        seeds[11] = 1.0
        prs, _, _ = srv.query(seeds)
        base = repro.open(rmat, part_size=32, num_iterations=40,
                          tol=0.0).server(batch=1)
        prb, _, _ = base.query(seeds)
        np.testing.assert_allclose(np.asarray(prs), np.asarray(prb),
                                   atol=1e-6, rtol=0)

    def test_scheduler_apply_delta_guard(self, rmat):
        from repro.stream import GraphDelta
        sess = repro.open(rmat, part_size=32, reorder="degree",
                          slots=2, chunk=4)
        sch = sess.serve()
        delta = GraphDelta.insert(np.array([[0, 5]], dtype=np.int32))
        with pytest.raises(ValueError, match="reorder"):
            sch.apply_delta(delta)

    def test_session_delta_rebuilds_and_warm_falls_back(self, rmat):
        from repro.stream import GraphDelta
        sess = repro.open(rmat, part_size=32, num_iterations=40,
                          tol=1e-10, reorder="degree")
        sess.pagerank()
        delta = GraphDelta.insert(
            np.array([[1, 7], [3, 9]], dtype=np.int32))
        sess.apply_delta(delta)
        warm = sess.pagerank(warm=True)      # honest cold fallback
        ref = pagerank_reference(sess.graph, num_iterations=40)
        np.testing.assert_allclose(np.asarray(warm.ranks), ref,
                                   atol=1e-6, rtol=0)
