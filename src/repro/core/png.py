"""Bipartite Partition-Node Graph (PNG) layout — paper §IV-B.

The PNG build *compresses* (dedup per (source node, destination
partition)) and *transposes* (groups by destination partition) the edge
set in the paper's two merged scans.  Host-side numpy pre-processing,
exactly like the paper's pre-processing step (§VI-D3); the output is a
set of flat, statically-shaped arrays consumable by XLA and by the
Pallas kernel:

  update_src[U]        source node of each deduplicated update,
                       sorted by (dst_partition, src_partition, src)
  update_offsets[k+1]  update range per destination partition
  edge_update_idx[M]   per edge: index into the update stream
  edge_dst[M]          per edge: global destination node id
  edge_offsets[k+1]    edge range per destination partition

The per-edge gather stream is sorted by destination node id (which is
partition-major automatically, since partitions are contiguous ID
ranges).  Sorted destinations make the gather phase's writes sequential
— the paper's cache-friendly partition-resident accumulation — and let
the device gather use the blocked segmented reduction of
``build_gather_schedule`` instead of an element-wise scatter-add
(DESIGN.md §3).

The MSB/branch-avoidance trick (paper §IV-C) is replaced by the explicit
``edge_update_idx`` stream — same 4 B/edge, branch-free, full 2^32 ID
space (DESIGN.md §2).

Compression ratio r = M / U is the paper's central statistic (table V).

These layouts are plan-layer artifacts: ``core/plan.py`` caches one
``PNGLayout`` per (graph, part_size) — shared by the ``pcpm`` and
``pcpm_pallas`` backends — inside the process-cached, serializable
``GraphPlan`` (DESIGN.md §8); call ``build_png`` directly only for
one-off host-side analysis (benchmarks, tests).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..graphs.formats import Graph
from .partition import Partitioning


@dataclasses.dataclass(frozen=True)
class PNGLayout:
    partitioning: Partitioning
    update_src: np.ndarray       # (U,) int32
    update_offsets: np.ndarray   # (k+1,) int64
    edge_update_idx: np.ndarray  # (M,) int32
    edge_dst: np.ndarray         # (M,) int32
    edge_offsets: np.ndarray     # (k+1,) int64
    num_nodes: int
    num_edges: int

    @property
    def num_updates(self) -> int:
        return int(self.update_src.shape[0])

    @property
    def num_partitions(self) -> int:
        return self.partitioning.num_partitions

    @property
    def compression_ratio(self) -> float:
        """r = |E| / |E'| (paper table V)."""
        return self.num_edges / max(self.num_updates, 1)

    # ------------------------------------------------------- comm model
    def model_bytes(self, *, d_i: int = 4, d_v: int = 4) -> dict:
        """Per-iteration DRAM/HBM byte model, eq. (5) of the paper,
        instantiated with the *actual* U and M of this layout."""
        n, m, u, k = (self.num_nodes, self.num_edges, self.num_updates,
                      self.num_partitions)
        scatter = n * d_v + u * d_v + (k * k + u) * d_i
        gather = m * d_i + u * d_v + n * d_v
        return {"scatter": scatter, "gather": gather,
                "total": scatter + gather}


def build_png(g: Graph, part: Partitioning) -> PNGLayout:
    """Merged compress+transpose build (paper §IV-B, two scans)."""
    dstp = (g.dst.astype(np.int64) // part.part_size)
    # Scan 1: sort edges by (dst_partition, src, dst) — the transposed,
    # destination-partition-major order the scatter phase streams in.
    order = np.lexsort((g.dst, g.src, dstp))
    src_s = g.src[order]
    dst_s = g.dst[order]
    dstp_s = dstp[order]
    # Scan 2: dedup (dst_partition, src) pairs → the update stream.
    pair_key = dstp_s * np.int64(g.num_nodes) + src_s
    # pair_key is already sorted (lexsort above) → run-length dedup.
    new_update = np.empty(len(pair_key), dtype=bool)
    if len(pair_key):
        new_update[0] = True
        np.not_equal(pair_key[1:], pair_key[:-1], out=new_update[1:])
    edge_update_idx = (np.cumsum(new_update) - 1).astype(np.int32)
    update_src = src_s[new_update].astype(np.int32)
    update_dstp = dstp_s[new_update]

    k = part.num_partitions
    update_offsets = np.zeros(k + 1, dtype=np.int64)
    np.add.at(update_offsets, update_dstp + 1, 1)
    np.cumsum(update_offsets, out=update_offsets)
    edge_offsets = np.zeros(k + 1, dtype=np.int64)
    np.add.at(edge_offsets, dstp_s + 1, 1)
    np.cumsum(edge_offsets, out=edge_offsets)

    # Re-sort the gather stream by destination node.  Stable, so edges
    # stay grouped by destination partition (partition = dst // psz is
    # monotone in dst) and edge_offsets remain valid; edge_update_idx
    # still points at the same (unchanged) update stream.
    gorder = np.argsort(dst_s, kind="stable")

    return PNGLayout(part, update_src, update_offsets,
                     edge_update_idx[gorder],
                     dst_s[gorder].astype(np.int32), edge_offsets,
                     g.num_nodes, g.num_edges)


# ---------------------------------------------------------------------------
# Blocked gather schedule — hierarchical segmented reduction (DESIGN.md §3).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GatherSchedule:
    """Precomputed schedule for the blocked gather phase.

    The dst-sorted edge stream is cut into fixed ``block``-sized chunks
    (the XLA analogue of the paper's cache-resident partition): a
    destination's contribution inside one chunk is a contiguous run, so
    it equals a difference of the chunk-local inclusive prefix sum —
    fully vectorized, and exact to f32 rounding because prefix
    magnitudes stay chunk-local.  Runs are then combined with one small
    scatter-add over ``num_pieces ≈ n + M/block`` entries instead of M.

      edge_update_idx_padded[Mp]  update pointer, M padded to block mult
      piece_start[P0], piece_end[P0]   inclusive run bounds (flat index)
      piece_dst[P0]               global destination, pad = num_nodes
    """
    block: int
    num_edges: int               # un-padded M
    edge_update_idx_padded: np.ndarray  # (Mp,) int32, pad = 0 (inert)
    piece_start: np.ndarray      # (P0,) int32
    piece_end: np.ndarray        # (P0,) int32
    piece_dst: np.ndarray        # (P0,) int32, pad = num_nodes

    @property
    def num_blocks(self) -> int:
        return len(self.edge_update_idx_padded) // self.block


def flat_gather_schedule(edge_update_idx: np.ndarray,
                         edge_dst: np.ndarray, *, num_nodes: int,
                         block: int = 256, pad_update: int = 0
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]:
    """Schedule-build core over raw dst-sorted streams.

    Returns ``(eui_padded, piece_start, piece_end, piece_dst)`` with
    the stream padded to a ``block`` multiple; pad edges point at
    ``pad_update`` and carry the ``num_nodes`` sentinel destination so
    the final segment-sum drops them.  Shared by the single-device PNG
    schedule and the per-shard schedule of ``core/distributed.py``
    (whose pad update is the receive buffer's zero slot).
    """
    m = len(edge_dst)
    mp = -(-max(m, 1) // block) * block
    dst_pad = np.full(mp, num_nodes, dtype=np.int32)
    dst_pad[:m] = edge_dst
    eui_pad = np.full(mp, pad_update, dtype=np.int32)
    eui_pad[:m] = edge_update_idx

    new_piece = np.empty(mp, dtype=bool)
    new_piece[0] = True
    np.not_equal(dst_pad[1:], dst_pad[:-1], out=new_piece[1:])
    new_piece[::block] = True
    starts = np.flatnonzero(new_piece).astype(np.int32)
    ends = np.append(starts[1:], mp).astype(np.int32) - 1
    return eui_pad, starts, ends, dst_pad[starts]


def build_gather_schedule(layout: PNGLayout, *,
                          block: int = 256) -> GatherSchedule:
    """Cut the dst-sorted gather stream into per-block runs.

    A new piece starts wherever the destination changes or a block
    boundary is crossed; pad edges (index >= M) point at update 0 but
    carry the ``num_nodes`` sentinel destination, so the final
    segment-sum drops them.
    """
    eui_pad, starts, ends, piece_dst = flat_gather_schedule(
        layout.edge_update_idx, layout.edge_dst,
        num_nodes=layout.num_nodes, block=block, pad_update=0)
    return GatherSchedule(block, layout.num_edges, eui_pad, starts,
                          ends, piece_dst)


# ---------------------------------------------------------------------------
# Blocked (per-partition padded) view — execution schedule of the paper &
# input format of the Pallas kernel.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockedPNG:
    """PNG re-laid-out as dense (k, max_*) blocks with padding.

    Pad entries have update value slot U (an extra zero row) and dst_local
    slot part_size (an extra accumulator row) so they are mathematically
    inert without branches — the static-shape analogue of the paper's
    deterministic layout.
    """
    part_size: int
    update_src: np.ndarray       # (k, max_u) int32, pad = -1
    edge_update_local: np.ndarray  # (k, max_e) int32 into partition updates,
                                   # pad = max_u (extra zero row)
    edge_dst_local: np.ndarray   # (k, max_e) int32, pad = part_size
    update_pad_frac: float
    edge_pad_frac: float


def block_png(layout: PNGLayout) -> BlockedPNG:
    """Vectorized re-layout: one scatter per stream, no per-partition
    Python loop (preprocessing time is a paper headline, table VII)."""
    k = layout.num_partitions
    psz = layout.partitioning.part_size
    u_cnt = np.diff(layout.update_offsets)
    e_cnt = np.diff(layout.edge_offsets)
    max_u = max(int(u_cnt.max(initial=0)), 1)
    max_e = max(int(e_cnt.max(initial=0)), 1)
    up = np.full((k, max_u), -1, dtype=np.int32)
    eu = np.full((k, max_e), max_u, dtype=np.int32)
    ed = np.full((k, max_e), psz, dtype=np.int32)
    # partition id + within-partition position of every update / edge
    part_u = np.repeat(np.arange(k), u_cnt)
    pos_u = np.arange(layout.num_updates) - layout.update_offsets[part_u]
    part_e = np.repeat(np.arange(k), e_cnt)
    pos_e = np.arange(layout.num_edges) - layout.edge_offsets[part_e]
    up[part_u, pos_u] = layout.update_src
    eu[part_e, pos_e] = (layout.edge_update_idx
                         - layout.update_offsets[part_e])
    ed[part_e, pos_e] = layout.edge_dst - part_e * psz
    u_pad = 1.0 - layout.num_updates / max(k * max_u, 1)
    e_pad = 1.0 - layout.num_edges / max(k * max_e, 1)
    return BlockedPNG(psz, up, eu, ed, u_pad, e_pad)
