"""Bipartite Partition-Node Graph (PNG) layout — paper §IV-B.

The PNG build *compresses* (dedup per (source node, destination
partition)) and *transposes* (groups by destination partition) the edge
set in the paper's two merged scans.  Host-side numpy pre-processing,
exactly like the paper's pre-processing step (§VI-D3); the output is a
set of flat, statically-shaped arrays consumable by XLA and by the
Pallas kernel:

  update_src[U]        source node of each deduplicated update,
                       sorted by (dst_partition, src_partition, src)
  update_offsets[k+1]  update range per destination partition
  edge_update_idx[M]   per edge: index into the update stream
  edge_dst[M]          per edge: global destination node id
  edge_offsets[k+1]    edge range per destination partition

The MSB/branch-avoidance trick (paper §IV-C) is replaced by the explicit
``edge_update_idx`` stream — same 4 B/edge, branch-free, full 2^32 ID
space (DESIGN.md §2).

Compression ratio r = M / U is the paper's central statistic (table V).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..graphs.formats import Graph
from .partition import Partitioning


@dataclasses.dataclass(frozen=True)
class PNGLayout:
    partitioning: Partitioning
    update_src: np.ndarray       # (U,) int32
    update_offsets: np.ndarray   # (k+1,) int64
    edge_update_idx: np.ndarray  # (M,) int32
    edge_dst: np.ndarray         # (M,) int32
    edge_offsets: np.ndarray     # (k+1,) int64
    num_nodes: int
    num_edges: int

    @property
    def num_updates(self) -> int:
        return int(self.update_src.shape[0])

    @property
    def num_partitions(self) -> int:
        return self.partitioning.num_partitions

    @property
    def compression_ratio(self) -> float:
        """r = |E| / |E'| (paper table V)."""
        return self.num_edges / max(self.num_updates, 1)

    # ------------------------------------------------------- comm model
    def model_bytes(self, *, d_i: int = 4, d_v: int = 4) -> dict:
        """Per-iteration DRAM/HBM byte model, eq. (5) of the paper,
        instantiated with the *actual* U and M of this layout."""
        n, m, u, k = (self.num_nodes, self.num_edges, self.num_updates,
                      self.num_partitions)
        scatter = n * d_v + u * d_v + (k * k + u) * d_i
        gather = m * d_i + u * d_v + n * d_v
        return {"scatter": scatter, "gather": gather,
                "total": scatter + gather}


def build_png(g: Graph, part: Partitioning) -> PNGLayout:
    """Merged compress+transpose build (paper §IV-B, two scans)."""
    dstp = (g.dst.astype(np.int64) // part.part_size)
    # Scan 1: sort edges by (dst_partition, src, dst) — the transposed,
    # destination-partition-major order the scatter phase streams in.
    order = np.lexsort((g.dst, g.src, dstp))
    src_s = g.src[order]
    dst_s = g.dst[order]
    dstp_s = dstp[order]
    # Scan 2: dedup (dst_partition, src) pairs → the update stream.
    pair_key = dstp_s * np.int64(g.num_nodes) + src_s
    # pair_key is already sorted (lexsort above) → run-length dedup.
    new_update = np.empty(len(pair_key), dtype=bool)
    if len(pair_key):
        new_update[0] = True
        np.not_equal(pair_key[1:], pair_key[:-1], out=new_update[1:])
    edge_update_idx = (np.cumsum(new_update) - 1).astype(np.int32)
    update_src = src_s[new_update].astype(np.int32)
    update_dstp = dstp_s[new_update]

    k = part.num_partitions
    update_offsets = np.zeros(k + 1, dtype=np.int64)
    np.add.at(update_offsets, update_dstp + 1, 1)
    np.cumsum(update_offsets, out=update_offsets)
    edge_offsets = np.zeros(k + 1, dtype=np.int64)
    np.add.at(edge_offsets, dstp_s + 1, 1)
    np.cumsum(edge_offsets, out=edge_offsets)

    return PNGLayout(part, update_src, update_offsets, edge_update_idx,
                     dst_s.astype(np.int32), edge_offsets,
                     g.num_nodes, g.num_edges)


# ---------------------------------------------------------------------------
# Blocked (per-partition padded) view — execution schedule of the paper &
# input format of the Pallas kernel.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockedPNG:
    """PNG re-laid-out as dense (k, max_*) blocks with padding.

    Pad entries have update value slot U (an extra zero row) and dst_local
    slot part_size (an extra accumulator row) so they are mathematically
    inert without branches — the static-shape analogue of the paper's
    deterministic layout.
    """
    part_size: int
    update_src: np.ndarray       # (k, max_u) int32, pad = -1
    edge_update_local: np.ndarray  # (k, max_e) int32 into partition updates,
                                   # pad = max_u (extra zero row)
    edge_dst_local: np.ndarray   # (k, max_e) int32, pad = part_size
    update_pad_frac: float
    edge_pad_frac: float


def block_png(layout: PNGLayout) -> BlockedPNG:
    k = layout.num_partitions
    psz = layout.partitioning.part_size
    u_cnt = np.diff(layout.update_offsets)
    e_cnt = np.diff(layout.edge_offsets)
    max_u = max(int(u_cnt.max(initial=0)), 1)
    max_e = max(int(e_cnt.max(initial=0)), 1)
    up = np.full((k, max_u), -1, dtype=np.int32)
    eu = np.full((k, max_e), max_u, dtype=np.int32)
    ed = np.full((k, max_e), psz, dtype=np.int32)
    for p in range(k):
        us, ue = layout.update_offsets[p], layout.update_offsets[p + 1]
        es, ee = layout.edge_offsets[p], layout.edge_offsets[p + 1]
        up[p, :ue - us] = layout.update_src[us:ue]
        eu[p, :ee - es] = layout.edge_update_idx[es:ee] - us
        ed[p, :ee - es] = layout.edge_dst[es:ee] - p * psz
    u_pad = 1.0 - layout.num_updates / max(k * max_u, 1)
    e_pad = 1.0 - layout.num_edges / max(k * max_e, 1)
    return BlockedPNG(psz, up, eu, ed, u_pad, e_pad)
