"""Distributed PCPM: the paper's communication-volume reduction lifted
from DRAM traffic to interconnect traffic (DESIGN.md §2).

Vertices are sharded contiguously over a mesh axis.  The PNG build at
shard granularity produces, per (source-shard s, destination-shard t),
the DEDUPLICATED update list — each source vertex's value crosses the
wire once per destination shard instead of once per cross-shard edge
(compression r on the wire).  The scatter phase is one all-to-all of
dense compressed buffers; the gather phase is a local segment-sum.

``edge_cut_spmv`` is the distributed BVGAS analogue (one update PER
EDGE on the wire) used as the communication baseline.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..graphs.formats import Graph


# ---------------------------------------------------------------- layout
@dataclasses.dataclass(frozen=True)
class ShardedPNG:
    """Static-shape sharded PNG (leading axis = owning shard).

    send_ids  (S, S, U) int32: send_ids[s, t] = local ids shard s sends
                               to shard t (pad -1 -> zero value)
    edge_upd  (S, E) int32:    per dst shard, index into its receive
                               buffer (concat over s, row-major), pad
                               points at S*U (zero slot)
    edge_dst  (S, E) int32:    local destination ids, pad = shard_size
    """
    num_shards: int
    shard_size: int
    num_nodes: int
    send_ids: np.ndarray
    edge_upd: np.ndarray
    edge_dst: np.ndarray
    # stats
    wire_updates: int      # deduplicated cross-shard update count (PCPM)
    wire_edges: int        # cross-shard edge count (edge-cut baseline)

    @property
    def wire_compression(self) -> float:
        return self.wire_edges / max(self.wire_updates, 1)


def build_sharded_png(g: Graph, num_shards: int) -> ShardedPNG:
    shard_size = -(-g.num_nodes // num_shards)
    src = g.src.astype(np.int64)
    dst = g.dst.astype(np.int64)
    s_sh = src // shard_size
    d_sh = dst // shard_size

    # --- dedup (src, dst_shard) pairs, grouped by (src_shard, dst_shard)
    order = np.lexsort((src, s_sh, d_sh))
    src_o, dst_o, ssh_o, dsh_o = (src[order], dst[order], s_sh[order],
                                  d_sh[order])
    pair_key = (dsh_o * num_shards + ssh_o) * g.num_nodes + src_o
    new = np.empty(len(pair_key), dtype=bool)
    if len(pair_key):
        new[0] = True
        np.not_equal(pair_key[1:], pair_key[:-1], out=new[1:])
    upd_rank_within_pair = np.empty(len(pair_key), dtype=np.int64)
    # rank of each update within its (s, t) group
    grp_key = dsh_o * num_shards + ssh_o
    grp_start = np.empty(len(grp_key), dtype=bool)
    if len(grp_key):
        grp_start[0] = True
        np.not_equal(grp_key[1:], grp_key[:-1], out=grp_start[1:])
    upd_idx_global = np.cumsum(new) - 1
    grp_of_upd = grp_key[new]
    # per-update rank within its group
    grp_first_upd = np.zeros(grp_of_upd.shape[0], dtype=np.int64)
    if len(grp_of_upd):
        starts = np.flatnonzero(np.r_[True, grp_of_upd[1:]
                                      != grp_of_upd[:-1]])
        sizes = np.diff(np.r_[starts, len(grp_of_upd)])
        grp_first_upd = np.repeat(
            np.arange(len(grp_of_upd))[starts], sizes)
    upd_rank = np.arange(len(grp_of_upd)) - grp_first_upd

    counts = np.zeros(num_shards * num_shards, dtype=np.int64)
    np.add.at(counts, grp_of_upd, 1)
    u_max = max(int(counts.max(initial=0)), 1)

    send_ids = np.full((num_shards, num_shards, u_max), -1, dtype=np.int32)
    upd_src = src_o[new]
    upd_ssh = ssh_o[new]
    upd_dsh = dsh_o[new]
    send_ids[upd_ssh, upd_dsh, upd_rank] = (upd_src
                                            - upd_ssh * shard_size)

    # --- per-dst-shard edge streams referencing the receive buffer
    # receive buffer at shard t: rows s = send_ids[s, t] -> flat s*U + r
    upd_slot = upd_ssh * u_max + upd_rank          # slot within dst buffer
    edge_slot = upd_slot[upd_idx_global]           # per edge (sorted order)
    e_counts = np.zeros(num_shards, dtype=np.int64)
    np.add.at(e_counts, dsh_o, 1)
    e_max = max(int(e_counts.max(initial=0)), 1)
    edge_upd = np.full((num_shards, e_max), num_shards * u_max,
                       dtype=np.int32)
    edge_dst = np.full((num_shards, e_max), shard_size, dtype=np.int32)
    e_first = np.zeros(len(dsh_o), dtype=np.int64)
    if len(dsh_o):
        starts = np.flatnonzero(np.r_[True, dsh_o[1:] != dsh_o[:-1]])
        sizes = np.diff(np.r_[starts, len(dsh_o)])
        e_first = np.repeat(np.arange(len(dsh_o))[starts], sizes)
    e_rank = np.arange(len(dsh_o)) - e_first
    edge_upd[dsh_o, e_rank] = edge_slot
    edge_dst[dsh_o, e_rank] = dst_o - dsh_o * shard_size

    wire_updates = int(np.sum(upd_ssh != upd_dsh))
    wire_edges = int(np.sum(s_sh != d_sh))
    return ShardedPNG(num_shards, shard_size, g.num_nodes,
                      send_ids, edge_upd, edge_dst,
                      wire_updates, wire_edges)


# --------------------------------------------------------------- engines
def pcpm_all_to_all_spmv(layout: ShardedPNG, mesh: Mesh, axis: str):
    """Returns a jitted y = A^T x over vertex-sharded x (padded to
    S * shard_size).  x: (n_pad,) or (n_pad, d)."""
    s, u = layout.num_shards, layout.send_ids.shape[2]
    ssz = layout.shard_size
    send_ids = jnp.asarray(layout.send_ids)     # (S, S, U)
    edge_upd = jnp.asarray(layout.edge_upd)     # (S, E)
    edge_dst = jnp.asarray(layout.edge_dst)     # (S, E)
    vec = P(axis)
    mat = P(axis, None)

    def local(x_l, send_l, eu_l, ed_l):
        # x_l (ssz, d); send_l (1, S, U); eu/ed (1, E)
        x_l = x_l.reshape(ssz, -1)
        d = x_l.shape[-1]
        ids = send_l[0]                                    # (S, U)
        bufs = x_l[jnp.clip(ids, 0, ssz - 1)] * (ids >= 0)[..., None]
        # scatter phase on the wire: compressed update bins
        recv = jax.lax.all_to_all(bufs, axis, 0, 0, tiled=True)
        recv = recv.reshape(s * u, d)
        recv = jnp.concatenate([recv, jnp.zeros((1, d), recv.dtype)], 0)
        # gather phase: local PCPM expand + accumulate
        vals = recv[eu_l[0]]                               # (E, d)
        y = jax.ops.segment_sum(vals, ed_l[0], num_segments=ssz + 1)
        return y[:ssz]

    fn = shard_map(local, mesh=mesh,
                   in_specs=(vec, mat, mat, mat),
                   out_specs=vec)

    @jax.jit
    def spmv(x):
        squeeze = x.ndim == 1
        xs = x[:, None] if squeeze else x
        y = fn(xs, send_ids, edge_upd, edge_dst)
        return y[:, 0] if squeeze else y

    return spmv


def edge_cut_spmv(g: Graph, num_shards: int, mesh: Mesh, axis: str):
    """Distributed BVGAS baseline: one update PER cross-shard edge on
    the wire (no dedup).  Send buffers are per-edge values grouped by
    destination shard."""
    shard_size = -(-g.num_nodes // num_shards)
    src, dst = g.src.astype(np.int64), g.dst.astype(np.int64)
    s_sh, d_sh = src // shard_size, dst // shard_size
    order = np.lexsort((dst, d_sh, s_sh))
    src_o, dst_o = src[order], dst[order]
    ssh_o, dsh_o = s_sh[order], d_sh[order]
    counts = np.zeros(num_shards * num_shards, dtype=np.int64)
    np.add.at(counts, ssh_o * num_shards + dsh_o, 1)
    e_max = max(int(counts.max(initial=0)), 1)
    send_src = np.full((num_shards, num_shards, e_max), -1, np.int32)
    send_dst = np.full((num_shards, num_shards, e_max), shard_size,
                       np.int32)
    grp = ssh_o * num_shards + dsh_o
    first = np.zeros(len(grp), dtype=np.int64)
    if len(grp):
        starts = np.flatnonzero(np.r_[True, grp[1:] != grp[:-1]])
        sizes = np.diff(np.r_[starts, len(grp)])
        first = np.repeat(np.arange(len(grp))[starts], sizes)
    rank = np.arange(len(grp)) - first
    send_src[ssh_o, dsh_o, rank] = src_o - ssh_o * shard_size
    send_dst[ssh_o, dsh_o, rank] = dst_o - dsh_o * shard_size

    send_src_j = jnp.asarray(send_src)
    send_dst_j = jnp.asarray(send_dst)
    vec, mat = P(axis), P(axis, None)

    def local(x_l, ss_l, sd_l):
        x_l = x_l.reshape(shard_size, -1)
        d = x_l.shape[-1]
        ids = ss_l[0]                                     # (S, E)
        bufs = x_l[jnp.clip(ids, 0, shard_size - 1)] * \
            (ids >= 0)[..., None]                          # (S, E, d)
        dsts = sd_l[0]                                    # (S, E) local dst
        recv_v = jax.lax.all_to_all(bufs, axis, 0, 0, tiled=True)
        recv_d = jax.lax.all_to_all(dsts, axis, 0, 0, tiled=True)
        y = jax.ops.segment_sum(recv_v.reshape(-1, d),
                                recv_d.reshape(-1),
                                num_segments=shard_size + 1)
        return y[:shard_size]

    fn = shard_map(local, mesh=mesh, in_specs=(vec, mat, mat),
                   out_specs=vec)

    @jax.jit
    def spmv(x):
        squeeze = x.ndim == 1
        xs = x[:, None] if squeeze else x
        y = fn(xs, send_src_j, send_dst_j)
        return y[:, 0] if squeeze else y

    return spmv


def pad_to_shards(x: np.ndarray, layout: ShardedPNG) -> np.ndarray:
    n_pad = layout.num_shards * layout.shard_size
    pad = n_pad - x.shape[0]
    width = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return np.pad(x, width)


def distributed_pagerank(g: Graph, mesh: Mesh, axis: str, *,
                         num_iterations: int = 20, damping: float = 0.85,
                         layout: ShardedPNG | None = None):
    """PageRank over the sharded PCPM engine."""
    num_shards = int(np.prod([s for n, s in
                              zip(mesh.axis_names, mesh.devices.shape)
                              if n == axis]))
    layout = layout or build_sharded_png(g, num_shards)
    spmv = pcpm_all_to_all_spmv(layout, mesh, axis)
    n = g.num_nodes
    n_pad = layout.num_shards * layout.shard_size
    out_deg = np.asarray(g.out_degree)
    inv_deg = np.where(out_deg == 0, 0.0, 1.0 / np.maximum(out_deg, 1))
    inv_deg = jnp.asarray(pad_to_shards(inv_deg.astype(np.float32),
                                        layout))
    sharding = NamedSharding(mesh, P(axis))
    pr = jax.device_put(jnp.full((n_pad,), 1.0 / n, jnp.float32), sharding)
    pr = pr * (jnp.arange(n_pad) < n)
    base = (1.0 - damping) / n
    for _ in range(num_iterations):
        pr = base + damping * spmv(pr * inv_deg)
        pr = pr * (jnp.arange(n_pad) < n)
    return np.asarray(pr)[:n]
