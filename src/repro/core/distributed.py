"""Distributed PCPM: the paper's communication-volume reduction lifted
from DRAM traffic to interconnect traffic (DESIGN.md §6).

Vertices are sharded contiguously over a mesh axis.  The PNG build at
shard granularity produces, per (source-shard s, destination-shard t),
the DEDUPLICATED update list — each source vertex's value crosses the
wire once per destination shard instead of once per cross-shard edge
(compression r on the wire).  The scatter phase is one all-to-all of
dense compressed buffers; the gather phase is the shard-local blocked
hierarchical reduction of DESIGN.md §3 over a dst-sorted edge stream.

``sharded_power_iteration`` is the device-resident iteration engine:
the WHOLE power iteration is one donated, jitted ``lax.while_loop``
whose body runs scatter + all-to-all + blocked gather under
``shard_map``; the L1 residual (and dangling-node mass) is combined
across shards with ``psum`` so ``tol`` early exit is decided on device
with zero host round-trips (DESIGN.md §6).

``edge_cut_spmv`` is the distributed BVGAS analogue (one update PER
EDGE on the wire) used as the communication baseline.

``ShardedPNG`` is a plan-layer artifact: the ``pcpm_sharded`` backend
(core/backends.py) builds it into the process-cached ``GraphPlan``
(core/plan.py), which also serializes it — consumers get it via
``engine.sharded_layout`` / ``plan.sharded`` rather than calling
``build_sharded_png`` directly (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..graphs.formats import Graph
from .png import flat_gather_schedule
from .spmv import pcpm_gather_blocked


# ---------------------------------------------------------------- layout
@dataclasses.dataclass(frozen=True)
class ShardedPNG:
    """Static-shape sharded PNG (leading axis = owning shard).

    send_ids  (S, S, U) int32: send_ids[s, t] = local ids shard s sends
                               to shard t (pad -1 -> zero value)
    edge_upd  (S, E) int32:    per dst shard, index into its receive
                               buffer (concat over s, row-major), pad
                               points at S*U (zero slot); dst-sorted
                               within each shard
    edge_dst  (S, E) int32:    local destination ids, ascending per
                               shard, pad = shard_size

    plus the per-shard blocked gather schedule (DESIGN.md §3 applied
    shard-locally): the dst-sorted stream padded to a ``gather_block``
    multiple and cut into contiguous same-destination runs.
    """
    num_shards: int
    shard_size: int
    num_nodes: int
    send_ids: np.ndarray
    edge_upd: np.ndarray
    edge_dst: np.ndarray
    # blocked gather schedule, per shard
    gather_block: int
    eui_padded: np.ndarray     # (S, Mp) int32, pad -> S*U zero slot
    piece_start: np.ndarray    # (S, P0) int32
    piece_end: np.ndarray      # (S, P0) int32
    piece_dst: np.ndarray      # (S, P0) int32, pad = shard_size
    # stats
    wire_updates: int      # deduplicated cross-shard update count (PCPM)
    wire_edges: int        # cross-shard edge count (edge-cut baseline)

    @property
    def wire_compression(self) -> float:
        return self.wire_edges / max(self.wire_updates, 1)

    @property
    def padded_nodes(self) -> int:
        return self.num_shards * self.shard_size


def build_sharded_png(g: Graph, num_shards: int, *,
                      gather_block: int = 256) -> ShardedPNG:
    shard_size = -(-g.num_nodes // num_shards)
    src = g.src.astype(np.int64)
    dst = g.dst.astype(np.int64)
    s_sh = src // shard_size
    d_sh = dst // shard_size

    # --- dedup (src, dst_shard) pairs, grouped by (src_shard, dst_shard)
    order = np.lexsort((src, s_sh, d_sh))
    src_o, dst_o, ssh_o, dsh_o = (src[order], dst[order], s_sh[order],
                                  d_sh[order])
    pair_key = (dsh_o * num_shards + ssh_o) * g.num_nodes + src_o
    new = np.empty(len(pair_key), dtype=bool)
    if len(pair_key):
        new[0] = True
        np.not_equal(pair_key[1:], pair_key[:-1], out=new[1:])
    # rank of each update within its (s, t) group
    grp_key = dsh_o * num_shards + ssh_o
    upd_idx_global = np.cumsum(new) - 1
    grp_of_upd = grp_key[new]
    # per-update rank within its group
    grp_first_upd = np.zeros(grp_of_upd.shape[0], dtype=np.int64)
    if len(grp_of_upd):
        starts = np.flatnonzero(np.r_[True, grp_of_upd[1:]
                                      != grp_of_upd[:-1]])
        sizes = np.diff(np.r_[starts, len(grp_of_upd)])
        grp_first_upd = np.repeat(
            np.arange(len(grp_of_upd))[starts], sizes)
    upd_rank = np.arange(len(grp_of_upd)) - grp_first_upd

    counts = np.zeros(num_shards * num_shards, dtype=np.int64)
    np.add.at(counts, grp_of_upd, 1)
    u_max = max(int(counts.max(initial=0)), 1)

    send_ids = np.full((num_shards, num_shards, u_max), -1, dtype=np.int32)
    upd_src = src_o[new]
    upd_ssh = ssh_o[new]
    upd_dsh = dsh_o[new]
    send_ids[upd_ssh, upd_dsh, upd_rank] = (upd_src
                                            - upd_ssh * shard_size)

    # --- per-dst-shard edge streams referencing the receive buffer.
    # Receive buffer at shard t: rows s = send_ids[s, t] -> flat s*U + r.
    upd_slot = upd_ssh * u_max + upd_rank          # slot within dst buffer
    edge_slot = upd_slot[upd_idx_global]           # per edge (sorted order)
    # Re-sort the gather stream by destination node within each shard so
    # the shard-local gather can use the blocked run reduction
    # (DESIGN.md §3); edge_slot still points at the same receive slots.
    gorder = np.lexsort((dst_o, dsh_o))
    dsh_g = dsh_o[gorder]
    dst_g = dst_o[gorder]
    slot_g = edge_slot[gorder]
    e_counts = np.zeros(num_shards, dtype=np.int64)
    np.add.at(e_counts, dsh_g, 1)
    e_max = max(int(e_counts.max(initial=0)), 1)
    zero_slot = num_shards * u_max
    edge_upd = np.full((num_shards, e_max), zero_slot, dtype=np.int32)
    edge_dst = np.full((num_shards, e_max), shard_size, dtype=np.int32)
    e_first = np.zeros(len(dsh_g), dtype=np.int64)
    if len(dsh_g):
        starts = np.flatnonzero(np.r_[True, dsh_g[1:] != dsh_g[:-1]])
        sizes = np.diff(np.r_[starts, len(dsh_g)])
        e_first = np.repeat(np.arange(len(dsh_g))[starts], sizes)
    e_rank = np.arange(len(dsh_g)) - e_first
    edge_upd[dsh_g, e_rank] = slot_g
    edge_dst[dsh_g, e_rank] = dst_g - dsh_g * shard_size

    # --- per-shard blocked gather schedule over the dst-sorted streams
    scheds = [flat_gather_schedule(edge_upd[s], edge_dst[s],
                                   num_nodes=shard_size,
                                   block=gather_block,
                                   pad_update=zero_slot)
              for s in range(num_shards)]
    p_max = max(len(sc[1]) for sc in scheds)
    eui_padded = np.stack([sc[0] for sc in scheds])
    piece_start = np.zeros((num_shards, p_max), dtype=np.int32)
    piece_end = np.zeros((num_shards, p_max), dtype=np.int32)
    piece_dst = np.full((num_shards, p_max), shard_size, dtype=np.int32)
    for s, (_, st, en, pd) in enumerate(scheds):
        # pad pieces re-read run [0, 0] but carry the sentinel dst, so
        # the segment-sum drops them — mathematically inert
        piece_start[s, :len(st)] = st
        piece_end[s, :len(en)] = en
        piece_dst[s, :len(pd)] = pd

    wire_updates = int(np.sum(upd_ssh != upd_dsh))
    wire_edges = int(np.sum(s_sh != d_sh))
    return ShardedPNG(num_shards, shard_size, g.num_nodes,
                      send_ids, edge_upd, edge_dst,
                      gather_block, eui_padded, piece_start, piece_end,
                      piece_dst, wire_updates, wire_edges)


# --------------------------------------------------------------- engines
def _scatter_all_to_all(x_l, send_l, axis, *, num_shards, shard_size,
                        u_max):
    """Shard-local scatter + wire phase: gather this shard's dedup send
    buffers from local values and all-to-all them.  Returns the receive
    buffer (S*U + 1, d) with a trailing zero slot for pad edges."""
    ids = send_l[0]                                    # (S, U)
    bufs = x_l[jnp.clip(ids, 0, shard_size - 1)] * (ids >= 0)[..., None]
    recv = jax.lax.all_to_all(bufs, axis, 0, 0, tiled=True)
    recv = recv.reshape(num_shards * u_max, x_l.shape[-1])
    return jnp.concatenate(
        [recv, jnp.zeros((1, recv.shape[-1]), recv.dtype)], 0)


def pcpm_all_to_all_spmv(layout: ShardedPNG, mesh: Mesh, axis: str, *,
                         blocked: bool = True):
    """Returns a jitted y = A^T x over vertex-sharded x (padded to
    S * shard_size).  x: (n_pad,) or (n_pad, d).

    ``blocked=True`` (default) runs the shard-local gather as the
    hierarchical blocked reduction over the dst-sorted stream
    (DESIGN.md §3); ``blocked=False`` keeps the flat segment-sum as a
    debug fallback.
    """
    s, u = layout.num_shards, layout.send_ids.shape[2]
    ssz = layout.shard_size
    blk = layout.gather_block
    send_ids = jnp.asarray(layout.send_ids)     # (S, S, U)
    edge_upd = jnp.asarray(layout.edge_upd)     # (S, E)
    edge_dst = jnp.asarray(layout.edge_dst)     # (S, E)
    eui = jnp.asarray(layout.eui_padded)        # (S, Mp)
    ps = jnp.asarray(layout.piece_start)        # (S, P0)
    pe = jnp.asarray(layout.piece_end)          # (S, P0)
    pd = jnp.asarray(layout.piece_dst)          # (S, P0)
    vec = P(axis)
    mat = P(axis, None)

    def local(x_l, send_l, eu_l, ed_l, eui_l, ps_l, pe_l, pd_l):
        x_l = x_l.reshape(ssz, -1)
        recv = _scatter_all_to_all(x_l, send_l, axis, num_shards=s,
                                   shard_size=ssz, u_max=u)
        if blocked:
            return pcpm_gather_blocked(recv, eui_l[0], ps_l[0], pe_l[0],
                                       pd_l[0], num_nodes=ssz, block=blk)
        vals = recv[eu_l[0]]                               # (E, d)
        y = jax.ops.segment_sum(vals, ed_l[0], num_segments=ssz + 1)
        return y[:ssz]

    fn = shard_map(local, mesh=mesh,
                   in_specs=(vec, P(axis, None, None), mat, mat, mat,
                             mat, mat, mat),
                   out_specs=vec)

    @jax.jit
    def spmv(x):
        squeeze = x.ndim == 1
        xs = x[:, None] if squeeze else x
        y = fn(xs, send_ids, edge_upd, edge_dst, eui, ps, pe, pd)
        return y[:, 0] if squeeze else y

    return spmv


def edge_cut_spmv(g: Graph, num_shards: int, mesh: Mesh, axis: str):
    """Distributed BVGAS baseline: one update PER cross-shard edge on
    the wire (no dedup).  Send buffers are per-edge values grouped by
    destination shard."""
    shard_size = -(-g.num_nodes // num_shards)
    src, dst = g.src.astype(np.int64), g.dst.astype(np.int64)
    s_sh, d_sh = src // shard_size, dst // shard_size
    order = np.lexsort((dst, d_sh, s_sh))
    src_o, dst_o = src[order], dst[order]
    ssh_o, dsh_o = s_sh[order], d_sh[order]
    counts = np.zeros(num_shards * num_shards, dtype=np.int64)
    np.add.at(counts, ssh_o * num_shards + dsh_o, 1)
    e_max = max(int(counts.max(initial=0)), 1)
    send_src = np.full((num_shards, num_shards, e_max), -1, np.int32)
    send_dst = np.full((num_shards, num_shards, e_max), shard_size,
                       np.int32)
    grp = ssh_o * num_shards + dsh_o
    first = np.zeros(len(grp), dtype=np.int64)
    if len(grp):
        starts = np.flatnonzero(np.r_[True, grp[1:] != grp[:-1]])
        sizes = np.diff(np.r_[starts, len(grp)])
        first = np.repeat(np.arange(len(grp))[starts], sizes)
    rank = np.arange(len(grp)) - first
    send_src[ssh_o, dsh_o, rank] = src_o - ssh_o * shard_size
    send_dst[ssh_o, dsh_o, rank] = dst_o - dsh_o * shard_size

    send_src_j = jnp.asarray(send_src)
    send_dst_j = jnp.asarray(send_dst)
    vec, mat = P(axis), P(axis, None, None)

    def local(x_l, ss_l, sd_l):
        x_l = x_l.reshape(shard_size, -1)
        d = x_l.shape[-1]
        ids = ss_l[0]                                     # (S, E)
        bufs = x_l[jnp.clip(ids, 0, shard_size - 1)] * \
            (ids >= 0)[..., None]                          # (S, E, d)
        dsts = sd_l[0]                                    # (S, E) local dst
        recv_v = jax.lax.all_to_all(bufs, axis, 0, 0, tiled=True)
        recv_d = jax.lax.all_to_all(dsts, axis, 0, 0, tiled=True)
        y = jax.ops.segment_sum(recv_v.reshape(-1, d),
                                recv_d.reshape(-1),
                                num_segments=shard_size + 1)
        return y[:shard_size]

    fn = shard_map(local, mesh=mesh, in_specs=(vec, mat, mat),
                   out_specs=vec)

    @jax.jit
    def spmv(x):
        squeeze = x.ndim == 1
        xs = x[:, None] if squeeze else x
        y = fn(xs, send_src_j, send_dst_j)
        return y[:, 0] if squeeze else y

    return spmv


def pad_to_shards(x: np.ndarray, layout: ShardedPNG) -> np.ndarray:
    n_pad = layout.num_shards * layout.shard_size
    pad = n_pad - x.shape[0]
    width = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return np.pad(x, width)


# ----------------------------------------------- fused sharded iteration
def _shard_streams(layout: ShardedPNG):
    """Device copies of the static layout streams plus the pad-row
    mask — the per-shard constants every shard_map'd iteration loop
    (fused batch loop and serving chunk stepper alike) closes over."""
    mask_host = np.zeros(layout.padded_nodes, dtype=np.float32)
    mask_host[:layout.num_nodes] = 1.0
    return (jnp.asarray(layout.send_ids), jnp.asarray(layout.eui_padded),
            jnp.asarray(layout.piece_start),
            jnp.asarray(layout.piece_end),
            jnp.asarray(layout.piece_dst), jnp.asarray(mask_host))


def _local_gather_spmv(layout: ShardedPNG, axis: str, send_l, eui_l,
                       ps_l, pe_l, pd_l):
    """The shard-local y = A^T x closure (scatter + all-to-all +
    blocked gather) over the shard_map-sliced stream arguments."""
    s, u = layout.num_shards, layout.send_ids.shape[2]
    ssz, blk = layout.shard_size, layout.gather_block

    def spmv(x2):
        recv = _scatter_all_to_all(x2, send_l, axis, num_shards=s,
                                   shard_size=ssz, u_max=u)
        return pcpm_gather_blocked(recv, eui_l[0], ps_l[0], pe_l[0],
                                   pd_l[0], num_nodes=ssz, block=blk)

    return spmv


def sharded_power_iteration(layout: ShardedPNG, mesh: Mesh, axis: str,
                            *, damping: float = 0.85,
                            num_iterations: int = 20, tol: float = 0.0,
                            check_every: int = 1, multi: bool = False,
                            dangling: str = "none"):
    """Device-resident sharded PageRank loop (DESIGN.md §6).

    Returns a jitted ``run(pr0, inv_deg, base) -> (pr, it, residuals)``
    over PADDED, vertex-sharded arrays (``n_pad = S * shard_size``):
    ``pr0`` is donated, ``base`` is the already-(1-damping)-scaled
    teleport vector (zero in pad slots).  The whole iteration is ONE
    ``lax.while_loop`` under ``shard_map``:

    - scatter + all-to-all + shard-local blocked gather per step;
    - the L1 residual is psum-combined so the ``tol``/``check_every``
      early exit is a replicated on-device decision — no host syncs;
    - ``dangling="redistribute"`` psum-combines the rank mass parked on
      zero-out-degree nodes each step and redistributes it over the
      teleport distribution (``base / (1 - damping)``), conserving
      total mass at 1;
    - the pad-slot mask is a precomputed sharded constant (the seed
      rebuilt a host-side ``arange(n_pad)`` every iteration).

    With ``multi=True`` the state is (n_pad, d) — d independent rank
    vectors in lockstep; the residual is the max over columns.
    """
    if dangling not in ("none", "redistribute"):
        raise ValueError(f"unknown dangling policy {dangling!r}")
    send_ids, eui, ps, pe, pd, mask = _shard_streams(layout)
    vec = P(axis)
    state_spec = P(axis, None) if multi else P(axis)

    def local_run(pr, inv_deg, base, mask_l, send_l, eui_l, ps_l, pe_l,
                  pd_l):
        # pr/base: (ssz,) or (ssz, d); inv_deg/mask_l: (ssz,)
        inv_col = inv_deg[:, None] if multi else inv_deg
        mask_col = mask_l[:, None] if multi else mask_l
        # loop-invariant: dangling indicator and the redistribution
        # direction (teleport distribution scaled by damping) — XLA
        # hoists both out of the while body
        dang = (inv_deg == 0).astype(pr.dtype) * mask_l
        dang_col = dang[:, None] if multi else dang
        redist = base * (damping / (1.0 - damping))
        residuals0 = jnp.full((max(num_iterations, 1),), -1.0,
                              dtype=jnp.float32)
        spmv = _local_gather_spmv(layout, axis, send_l, eui_l, ps_l,
                                  pe_l, pd_l)

        def cond(state):
            it, _, _, done = state
            return (it < num_iterations) & ~done

        def body(state):
            it, pr, residuals, done = state
            spr = pr * inv_col                  # scaled ranks (alg.1 l.3)
            y = spmv(spr if multi else spr[:, None])
            y = y if multi else y[:, 0]
            pr_next = base + damping * y
            if dangling == "redistribute":
                dmass = jax.lax.psum((pr * dang_col).sum(axis=0), axis)
                pr_next = pr_next + dmass * redist
            pr_next = pr_next * mask_col
            check = (((it + 1) % check_every == 0)
                     | (it + 1 >= num_iterations))
            res_g = jax.lax.psum(jnp.abs(pr_next - pr).sum(axis=0),
                                 axis)
            res = jnp.where(check, res_g.max() if multi else res_g,
                            -1.0)
            residuals = residuals.at[it].set(res)
            if tol > 0:
                done = done | (check & (res >= 0) & (res < tol))
            return it + 1, pr_next, residuals, done

        it, pr, residuals, _ = jax.lax.while_loop(
            cond, body, (jnp.int32(0), pr, residuals0, jnp.bool_(False)))
        return pr, it, residuals

    fn = shard_map(local_run, mesh=mesh,
                   in_specs=(state_spec, vec, state_spec, vec,
                             P(axis, None, None), P(axis, None),
                             P(axis, None), P(axis, None),
                             P(axis, None)),
                   out_specs=(state_spec, P(), P()),
                   check_rep=False)

    @partial(jax.jit, donate_argnums=(0,))
    def run(pr, inv_deg, base):
        return fn(pr, inv_deg, base, mask, send_ids, eui, ps, pe, pd)

    return run


def sharded_chunk_stepper(layout: ShardedPNG, mesh: Mesh, axis: str, *,
                          damping: float = 0.85, chunk: int = 8,
                          dangling: str = "none"):
    """Sharded analogue of ``core.pagerank.masked_chunk_stepper``
    (DESIGN.md §7): advances a vertex-sharded (n_pad, B) slot pool by up
    to ``chunk`` iterations in ONE donated dispatch — scatter +
    all-to-all + blocked gather per step, per-column L1 residuals
    psum-combined so each column's freeze decision is replicated on
    device.  Per-column ``tol_col``/``budget`` are replicated data, so
    per-request parameters never retrace; frozen columns are masked out
    of the damping update exactly as in the single-device stepper.

    Returns ``step(pr, base, active, tol_col, budget, inv_deg) ->
    (pr, active, took, res)`` over PADDED sharded ``pr/base/inv_deg``
    and replicated (B,) control arrays.
    """
    if dangling not in ("none", "redistribute"):
        raise ValueError(f"unknown dangling policy {dangling!r}")
    send_ids, eui, ps, pe, pd, mask = _shard_streams(layout)
    vec = P(axis)
    state_spec = P(axis, None)
    rep = P()

    def local_step(pr, base, active, tol_col, budget, inv_deg, mask_l,
                   send_l, eui_l, ps_l, pe_l, pd_l):
        # pr/base: (shard_size, B); active/tol_col/budget: (B,) replicated
        inv_col = inv_deg[:, None]
        mask_col = mask_l[:, None]
        dang_col = ((inv_deg == 0).astype(pr.dtype) * mask_l)[:, None]
        redist = base * (damping / (1.0 - damping))
        took0 = jnp.zeros(pr.shape[1], dtype=jnp.int32)
        res0 = jnp.full((pr.shape[1],), -1.0, dtype=jnp.float32)
        spmv = _local_gather_spmv(layout, axis, send_l, eui_l, ps_l,
                                  pe_l, pd_l)

        def cond(state):
            i, _, act, _, _ = state
            return (i < chunk) & act.any()

        def body(state):
            i, pr, act, took, res = state
            spr = pr * inv_col
            pr_next = base + damping * spmv(spr)
            if dangling == "redistribute":
                dmass = jax.lax.psum((pr * dang_col).sum(axis=0), axis)
                pr_next = pr_next + dmass[None, :] * redist
            pr_next = pr_next * mask_col
            r = jax.lax.psum(jnp.abs(pr_next - pr).sum(axis=0), axis)
            pr = jnp.where(act[None, :], pr_next, pr)
            res = jnp.where(act, r, res)
            took = took + act.astype(jnp.int32)
            # quarantine guardrail (DESIGN.md §10): the psum residual
            # is replicated, so every shard freezes a NaN/Inf-poisoned
            # column on the same iteration — no extra collective
            act = act & jnp.isfinite(r) & (r >= tol_col) & (took < budget)
            return i + 1, pr, act, took, res

        _, pr, active, took, res = jax.lax.while_loop(
            cond, body, (jnp.int32(0), pr, active, took0, res0))
        return pr, active, took, res

    fn = shard_map(local_step, mesh=mesh,
                   in_specs=(state_spec, state_spec, rep, rep, rep,
                             vec, vec, P(axis, None, None),
                             P(axis, None), P(axis, None),
                             P(axis, None), P(axis, None)),
                   out_specs=(state_spec, rep, rep, rep),
                   check_rep=False)

    @partial(jax.jit, donate_argnums=(0,))
    def step(pr, base, active, tol_col, budget, inv_deg):
        return fn(pr, base, active, tol_col, budget, inv_deg, mask,
                  send_ids, eui, ps, pe, pd)

    return step


def _padded_inv_degree(g: Graph, layout: ShardedPNG) -> np.ndarray:
    out_deg = np.asarray(g.out_degree)
    inv = np.where(out_deg == 0, 0.0, 1.0 / np.maximum(out_deg, 1))
    return pad_to_shards(inv.astype(np.float32), layout)


def distributed_pagerank(g: Graph, mesh: Mesh, axis: str, *,
                         num_iterations: int = 20, damping: float = 0.85,
                         tol: float = 0.0, check_every: int = 1,
                         dangling: str = "none",
                         layout: ShardedPNG | None = None,
                         fused_cache: dict | None = None):
    """PageRank over the sharded PCPM engine — one donated fused
    ``lax.while_loop`` dispatch for the whole run (DESIGN.md §6).

    ``fused_cache`` (the plan-level loop cache when called through
    ``pagerank()``/``Session``) memoizes the jitted run per
    hyper-parameter set, so repeated calls skip the shard_map
    re-trace + re-compile exactly like the single-device driver.

    Returns a ``PageRankResult`` (ranks sliced back to ``num_nodes``).
    """
    from .pagerank import PageRankResult   # local: avoids import cycle
    num_shards = int(np.prod([sz for nme, sz in
                              zip(mesh.axis_names, mesh.devices.shape)
                              if nme == axis]))
    layout = layout or build_sharded_png(g, num_shards)
    key = ("sharded_fused", axis, damping, num_iterations, tol,
           check_every, dangling)
    run = fused_cache.get(key) if fused_cache is not None else None
    if run is None:
        run = sharded_power_iteration(layout, mesh, axis,
                                      damping=damping,
                                      num_iterations=num_iterations,
                                      tol=tol, check_every=check_every,
                                      dangling=dangling)
        if fused_cache is not None:
            fused_cache[key] = run
    n = g.num_nodes
    n_pad = layout.padded_nodes
    sharding = NamedSharding(mesh, P(axis))
    pr0_host = np.zeros(n_pad, dtype=np.float32)
    pr0_host[:n] = 1.0 / n
    base_host = np.zeros(n_pad, dtype=np.float32)
    base_host[:n] = (1.0 - damping) / n
    pr0 = jax.device_put(jnp.asarray(pr0_host), sharding)
    inv_deg = jax.device_put(jnp.asarray(_padded_inv_degree(g, layout)),
                             sharding)
    base = jax.device_put(jnp.asarray(base_host), sharding)
    pr, it, res = run(pr0, inv_deg, base)
    it = int(it)
    res_host = np.asarray(res)[:it]
    return PageRankResult(pr[:n], it,
                          [float(r) for r in res_host if r >= 0.0])
