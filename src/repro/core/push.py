"""The donated residual-push while_loop — shared home (DESIGN.md §9/§11).

One device loop, two callers with different seedings:

- **Delta push** (stream/incremental.py): ``r0`` is the sparse
  residual of a graph delta over a converged prior — a warm start.
- **Query push** (serve/push.py): ``pr0 = seed`` and ``r0 = x1 - x0``,
  the first power-iteration step from the seed — so the push iterates
  are EXACTLY the masked chunk stepper's iterates for the same query
  (same x0, same operator), and its stopping rule ``‖r‖₁ < tol`` is
  the stepper's per-step L1-change rule.  Equal tolerances mean equal
  stopping accuracy (final L1 distance to the fixed point
  ≤ tol·d/(1−d) either way).

The loop is ONE donated jitted ``lax.while_loop`` over the plan's
``spmv_fn``; pcpm plans route through the arg-passing ``_pcpm_push``
whose jit cache keys on bucket-padded stream SHAPES, so a stream of
patched plans — and every per-seed query — reuses one compiled
executable.  ``tol``/``max_push`` are runtime data: one trace serves
every tolerance.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .backends import fused_loop_cache, spmv_fn
from .plan import GraphPlan

# residuals ring size; ``max_push`` is runtime data clamped to this,
# so changing it (or tol) NEVER retraces the push loop
MAX_PUSH_BUF = 400

# shape buckets for the arg-passing pcpm push path: stream lengths are
# rounded up with inert pads to a multiple of max(PUSH_PAD, ~3-6% of
# the length), so consecutive small deltas (whose true lengths wobble
# by O(|delta|)) land in the SAME bucket and reuse one compiled
# executable — zero compile per delta.  A delta that outgrows its
# bucket costs one retrace, nothing else.
PUSH_PAD = 4096


def _bucket(length: int, *, align: int = 1) -> int:
    mult = max(PUSH_PAD, 1 << max(int(length).bit_length() - 5, 0))
    tgt = -(-max(length, 1) // mult) * mult
    return -(-tgt // align) * align


def _pad_to(arr: np.ndarray, fill, *, align: int = 1) -> np.ndarray:
    tgt = _bucket(len(arr), align=align)
    if tgt == len(arr):
        return arr
    out = np.full(tgt, fill, dtype=arr.dtype)
    out[:len(arr)] = arr
    return out


def _pcpm_push_streams(plan: GraphPlan):
    """Bucket-padded device copies of the pcpm streams for the
    arg-passing push loop (cached on the plan).

    Pads are inert by the same sentinel scheme the gather schedule
    already uses: pad pieces have start=end=0 and the ``num_nodes``
    destination (their contribution lands in the dropped overflow
    segment), pad pointer entries reference update 0 but belong to no
    piece, pad updates are referenced by no edge."""
    dev = plan._device.get("push_streams")
    if dev is None:
        s = plan.schedule
        n = plan.num_nodes
        blk = s.block
        dev = (jnp.asarray(_pad_to(plan.png.update_src, 0)),
               jnp.asarray(_pad_to(s.edge_update_idx_padded, 0,
                                   align=blk)),
               jnp.asarray(_pad_to(s.piece_start, 0)),
               jnp.asarray(_pad_to(s.piece_end, 0)),
               jnp.asarray(_pad_to(s.piece_dst, n)))
        plan._device["push_streams"] = dev
    return dev


def _push_while(pr, r, inv_deg, tol, max_push, spmv, *, num_nodes: int,
                damping: float, dangling: str):
    """THE push loop body — single home of the stopping rule, residual
    ring and dangling handling, shared by the arg-passing pcpm path
    and the generic closure path (``spmv`` is any traceable
    ``x -> AᵀD⁻¹-applied x``)."""
    dang = (inv_deg == 0).astype(pr.dtype)
    residuals0 = jnp.full((MAX_PUSH_BUF,), -1.0, dtype=jnp.float32)

    def cond(state):
        it, _, r, _ = state
        return ((it < jnp.minimum(max_push, MAX_PUSH_BUF))
                & (jnp.abs(r).sum() >= tol))

    def body(state):
        it, pr, r, residuals = state
        residuals = residuals.at[it].set(jnp.abs(r).sum())
        pr = pr + r
        r_next = damping * spmv(r * inv_deg)
        if dangling == "redistribute":
            r_next = r_next + (r * dang).sum() * (damping / num_nodes)
        return it + 1, pr, r_next, residuals

    it, pr, r, residuals = jax.lax.while_loop(
        cond, body, (jnp.int32(0), pr, r, residuals0))
    return pr, it, residuals, r


@partial(jax.jit, donate_argnums=(0, 1),
         static_argnames=("num_nodes", "block", "damping", "dangling"))
def _pcpm_push(pr, r, inv_deg, tol, max_push, upd_src, eui, ps, pe, pd,
               *, num_nodes: int, block: int, damping: float,
               dangling: str):
    """Module-level push loop with the streams as ARGUMENTS: the jit
    cache keys on their (bucketed) shapes, not their contents, so a
    stream of patched plans shares one compiled loop."""
    from .spmv import pcpm_gather_blocked

    def spmv(x):
        return pcpm_gather_blocked(x[upd_src], eui, ps, pe, pd,
                                   num_nodes=num_nodes, block=block)

    return _push_while(pr, r, inv_deg, tol, max_push, spmv,
                       num_nodes=num_nodes, damping=damping,
                       dangling=dangling)


def residual_push_loop(plan: GraphPlan, *, damping: float = 0.85,
                       dangling: str = "none"):
    """The plan's jitted push loop: ``run(pr, r, inv_deg, tol,
    max_push) -> (pr, sweeps, residuals, r_out)`` with ``pr`` and
    ``r`` donated; ``residuals`` is a (MAX_PUSH_BUF,) device array of
    the per-sweep pre-push ‖r‖₁ (−1.0 in unused slots) and ``r_out``
    the remaining residual vector (its norm is < tol iff the loop
    converged; ``update_ranks`` re-invokes with it when a budget
    larger than MAX_PUSH_BUF has sweeps left).  ``tol``/``max_push``
    are runtime data — one trace serves every tolerance.

    pcpm plans route through the arg-passing ``_pcpm_push`` (compiled
    once per shape bucket per process); other backends get a per-plan
    closure loop over their ``spmv_fn`` (compiled once per plan)."""
    if dangling not in ("none", "redistribute"):
        raise ValueError(f"unknown dangling policy {dangling!r}")
    key = ("push", damping, dangling)
    cache = fused_loop_cache(plan)
    cached = cache.get(key)
    if cached is not None:
        return cached

    if plan.method == "pcpm":
        streams = _pcpm_push_streams(plan)
        n, blk = plan.num_nodes, plan.schedule.block

        def run(pr, r, inv_deg, tol, max_push):
            return _pcpm_push(pr, r, inv_deg,
                              jnp.float32(tol), jnp.int32(max_push),
                              *streams, num_nodes=n, block=blk,
                              damping=damping, dangling=dangling)
    else:
        spmv = spmv_fn(plan)
        n = plan.num_nodes

        @partial(jax.jit, donate_argnums=(0, 1))
        def run(pr, r, inv_deg, tol, max_push):
            return _push_while(pr, r, inv_deg, tol, max_push, spmv,
                               num_nodes=n, damping=damping,
                               dangling=dangling)

    cache[key] = run
    return run


def seed_query_state(plan: GraphPlan, *, damping: float = 0.85,
                     dangling: str = "none"):
    """The plan's jitted query seeding: ``init(seed, inv_deg) ->
    (pr0, r0)`` with ``pr0 = seed`` and ``r0 = x1 − x0`` — the first
    power-iteration step from the seed, so handing ``(pr0, r0)`` to
    ``residual_push_loop`` makes the push walk the chunk stepper's
    exact iterates for the same personalized query (cached per plan
    like the loop itself)."""
    if dangling not in ("none", "redistribute"):
        raise ValueError(f"unknown dangling policy {dangling!r}")
    key = ("push_seed", damping, dangling)
    cache = fused_loop_cache(plan)
    cached = cache.get(key)
    if cached is not None:
        return cached

    spmv = spmv_fn(plan)
    n = plan.num_nodes

    @jax.jit
    def init(seed, inv_deg):
        x1 = (1.0 - damping) * seed + damping * spmv(seed * inv_deg)
        if dangling == "redistribute":
            dang = (inv_deg == 0).astype(seed.dtype)
            x1 = x1 + (seed * dang).sum() * (damping / n)
        return seed, x1 - seed

    cache[key] = init
    return init
