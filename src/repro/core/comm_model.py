"""Analytic communication & random-access models — paper §V, eqs. 3-10.

These are the paper's own napkin-math models; benchmarks/comm_model.py
evaluates them against the byte counts of our compiled engines
(cost_analysis) to validate the reproduction (EXPERIMENTS.md §Paper-claims).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelParams:
    n: int            # |V|
    m: int            # |E|
    k: int            # |P| partitions
    r: float          # compression ratio |E|/|E'|
    c_mr: float = 1.0  # PDPR cache miss ratio for source reads
    l: int = 64       # cache line bytes
    d_v: int = 4      # rank value bytes
    d_i: int = 4      # index bytes


def pdpr_bytes(p: ModelParams) -> float:
    """Eq. (3): m(d_i + c_mr*l) + n(d_i + d_v)."""
    return p.m * (p.d_i + p.c_mr * p.l) + p.n * (p.d_i + p.d_v)


def bvgas_bytes(p: ModelParams) -> float:
    """Eq. (4): 2m(d_i + d_v) + n(d_i + 2 d_v)."""
    return 2 * p.m * (p.d_i + p.d_v) + p.n * (p.d_i + 2 * p.d_v)


def pcpm_bytes(p: ModelParams) -> float:
    """Eq. (5): m(d_i(1+1/r) + 2 d_v/r) + k^2 d_i + 2 n d_v."""
    return (p.m * (p.d_i * (1 + 1 / p.r) + 2 * p.d_v / p.r)
            + p.k * p.k * p.d_i + 2 * p.n * p.d_v)


def bvgas_wins_over_pdpr(p: ModelParams) -> bool:
    """Eq. (6): c_mr > (d_i + 2 d_v) / l."""
    return p.c_mr > (p.d_i + 2 * p.d_v) / p.l


def pcpm_wins_over_pdpr(p: ModelParams) -> bool:
    """Eq. (7): c_mr > (d_i + 2 d_v) / (r l)."""
    return p.c_mr > (p.d_i + 2 * p.d_v) / (p.r * p.l)


def random_accesses(p: ModelParams) -> dict:
    """Eqs. (8)-(10)."""
    return {
        "pdpr": p.m * p.c_mr,
        "bvgas": p.m * p.d_v / p.l + p.k,
        "pcpm": p.k * p.k + p.k,
    }
