"""The three SpMV engines from the paper, in JAX.

All compute  y = A^T @ x  for the (possibly multi-)vector x — PageRank
uses x = scaled ranks, GNNs use x = node features (n, d).

- ``pdpr``  : pull-direction baseline (alg. 1) — per-destination gather
              of source values, i.e. segment-sum over CSC order.
- ``bvgas`` : Binning w/ Vertex-centric GAS (alg. 2) — scatter phase
              materializes one update PER EDGE into dst-partition-major
              bins; gather phase segment-sums them.
- ``pcpm``  : Partition-Centric (algs. 4+5) — scatter phase materializes
              one update PER (src, dst-partition) pair (the PNG update
              stream, m/r entries); gather expands updates over edges via
              the ``edge_update_idx`` stream and segment-sums.

The two-phase engines intentionally keep scatter and gather as separate
jitted stages so the bins round-trip through HBM exactly as the paper's
bins round-trip through DRAM; ``fused=True`` collapses them into one XLA
program (a beyond-paper optimization measured in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.formats import Graph
from .partition import Partitioning
from .png import PNGLayout, build_png


# ---------------------------------------------------------------------------
# Device-resident layouts
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DeviceCSC:
    """Edges sorted by destination (pull order)."""
    num_nodes: int
    src: jnp.ndarray   # (m,) int32, sorted by dst
    dst: jnp.ndarray   # (m,) int32, ascending

    @staticmethod
    def build(g: Graph) -> "DeviceCSC":
        order = np.lexsort((g.src, g.dst))
        return DeviceCSC(g.num_nodes, jnp.asarray(g.src[order]),
                         jnp.asarray(g.dst[order]))


@dataclasses.dataclass(frozen=True)
class DeviceBVGAS:
    """Edges sorted by destination partition (BVGAS deterministic layout:
    dst ids are written once, then reused every iteration)."""
    num_nodes: int
    src: jnp.ndarray   # (m,) int32, dst-partition-major
    dst: jnp.ndarray   # (m,) int32

    @staticmethod
    def build(g: Graph, part: Partitioning) -> "DeviceBVGAS":
        dstp = g.dst.astype(np.int64) // part.part_size
        order = np.lexsort((g.dst, g.src, dstp))
        return DeviceBVGAS(g.num_nodes, jnp.asarray(g.src[order]),
                           jnp.asarray(g.dst[order]))


@dataclasses.dataclass(frozen=True)
class DevicePNG:
    """Flat PNG streams on device (see core/png.py)."""
    num_nodes: int
    update_src: jnp.ndarray       # (U,) int32
    edge_update_idx: jnp.ndarray  # (M,) int32
    edge_dst: jnp.ndarray         # (M,) int32
    compression_ratio: float

    @staticmethod
    def build(g: Graph, part: Partitioning,
              layout: PNGLayout | None = None) -> "DevicePNG":
        layout = layout or build_png(g, part)
        return DevicePNG(layout.num_nodes,
                         jnp.asarray(layout.update_src),
                         jnp.asarray(layout.edge_update_idx),
                         jnp.asarray(layout.edge_dst),
                         layout.compression_ratio)


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("num_nodes",))
def pdpr_spmv(src: jnp.ndarray, dst: jnp.ndarray, x: jnp.ndarray,
              *, num_nodes: int) -> jnp.ndarray:
    """Pull-direction SpMV: y[v] = sum_{(u,v) in E} x[u]."""
    return jax.ops.segment_sum(x[src], dst, num_segments=num_nodes)


@partial(jax.jit, static_argnames=())
def bvgas_scatter(src: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Scatter: one update per edge, written to dst-partition-major bins."""
    return x[src]


@partial(jax.jit, static_argnames=("num_nodes",))
def bvgas_gather(bins: jnp.ndarray, dst: jnp.ndarray,
                 *, num_nodes: int) -> jnp.ndarray:
    return jax.ops.segment_sum(bins, dst, num_segments=num_nodes)


@partial(jax.jit, static_argnames=())
def pcpm_scatter(update_src: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Scatter: ONE update per (src, dst-partition) — the PNG compression.
    Update bins are m/r entries instead of m."""
    return x[update_src]


@partial(jax.jit, static_argnames=("num_nodes",))
def pcpm_gather(update_bins: jnp.ndarray, edge_update_idx: jnp.ndarray,
                edge_dst: jnp.ndarray, *, num_nodes: int) -> jnp.ndarray:
    """Gather: expand each update over its in-partition destinations
    (branch-free analogue of the MSB stream) and accumulate."""
    return jax.ops.segment_sum(update_bins[edge_update_idx], edge_dst,
                               num_segments=num_nodes)


@partial(jax.jit, static_argnames=("num_nodes", "fused"))
def pcpm_spmv(png_update_src, png_edge_update_idx, png_edge_dst, x,
              *, num_nodes: int, fused: bool = True) -> jnp.ndarray:
    bins = pcpm_scatter(png_update_src, x)
    return pcpm_gather(bins, png_edge_update_idx, png_edge_dst,
                       num_nodes=num_nodes)


# Weighted variant (paper §VII extension: weights travel with dest IDs).
@partial(jax.jit, static_argnames=("num_nodes",))
def pcpm_spmv_weighted(png_update_src, png_edge_update_idx, png_edge_dst,
                       edge_weight, x, *, num_nodes: int) -> jnp.ndarray:
    bins = x[png_update_src]
    vals = bins[png_edge_update_idx]
    if x.ndim > 1:
        vals = vals * edge_weight[:, None]
    else:
        vals = vals * edge_weight
    return jax.ops.segment_sum(vals, png_edge_dst, num_segments=num_nodes)


# ---------------------------------------------------------------------------
# Engine wrapper with a uniform API
# ---------------------------------------------------------------------------
class SpMVEngine:
    """y = A^T x with a fixed graph; `method` in {pdpr, bvgas, pcpm}."""

    def __init__(self, g: Graph, *, method: str = "pcpm",
                 part_size: int = 65536, two_phase: bool = False):
        self.method = method
        self.num_nodes = g.num_nodes
        self.num_edges = g.num_edges
        self.two_phase = two_phase
        part = Partitioning(g.num_nodes, part_size)
        self.partitioning = part
        if method == "pdpr":
            self._csc = DeviceCSC.build(g)
        elif method == "bvgas":
            self._bv = DeviceBVGAS.build(g, part)
        elif method == "pcpm":
            self.layout = build_png(g, part)
            self._png = DevicePNG.build(g, part, self.layout)
        else:
            raise ValueError(f"unknown method {method!r}")

    @property
    def compression_ratio(self) -> float:
        if self.method == "pcpm":
            return self._png.compression_ratio
        return 1.0

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.method == "pdpr":
            return pdpr_spmv(self._csc.src, self._csc.dst, x,
                             num_nodes=self.num_nodes)
        if self.method == "bvgas":
            bins = bvgas_scatter(self._bv.src, x)
            if self.two_phase:
                bins = jax.block_until_ready(bins)
            return bvgas_gather(bins, self._bv.dst,
                                num_nodes=self.num_nodes)
        bins = pcpm_scatter(self._png.update_src, x)
        if self.two_phase:
            bins = jax.block_until_ready(bins)
        return pcpm_gather(bins, self._png.edge_update_idx,
                           self._png.edge_dst, num_nodes=self.num_nodes)
