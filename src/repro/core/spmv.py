"""The three SpMV engines from the paper, in JAX.

All compute  y = A^T @ x  for the (possibly multi-)vector x — PageRank
uses x = scaled ranks, GNNs use x = node features (n, d).

- ``pdpr``  : pull-direction baseline (alg. 1) — per-destination gather
              of source values, i.e. segment-sum over CSC order.
- ``bvgas`` : Binning w/ Vertex-centric GAS (alg. 2) — scatter phase
              materializes one update PER EDGE into dst-partition-major
              bins; gather phase segment-sums them.
- ``pcpm``  : Partition-Centric (algs. 4+5) — scatter phase materializes
              one update PER (src, dst-partition) pair (the PNG update
              stream, m/r entries); gather expands updates over edges via
              the ``edge_update_idx`` stream and segment-sums.

The two-phase engines intentionally keep scatter and gather as separate
jitted stages so the bins round-trip through HBM exactly as the paper's
bins round-trip through DRAM; ``fused=True`` collapses them into one XLA
program (a beyond-paper optimization measured in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.formats import Graph
from .partition import Partitioning
from .png import (GatherSchedule, PNGLayout, block_png, build_png,
                  build_gather_schedule)


# ---------------------------------------------------------------------------
# Device-resident layouts
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DeviceCSC:
    """Edges sorted by destination (pull order)."""
    num_nodes: int
    src: jnp.ndarray   # (m,) int32, sorted by dst
    dst: jnp.ndarray   # (m,) int32, ascending

    @staticmethod
    def build(g: Graph) -> "DeviceCSC":
        order = np.lexsort((g.src, g.dst))
        return DeviceCSC(g.num_nodes, jnp.asarray(g.src[order]),
                         jnp.asarray(g.dst[order]))


@dataclasses.dataclass(frozen=True)
class DeviceBVGAS:
    """Edges sorted by destination partition (BVGAS deterministic layout:
    dst ids are written once, then reused every iteration)."""
    num_nodes: int
    src: jnp.ndarray   # (m,) int32, dst-partition-major
    dst: jnp.ndarray   # (m,) int32

    @staticmethod
    def build(g: Graph, part: Partitioning) -> "DeviceBVGAS":
        dstp = g.dst.astype(np.int64) // part.part_size
        order = np.lexsort((g.dst, g.src, dstp))
        return DeviceBVGAS(g.num_nodes, jnp.asarray(g.src[order]),
                           jnp.asarray(g.dst[order]))


@dataclasses.dataclass(frozen=True)
class DevicePNG:
    """Flat PNG streams on device (see core/png.py), plus the blocked
    gather schedule (piece bounds over the dst-sorted edge stream)."""
    num_nodes: int
    update_src: jnp.ndarray       # (U,) int32
    edge_update_idx: jnp.ndarray  # (M,) int32
    edge_dst: jnp.ndarray         # (M,) int32, ascending
    compression_ratio: float
    # blocked-gather schedule (see png.build_gather_schedule)
    gather_block: int
    eui_padded: jnp.ndarray       # (Mp,) int32
    piece_start: jnp.ndarray      # (P0,) int32
    piece_end: jnp.ndarray        # (P0,) int32
    piece_dst: jnp.ndarray        # (P0,) int32, pad = num_nodes

    @staticmethod
    def build(g: Graph, part: Partitioning,
              layout: PNGLayout | None = None, *,
              gather_block: int = 256) -> "DevicePNG":
        layout = layout or build_png(g, part)
        sched = build_gather_schedule(layout, block=gather_block)
        return DevicePNG(layout.num_nodes,
                         jnp.asarray(layout.update_src),
                         jnp.asarray(layout.edge_update_idx),
                         jnp.asarray(layout.edge_dst),
                         layout.compression_ratio,
                         sched.block,
                         jnp.asarray(sched.edge_update_idx_padded),
                         jnp.asarray(sched.piece_start),
                         jnp.asarray(sched.piece_end),
                         jnp.asarray(sched.piece_dst))


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("num_nodes",))
def pdpr_spmv(src: jnp.ndarray, dst: jnp.ndarray, x: jnp.ndarray,
              *, num_nodes: int) -> jnp.ndarray:
    """Pull-direction SpMV: y[v] = sum_{(u,v) in E} x[u]."""
    return jax.ops.segment_sum(x[src], dst, num_segments=num_nodes)


@partial(jax.jit, static_argnames=())
def bvgas_scatter(src: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Scatter: one update per edge, written to dst-partition-major bins."""
    return x[src]


@partial(jax.jit, static_argnames=("num_nodes",))
def bvgas_gather(bins: jnp.ndarray, dst: jnp.ndarray,
                 *, num_nodes: int) -> jnp.ndarray:
    return jax.ops.segment_sum(bins, dst, num_segments=num_nodes)


@partial(jax.jit, static_argnames=())
def pcpm_scatter(update_src: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Scatter: ONE update per (src, dst-partition) — the PNG compression.
    Update bins are m/r entries instead of m."""
    return x[update_src]


@partial(jax.jit, static_argnames=("num_nodes",))
def pcpm_gather(update_bins: jnp.ndarray, edge_update_idx: jnp.ndarray,
                edge_dst: jnp.ndarray, *, num_nodes: int) -> jnp.ndarray:
    """Gather: expand each update over its in-partition destinations
    (branch-free analogue of the MSB stream) and accumulate.

    Flat element-wise scatter-add — kept as the shape-agnostic fallback
    and for the paper's two-phase timing; the hot path is
    ``pcpm_gather_blocked``.
    """
    return jax.ops.segment_sum(update_bins[edge_update_idx], edge_dst,
                               num_segments=num_nodes)


@partial(jax.jit, static_argnames=("num_nodes", "block"))
def pcpm_gather_blocked(update_bins: jnp.ndarray, eui_padded: jnp.ndarray,
                        piece_start: jnp.ndarray, piece_end: jnp.ndarray,
                        piece_dst: jnp.ndarray, *, num_nodes: int,
                        block: int) -> jnp.ndarray:
    """Hierarchical gather over the dst-sorted stream (DESIGN.md §3).

    Per-block inclusive prefix sums turn each destination's run into a
    difference of two gathers; only the ~n + M/block run sums hit the
    element-wise scatter-add, which XLA:CPU executes serially.  ~9x
    faster than the flat ``pcpm_gather`` at bench scale, identical to
    f32 rounding.
    """
    vals = update_bins[eui_padded]                  # (Mp,) or (Mp, d)
    nb = eui_padded.shape[0] // block
    local = jnp.cumsum(
        vals.reshape((nb, block) + vals.shape[1:]), axis=1
    ).reshape(vals.shape)
    lead = local[piece_end]
    prev = local[jnp.maximum(piece_start - 1, 0)]
    at_block_start = piece_start % block == 0
    if vals.ndim > 1:
        at_block_start = at_block_start[:, None]
    piece_sum = lead - jnp.where(at_block_start, 0, prev)
    return jax.ops.segment_sum(piece_sum, piece_dst,
                               num_segments=num_nodes + 1,
                               indices_are_sorted=True)[:num_nodes]


@partial(jax.jit, static_argnames=("num_nodes", "fused"))
def pcpm_spmv(png_update_src, png_edge_update_idx, png_edge_dst, x,
              *, num_nodes: int, fused: bool = True) -> jnp.ndarray:
    """Two-phase PCPM SpMV.  ``fused=True`` (default) lets XLA fuse the
    scatter into the gather's expansion; ``fused=False`` places an
    optimization barrier between the phases so the m/r-entry update bins
    materialize in HBM, reproducing the paper's bins-round-trip-through-
    DRAM structure inside a single program."""
    bins = pcpm_scatter(png_update_src, x)
    if not fused:
        bins = jax.lax.optimization_barrier(bins)
    return pcpm_gather(bins, png_edge_update_idx, png_edge_dst,
                       num_nodes=num_nodes)


# Weighted variant (paper §VII extension: weights travel with dest IDs).
@partial(jax.jit, static_argnames=("num_nodes",))
def pcpm_spmv_weighted(png_update_src, png_edge_update_idx, png_edge_dst,
                       edge_weight, x, *, num_nodes: int) -> jnp.ndarray:
    bins = x[png_update_src]
    vals = bins[png_edge_update_idx]
    if x.ndim > 1:
        vals = vals * edge_weight[:, None]
    else:
        vals = vals * edge_weight
    return jax.ops.segment_sum(vals, png_edge_dst, num_segments=num_nodes)


# ---------------------------------------------------------------------------
# Engine wrapper with a uniform API
# ---------------------------------------------------------------------------
class SpMVEngine:
    """y = A^T x with a fixed graph.

    ``method`` in {pdpr, bvgas, pcpm, pcpm_pallas, pcpm_sharded}: the
    three paper engines, the Pallas-kernel PCPM path (tiled one-hot
    gather v2, interpret-mode fallback off-TPU — see kernels/pcpm_spmv),
    and the multi-device all-to-all PCPM path (core/distributed.py;
    vertex-sharded over ``num_shards`` devices, default all of them).
    """

    def __init__(self, g: Graph, *, method: str = "pcpm",
                 part_size: int = 65536, two_phase: bool = False,
                 num_shards: int | None = None,
                 shard_axis: str = "shards"):
        self.method = method
        self.num_nodes = g.num_nodes
        self.num_edges = g.num_edges
        self.two_phase = two_phase
        part = Partitioning(g.num_nodes, part_size)
        self.partitioning = part
        self._fused_cache: dict = {}   # used by core.pagerank
        if method == "pdpr":
            self._csc = DeviceCSC.build(g)
        elif method == "bvgas":
            self._bv = DeviceBVGAS.build(g, part)
        elif method == "pcpm":
            self.layout = build_png(g, part)
            self._png = DevicePNG.build(g, part, self.layout)
        elif method == "pcpm_pallas":
            from ..kernels.pcpm_spmv import pack_blocked
            self.layout = build_png(g, part)
            self._packed = pack_blocked(block_png(self.layout),
                                        g.num_nodes)
        elif method == "pcpm_sharded":
            from jax.sharding import Mesh
            from .distributed import (build_sharded_png,
                                      pcpm_all_to_all_spmv)
            avail = jax.device_count()
            num_shards = num_shards or avail
            if num_shards > avail:
                raise ValueError(
                    f"num_shards={num_shards} exceeds the "
                    f"{avail} available devices")
            self.shard_axis = shard_axis
            self.mesh = Mesh(
                np.array(jax.devices()[:num_shards]), (shard_axis,))
            self.sharded_layout = build_sharded_png(g, num_shards)
            self._sharded_spmv = pcpm_all_to_all_spmv(
                self.sharded_layout, self.mesh, shard_axis)
        else:
            raise ValueError(f"unknown method {method!r}")

    @property
    def compression_ratio(self) -> float:
        if self.method in ("pcpm", "pcpm_pallas"):
            return self.layout.compression_ratio
        if self.method == "pcpm_sharded":
            return self.sharded_layout.wire_compression
        return 1.0

    def spmv_fn(self):
        """A pure, traceable ``x -> A^T x`` closure over the device-
        resident layout — what the fused `lax.while_loop` PageRank
        driver and AOT compilation consume.  Ignores ``two_phase``
        (a host-side timing barrier has no meaning under jit)."""
        if self.method == "pdpr":
            csc, n = self._csc, self.num_nodes
            return lambda x: pdpr_spmv(csc.src, csc.dst, x, num_nodes=n)
        if self.method == "bvgas":
            bv, n = self._bv, self.num_nodes
            return lambda x: bvgas_gather(bvgas_scatter(bv.src, x),
                                          bv.dst, num_nodes=n)
        if self.method == "pcpm_pallas":
            from ..kernels.pcpm_spmv import pcpm_spmv_pallas
            packed = self._packed
            return lambda x: pcpm_spmv_pallas(packed, x)
        if self.method == "pcpm_sharded":
            spmv, n = self._sharded_spmv, self.num_nodes
            n_pad = self.sharded_layout.padded_nodes

            def fn(x):
                width = ((0, n_pad - n),) + ((0, 0),) * (x.ndim - 1)
                return spmv(jnp.pad(x, width))[:n]
            return fn
        png, n = self._png, self.num_nodes
        return lambda x: pcpm_gather_blocked(
            pcpm_scatter(png.update_src, x), png.eui_padded,
            png.piece_start, png.piece_end, png.piece_dst,
            num_nodes=n, block=png.gather_block)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.method in ("pdpr", "pcpm_pallas", "pcpm_sharded"):
            return self.spmv_fn()(x)
        if self.method == "bvgas":
            bins = bvgas_scatter(self._bv.src, x)
            if self.two_phase:
                bins = jax.block_until_ready(bins)
            return bvgas_gather(bins, self._bv.dst,
                                num_nodes=self.num_nodes)
        bins = pcpm_scatter(self._png.update_src, x)
        if self.two_phase:
            bins = jax.block_until_ready(bins)
        return pcpm_gather_blocked(
            bins, self._png.eui_padded, self._png.piece_start,
            self._png.piece_end, self._png.piece_dst,
            num_nodes=self.num_nodes, block=self._png.gather_block)
