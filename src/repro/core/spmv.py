"""The three SpMV engines from the paper, in JAX.

All compute  y = A^T @ x  for the (possibly multi-)vector x — PageRank
uses x = scaled ranks, GNNs use x = node features (n, d).

- ``pdpr``  : pull-direction baseline (alg. 1) — per-destination gather
              of source values, i.e. segment-sum over CSC order.
- ``bvgas`` : Binning w/ Vertex-centric GAS (alg. 2) — scatter phase
              materializes one update PER EDGE into dst-partition-major
              bins; gather phase segment-sums them.
- ``pcpm``  : Partition-Centric (algs. 4+5) — scatter phase materializes
              one update PER (src, dst-partition) pair (the PNG update
              stream, m/r entries); gather expands updates over edges via
              the ``edge_update_idx`` stream and segment-sums.

The two-phase engines intentionally keep scatter and gather as separate
jitted stages so the bins round-trip through HBM exactly as the paper's
bins round-trip through DRAM; ``fused=True`` collapses them into one XLA
program (a beyond-paper optimization measured in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.formats import Graph
from .partition import Partitioning
from .png import (GatherSchedule, PNGLayout, build_png,
                  build_gather_schedule)


# ---------------------------------------------------------------------------
# Device-resident layouts
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DeviceCSC:
    """Edges sorted by destination (pull order)."""
    num_nodes: int
    src: jnp.ndarray   # (m,) int32, sorted by dst
    dst: jnp.ndarray   # (m,) int32, ascending

    @staticmethod
    def build(g: Graph) -> "DeviceCSC":
        order = np.lexsort((g.src, g.dst))
        return DeviceCSC(g.num_nodes, jnp.asarray(g.src[order]),
                         jnp.asarray(g.dst[order]))


@dataclasses.dataclass(frozen=True)
class DeviceBVGAS:
    """Edges sorted by destination partition (BVGAS deterministic layout:
    dst ids are written once, then reused every iteration)."""
    num_nodes: int
    src: jnp.ndarray   # (m,) int32, dst-partition-major
    dst: jnp.ndarray   # (m,) int32

    @staticmethod
    def build(g: Graph, part: Partitioning) -> "DeviceBVGAS":
        dstp = g.dst.astype(np.int64) // part.part_size
        order = np.lexsort((g.dst, g.src, dstp))
        return DeviceBVGAS(g.num_nodes, jnp.asarray(g.src[order]),
                           jnp.asarray(g.dst[order]))


@dataclasses.dataclass(frozen=True)
class DevicePNG:
    """Flat PNG streams on device (see core/png.py), plus the blocked
    gather schedule (piece bounds over the dst-sorted edge stream)."""
    num_nodes: int
    update_src: jnp.ndarray       # (U,) int32
    edge_update_idx: jnp.ndarray  # (M,) int32
    edge_dst: jnp.ndarray         # (M,) int32, ascending
    compression_ratio: float
    # blocked-gather schedule (see png.build_gather_schedule)
    gather_block: int
    eui_padded: jnp.ndarray       # (Mp,) int32
    piece_start: jnp.ndarray      # (P0,) int32
    piece_end: jnp.ndarray        # (P0,) int32
    piece_dst: jnp.ndarray        # (P0,) int32, pad = num_nodes

    @staticmethod
    def build(g: Graph, part: Partitioning,
              layout: PNGLayout | None = None, *,
              gather_block: int = 256) -> "DevicePNG":
        layout = layout or build_png(g, part)
        sched = build_gather_schedule(layout, block=gather_block)
        return DevicePNG(layout.num_nodes,
                         jnp.asarray(layout.update_src),
                         jnp.asarray(layout.edge_update_idx),
                         jnp.asarray(layout.edge_dst),
                         layout.compression_ratio,
                         sched.block,
                         jnp.asarray(sched.edge_update_idx_padded),
                         jnp.asarray(sched.piece_start),
                         jnp.asarray(sched.piece_end),
                         jnp.asarray(sched.piece_dst))


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("num_nodes",))
def pdpr_spmv(src: jnp.ndarray, dst: jnp.ndarray, x: jnp.ndarray,
              *, num_nodes: int) -> jnp.ndarray:
    """Pull-direction SpMV: y[v] = sum_{(u,v) in E} x[u]."""
    return jax.ops.segment_sum(x[src], dst, num_segments=num_nodes)


@partial(jax.jit, static_argnames=())
def bvgas_scatter(src: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Scatter: one update per edge, written to dst-partition-major bins."""
    return x[src]


@partial(jax.jit, static_argnames=("num_nodes",))
def bvgas_gather(bins: jnp.ndarray, dst: jnp.ndarray,
                 *, num_nodes: int) -> jnp.ndarray:
    return jax.ops.segment_sum(bins, dst, num_segments=num_nodes)


@partial(jax.jit, static_argnames=())
def pcpm_scatter(update_src: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Scatter: ONE update per (src, dst-partition) — the PNG compression.
    Update bins are m/r entries instead of m."""
    return x[update_src]


@partial(jax.jit, static_argnames=("num_nodes",))
def pcpm_gather(update_bins: jnp.ndarray, edge_update_idx: jnp.ndarray,
                edge_dst: jnp.ndarray, *, num_nodes: int) -> jnp.ndarray:
    """Gather: expand each update over its in-partition destinations
    (branch-free analogue of the MSB stream) and accumulate.

    Flat element-wise scatter-add — kept as the shape-agnostic fallback
    and for the paper's two-phase timing; the hot path is
    ``pcpm_gather_blocked``.
    """
    return jax.ops.segment_sum(update_bins[edge_update_idx], edge_dst,
                               num_segments=num_nodes)


@partial(jax.jit, static_argnames=("num_nodes", "block"))
def pcpm_gather_blocked(update_bins: jnp.ndarray, eui_padded: jnp.ndarray,
                        piece_start: jnp.ndarray, piece_end: jnp.ndarray,
                        piece_dst: jnp.ndarray, *, num_nodes: int,
                        block: int) -> jnp.ndarray:
    """Hierarchical gather over the dst-sorted stream (DESIGN.md §3).

    Per-block inclusive prefix sums turn each destination's run into a
    difference of two gathers; only the ~n + M/block run sums hit the
    element-wise scatter-add, which XLA:CPU executes serially.  ~9x
    faster than the flat ``pcpm_gather`` at bench scale, identical to
    f32 rounding.
    """
    vals = update_bins[eui_padded]                  # (Mp,) or (Mp, d)
    nb = eui_padded.shape[0] // block
    local = jnp.cumsum(
        vals.reshape((nb, block) + vals.shape[1:]), axis=1
    ).reshape(vals.shape)
    lead = local[piece_end]
    prev = local[jnp.maximum(piece_start - 1, 0)]
    at_block_start = piece_start % block == 0
    if vals.ndim > 1:
        at_block_start = at_block_start[:, None]
    piece_sum = lead - jnp.where(at_block_start, 0, prev)
    return jax.ops.segment_sum(piece_sum, piece_dst,
                               num_segments=num_nodes + 1,
                               indices_are_sorted=True)[:num_nodes]


@partial(jax.jit, static_argnames=("num_nodes", "fused"))
def pcpm_spmv(png_update_src, png_edge_update_idx, png_edge_dst, x,
              *, num_nodes: int, fused: bool = True) -> jnp.ndarray:
    """Two-phase PCPM SpMV.  ``fused=True`` (default) lets XLA fuse the
    scatter into the gather's expansion; ``fused=False`` places an
    optimization barrier between the phases so the m/r-entry update bins
    materialize in HBM, reproducing the paper's bins-round-trip-through-
    DRAM structure inside a single program."""
    bins = pcpm_scatter(png_update_src, x)
    if not fused:
        bins = jax.lax.optimization_barrier(bins)
    return pcpm_gather(bins, png_edge_update_idx, png_edge_dst,
                       num_nodes=num_nodes)


# Weighted variant (paper §VII extension: weights travel with dest IDs).
@partial(jax.jit, static_argnames=("num_nodes",))
def pcpm_spmv_weighted(png_update_src, png_edge_update_idx, png_edge_dst,
                       edge_weight, x, *, num_nodes: int) -> jnp.ndarray:
    bins = x[png_update_src]
    vals = bins[png_edge_update_idx]
    if x.ndim > 1:
        vals = vals * edge_weight[:, None]
    else:
        vals = vals * edge_weight
    return jax.ops.segment_sum(vals, png_edge_dst, num_segments=num_nodes)


# ---------------------------------------------------------------------------
# Engine wrapper with a uniform API
# ---------------------------------------------------------------------------
class SpMVEngine:
    """y = A^T x with a fixed graph — a thin shim over the plan/run
    split (DESIGN.md §8): construction resolves ``method`` through the
    backend registry (``core.backends``) and fetches the preprocessing
    artifact from the process-level plan cache (``core.plan``), so two
    engines on the same ``(graph, config)`` share ONE ``GraphPlan``
    (layouts sorted once, device streams uploaded once).

    ``method`` is any registered backend — the built-ins are the three
    paper engines (pdpr, bvgas, pcpm), the Pallas-kernel PCPM path
    (pcpm_pallas) and the multi-device all-to-all PCPM path
    (pcpm_sharded; vertex-sharded over ``num_shards`` devices, default
    all of them).  A prebuilt/loaded ``plan`` overrides the knob
    arguments.  New code should prefer ``repro.open`` (repro/api.py).
    """

    def __init__(self, g: Graph, *, method: str = "pcpm",
                 part_size: int = 65536, two_phase: bool = False,
                 num_shards: int | None = None, plan=None):
        from . import backends
        from .plan import PlanConfig, build_plan, validate_plan
        if plan is None:
            plan = build_plan(g, PlanConfig(
                method=method, part_size=part_size,
                num_shards=num_shards))
        else:
            validate_plan(g, plan)
            if plan.sharded is not None:
                backends.check_device_count(plan.sharded.num_shards)
        self.plan = plan
        self.method = plan.method
        self.backend = backends.get_backend(plan.method)
        if two_phase and not self.backend.supports_two_phase:
            raise ValueError(
                f"two_phase=True is only meaningful for the two-phase "
                f"engines; backend {self.method!r} does not support it")
        self.num_nodes = plan.num_nodes
        self.num_edges = plan.num_edges
        self.two_phase = two_phase
        self.partitioning = plan.partitioning
        # mesh axis name — the plan's (normalized) axis, so the fused
        # drivers, serving paths and the spmv closure all share ONE
        # mesh and one compiled all-to-all program
        self.shard_axis = plan.config.shard_axis

    # ------------------------------------------------------ plan views
    @property
    def layout(self) -> PNGLayout:
        """The PNG layout (pcpm/pcpm_pallas plans)."""
        if self.plan.png is None:
            raise AttributeError(
                f"backend {self.method!r} has no PNG layout")
        return self.plan.png

    @property
    def sharded_layout(self):
        if self.plan.sharded is None:
            raise AttributeError(
                f"backend {self.method!r} has no sharded layout")
        return self.plan.sharded

    @property
    def mesh(self):
        from . import backends
        return backends.sharded_mesh(self.plan, self.shard_axis)

    @property
    def compression_ratio(self) -> float:
        return self.plan.compression_ratio

    @property
    def _fused_cache(self) -> dict:
        # plan-level, so every engine/driver on one plan shares traces
        from . import backends
        return backends.fused_loop_cache(self.plan)

    def spmv_fn(self):
        """A pure, traceable ``x -> A^T x`` closure over the plan's
        device-resident streams — what the fused `lax.while_loop`
        PageRank driver and AOT compilation consume.  Raises for
        ``two_phase`` engines rather than silently dropping the phase
        barrier (a host-side barrier has no meaning under jit).

        For reordered plans (``plan.reorder_perm`` set) this closure
        operates in INTERNAL (relabeled) space — fused consumers
        iterate there and map results once at the boundary
        (``core.plan.internal_graph`` / ``backends.reorder_device``);
        ``__call__`` is the original-space per-pass wrapper."""
        if self.two_phase:
            raise ValueError(
                "a two_phase engine cannot provide a fused spmv_fn: "
                "the host-side phase barrier does not exist under jit."
                " Construct the engine with two_phase=False for fused/"
                "serving consumers.")
        from . import backends
        return backends.spmv_fn(self.plan)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        from . import backends
        fn = (backends.two_phase_spmv_fn(self.plan) if self.two_phase
              # host barrier between scatter and gather: the backend's
              # own two_phase_fn (bins round-trip through HBM exactly
              # as the paper's bins round-trip through DRAM)
              else backends.spmv_fn(self.plan))
        if self.plan.reorder_perm is None:
            return fn(x)
        # reordered plan: the layouts index the relabeled graph, so map
        # x into internal space and the result back — callers see the
        # original labeling.  Fused consumers skip this by iterating in
        # internal space via spmv_fn() and mapping once at the end.
        perm, inv = backends.reorder_device(self.plan)
        y = fn(jnp.take(jnp.asarray(x), inv, axis=0))
        return jnp.take(y, perm, axis=0)
