"""Vertex partitioning (paper §III/§IV).

Partitions are contiguous vertex-ID ranges: node v belongs to partition
``v // part_size`` — identical to the paper's ``u/m`` binning.  The
partition size is the cache-residency knob on CPU; on TPU it is the
VMEM-residency knob (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Partitioning:
    num_nodes: int
    part_size: int

    @property
    def num_partitions(self) -> int:
        return -(-self.num_nodes // self.part_size)

    @property
    def padded_nodes(self) -> int:
        return self.num_partitions * self.part_size

    def part_of(self, node_ids: np.ndarray) -> np.ndarray:
        return node_ids // self.part_size

    def local_of(self, node_ids: np.ndarray) -> np.ndarray:
        return node_ids % self.part_size


def partition_for_vmem(num_nodes: int, *, value_bytes: int = 4,
                       vmem_budget_bytes: int = 8 * 2 ** 20) -> Partitioning:
    """Pick the largest power-of-two partition size whose rank-accumulator
    fits the VMEM budget (paper's 256 KB LLC heuristic, scaled to TPU).
    """
    part = 1 << max(8, (vmem_budget_bytes // value_bytes).bit_length() - 1)
    part = min(part, max(256, 1 << (num_nodes - 1).bit_length()))
    return Partitioning(num_nodes, part)
