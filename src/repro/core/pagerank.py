"""PageRank driver (paper eq. 1/2) over any SpMV engine.

Matches the paper's algorithms: ranks are stored SCALED (PR/|N_o|)
during iteration (alg. 1 line 3 / alg. 2) and unscaled at the end.
Dangling nodes (|N_o| = 0) contribute nothing downstream, matching the
paper's implicit behaviour; their own rank is still computed.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..graphs.formats import Graph
from .spmv import SpMVEngine


@dataclasses.dataclass
class PageRankResult:
    ranks: jnp.ndarray       # unscaled PR vector
    iterations: int
    residuals: list


def pagerank(g: Graph, *, method: str = "pcpm", num_iterations: int = 20,
             damping: float = 0.85, part_size: int = 65536,
             tol: float = 0.0, engine: SpMVEngine | None = None
             ) -> PageRankResult:
    eng = engine or SpMVEngine(g, method=method, part_size=part_size)
    n = g.num_nodes
    out_deg = np.asarray(g.out_degree)
    inv_deg = jnp.asarray(
        np.where(out_deg == 0, 0.0, 1.0 / np.maximum(out_deg, 1))
    ).astype(jnp.float32)

    pr = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    base = (1.0 - damping) / n
    residuals = []
    it = 0
    for it in range(1, num_iterations + 1):
        spr = pr * inv_deg                    # scaled ranks (alg. 1 l. 3)
        pr_next = base + damping * eng(spr)   # A^T @ SPR
        res = float(jnp.abs(pr_next - pr).sum())
        residuals.append(res)
        pr = pr_next
        if tol and res < tol:
            break
    return PageRankResult(pr, it, residuals)


def pagerank_reference(g: Graph, *, num_iterations: int = 20,
                       damping: float = 0.85) -> np.ndarray:
    """Dense numpy oracle for tests (small graphs only)."""
    n = g.num_nodes
    A = np.zeros((n, n), dtype=np.float64)
    np.add.at(A, (g.src, g.dst), 1.0)
    deg = np.maximum(g.out_degree, 1).astype(np.float64)
    inv = np.where(g.out_degree == 0, 0.0, 1.0 / deg)
    pr = np.full(n, 1.0 / n)
    for _ in range(num_iterations):
        pr = (1 - damping) / n + damping * (A.T @ (pr * inv))
    return pr
