"""PageRank driver (paper eq. 1/2) over any SpMV engine.

Matches the paper's algorithms: ranks are stored SCALED (PR/|N_o|)
during iteration (alg. 1 line 3 / alg. 2) and unscaled at the end.
Dangling nodes (|N_o| = 0) contribute nothing downstream, matching the
paper's implicit behaviour; their own rank is still computed.

Two drivers (DESIGN.md §4):

- ``driver="fused"`` (default): the whole power iteration is ONE
  donated, jitted ``lax.while_loop`` — rank buffers never leave the
  device, the L1 residual is computed on device, and the ``tol`` early
  exit is decided on device every ``check_every`` iterations.  Zero
  host transfers inside the loop; one dispatch for the entire run.
- ``driver="python"``: the original per-iteration Python loop, kept as
  a debug fallback (and used automatically for ``two_phase`` engines,
  whose host-side phase barrier cannot exist under jit).  It blocks on
  a host float once per iteration.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.formats import Graph
from .spmv import SpMVEngine


@dataclasses.dataclass
class PageRankResult:
    ranks: jnp.ndarray       # unscaled PR vector
    iterations: int
    residuals: list


def _inv_degree(g: Graph) -> jnp.ndarray:
    out_deg = np.asarray(g.out_degree)
    return jnp.asarray(
        np.where(out_deg == 0, 0.0, 1.0 / np.maximum(out_deg, 1))
    ).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Fused driver
# ---------------------------------------------------------------------------
def fused_power_iteration(engine: SpMVEngine, *, damping: float = 0.85,
                          num_iterations: int = 20, tol: float = 0.0,
                          check_every: int = 1, multi: bool = False,
                          dangling: str = "none"):
    """Build (and cache on the engine) the jitted fused iteration loop.

    Returns a callable ``run(pr0, inv_deg, base) -> (pr, it, residuals)``
    where ``pr0`` is donated, ``base`` is the already-(1-damping)-scaled
    teleport vector (same shape as ``pr0``; a uniform vector for plain
    PageRank, per-column seed distributions for personalized queries),
    and ``residuals`` is a (num_iterations,) device array with -1.0 in
    slots where convergence was not checked.

    With ``multi=True`` the state is (n, d) — d independent rank vectors
    iterated in lockstep (the batched/personalized serving shape); the
    recorded residual is the max over columns and the loop exits only
    once every column is below ``tol``.

    The L1 residual is evaluated every ``check_every`` iterations (and
    on the last), so ``tol`` no longer costs a per-step reduction, let
    alone the Python driver's per-step host sync.

    ``dangling="redistribute"`` adds sink handling: the rank mass
    parked on zero-out-degree nodes is summed each step and
    redistributed over the teleport distribution (``base`` rescaled by
    ``damping / (1 - damping)``), so total mass is conserved at 1.  The
    default ``"none"`` keeps the paper's implicit drop-the-mass
    behaviour.
    """
    if dangling not in ("none", "redistribute"):
        raise ValueError(f"unknown dangling policy {dangling!r}")
    key = ("fused", damping, num_iterations, tol, check_every, multi,
           dangling)
    cached = engine._fused_cache.get(key)
    if cached is not None:
        return cached

    spmv = engine.spmv_fn()
    n = engine.num_nodes

    @partial(jax.jit, donate_argnums=(0,))
    def run(pr, inv_deg, base):
        if multi:
            inv_deg = inv_deg[:, None]
        # loop-invariant sink terms — XLA hoists both out of the body
        dang = (inv_deg == 0).astype(pr.dtype)
        redist = base * (damping / (1.0 - damping))
        residuals0 = jnp.full((max(num_iterations, 1),), -1.0,
                              dtype=jnp.float32)

        def cond(state):
            it, _, _, done = state
            return (it < num_iterations) & ~done

        def body(state):
            it, pr, residuals, done = state
            spr = pr * inv_deg                  # scaled ranks (alg.1 l.3)
            pr_next = base + damping * spmv(spr)
            if dangling == "redistribute":
                dmass = (pr * dang).sum(axis=0)
                pr_next = pr_next + dmass * redist
            check = (((it + 1) % check_every == 0)
                     | (it + 1 >= num_iterations))
            res = jnp.where(
                check, jnp.abs(pr_next - pr).sum(axis=0).max()
                if multi else jnp.abs(pr_next - pr).sum(), -1.0)
            residuals = residuals.at[it].set(res)
            if tol > 0:
                done = done | (check & (res >= 0) & (res < tol))
            return it + 1, pr_next, residuals, done

        it, pr, residuals, _ = jax.lax.while_loop(
            cond, body, (jnp.int32(0), pr, residuals0, jnp.bool_(False)))
        return pr, it, residuals

    engine._fused_cache[key] = run
    return run


def masked_chunk_stepper(engine: SpMVEngine, *, damping: float = 0.85,
                         chunk: int = 8, dangling: str = "none"):
    """Chunked variant of the fused loop for continuous-batching query
    serving (DESIGN.md §7): the state is a (n, B) slot pool of
    independent rank vectors, each column carrying its OWN convergence
    state, and one call advances every still-active column by up to
    ``chunk`` iterations as a single donated device dispatch.

    Returns ``step(pr, base, active, tol_col, budget, inv_deg) ->
    (pr, active, took, res)``:

    - ``pr/base`` (n, B): rank state and per-column (1-damping)-scaled
      teleport vectors; ``pr`` is donated.
    - ``active`` (B,) bool: columns still iterating.  Converged (or
      empty) columns are FROZEN — masked out of the damping update so
      their ranks stay bit-identical while neighbours keep iterating.
    - ``tol_col`` (B,) f32 / ``budget`` (B,) i32: per-column tolerance
      and remaining-iteration allowance.  Both are DATA, not trace
      constants, so per-request tol/max_iters never retrace.
    - outputs: updated ``pr``; ``active`` with newly converged or
      budget-exhausted columns cleared; ``took`` (B,) i32 iterations
      actually executed per column this chunk; ``res`` (B,) f32 last
      L1 residual per column (-1 for columns that never ran).

    The chunk loop is a ``lax.while_loop`` that exits as soon as every
    column froze, so a nearly-drained pool doesn't pay ``chunk`` full
    SpMV passes.  The SpMV itself always runs on the full (n, B) state
    (static shapes — the TPU constraint); frozen columns simply have
    their update discarded, which is exactly what makes one multi-
    vector pass the cheap unit of work the scheduler batches over.
    """
    if dangling not in ("none", "redistribute"):
        raise ValueError(f"unknown dangling policy {dangling!r}")
    key = ("chunk", damping, chunk, dangling)
    cached = engine._fused_cache.get(key)
    if cached is not None:
        return cached

    spmv = engine.spmv_fn()

    @partial(jax.jit, donate_argnums=(0,))
    def step(pr, base, active, tol_col, budget, inv_deg):
        inv_col = inv_deg[:, None]
        dang_col = (inv_col == 0).astype(pr.dtype)
        redist = base * (damping / (1.0 - damping))
        took0 = jnp.zeros(pr.shape[1], dtype=jnp.int32)
        res0 = jnp.full((pr.shape[1],), -1.0, dtype=jnp.float32)

        def cond(state):
            i, _, act, _, _ = state
            return (i < chunk) & act.any()

        def body(state):
            i, pr, act, took, res = state
            spr = pr * inv_col                  # scaled ranks (alg.1 l.3)
            pr_next = base + damping * spmv(spr)
            if dangling == "redistribute":
                dmass = (pr * dang_col).sum(axis=0)       # (B,)
                pr_next = pr_next + dmass[None, :] * redist
            r = jnp.abs(pr_next - pr).sum(axis=0)         # (B,) per slot
            pr = jnp.where(act[None, :], pr_next, pr)     # freeze others
            res = jnp.where(act, r, res)
            took = took + act.astype(jnp.int32)
            # quarantine guardrail (DESIGN.md §10): a non-finite L1
            # residual means the column is NaN/Inf-poisoned — freeze it
            # immediately (NaN already compares False below, but +Inf
            # would keep burning budget) so the host sees the non-
            # finite residual and quarantines the slot.  Folded into
            # the existing reduction: no extra device sync.
            act = act & jnp.isfinite(r) & (r >= tol_col) & (took < budget)
            return i + 1, pr, act, took, res

        _, pr, active, took, res = jax.lax.while_loop(
            cond, body, (jnp.int32(0), pr, active, took0, res0))
        return pr, active, took, res

    engine._fused_cache[key] = step
    return step


def _run_fused(g: Graph, eng: SpMVEngine, *, num_iterations: int,
               damping: float, tol: float, check_every: int,
               dangling: str) -> PageRankResult:
    if eng.backend.supports_sharding:
        # a sharding backend owns its own fused loop (all-to-all +
        # blocked gather + psum residual under shard_map)
        from .distributed import distributed_pagerank
        return distributed_pagerank(
            g, eng.mesh, eng.shard_axis, num_iterations=num_iterations,
            damping=damping, tol=tol, check_every=check_every,
            dangling=dangling, layout=eng.sharded_layout,
            fused_cache=eng._fused_cache)
    n = g.num_nodes
    run = fused_power_iteration(eng, damping=damping,
                                num_iterations=num_iterations, tol=tol,
                                check_every=check_every,
                                dangling=dangling)
    pr0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    base = jnp.full((n,), (1.0 - damping) / n, dtype=jnp.float32)
    pr, it, res = run(pr0, _inv_degree(g), base)
    res_host = np.asarray(res)[:int(it)]
    return PageRankResult(pr, int(it),
                          [float(r) for r in res_host if r >= 0.0])


# ---------------------------------------------------------------------------
# Python-loop driver (debug fallback; syncs on the host every iteration)
# ---------------------------------------------------------------------------
def _run_python(g: Graph, eng: SpMVEngine, *, num_iterations: int,
                damping: float, tol: float,
                dangling: str = "none") -> PageRankResult:
    n = g.num_nodes
    inv_deg = _inv_degree(g)
    dang = (inv_deg == 0).astype(jnp.float32)
    pr = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    base = (1.0 - damping) / n
    residuals = []
    it = 0
    for it in range(1, num_iterations + 1):
        spr = pr * inv_deg
        pr_next = base + damping * eng(spr)   # A^T @ SPR
        if dangling == "redistribute":
            pr_next = pr_next + (pr * dang).sum() * (damping / n)
        res = float(jnp.abs(pr_next - pr).sum())
        residuals.append(res)
        pr = pr_next
        if tol and res < tol:
            break
    return PageRankResult(pr, it, residuals)


def pagerank(g: Graph, *, method: str = "pcpm", num_iterations: int = 20,
             damping: float = 0.85, part_size: int = 65536,
             tol: float = 0.0, engine: SpMVEngine | None = None,
             driver: str = "fused", check_every: int = 1,
             dangling: str = "none") -> PageRankResult:
    """Compatibility front-end.  ``method`` is resolved through the
    backend registry and the graph plan comes from the process-level
    plan cache, so repeated calls on one graph never re-sort edges.
    New code should prefer ``repro.open(g, cfg).pagerank()``."""
    eng = engine or SpMVEngine(g, method=method, part_size=part_size)
    if driver == "python" or eng.two_phase:
        # the engine's __call__ already maps reordered plans back to
        # the original labeling per pass — nothing to do here
        return _run_python(g, eng, num_iterations=num_iterations,
                           damping=damping, tol=tol, dangling=dangling)
    if driver != "fused":
        raise ValueError(f"unknown driver {driver!r}")
    if eng.plan.reorder_perm is None:
        return _run_fused(g, eng, num_iterations=num_iterations,
                          damping=damping, tol=tol,
                          check_every=check_every, dangling=dangling)
    # reordered plan: iterate wholly in internal (relabeled) space —
    # the uniform start/teleport vectors are permutation-invariant, so
    # only the FINAL ranks pay one gather back to the original ids
    from .backends import reorder_device
    from .plan import internal_graph
    res = _run_fused(internal_graph(g, eng.plan), eng,
                     num_iterations=num_iterations, damping=damping,
                     tol=tol, check_every=check_every, dangling=dangling)
    perm, _ = reorder_device(eng.plan)
    res.ranks = jnp.take(res.ranks, perm, axis=0)
    return res


def pagerank_reference(g: Graph, *, num_iterations: int = 20,
                       damping: float = 0.85,
                       dangling: str = "none") -> np.ndarray:
    """Dense numpy oracle for tests (small graphs only)."""
    n = g.num_nodes
    A = np.zeros((n, n), dtype=np.float64)
    np.add.at(A, (g.src, g.dst), 1.0)
    deg = np.maximum(g.out_degree, 1).astype(np.float64)
    inv = np.where(g.out_degree == 0, 0.0, 1.0 / deg)
    sink = (np.asarray(g.out_degree) == 0).astype(np.float64)
    pr = np.full(n, 1.0 / n)
    for _ in range(num_iterations):
        y = A.T @ (pr * inv)
        if dangling == "redistribute":
            y = y + (pr * sink).sum() / n
        pr = (1 - damping) / n + damping * y
    return pr
