"""Backend registry — the run-layer half of the plan/run split
(DESIGN.md §8).

Every SpMV engine registers ONE ``Backend`` entry:

- ``build_plan(g, cfg) -> GraphPlan``: the host-side preprocessing
  (edge sorts, PNG build, schedules) for that method;
- ``spmv_fn(plan) -> (x -> A^T x)``: a pure traceable closure over the
  plan's device-resident streams — what the fused ``lax.while_loop``
  drivers, the chunk steppers and AOT compilation consume;
- capability flags (``supports_sharding``, ``supports_aot``,
  ``multi_vector``, ``supports_two_phase``) that consumers branch on
  instead of comparing method strings.

``SpMVEngine``, ``pagerank()``, ``PageRankServer`` and
``SlotScheduler`` all resolve backends through this table, so a new
engine plugs in with one ``register_backend`` call and no call-site
edits.  Device-side uploads are cached on ``plan._device`` — shared by
every consumer of the same plan.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..graphs.formats import Graph
from .partition import Partitioning
from .plan import GraphPlan, PlanConfig, shared_png
from .png import (GatherSchedule, block_png, build_gather_schedule,
                  flat_gather_schedule)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Backend:
    """One SpMV engine: plan builder + runner + capabilities.

    ``phase_fns`` (optional) returns ``(scatter, gather)`` closures
    over the plan's device streams — the seam for paper-faithful
    phase timing (benchmarks/table4_runtime.py) and for the
    ``two_phase=True`` host-barrier execution; backends without it
    reject ``two_phase=True`` at engine construction.
    """
    name: str
    build_plan: Callable[[Graph, PlanConfig], GraphPlan]
    spmv_fn: Callable[[GraphPlan], Callable]
    supports_sharding: bool = False    # runs under shard_map on a mesh
    supports_aot: bool = True          # closure is .lower().compile()-able
    multi_vector: bool = True          # accepts (n, d) as well as (n,)
    uses_gather_block: bool = False    # plan depends on cfg.gather_block
    # the forward-push QUERY backend (serve/push.py) can answer
    # single-seed personalized queries against this backend's plans —
    # single-device only: the push state is one (n,) vector, so the
    # sharded all-to-all layout has nothing to shard
    supports_push_query: bool = False
    phase_fns: Optional[
        Callable[[GraphPlan], tuple[Callable, Callable]]] = None
    # incremental plan patching (stream/patch.py): rebuild only the
    # partitions an edge delta touched and splice them into the old
    # plan.  ``(plan, g_new, delta) -> GraphPlan`` — backends without
    # it fall back to a full rebuild on every delta.
    patch_plan: Optional[
        Callable[[GraphPlan, Graph, "object"], GraphPlan]] = None

    @property
    def supports_two_phase(self) -> bool:
        return self.phase_fns is not None

    @property
    def supports_incremental(self) -> bool:
        return self.patch_plan is not None


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown method {name!r}; registered: "
                         f"{available_backends()}") from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def resolve_method(method: str, *, sharded: bool = False) -> str:
    """Map a requested method (+ the ``sharded=True`` convenience flag
    of the serving front-ends) to a registered backend name: when the
    named backend cannot shard, fall back to the registered
    sharding-capable one."""
    backend = get_backend(method)
    if not sharded or backend.supports_sharding:
        return method
    for b in _REGISTRY.values():
        if b.supports_sharding:
            return b.name
    raise ValueError("sharded=True but no registered backend supports "
                     "sharding")


def check_device_count(num_shards: int) -> None:
    """The single home of the shards-vs-devices rule (used by config
    normalization, the engine's loaded-plan path and mesh building)."""
    avail = jax.device_count()
    if num_shards > avail:
        raise ValueError(f"num_shards={num_shards} exceeds the "
                         f"{avail} available devices")


def resolve_engine(g: Graph, *, method: str, sharded: bool,
                   part_size: int, num_shards: Optional[int],
                   engine=None):
    """Shared engine resolution of the serving front-ends
    (``PageRankServer``, ``SlotScheduler``): construct through the
    registry when no engine is given, otherwise validate the caller's
    engine against the ``sharded=True`` request."""
    from .spmv import SpMVEngine
    if engine is None:
        return SpMVEngine(g, part_size=part_size, num_shards=num_shards,
                          method=resolve_method(method, sharded=sharded))
    if sharded and not engine.backend.supports_sharding:
        raise ValueError(
            "sharded=True requires a sharding-capable engine; got "
            f"method={engine.method!r}")
    return engine


def normalize_config(g: Graph, cfg: PlanConfig) -> PlanConfig:
    """Canonical cache key: resolve ``num_shards=None`` to the device
    count for sharding backends (validating the bound), and blank the
    knobs a backend ignores (sharding fields, gather_block) so configs
    differing only in irrelevant knobs share one plan."""
    from .plan import DEFAULT_GATHER_BLOCK
    backend = get_backend(cfg.method)
    if cfg.reorder != "none":
        from ..graphs.reorder import available_orderings
        if cfg.reorder not in available_orderings():
            raise ValueError(
                f"unknown reorder {cfg.reorder!r}; valid: "
                f"{available_orderings()}")
    kw = {}
    if backend.supports_sharding:
        shards = cfg.num_shards or jax.device_count()
        check_device_count(shards)
        if shards != cfg.num_shards:
            kw["num_shards"] = shards
    elif cfg.num_shards is not None:
        kw["num_shards"] = None
    # the mesh axis NAME never affects host preprocessing (meshes are
    # cached per axis on plan._device) — keep it out of the cache key
    if cfg.shard_axis != "shards":
        kw["shard_axis"] = "shards"
    if (not backend.uses_gather_block
            and cfg.gather_block != DEFAULT_GATHER_BLOCK):
        kw["gather_block"] = DEFAULT_GATHER_BLOCK
    return cfg.replace(**kw) if kw else cfg


def spmv_fn(plan: GraphPlan):
    """The plan's runner closure, built once and cached on the plan —
    every consumer (engine, drivers, steppers, AOT server) of one plan
    shares one closure and one set of device uploads."""
    fn = plan._device.get("spmv")
    if fn is None:
        fn = get_backend(plan.method).spmv_fn(plan)
        plan._device["spmv"] = fn
    return fn


def two_phase_spmv_fn(plan: GraphPlan):
    """The plan's host-barriered scatter/gather closure (backends with
    ``phase_fns`` only), cached like ``spmv_fn``.  The barrier makes
    the bins round-trip through HBM exactly as the paper's bins
    round-trip through DRAM (phase-timing fidelity)."""
    fn = plan._device.get("two_phase_spmv")
    if fn is None:
        backend = get_backend(plan.method)
        if backend.phase_fns is None:
            raise ValueError(f"backend {plan.method!r} does not support "
                             "two_phase execution")
        scatter, gather = backend.phase_fns(plan)

        def fn(x):
            return gather(jax.block_until_ready(scatter(x)))

        plan._device["two_phase_spmv"] = fn
    return fn


def reorder_device(plan: GraphPlan):
    """Device-resident ``(perm, inv)`` int32 arrays for a reordered
    plan (``perm[old] = new``, ``inv[new] = old``), cached on the plan
    — the one-shot boundary maps (``x_int = x[inv]``,
    ``y_orig = y_int[perm]``) gather through these."""
    dev = plan._device.get("reorder_dev")
    if dev is None:
        from .plan import reorder_inverse
        dev = (jnp.asarray(plan.reorder_perm),
               jnp.asarray(reorder_inverse(plan)))
        plan._device["reorder_dev"] = dev
    return dev


def fused_loop_cache(plan: GraphPlan) -> dict:
    """Per-plan cache of jitted iteration loops/steppers (keyed on
    their hyper-parameters) — shared across every engine wrapping the
    same plan so e.g. ``Session.pagerank()`` and a later shim call
    reuse one trace."""
    return plan._device.setdefault("fused_cache", {})


def sharded_mesh(plan: GraphPlan, axis: str | None = None):
    """The 1-D device mesh a sharded plan runs on (built lazily,
    cached per axis name on the plan).  Raises when the plan wants
    more shards than this runtime has devices — e.g. an 8-shard plan
    loaded on a 1-device box — instead of silently truncating the
    mesh against the plan's fixed-shape shard arrays."""
    from jax.sharding import Mesh
    axis = axis or plan.config.shard_axis
    if plan.sharded is None:
        raise ValueError(
            f"backend {plan.method!r} has no sharded layout (mesh is "
            "only meaningful for sharding backends)")
    shards = plan.sharded.num_shards
    check_device_count(shards)
    key = ("mesh", axis)
    mesh = plan._device.get(key)
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()[:shards]), (axis,))
        plan._device[key] = mesh
    return mesh


# ---------------------------------------------------------------------------
# pdpr — pull-direction baseline (paper alg. 1)
# ---------------------------------------------------------------------------
def _plan_fields(g: Graph, cfg: PlanConfig) -> dict:
    return dict(config=cfg, num_nodes=g.num_nodes, num_edges=g.num_edges,
                partitioning=Partitioning(g.num_nodes, cfg.part_size))


def pdpr_schedule(csc_src: np.ndarray, csc_dst: np.ndarray, *,
                  num_nodes: int, block: int) -> GatherSchedule:
    """Blocked-gather schedule over the pull-order edge stream: the
    "update bins" are x itself, so the per-edge pointer stream is just
    the dst-sorted source ids.  Gives pdpr the same hierarchical
    segmented reduction as pcpm (DESIGN.md §3) — the engines now differ
    only in what they stream, not in how they reduce, which is what
    makes the table-4 comparison honest."""
    eui, starts, ends, pdst = flat_gather_schedule(
        csc_src, csc_dst, num_nodes=num_nodes, block=block)
    return GatherSchedule(block, len(csc_dst), eui, starts, ends, pdst)


def _build_pdpr(g: Graph, cfg: PlanConfig) -> GraphPlan:
    order = np.lexsort((g.src, g.dst))
    src, dst = g.src[order], g.dst[order]
    return GraphPlan(csc_src=src, csc_dst=dst,
                     schedule=pdpr_schedule(src, dst,
                                            num_nodes=g.num_nodes,
                                            block=cfg.gather_block),
                     **_plan_fields(g, cfg))


def _sched_device(plan: GraphPlan):
    dev = plan._device.get("sched")
    if dev is None:
        s = plan.schedule
        dev = (jnp.asarray(s.edge_update_idx_padded),
               jnp.asarray(s.piece_start), jnp.asarray(s.piece_end),
               jnp.asarray(s.piece_dst))
        plan._device["sched"] = dev
    return dev


def _spmv_pdpr(plan: GraphPlan):
    from .spmv import pcpm_gather_blocked
    eui, ps, pe, pd = _sched_device(plan)
    n, blk = plan.num_nodes, plan.schedule.block
    return lambda x: pcpm_gather_blocked(x, eui, ps, pe, pd,
                                         num_nodes=n, block=blk)


# ---------------------------------------------------------------------------
# bvgas — Binning w/ Vertex-centric GAS (paper alg. 2)
# ---------------------------------------------------------------------------
def bvgas_schedule(bv_dst: np.ndarray, *, num_nodes: int,
                   block: int) -> GatherSchedule:
    """Blocked-gather schedule over the per-edge bins: the pointer
    stream is the permutation putting the dst-partition-major bins in
    destination order (bins are written in scatter order and read in
    gather order, exactly the paper's bin round-trip)."""
    gorder = np.argsort(bv_dst, kind="stable").astype(np.int32)
    eui, starts, ends, pdst = flat_gather_schedule(
        gorder, bv_dst[gorder], num_nodes=num_nodes, block=block)
    return GatherSchedule(block, len(bv_dst), eui, starts, ends, pdst)


def _build_bvgas(g: Graph, cfg: PlanConfig) -> GraphPlan:
    dstp = g.dst.astype(np.int64) // cfg.part_size
    order = np.lexsort((g.dst, g.src, dstp))
    dst = g.dst[order]
    return GraphPlan(bv_src=g.src[order], bv_dst=dst,
                     schedule=bvgas_schedule(dst, num_nodes=g.num_nodes,
                                             block=cfg.gather_block),
                     **_plan_fields(g, cfg))


def _bvgas_device(plan: GraphPlan):
    dev = plan._device.get("bvgas")
    if dev is None:
        dev = jnp.asarray(plan.bv_src)
        plan._device["bvgas"] = dev
    return dev


def _spmv_bvgas(plan: GraphPlan):
    from .spmv import bvgas_scatter, pcpm_gather_blocked
    src = _bvgas_device(plan)
    eui, ps, pe, pd = _sched_device(plan)
    n, blk = plan.num_nodes, plan.schedule.block
    return lambda x: pcpm_gather_blocked(
        bvgas_scatter(src, x), eui, ps, pe, pd, num_nodes=n, block=blk)


def _phases_bvgas(plan: GraphPlan):
    from .spmv import bvgas_scatter, pcpm_gather_blocked
    src = _bvgas_device(plan)
    eui, ps, pe, pd = _sched_device(plan)
    n, blk = plan.num_nodes, plan.schedule.block
    return (lambda x: bvgas_scatter(src, x),
            lambda bins: pcpm_gather_blocked(bins, eui, ps, pe, pd,
                                             num_nodes=n, block=blk))


# ---------------------------------------------------------------------------
# pcpm — Partition-Centric, blocked hierarchical gather (paper algs. 4+5)
# ---------------------------------------------------------------------------
def _build_pcpm(g: Graph, cfg: PlanConfig) -> GraphPlan:
    png = shared_png(g, cfg.part_size)
    sched = build_gather_schedule(png, block=cfg.gather_block)
    return GraphPlan(png=png, schedule=sched, **_plan_fields(g, cfg))


def _pcpm_device(plan: GraphPlan):
    dev = plan._device.get("pcpm")
    if dev is None:
        s = plan.schedule
        dev = (jnp.asarray(plan.png.update_src),
               jnp.asarray(s.edge_update_idx_padded),
               jnp.asarray(s.piece_start), jnp.asarray(s.piece_end),
               jnp.asarray(s.piece_dst))
        plan._device["pcpm"] = dev
    return dev


def _spmv_pcpm(plan: GraphPlan):
    from .spmv import pcpm_gather_blocked, pcpm_scatter
    upd, eui, ps, pe, pd = _pcpm_device(plan)
    n, blk = plan.num_nodes, plan.schedule.block
    return lambda x: pcpm_gather_blocked(
        pcpm_scatter(upd, x), eui, ps, pe, pd, num_nodes=n, block=blk)


def _phases_pcpm(plan: GraphPlan):
    from .spmv import pcpm_gather_blocked, pcpm_scatter
    upd, eui, ps, pe, pd = _pcpm_device(plan)
    n, blk = plan.num_nodes, plan.schedule.block
    return (lambda x: pcpm_scatter(upd, x),
            lambda bins: pcpm_gather_blocked(bins, eui, ps, pe, pd,
                                             num_nodes=n, block=blk))


# ---------------------------------------------------------------------------
# pcpm_pallas — the Pallas gather kernel path (kernels/pcpm_spmv)
# ---------------------------------------------------------------------------
def _build_pcpm_pallas(g: Graph, cfg: PlanConfig) -> GraphPlan:
    png = shared_png(g, cfg.part_size)
    return GraphPlan(png=png, blocked=block_png(png),
                     **_plan_fields(g, cfg))


def _packed_device(plan: GraphPlan):
    dev = plan._device.get("packed")
    if dev is None:
        from ..kernels.pcpm_spmv import pack_blocked
        dev = pack_blocked(plan.blocked, plan.num_nodes)
        plan._device["packed"] = dev
    return dev


def _spmv_pcpm_pallas(plan: GraphPlan):
    from ..kernels.pcpm_spmv import pcpm_spmv_pallas
    packed = _packed_device(plan)
    return lambda x: pcpm_spmv_pallas(packed, x)


# ---------------------------------------------------------------------------
# pcpm_sharded — multi-device all-to-all PCPM (core/distributed.py)
# ---------------------------------------------------------------------------
def _build_pcpm_sharded(g: Graph, cfg: PlanConfig) -> GraphPlan:
    from .distributed import build_sharded_png
    layout = build_sharded_png(g, cfg.num_shards,
                               gather_block=cfg.gather_block)
    return GraphPlan(sharded=layout, **_plan_fields(g, cfg))


def _spmv_pcpm_sharded(plan: GraphPlan):
    from .distributed import pcpm_all_to_all_spmv
    axis = plan.config.shard_axis
    key = ("sharded_spmv", axis)
    spmv = plan._device.get(key)
    if spmv is None:
        spmv = pcpm_all_to_all_spmv(plan.sharded, sharded_mesh(plan, axis),
                                    axis)
        plan._device[key] = spmv
    n, n_pad = plan.num_nodes, plan.sharded.padded_nodes

    def fn(x):
        width = ((0, n_pad - n),) + ((0, 0),) * (x.ndim - 1)
        return spmv(jnp.pad(x, width))[:n]

    return fn


# ---------------------------------------------------------------------------
# Incremental patchers (stream/patch.py) — imported lazily: the stream
# package imports this registry, so the hook bodies must not import it
# at module load.
# ---------------------------------------------------------------------------
def _patch_pdpr(plan, g_new, delta):
    from ..stream.patch import patch_pdpr_plan
    return patch_pdpr_plan(plan, g_new, delta)


def _patch_bvgas(plan, g_new, delta):
    from ..stream.patch import patch_bvgas_plan
    return patch_bvgas_plan(plan, g_new, delta)


def _patch_pcpm(plan, g_new, delta):
    from ..stream.patch import patch_pcpm_plan
    return patch_pcpm_plan(plan, g_new, delta)


def _patch_pcpm_pallas(plan, g_new, delta):
    from ..stream.patch import patch_pcpm_pallas_plan
    return patch_pcpm_pallas_plan(plan, g_new, delta)


# ---------------------------------------------------------------------------
for _backend in (
    Backend("pdpr", _build_pdpr, _spmv_pdpr, uses_gather_block=True,
            patch_plan=_patch_pdpr, supports_push_query=True),
    Backend("bvgas", _build_bvgas, _spmv_bvgas, uses_gather_block=True,
            phase_fns=_phases_bvgas, patch_plan=_patch_bvgas,
            supports_push_query=True),
    Backend("pcpm", _build_pcpm, _spmv_pcpm, uses_gather_block=True,
            phase_fns=_phases_pcpm, patch_plan=_patch_pcpm,
            supports_push_query=True),
    Backend("pcpm_pallas", _build_pcpm_pallas, _spmv_pcpm_pallas,
            patch_plan=_patch_pcpm_pallas, supports_push_query=True),
    # pcpm_sharded has no patcher: shard-local receive buffers and the
    # all-to-all send schedule are global layouts (a delta anywhere can
    # grow any shard's wire stream), so deltas fall back to a full
    # rebuild — the residual-push warm start still applies.  No push
    # queries either: the (n,) query state is single-device.
    Backend("pcpm_sharded", _build_pcpm_sharded, _spmv_pcpm_sharded,
            supports_sharding=True, uses_gather_block=True),
):
    register_backend(_backend)
