"""GraphPlan — the immutable preprocessing artifact (DESIGN.md §8).

The paper's central amortization argument (§VI-D3) is that PCPM is a
*preprocess-then-iterate* method: the PNG layout, partitioning and
gather schedules are built once on the host and reused by every
subsequent SpMV.  This module makes that artifact a first-class value:

- ``PlanConfig``: the hashable knob set that determines a plan
  (method, part_size, num_shards, gather_block) — one config type
  instead of four constructors' keyword soup.
- ``GraphPlan``: everything host-side preprocessing produces for one
  ``(graph, PlanConfig)`` — ``Partitioning``, ``PNGLayout``, blocked /
  gather-schedule variants, sharded layouts.  Immutable and hashable
  (identity), with a non-serialized device-side cache (``_device``)
  where backends park uploaded streams, packed kernels, meshes and
  jitted closures.
- a process-level plan cache keyed on ``(graph fingerprint, config)``
  — every consumer (``SpMVEngine``, ``pagerank()``, ``PageRankServer``,
  ``SlotScheduler``, ``Session``) resolves plans through it, so one
  graph served four ways still sorts its edges exactly once.
- ``save``/``load`` to ``.npz`` so million-node plans load warm
  instead of re-sorting edges (what ``GraphRegistry`` warm-loading
  stores).

The per-backend *build* functions live in ``core/backends.py``; this
module only owns the artifact, the cache and the serialization.
"""
from __future__ import annotations

import dataclasses
import json
import time
import weakref
from typing import Any, Optional

import numpy as np

from ..graphs.formats import Graph
from .partition import Partitioning
from .png import BlockedPNG, GatherSchedule, PNGLayout, build_png


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------
DEFAULT_GATHER_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    """Host-preprocessing knobs.  Hashable — the cache key half."""
    method: str = "pcpm"
    part_size: int = 65536
    num_shards: Optional[int] = None   # sharded backends; None = all devices
    shard_axis: str = "shards"
    gather_block: int = DEFAULT_GATHER_BLOCK
    # locality-enhancing node relabeling (paper §VI-D1, graphs/
    # reorder.py): the plan's layouts are built on the RELABELED graph
    # while the plan itself stays keyed to the original graph's
    # fingerprint — the reorder name is part of this cache-key half,
    # so each ordering gets its own plan/chain entry
    reorder: str = "none"

    def replace(self, **kw) -> "PlanConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)   # eq=False: identity hash
class GraphPlan:
    """Everything host-side preprocessing produced for one
    ``(graph, PlanConfig)``.  Only the fields the plan's backend needs
    are populated; the rest stay None.

    ``_device`` is a runtime-only cache (device uploads, packed kernel
    layouts, meshes, jitted spmv closures, the fused-loop cache) — it
    never serializes and never participates in plan identity.
    """
    config: PlanConfig
    num_nodes: int
    num_edges: int
    partitioning: Partitioning
    # pdpr: edges in pull (dst-sorted) order
    csc_src: Optional[np.ndarray] = None
    csc_dst: Optional[np.ndarray] = None
    # bvgas: edges in dst-partition-major order
    bv_src: Optional[np.ndarray] = None
    bv_dst: Optional[np.ndarray] = None
    # pcpm / pcpm_pallas
    png: Optional[PNGLayout] = None
    schedule: Optional[GatherSchedule] = None
    blocked: Optional[BlockedPNG] = None
    # pcpm_sharded (core/distributed.py ShardedPNG; typed loosely to
    # keep this module importable without the distributed stack)
    sharded: Optional[Any] = None
    # content hash of the graph this plan was built from — lets
    # install_plan refuse a plan/graph mismatch instead of silently
    # serving wrong preprocessing
    graph_fp: Optional[str] = None
    # fingerprint of the graph this plan was PATCHED from (stream/
    # patch.py): patched plans form a parent chain g0 -> g1 -> ... that
    # ``evict_plans`` can release as one unit
    parent_fp: Optional[str] = None
    # locality relabeling (config.reorder != "none"): the layouts above
    # were built on ``g.relabel(reorder_perm)``; every consumer maps
    # inputs in via the inverse and results back via the permutation
    # (``internal_graph`` / ``reorder_inverse`` below)
    reorder_perm: Optional[np.ndarray] = None    # (n,) int32, old -> new
    _device: dict = dataclasses.field(default_factory=dict, repr=False)

    # ------------------------------------------------------------- views
    @property
    def method(self) -> str:
        return self.config.method

    @property
    def part_size(self) -> int:
        return self.config.part_size

    @property
    def num_shards(self) -> Optional[int]:
        return self.config.num_shards

    @property
    def compression_ratio(self) -> float:
        """r = |E| / |E'| — on the wire for sharded plans (paper
        table V / DESIGN.md §6), in DRAM traffic otherwise."""
        if self.sharded is not None:
            return self.sharded.wire_compression
        if self.png is not None:
            return self.png.compression_ratio
        return 1.0

    # ----------------------------------------------------- serialization
    def save(self, path: str) -> None:
        """Persist the host-side artifact as one compressed ``.npz``.

        Device-side state (``_device``) is rebuilt on first use after
        ``load`` — meshes and compiled closures are runtime-specific.
        """
        arrays: dict[str, np.ndarray] = {}
        if self.reorder_perm is not None:
            arrays["reorder_perm"] = self.reorder_perm
        meta: dict[str, Any] = {
            "version": 3,
            "config": dataclasses.asdict(self.config),
            "num_nodes": self.num_nodes,
            "num_edges": self.num_edges,
            "graph_fp": self.graph_fp,
            "parent_fp": self.parent_fp,
        }
        for key in ("csc_src", "csc_dst", "bv_src", "bv_dst"):
            arr = getattr(self, key)
            if arr is not None:
                arrays[key] = arr
        if self.png is not None:
            p = self.png
            arrays.update({"png/update_src": p.update_src,
                           "png/update_offsets": p.update_offsets,
                           "png/edge_update_idx": p.edge_update_idx,
                           "png/edge_dst": p.edge_dst,
                           "png/edge_offsets": p.edge_offsets})
        if self.schedule is not None:
            s = self.schedule
            meta["schedule"] = {"block": s.block, "num_edges": s.num_edges}
            arrays.update({"sched/eui": s.edge_update_idx_padded,
                           "sched/piece_start": s.piece_start,
                           "sched/piece_end": s.piece_end,
                           "sched/piece_dst": s.piece_dst})
        if self.blocked is not None:
            b = self.blocked
            meta["blocked"] = {"part_size": b.part_size,
                               "update_pad_frac": b.update_pad_frac,
                               "edge_pad_frac": b.edge_pad_frac}
            arrays.update({"blk/update_src": b.update_src,
                           "blk/edge_update_local": b.edge_update_local,
                           "blk/edge_dst_local": b.edge_dst_local})
        if self.sharded is not None:
            h = self.sharded
            meta["sharded"] = {"num_shards": h.num_shards,
                               "shard_size": h.shard_size,
                               "num_nodes": h.num_nodes,
                               "gather_block": h.gather_block,
                               "wire_updates": h.wire_updates,
                               "wire_edges": h.wire_edges}
            arrays.update({"shd/send_ids": h.send_ids,
                           "shd/edge_upd": h.edge_upd,
                           "shd/edge_dst": h.edge_dst,
                           "shd/eui_padded": h.eui_padded,
                           "shd/piece_start": h.piece_start,
                           "shd/piece_end": h.piece_end,
                           "shd/piece_dst": h.piece_dst})
        np.savez_compressed(path, __meta__=json.dumps(meta), **arrays)

    @staticmethod
    def load(path: str) -> "GraphPlan":
        z = np.load(path, allow_pickle=False)
        if "__meta__" not in z:
            raise ValueError(
                f"{path!r} is not a GraphPlan file (no __meta__ entry "
                "— a raw graph npz goes through graphs.io.load)")
        meta = json.loads(str(z["__meta__"]))
        if meta.get("version") not in (1, 2, 3):
            raise ValueError(
                f"unsupported plan format version {meta.get('version')!r}"
                f" in {path!r} (this build reads versions 1-3)")
        # pre-v3 configs lack the reorder key; the dataclass default
        # ("none") is exactly what those plans were built with
        cfg = PlanConfig(**meta["config"])
        n, m = int(meta["num_nodes"]), int(meta["num_edges"])
        part = Partitioning(n, cfg.part_size)
        kw: dict[str, Any] = {}
        if "reorder_perm" in z:
            kw["reorder_perm"] = z["reorder_perm"]
        for key in ("csc_src", "csc_dst", "bv_src", "bv_dst"):
            if key in z:
                kw[key] = z[key]
        if "png/update_src" in z:
            kw["png"] = PNGLayout(part, z["png/update_src"],
                                  z["png/update_offsets"],
                                  z["png/edge_update_idx"],
                                  z["png/edge_dst"],
                                  z["png/edge_offsets"], n, m)
        if "schedule" in meta:
            s = meta["schedule"]
            kw["schedule"] = GatherSchedule(
                int(s["block"]), int(s["num_edges"]), z["sched/eui"],
                z["sched/piece_start"], z["sched/piece_end"],
                z["sched/piece_dst"])
        if "blocked" in meta:
            b = meta["blocked"]
            kw["blocked"] = BlockedPNG(
                int(b["part_size"]), z["blk/update_src"],
                z["blk/edge_update_local"], z["blk/edge_dst_local"],
                float(b["update_pad_frac"]), float(b["edge_pad_frac"]))
        if "sharded" in meta:
            from .distributed import ShardedPNG
            h = meta["sharded"]
            kw["sharded"] = ShardedPNG(
                int(h["num_shards"]), int(h["shard_size"]),
                int(h["num_nodes"]), z["shd/send_ids"],
                z["shd/edge_upd"], z["shd/edge_dst"],
                int(h["gather_block"]), z["shd/eui_padded"],
                z["shd/piece_start"], z["shd/piece_end"],
                z["shd/piece_dst"], int(h["wire_updates"]),
                int(h["wire_edges"]))
        graph_fp = meta.get("graph_fp")
        if meta["version"] < 2:
            # v1 fingerprints are sha1-of-sorted-edges; current builds
            # use the multiset hash — drop the stale fp (install_plan
            # re-stamps it) rather than spuriously reject the plan
            graph_fp = None
        if "schedule" not in kw and cfg.method in ("pdpr", "bvgas"):
            # version-1 files predate the baseline engines adopting the
            # blocked gather; the schedule is a sort-free O(M) derive
            # (pdpr) / one argsort (bvgas) from the stored streams
            from .backends import bvgas_schedule, pdpr_schedule
            if cfg.method == "pdpr":
                kw["schedule"] = pdpr_schedule(
                    kw["csc_src"], kw["csc_dst"], num_nodes=n,
                    block=cfg.gather_block)
            else:
                kw["schedule"] = bvgas_schedule(
                    kw["bv_dst"], num_nodes=n, block=cfg.gather_block)
        if cfg.reorder != "none" and "reorder_perm" not in kw:
            raise ValueError(
                f"{path!r} declares reorder={cfg.reorder!r} but stores "
                "no permutation — refusing to serve internal-space "
                "layouts without the mapping back")
        return GraphPlan(cfg, n, m, part, graph_fp=graph_fp,
                         parent_fp=meta.get("parent_fp"), **kw)


# ---------------------------------------------------------------------------
# Process-level plan cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PlanCacheStats:
    plan_builds: int = 0
    plan_hits: int = 0
    png_builds: int = 0
    png_hits: int = 0
    plan_patches: int = 0    # incremental patches (stream/patch.py)


_PLAN_CACHE: dict[tuple, GraphPlan] = {}
_PNG_CACHE: dict[tuple, PNGLayout] = {}
_STATS = PlanCacheStats()

# Observability taps (obs/__init__.py Observability registers itself).
# WeakSet: a dropped Observability stops receiving events without an
# unregister call; emission with no observers is one falsy check.
_PLAN_OBSERVERS: "weakref.WeakSet" = weakref.WeakSet()


def add_plan_observer(obs) -> None:
    """Register an object with a ``plan_event(name, **attrs)`` method
    to receive plan build/hit/patch notifications (held weakly)."""
    _PLAN_OBSERVERS.add(obs)


def remove_plan_observer(obs) -> None:
    _PLAN_OBSERVERS.discard(obs)


def notify_plan_event(name: str, **attrs) -> None:
    """Fan an event out to registered observers.  Observer errors are
    swallowed: telemetry must never fail a build."""
    if not _PLAN_OBSERVERS:
        return
    for obs in list(_PLAN_OBSERVERS):
        try:
            obs.plan_event(name, **attrs)
        except Exception:
            pass

# Bound on cached entries: a long-lived process streaming many graphs
# through the (shim) constructors must not pin preprocessing arrays +
# device uploads without limit.  Overflow evicts the oldest entry —
# safe, because live engines/Sessions hold their own plan reference;
# only a future cache hit is lost.  ``evict_plans(g)`` retires a
# specific graph eagerly.
MAX_CACHED_PLANS = 128
MAX_CACHED_PNGS = 128


def _bounded_insert(cache: dict, limit: int, key, value) -> None:
    if key not in cache and len(cache) >= limit:
        cache.pop(next(iter(cache)))       # least recently used
    cache[key] = value


def _touch(cache: dict, key) -> None:
    """Refresh recency (dicts iterate in insertion order, so a hit
    moves the entry to the back — a hot graph's plan is never the
    one evicted by a stream of one-shot graphs)."""
    cache[key] = cache.pop(key)


def plan_cache_stats() -> PlanCacheStats:
    """Live build/hit counters (tests assert build count == 1)."""
    return _STATS


def clear_plan_cache() -> None:
    """Drop every cached plan and PNG layout and reset the counters."""
    _PLAN_CACHE.clear()
    _PNG_CACHE.clear()
    _STATS.plan_builds = _STATS.plan_hits = 0
    _STATS.png_builds = _STATS.png_hits = 0
    _STATS.plan_patches = 0


def peek_plan(fp: str, config: PlanConfig) -> Optional[GraphPlan]:
    """Plan-cache lookup by fingerprint without building on miss (the
    hit refreshes LRU recency and counts as a cache hit) — the public
    seam the incremental patcher uses, so the cache's key/LRU/stats
    policy stays in this module."""
    plan = _PLAN_CACHE.get((fp, config))
    if plan is not None:
        _STATS.plan_hits += 1
        _touch(_PLAN_CACHE, (fp, config))
    return plan


def peek_shared_png(fp: str, part_size: int) -> Optional[PNGLayout]:
    """PNG-cache lookup by fingerprint without building on miss — the
    incremental patcher (stream/patch.py) uses it so a pcpm patch and
    a pcpm_pallas patch of the same delta share ONE spliced layout."""
    png = _PNG_CACHE.get((fp, part_size))
    if png is not None:
        _STATS.png_hits += 1
        _touch(_PNG_CACHE, (fp, part_size))
    return png


def _edge_hash64(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """splitmix64 of the packed (src, dst) pair, vectorized (uint64
    arithmetic wraps, which is the point)."""
    h = ((src.astype(np.uint64) << np.uint64(32))
         | dst.astype(np.uint64))
    h = (h ^ (h >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    h = (h ^ (h >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return h ^ (h >> np.uint64(31))


def _fp_string(num_nodes: int, num_edges: int, parts) -> str:
    return (f"{num_nodes:x}.{num_edges:x}."
            f"{int(parts[0]):016x}{int(parts[1]):016x}")


def graph_fingerprint(g: Graph) -> str:
    """Content hash of the edge MULTISET — two equal graphs share
    plans even when their COO edge lists arrive in different orders
    (every backend lexsorts before building, so the plans are
    identical).

    The hash is a commutative-invertible pair (sum, xor) over per-edge
    splitmix64 values: order-independent WITHOUT sorting (one O(M)
    vectorized pass, vs. the lexsort a content sort would cost), and
    incrementally updatable — ``stream.apply_delta`` derives the new
    graph's fingerprint from the old one in O(|delta|), so a delta
    stream never re-hashes the full edge list.  Memoized on the
    instance."""
    fp = g.__dict__.get("_plan_fingerprint")
    if fp is None:
        parts = g.__dict__.get("_fp_parts")
        if parts is None:
            h = _edge_hash64(g.src, g.dst)
            parts = (int(h.sum(dtype=np.uint64)),
                     int(np.bitwise_xor.reduce(h, initial=np.uint64(0))))
            g.__dict__["_fp_parts"] = parts   # frozen-safe: dict write
        fp = _fp_string(g.num_nodes, g.num_edges, parts)
        g.__dict__["_plan_fingerprint"] = fp
    return fp


def validate_plan(g: Graph, plan: GraphPlan) -> GraphPlan:
    """Raise ``ValueError`` unless ``plan`` belongs to ``g`` (size and
    content fingerprint) — shared guard of ``install_plan`` and
    ``SpMVEngine(plan=...)``; a wrong plan must fail loudly, never
    silently serve wrong preprocessing."""
    if (plan.num_nodes, plan.num_edges) != (g.num_nodes, g.num_edges):
        raise ValueError(
            f"plan/graph mismatch: plan is for n={plan.num_nodes}, "
            f"m={plan.num_edges}; graph has n={g.num_nodes}, "
            f"m={g.num_edges}")
    fp = graph_fingerprint(g)
    if plan.graph_fp is not None and plan.graph_fp != fp:
        raise ValueError(
            "plan/graph mismatch: the plan was built from a graph "
            "with a different edge set (content fingerprint "
            f"{plan.graph_fp[:12]}… != {fp[:12]}…)")
    return plan


def shared_png(g: Graph, part_size: int) -> PNGLayout:
    """The PNG layout for ``(graph, part_size)`` — method-independent,
    so ``pcpm`` and ``pcpm_pallas`` plans share ONE build (the old
    ``SpMVEngine`` built it once per constructor per method)."""
    key = (graph_fingerprint(g), part_size)
    png = _PNG_CACHE.get(key)
    if png is not None:
        _STATS.png_hits += 1
        _touch(_PNG_CACHE, key)
        return png
    _STATS.png_builds += 1
    t0 = time.perf_counter()
    png = build_png(g, Partitioning(g.num_nodes, part_size))
    _bounded_insert(_PNG_CACHE, MAX_CACHED_PNGS, key, png)
    notify_plan_event("png_build", part_size=part_size,
                      n=g.num_nodes, m=g.num_edges,
                      duration_s=time.perf_counter() - t0)
    return png


def build_plan(g: Graph, config: PlanConfig | None = None) -> GraphPlan:
    """THE way to get a plan: normalize the config, consult the
    process-level cache, delegate a miss to the registered backend's
    ``build_plan``."""
    from .backends import get_backend, normalize_config
    from ..graphs.formats import validate_graph
    validate_graph(g)     # crisp ValueError on out-of-range ids, not
    cfg = normalize_config(g, config or PlanConfig())  # an index crash
    fp = graph_fingerprint(g)
    key = (fp, cfg)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _STATS.plan_hits += 1
        _touch(_PLAN_CACHE, key)
        notify_plan_event("plan_cache_hit", method=cfg.method,
                          fp=fp[:12])
        return plan
    _STATS.plan_builds += 1
    t0 = time.perf_counter()
    if cfg.reorder != "none":
        # build every layout on the RELABELED graph (that's the whole
        # point — contiguous hub labels raise PNG compression), but
        # stamp the ORIGINAL graph's fingerprint: the plan belongs to
        # g, and the reorder name in cfg keeps the cache entry distinct
        from ..graphs.reorder import reorder_permutation
        perm = reorder_permutation(g, cfg.reorder)
        plan = get_backend(cfg.method).build_plan(g.relabel(perm), cfg)
        plan = dataclasses.replace(plan, reorder_perm=perm, graph_fp=fp)
    else:
        plan = get_backend(cfg.method).build_plan(g, cfg)
    if plan.graph_fp is None:
        plan = dataclasses.replace(plan, graph_fp=fp)
    _bounded_insert(_PLAN_CACHE, MAX_CACHED_PLANS, key, plan)
    notify_plan_event("plan_build", method=cfg.method,
                      n=g.num_nodes, m=g.num_edges,
                      reorder=cfg.reorder, fp=fp[:12],
                      duration_s=time.perf_counter() - t0)
    return plan


def install_plan(g: Graph, plan: GraphPlan) -> GraphPlan:
    """Seed the cache with a plan built elsewhere (e.g. ``GraphPlan.
    load`` of a persisted million-node plan) so every subsequent
    ``build_plan``/``Session``/scheduler on ``g`` with the same config
    warm-starts instead of re-sorting edges.

    Raises ``ValueError`` when the plan does not belong to ``g`` (size
    or content-fingerprint mismatch, see ``validate_plan``) — a wrong
    plan would otherwise silently serve wrong preprocessing."""
    from .backends import normalize_config
    validate_plan(g, plan)
    fp = graph_fingerprint(g)
    cfg = normalize_config(g, plan.config)
    if plan.graph_fp is None:
        plan = dataclasses.replace(plan, graph_fp=fp)
    _bounded_insert(_PLAN_CACHE, MAX_CACHED_PLANS, (fp, cfg), plan)
    # a reordered plan's PNG is of the RELABELED graph — seeding the
    # shared PNG cache under the original fingerprint would poison a
    # later reorder="none" build of the same (graph, part_size)
    if (plan.png is not None and plan.reorder_perm is None
            and (fp, cfg.part_size) not in _PNG_CACHE):
        _bounded_insert(_PNG_CACHE, MAX_CACHED_PNGS,
                        (fp, cfg.part_size), plan.png)
    return plan


def internal_graph(g: Graph, plan: GraphPlan) -> Graph:
    """The graph the plan's layouts actually index: ``g`` itself for
    plain plans, ``g.relabel(perm)`` (cached on the plan) for reordered
    ones.  Fused drivers, steppers and push engines run wholly in this
    internal space — results map back once at the boundary, so the
    locality win is never taxed by per-iteration permutes."""
    if plan.reorder_perm is None:
        return g
    gi = plan._device.get("internal_graph")
    if gi is None:
        gi = g.relabel(plan.reorder_perm)
        plan._device["internal_graph"] = gi
    return gi


def reorder_inverse(plan: GraphPlan) -> np.ndarray:
    """``inv[internal_id] = original_id`` for a reordered plan (cached
    on the plan's runtime dict)."""
    inv = plan._device.get("reorder_inv")
    if inv is None:
        from ..graphs.reorder import inverse_permutation
        inv = inverse_permutation(plan.reorder_perm)
        plan._device["reorder_inv"] = inv
    return inv


def plan_nbytes(plan: GraphPlan) -> int:
    """Host-side footprint of a plan in bytes — the sum of every array
    ``save`` would persist.  This is what a multi-graph registry's
    memory budget accounts against (serve/scheduler.py GraphRegistry):
    the plan streams dominate a resident graph's cost, and unlike
    device buffers they are exactly enumerable."""
    arrays: list[np.ndarray] = []
    if plan.reorder_perm is not None:
        arrays.append(plan.reorder_perm)
    for key in ("csc_src", "csc_dst", "bv_src", "bv_dst"):
        arr = getattr(plan, key)
        if arr is not None:
            arrays.append(arr)
    if plan.png is not None:
        p = plan.png
        arrays += [p.update_src, p.update_offsets, p.edge_update_idx,
                   p.edge_dst, p.edge_offsets]
    if plan.schedule is not None:
        s = plan.schedule
        arrays += [s.edge_update_idx_padded, s.piece_start,
                   s.piece_end, s.piece_dst]
    if plan.blocked is not None:
        b = plan.blocked
        arrays += [b.update_src, b.edge_update_local, b.edge_dst_local]
    if plan.sharded is not None:
        h = plan.sharded
        arrays += [h.send_ids, h.edge_upd, h.edge_dst, h.eui_padded,
                   h.piece_start, h.piece_end, h.piece_dst]
    return sum(int(np.asarray(a).nbytes) for a in arrays)


def _chain_fingerprints(fp: str) -> set[str]:
    """Every fingerprint connected to ``fp`` through cached plans'
    ``parent_fp`` links (both directions, transitively).  A stream of
    patched plans forms a chain g0 -> g1 -> ... gT; retiring any link
    retires the whole chain — the intermediate graphs are gone, so
    their plans can never be cache-hit again."""
    fps = {fp}
    changed = True
    while changed:
        changed = False
        for plan in _PLAN_CACHE.values():
            links = {f for f in (plan.graph_fp, plan.parent_fp)
                     if f is not None}
            if links & fps and not links <= fps:
                fps |= links
                changed = True
    return fps


def evict_plans(g: Graph, *, chain: bool = True) -> int:
    """Drop every cached plan/PNG for ``g`` (a long-lived server that
    rotates graphs uses this instead of the nuclear
    ``clear_plan_cache``); live Sessions/engines keep their plan
    references, only the cache entries — and with them the pinned
    host + device memory once those references drop — are released.

    ``chain=True`` (default) also releases every plan linked to ``g``
    through ``parent_fp`` patch chains (stream/patch.py): evicting any
    version of a dynamically-updated graph releases all its patched
    ancestors/descendants, so a delta stream cannot pin memory through
    stale intermediate versions.  Returns the number of entries
    evicted."""
    fps = ({graph_fingerprint(g)} if not chain
           else _chain_fingerprints(graph_fingerprint(g)))
    plan_keys = [k for k in _PLAN_CACHE if k[0] in fps]
    png_keys = [k for k in _PNG_CACHE if k[0] in fps]
    for k in plan_keys:
        del _PLAN_CACHE[k]
    for k in png_keys:
        del _PNG_CACHE[k]
    return len(plan_keys) + len(png_keys)
