from .partition import Partitioning, partition_for_vmem
from .png import (PNGLayout, BlockedPNG, GatherSchedule, build_png,
                  block_png, build_gather_schedule,
                  flat_gather_schedule)
from .plan import (GraphPlan, PlanConfig, build_plan, clear_plan_cache,
                   evict_plans, graph_fingerprint, install_plan,
                   plan_cache_stats, validate_plan)
from .backends import (Backend, available_backends, get_backend,
                       register_backend, resolve_method)
from .spmv import (SpMVEngine, pdpr_spmv, pcpm_spmv, pcpm_scatter,
                   pcpm_gather, pcpm_gather_blocked, bvgas_scatter,
                   bvgas_gather, pcpm_spmv_weighted, DevicePNG,
                   DeviceCSC, DeviceBVGAS)
from .pagerank import (pagerank, pagerank_reference, PageRankResult,
                       fused_power_iteration, masked_chunk_stepper)
from . import comm_model

__all__ = [
    "Partitioning", "partition_for_vmem", "PNGLayout", "BlockedPNG",
    "GatherSchedule", "build_png", "block_png", "build_gather_schedule",
    "flat_gather_schedule",
    "GraphPlan", "PlanConfig", "build_plan", "clear_plan_cache",
    "evict_plans", "graph_fingerprint", "install_plan",
    "plan_cache_stats", "validate_plan",
    "Backend", "available_backends", "get_backend", "register_backend",
    "resolve_method",
    "SpMVEngine", "pdpr_spmv", "pcpm_spmv", "pcpm_scatter",
    "pcpm_gather", "pcpm_gather_blocked", "bvgas_scatter",
    "bvgas_gather", "pcpm_spmv_weighted", "DevicePNG", "DeviceCSC",
    "DeviceBVGAS", "pagerank", "pagerank_reference", "PageRankResult",
    "fused_power_iteration", "masked_chunk_stepper", "comm_model",
]
