from .partition import Partitioning, partition_for_vmem
from .png import PNGLayout, BlockedPNG, build_png, block_png
from .spmv import (SpMVEngine, pdpr_spmv, pcpm_spmv, pcpm_scatter,
                   pcpm_gather, bvgas_scatter, bvgas_gather,
                   pcpm_spmv_weighted, DevicePNG, DeviceCSC, DeviceBVGAS)
from .pagerank import pagerank, pagerank_reference, PageRankResult
from . import comm_model

__all__ = [
    "Partitioning", "partition_for_vmem", "PNGLayout", "BlockedPNG",
    "build_png", "block_png", "SpMVEngine", "pdpr_spmv", "pcpm_spmv",
    "pcpm_scatter", "pcpm_gather", "bvgas_scatter", "bvgas_gather",
    "pcpm_spmv_weighted", "DevicePNG", "DeviceCSC", "DeviceBVGAS",
    "pagerank", "pagerank_reference", "PageRankResult", "comm_model",
]
