"""JAX/Pallas reproduction of 'Accelerating PageRank using
Partition-Centric Processing' — public API.

    import repro
    sess = repro.open(g, repro.EngineConfig(method="pcpm"))
    res  = sess.pagerank()
    sch  = sess.serve()

The plan/run split behind this facade lives in ``repro.core.plan``
(one immutable ``GraphPlan`` per (graph, config), process-cached and
``.npz``-serializable) and ``repro.core.backends`` (the engine
registry all consumers dispatch through) — see DESIGN.md §8.
"""
from .api import EngineConfig, Session, open
from .core.backends import (Backend, available_backends, get_backend,
                            register_backend)
from .gateway import Gateway, GatewayConfig
from .ingest import (LinkFilter, NodeIdMapping, VirtualLinks,
                     ingest_edge_list)
from .obs import (FlightRecorder, MetricsRegistry, Observability,
                  Tracer)
from .core.plan import (GraphPlan, PlanConfig, build_plan,
                        clear_plan_cache, evict_plans, install_plan,
                        plan_cache_stats)
from .reliability import ResilienceConfig, check_plan_integrity
from .stream import DynamicGraph, GraphDelta

__all__ = [
    "EngineConfig", "Session", "open",
    "Backend", "available_backends", "get_backend", "register_backend",
    "GraphPlan", "PlanConfig", "build_plan", "clear_plan_cache",
    "evict_plans", "install_plan", "plan_cache_stats",
    "Gateway", "GatewayConfig",
    "ResilienceConfig", "check_plan_integrity",
    "DynamicGraph", "GraphDelta",
    "LinkFilter", "NodeIdMapping", "VirtualLinks", "ingest_edge_list",
    "FlightRecorder", "MetricsRegistry", "Observability", "Tracer",
]
