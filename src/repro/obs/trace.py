"""Span tracer + flight recorder (DESIGN.md §14).

A query's life crosses at least three threads — a gateway submit
thread (intake, cache probe, backlog), the device thread (admission,
chunk dispatch, readback, top-k) and possibly a push worker — so the
tracer uses EXPLICIT parents: a ``Span`` handle is passed along with
the work (rides the ``Query`` dataclass through the scheduler, the
pending tuple through the gateway backlog), never inferred from
thread-local ambient context.  That makes well-nestedness a checkable
property instead of an accident of which thread ran the callback.

Spans are recorded into a ``FlightRecorder`` — a lock-protected
bounded ring buffer (``collections.deque(maxlen=N)``) — at END time,
so the buffer holds complete ``(t_start, t_end)`` intervals; instant
events are zero-duration spans recorded immediately.  The ring is the
crash-forensics surface: bounded memory under storm load, oldest
records evicted first, dumpable as JSON-lines on demand and
automatically on quarantine/stepper failure via PR 6's snapshot path.

Overhead discipline: with observability off no Span objects exist and
every hot-path hook is one ``is None`` branch.  With it on, a span is
one small object + one deque append under a lock held for O(1).
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional

TRACE_SCHEMA_VERSION = 1

_ids = itertools.count(1)


def _next_id() -> int:
    # next() on an itertools.count is atomic under the GIL — no lock on
    # the one allocation every span and event pays
    return next(_ids)


class SpanRecord:
    """Immutable-after-record row in the flight recorder."""

    __slots__ = ("name", "span_id", "parent_id", "trace", "t_start",
                 "t_end", "status", "attrs")

    def __init__(self, name, span_id, parent_id, trace, t_start, t_end,
                 status, attrs):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.trace = trace
        self.t_start = t_start
        self.t_end = t_end
        self.status = status
        self.attrs = attrs

    @property
    def duration_s(self) -> float:
        return self.t_end - self.t_start

    @property
    def is_event(self) -> bool:
        return self.t_end == self.t_start

    def to_dict(self) -> dict:
        return {"name": self.name, "span": self.span_id,
                "parent": self.parent_id, "trace": self.trace,
                "t0": self.t_start, "t1": self.t_end,
                "status": self.status, "attrs": self.attrs}

    def __repr__(self):
        return (f"SpanRecord({self.name!r}, trace={self.trace!r}, "
                f"span={self.span_id}, parent={self.parent_id}, "
                f"status={self.status!r}, dur={self.duration_s:.6f})")


class FlightRecorder:
    """Bounded ring of SpanRecords; oldest evicted first."""

    def __init__(self, capacity: int = 8192):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._buf: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.recorded = 0          # total ever recorded
        self.dropped = 0           # evicted by ring pressure

    def record(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._buf) == self.capacity:
                self.dropped += 1
            self._buf.append(rec)
            self.recorded += 1

    def snapshot(self) -> list:
        with self._lock:
            return list(self._buf)

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def to_jsonl(self) -> str:
        recs = self.snapshot()
        header = {"schema": TRACE_SCHEMA_VERSION,
                  "recorded": self.recorded, "dropped": self.dropped,
                  "capacity": self.capacity, "held": len(recs)}
        lines = [json.dumps(header)]
        lines.extend(json.dumps(r.to_dict(), default=str) for r in recs)
        return "\n".join(lines) + "\n"

    def dump(self, path: str) -> str:
        """Write the ring as JSON-lines: one header line (schema,
        recorded/dropped totals) then one record per line, oldest
        first.  Returns ``path``."""
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return path


class Span:
    """Open interval; becomes visible in the recorder on ``end()``."""

    __slots__ = ("_tracer", "name", "span_id", "parent_id", "trace",
                 "t_start", "attrs", "_done")

    def __init__(self, tracer, name, parent_id, trace, t_start, attrs):
        self._tracer = tracer
        self.name = name
        self.span_id = _next_id()
        self.parent_id = parent_id
        self.trace = trace
        self.t_start = t_start
        self.attrs = attrs
        self._done = False

    def bind(self, trace) -> None:
        """Late-bind the trace id (a query's uid is allocated under
        the scheduler intake lock, after the gateway already opened
        the root span)."""
        self.trace = trace

    def annotate(self, **attrs) -> None:
        self.attrs.update(attrs)

    def event(self, name: str, **attrs):
        """Zero-duration child, recorded immediately."""
        return self._tracer.event(name, parent=self, trace=self.trace,
                                  **attrs)

    def child(self, name: str, **attrs) -> "Span":
        return self._tracer.start(name, parent=self, trace=self.trace,
                                  **attrs)

    def end(self, status: str = "ok", **attrs) -> None:
        """Record the span.  Idempotent: a second ``end`` is a counted
        no-op (``tracer.double_ends``), never a duplicate record — the
        flight recorder's exactly-once guarantee lives here."""
        if self._done:
            self._tracer.double_ends += 1
            return
        self._done = True
        if attrs:
            self.attrs.update(attrs)
        self._tracer.recorder.record(SpanRecord(
            self.name, self.span_id, self.parent_id, self.trace,
            self.t_start, self._tracer.clock(), status, self.attrs))

    @property
    def ended(self) -> bool:
        return self._done


class Tracer:
    def __init__(self, recorder: Optional[FlightRecorder] = None, *,
                 clock=time.perf_counter):
        self.recorder = recorder if recorder is not None else FlightRecorder()
        self.clock = clock
        self.double_ends = 0

    @staticmethod
    def _parent_id(parent) -> Optional[int]:
        if parent is None:
            return None
        return parent.span_id if isinstance(parent, Span) else int(parent)

    def start(self, name: str, *, parent=None, trace=None,
              **attrs) -> Span:
        if trace is None and isinstance(parent, Span):
            trace = parent.trace
        return Span(self, name, self._parent_id(parent), trace,
                    self.clock(), attrs)

    def event(self, name: str, *, parent=None, trace=None,
              status: str = "ok", **attrs) -> SpanRecord:
        if trace is None and isinstance(parent, Span):
            trace = parent.trace
        t = self.clock()
        rec = SpanRecord(name, _next_id(), self._parent_id(parent),
                         trace, t, t, status, attrs)
        self.recorder.record(rec)
        return rec

    @contextmanager
    def span(self, name: str, *, parent=None, trace=None, **attrs):
        sp = self.start(name, parent=parent, trace=trace, **attrs)
        try:
            yield sp
        except BaseException as e:
            sp.end(status="error", error=f"{type(e).__name__}: {e}")
            raise
        sp.end()


class QuerySpans:
    """Per-query span bundle threaded through gateway and scheduler.

    Holds the root ``query`` span plus at most one open child per
    phase name (``backlog``/``queue``/``slot``/``push``).  Terminal
    discipline: ``finish()`` closes any open children, records exactly
    one ``terminal`` event, and ends the root — unless the bundle is
    ``gateway_owned``, in which case the root stays open until the
    gateway resolves the caller-visible future (``resolve()``), so the
    recorded root interval covers the FULL client-observed latency.
    """

    __slots__ = ("tracer", "root", "children", "gateway_owned",
                 "terminals")

    def __init__(self, tracer: Tracer, root: Span, *,
                 gateway_owned: bool = False):
        self.tracer = tracer
        self.root = root
        self.children: dict = {}
        self.gateway_owned = gateway_owned
        self.terminals = 0

    def bind(self, uid) -> None:
        self.root.bind(uid)
        for sp in self.children.values():
            sp.bind(uid)

    def event(self, name: str, **attrs) -> None:
        self.root.event(name, **attrs)

    def start_child(self, name: str, **attrs) -> Span:
        """Open a phase child; an already-open child of the same name
        is closed with status ``retry`` first (quarantine re-admits
        open a second ``slot`` span)."""
        prev = self.children.get(name)
        if prev is not None and not prev.ended:
            prev.end(status="retry")
        sp = self.root.child(name, **attrs)
        self.children[name] = sp
        return sp

    def end_child(self, name: str, status: str = "ok", **attrs) -> None:
        sp = self.children.get(name)
        if sp is not None and not sp.ended:
            sp.end(status=status, **attrs)

    def finish(self, status: str = "ok", **attrs) -> None:
        """The query reached a terminal state in the scheduler (or the
        gateway rejected/cache-served it)."""
        for name, sp in self.children.items():
            if not sp.ended:
                sp.end(status=status if status != "ok" else "ok")
        self.terminals += 1
        self.root.event("terminal", status=status, **attrs)
        if not self.gateway_owned:
            self.root.end(status)

    def resolve(self, **attrs) -> None:
        """Gateway-side: the caller-visible future was fulfilled."""
        if self.gateway_owned and not self.root.ended:
            self.root.event("resolve", **attrs)
            self.root.end()
