"""Unified observability layer (DESIGN.md §14).

One ``Observability`` bundle ties the three instruments together:

- ``tracer``/``recorder`` — explicit-parent span tracing into a
  bounded flight-recorder ring (obs/trace.py), threaded through the
  full query lifecycle, plan builds/patches, deltas and XLA compiles.
- ``registry`` — the typed metrics registry (obs/metrics.py) that
  cross-cutting counters/gauges/histograms report into; per-scheduler
  ``ServeMetrics`` keep their OWN registries (reconciliation is
  per-scheduler) and the gateway scrape endpoint merges all of them.
- ``comm`` — measured-vs-model communication accounting (obs/comm.py).

Off by default: nothing constructs a bundle unless
``EngineConfig(observe=True)`` / ``Session.observe()`` /
``SlotScheduler(obs=...)`` asks, and every hot-path hook is a single
``is None`` branch.
"""
from __future__ import annotations

import itertools
import os
import threading
from typing import Optional

from .comm import CommAccountant, CommBreakdown, measure_plan, vs_model
from .metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, render_prometheus)
from .trace import (TRACE_SCHEMA_VERSION, FlightRecorder, QuerySpans,
                    Span, SpanRecord, Tracer)

__all__ = [
    "Observability", "Tracer", "Span", "SpanRecord", "QuerySpans",
    "FlightRecorder", "MetricsRegistry", "Counter", "Gauge",
    "Histogram", "render_prometheus", "DEFAULT_BUCKETS",
    "CommAccountant", "CommBreakdown", "measure_plan", "vs_model",
    "TRACE_SCHEMA_VERSION",
]


class Observability:
    """The bundle a Session/SlotScheduler/Gateway reports through."""

    def __init__(self, *, capacity: int = 8192,
                 dump_dir: Optional[str] = None, clock=None):
        kw = {} if clock is None else {"clock": clock}
        self.recorder = FlightRecorder(capacity)
        self.tracer = Tracer(self.recorder, **kw)
        self.registry = MetricsRegistry()
        self.comm = CommAccountant(registry=self.registry)
        self.dump_dir = dump_dir
        self._dump_seq = itertools.count(1)
        self._dump_lock = threading.Lock()
        # Plan build/hit/patch events fan in from core/plan.py (weak
        # registration: dropping the bundle detaches it).
        from ..core import plan as _plan
        self._plan_mod = _plan
        _plan.add_plan_observer(self)

    # ------------------------------------------------------------- events
    def plan_event(self, name: str, **attrs) -> None:
        """Callback target for ``core.plan.notify_plan_event``."""
        self.tracer.event(name, trace="plan", **attrs)
        self.registry.counter("plan_events_total",
                              "plan build/hit/patch events",
                              event=name).inc()

    # -------------------------------------------------------------- dumps
    def dump(self, path: str) -> str:
        """Flight-recorder JSONL on demand."""
        return self.recorder.dump(path)

    def crash_dump(self, reason: str) -> Optional[str]:
        """Automatic dump on quarantine/stepper failure (PR 6's
        resilience path).  Records a ``crash_dump`` event either way;
        writes a file only when ``dump_dir`` is configured."""
        self.registry.counter("crash_dumps_total",
                              "automatic flight-recorder dumps").inc()
        if self.dump_dir is None:
            self.tracer.event("crash_dump", trace="crash",
                              reason=reason, path=None)
            return None
        with self._dump_lock:
            seq = next(self._dump_seq)
        path = os.path.join(self.dump_dir, f"flight-{seq:04d}.jsonl")
        self.tracer.event("crash_dump", trace="crash", reason=reason,
                          path=path)
        try:
            os.makedirs(self.dump_dir, exist_ok=True)
            return self.recorder.dump(path)
        except OSError:
            return None

    # ------------------------------------------------------------ exports
    def prometheus(self) -> str:
        return self.registry.prometheus_text()

    def stats(self) -> dict:
        return {"metrics": self.registry.to_json(),
                "comm": self.comm.summary(),
                "flight_recorder": {
                    "held": len(self.recorder),
                    "recorded": self.recorder.recorded,
                    "dropped": self.recorder.dropped,
                    "capacity": self.recorder.capacity}}

    def close(self) -> None:
        self._plan_mod.remove_plan_observer(self)
