"""Measured communication accounting (DESIGN.md §14).

``core/comm_model.py`` carries the paper's §V napkin math (eqs. 3-10,
the Table 2 traffic model behind Fig. 8 / Table 6) — a PREDICTION
from (n, m, k, r).  This module produces the matching MEASUREMENT
from a live system: enumerate the arrays one SpMV pass actually
streams — at their real, padded, on-device sizes — and multiply by
executed pass counts reported by the solvers.  Predicted and measured
land side by side in benchmark ``comm/`` rows, which is how ROADMAP
items 3-5 (zero-recompile rebinds, overlapped comms, TPU kernels) get
scored against the paper's 1.7x DRAM-traffic claim instead of against
the model alone.

Accounting rules (full derivation in DESIGN.md §14):

- ``dram`` streams count bytes the paper's model also counts: index
  streams once, value streams per vector column (``ncols`` — the
  multi-vector batch reuses every index stream across B columns, the
  serving stack's amortization story).
- Measured sizes include padding the model ignores: the gather
  schedule's block-padded edge stream ``Mp >= M`` and padded piece
  table.  This is the honest number — padding is traffic.
- ``onchip`` streams are expected to be cache-resident (per-partition
  bins during blocked gather, piece bounds) and are reported
  separately rather than silently dropped or silently added.
- Random-access counters mirror eqs. (8)-(10): we count the
  element-granularity gathers/scatters our implementation issues, the
  measurable analogue of the paper's cache-miss terms.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

from ..core import comm_model

D_V = 4   # float32 rank values
D_I = 4   # int32 indices


@dataclasses.dataclass(frozen=True)
class CommBreakdown:
    """Bytes one SpMV pass moves, from actual plan array sizes."""

    method: str
    n: int
    m: int
    ncols: int
    dram: dict          # stream name -> bytes/pass (model-comparable)
    onchip: dict        # cache-expected traffic, reported not summed
    gather_ops: int     # element-granularity gathers issued per pass
    scatter_ops: int    # element-granularity scatter-adds per pass

    @property
    def dram_bytes(self) -> int:
        return sum(self.dram.values())

    @property
    def onchip_bytes(self) -> int:
        return sum(self.onchip.values())

    def to_dict(self) -> dict:
        return {"method": self.method, "n": self.n, "m": self.m,
                "ncols": self.ncols, "dram_bytes": self.dram_bytes,
                "onchip_bytes": self.onchip_bytes,
                "dram": dict(self.dram), "onchip": dict(self.onchip),
                "gather_ops": self.gather_ops,
                "scatter_ops": self.scatter_ops}


def measure_plan(plan, ncols: int = 1) -> CommBreakdown:
    """Enumerate the arrays one pass of ``plan``'s SpMV streams.

    Works from the same arrays ``plan_nbytes`` accounts and the
    backends actually bind, so a padded schedule shows up here at its
    padded size.
    """
    n, m = plan.num_nodes, plan.num_edges
    method = plan.config.method
    c = ncols
    dram: dict = {}
    onchip: dict = {}

    if method in ("pcpm", "pcpm_blocked") and plan.png is not None:
        png, sched = plan.png, plan.schedule
        U = int(len(png.update_src))
        if sched is not None:
            Mp = int(len(sched.edge_update_idx_padded))
            P0 = int(len(sched.piece_start))
        else:
            Mp = int(len(png.edge_update_idx))
            P0 = 0
        # Scatter phase: read the update-source list, gather x, write
        # one bin per update; gather phase: stream the (padded) edge->
        # update index list and read each bin back once from DRAM —
        # the expansion to edge granularity hits the per-partition bin
        # working set, which is the paper's cache-residency argument.
        dram["update_src_read"] = U * D_I
        dram["x_gather"] = U * D_V * c
        dram["bins_write"] = U * D_V * c
        dram["bins_read"] = U * D_V * c
        dram["edge_stream_read"] = Mp * D_I
        dram["rank_rw"] = 2 * n * D_V * c
        onchip["bins_expand"] = Mp * D_V * c
        onchip["piece_table"] = 3 * P0 * D_I
        onchip["piece_partials"] = P0 * D_V * c
        gather_ops = U + Mp          # x[update_src] + bins[eui]
        scatter_ops = P0 + n         # piece segment-sum + final rows
    elif method == "pdpr" and plan.csc_src is not None:
        M = int(len(plan.csc_src))
        sched = plan.schedule
        Mp = int(len(sched.edge_update_idx_padded)) if sched is not None else M
        P0 = int(len(sched.piece_start)) if sched is not None else 0
        # Pull: stream src ids, random-gather x per edge (best case one
        # value per access — the model's c_mr*l term is the worst case,
        # reported via vs_model), segment-sum into y.
        dram["src_read"] = M * D_I
        dram["x_gather"] = Mp * D_V * c
        dram["rank_rw"] = 2 * n * D_V * c
        onchip["piece_table"] = 3 * P0 * D_I
        onchip["piece_partials"] = P0 * D_V * c
        gather_ops = Mp
        scatter_ops = P0 + n
    elif method == "bvgas" and plan.bv_src is not None:
        M = int(len(plan.bv_src))
        sched = plan.schedule
        Mp = int(len(sched.edge_update_idx_padded)) if sched is not None else M
        P0 = int(len(sched.piece_start)) if sched is not None else 0
        # Scatter: stream src ids, gather x, write one bin per EDGE
        # (no compression — the r=1 baseline); gather: read every bin
        # back and segment-sum by destination.
        dram["src_read"] = M * D_I
        dram["x_gather"] = M * D_V * c
        dram["bins_write"] = M * D_V * c
        dram["bins_read"] = M * D_V * c
        dram["edge_stream_read"] = Mp * D_I
        dram["rank_rw"] = 2 * n * D_V * c
        onchip["piece_table"] = 3 * P0 * D_I
        onchip["piece_partials"] = P0 * D_V * c
        gather_ops = M + Mp
        scatter_ops = P0 + n
    else:
        raise ValueError(
            f"cannot measure method {method!r}: plan carries none of "
            "png/csc/bv layouts (sharded plans account per-shard; "
            "measure the unsharded base plan)")
    return CommBreakdown(method=method, n=n, m=m, ncols=c, dram=dram,
                         onchip=onchip, gather_ops=gather_ops,
                         scatter_ops=scatter_ops)


def model_params(plan, c_mr: float = 1.0) -> comm_model.ModelParams:
    """Model inputs taken from the plan's MEASURED geometry — k from
    the actual partitioning, r from the built PNG — so prediction and
    measurement disagree only where the model idealizes, not because
    they saw different graphs."""
    part = plan.partitioning
    k = part.num_partitions if part is not None else 1
    try:
        r = float(plan.compression_ratio)
    except Exception:
        r = 1.0
    return comm_model.ModelParams(n=plan.num_nodes, m=plan.num_edges,
                                  k=k, r=max(r, 1e-9), c_mr=c_mr)


_MODEL_FNS = {"pcpm": comm_model.pcpm_bytes,
              "pcpm_blocked": comm_model.pcpm_bytes,
              "pdpr": comm_model.pdpr_bytes,
              "bvgas": comm_model.bvgas_bytes}

_MODEL_KEY = {"pcpm": "pcpm", "pcpm_blocked": "pcpm",
              "pdpr": "pdpr", "bvgas": "bvgas"}


def vs_model(plan, ncols: int = 1) -> dict:
    """Measured-vs-predicted bytes per iteration for one plan — the
    live Fig. 8 row.  ``ratio`` is measured/model at ncols=1 (the
    model is single-vector); the pdpr model is also reported at its
    best case (c_mr = d_v/l) since eq. (3)'s default c_mr=1 is the
    all-miss worst case."""
    meas = measure_plan(plan, ncols=1)
    p = model_params(plan)
    key = _MODEL_KEY[meas.method]
    model_b = float(_MODEL_FNS[meas.method](p))
    out = {
        "method": meas.method,
        "n": meas.n, "m": meas.m, "k": p.k, "r": p.r,
        "measured_bytes_per_iter": meas.dram_bytes,
        "measured_onchip_bytes": meas.onchip_bytes,
        "model_bytes_per_iter": model_b,
        "ratio": meas.dram_bytes / model_b if model_b else float("inf"),
        "measured_gather_ops": meas.gather_ops,
        "measured_scatter_ops": meas.scatter_ops,
        "model_random_accesses": comm_model.random_accesses(p)[key],
    }
    if key == "pdpr":
        best = dataclasses.replace(p, c_mr=p.d_v / p.l)
        out["model_bytes_per_iter_best"] = float(comm_model.pdpr_bytes(best))
    if ncols != 1:
        out["measured_bytes_per_iter_ncols"] = measure_plan(
            plan, ncols=ncols).dram_bytes
        out["ncols"] = ncols
    return out


class CommAccountant:
    """Accumulates executed-pass counts against per-plan breakdowns.

    Solvers report ``record_solve(plan, iterations)`` (one pass per
    iteration) and the SlotScheduler reports ``record_pass`` per
    dispatched device chunk with the chunk's iteration count and the
    batch width B.  Totals land in the shared registry under
    ``comm_*`` and in ``summary()`` next to the model prediction.
    """

    def __init__(self, registry=None):
        self._lock = threading.Lock()
        self._registry = registry
        # (id(plan), ncols) -> CommBreakdown — plans are immutable and
        # identity-hashed, so id() is a stable key for a live plan.
        self._breakdowns: dict = {}
        self._plans: dict = {}      # keep plans alive while accounted
        # method -> accumulated {passes, dram_bytes, gather, scatter}
        self._totals: dict = {}
        # (id(plan), ncols) -> (passes Counter, bytes Counter) — the
        # registry lookup (sorted-label key + family dict walk) is the
        # expensive part of a scrape-live counter; record_pass runs
        # once per device chunk, so the handles are resolved once
        self._counters: dict = {}

    def _breakdown(self, plan, ncols: int) -> Optional[CommBreakdown]:
        key = (id(plan), int(ncols))
        bd = self._breakdowns.get(key)
        if bd is None:
            try:
                bd = measure_plan(plan, ncols=ncols)
            except ValueError:
                return None          # sharded/exotic plan: skip
            self._breakdowns[key] = bd
            self._plans[key] = plan
            if self._registry is not None:
                self._counters[key] = (
                    self._registry.counter(
                        "comm_passes_total",
                        "executed SpMV passes", method=bd.method),
                    self._registry.counter(
                        "comm_dram_bytes_total",
                        "measured DRAM-model bytes moved",
                        method=bd.method))
        return bd

    def record_pass(self, plan, *, iters: int = 1,
                    ncols: int = 1) -> None:
        if iters <= 0:
            return
        key = (id(plan), int(ncols))
        with self._lock:
            bd = self._breakdown(plan, ncols)
            if bd is None:
                return
            t = self._totals.setdefault(
                bd.method, {"passes": 0, "dram_bytes": 0,
                            "gather_ops": 0, "scatter_ops": 0})
            t["passes"] += iters
            t["dram_bytes"] += iters * bd.dram_bytes
            t["gather_ops"] += iters * bd.gather_ops
            t["scatter_ops"] += iters * bd.scatter_ops
            handles = self._counters.get(key)
        if handles is not None:
            handles[0].inc(iters)
            handles[1].inc(iters * bd.dram_bytes)

    def record_solve(self, plan, iterations: int,
                     ncols: int = 1) -> None:
        self.record_pass(plan, iters=int(iterations), ncols=ncols)

    def summary(self) -> dict:
        """Accumulated measured traffic per method, each with the
        model prediction scaled by the same pass count."""
        with self._lock:
            totals = {k: dict(v) for k, v in self._totals.items()}
            plans = dict(self._plans)
        out = {}
        for method, t in totals.items():
            row = dict(t)
            plan = next((p for (pid, nc), p in plans.items()
                         if p.config.method == method), None)
            if plan is not None and t["passes"]:
                cmp_ = vs_model(plan)
                row["model_dram_bytes"] = (cmp_["model_bytes_per_iter"]
                                           * t["passes"])
                row["bytes_per_pass"] = t["dram_bytes"] / t["passes"]
                row["ratio_vs_model"] = cmp_["ratio"]
            out[method] = row
        return out
