"""Typed metrics registry (DESIGN.md §14).

The serving stack grew four disjoint telemetry surfaces — the
``ServeMetrics`` event ``Counter``, the SlotScheduler's
``trace_count``/``rebind_count`` attributes, the gateway's cache and
autotune reports, and the reliability ``delta_failures`` counters.
This module is the single typed home they all route through:

- ``Counter``   — monotone; ``inc(n)`` with ``n >= 0`` enforced.
- ``Gauge``     — last-write-wins level (queue depth, cache entries).
- ``Histogram`` — fixed upper-bound buckets with EXACT exposed-bucket
  semantics: ``observe(v)`` lands in the first bucket with
  ``v <= upper_bound`` (Prometheus ``le`` inclusive), the exported
  counts are cumulative, and ``sum``/``count`` are exact — what a
  scraper reads is precisely what was observed, no interpolation.

A ``MetricsRegistry`` is a named family table: ``registry.counter
("serve_events_total", event="rejected")`` get-or-creates one child
per label set, and re-registering a name with a different type is a
loud ``ValueError`` (silent type drift is how double-homed counters
happen).  Registries export as Prometheus text (``render_prometheus``
merges several registries under extra labels — the gateway scrape
endpoint labels each scheduler's registry with its graph name) and as
JSON for benchmark rows.

Every metric carries its own lock: increments from the gateway's
submit threads, the device thread and push workers never lose updates
(the pre-gateway ``Counter[name] += 1`` read-modify-write bug, now
structurally impossible).
"""
from __future__ import annotations

import bisect
import threading
from typing import Optional

# Latency-shaped default buckets (seconds), sub-ms to 10 s — the
# serving stack's observed range from cache hits (~0.1 ms) to cold
# full-vector solves (seconds).
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class Counter:
    """Monotone event counter."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counters are monotone; inc({n}) < 0 "
                             "(use a Gauge for levels)")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins level."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram with exact exposed-bucket semantics.

    ``bounds`` are finite ascending upper bounds; the implicit +Inf
    bucket is always present.  ``observe(v)`` increments the FIRST
    bucket with ``v <= bound`` — Prometheus ``le`` inclusive — and the
    exported per-bucket counts are cumulative.
    """

    __slots__ = ("bounds", "_counts", "_sum", "_count", "_lock")

    def __init__(self, bounds=DEFAULT_BUCKETS):
        bounds = tuple(float(b) for b in bounds)
        if list(bounds) != sorted(set(bounds)):
            raise ValueError(f"histogram bounds must be strictly "
                             f"ascending; got {bounds}")
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)   # [+Inf] last
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        idx = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[idx] += 1
            self._sum += v
            self._count += 1

    def snapshot(self) -> dict:
        """``buckets`` is the exact exposed form: ``(le, cumulative)``
        pairs ending with ``("+Inf", count)``."""
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        cum, buckets = 0, []
        for bound, c in zip(self.bounds, counts[:-1]):
            cum += c
            buckets.append((bound, cum))
        buckets.append(("+Inf", total))
        return {"buckets": buckets, "sum": s, "count": total}

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create table of metric families keyed (name, labels)."""

    def __init__(self):
        self._lock = threading.Lock()
        # name -> {"kind": str, "help": str, "metrics": {labelkey: m}}
        self._families: dict[str, dict] = {}

    # ------------------------------------------------------------ create
    def _get(self, kind: str, name: str, help_: str, labels: dict,
             factory):
        key = tuple(sorted(labels.items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = {"kind": kind, "help": help_, "metrics": {}}
                self._families[name] = fam
            elif fam["kind"] != kind:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{fam['kind']}; cannot re-register as {kind} "
                    "(type drift is how counters get double-homed)")
            m = fam["metrics"].get(key)
            if m is None:
                m = factory()
                fam["metrics"][key] = m
            if help_ and not fam["help"]:
                fam["help"] = help_
            return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get("counter", name, help, labels, Counter)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get("gauge", name, help, labels, Gauge)

    def histogram(self, name: str, help: str = "", *,
                  buckets=None, **labels) -> Histogram:
        return self._get("histogram", name, help, labels,
                         lambda: Histogram(buckets or DEFAULT_BUCKETS))

    # -------------------------------------------------------------- read
    def family_items(self, name: str) -> list[tuple[dict, object]]:
        """``(labels, metric)`` children of one family (empty list for
        an unknown name)."""
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                return []
            return [(dict(k), m) for k, m in fam["metrics"].items()]

    def counter_value(self, name: str, **labels) -> float:
        key = tuple(sorted(labels.items()))
        with self._lock:
            fam = self._families.get(name)
            m = fam["metrics"].get(key) if fam else None
        return m.value if m is not None else 0.0

    def collect(self) -> list[dict]:
        """Point-in-time snapshot of every family, render-ready."""
        with self._lock:
            fams = [(name, fam["kind"], fam["help"],
                     list(fam["metrics"].items()))
                    for name, fam in sorted(self._families.items())]
        out = []
        for name, kind, help_, metrics in fams:
            children = []
            for key, m in metrics:
                if kind == "histogram":
                    children.append((dict(key), m.snapshot()))
                else:
                    children.append((dict(key), m.value))
            out.append({"name": name, "kind": kind, "help": help_,
                        "metrics": children})
        return out

    def to_json(self) -> dict:
        """``{name: {kind, help, values: [{labels, value|histogram}]}}``
        — what benchmark rows and ``Session.stats()`` embed."""
        doc = {}
        for fam in self.collect():
            doc[fam["name"]] = {
                "kind": fam["kind"], "help": fam["help"],
                "values": [
                    {"labels": labels,
                     **({"histogram": {
                          "buckets": [[str(le), c] for le, c
                                      in v["buckets"]],
                          "sum": v["sum"], "count": v["count"]}}
                        if fam["kind"] == "histogram"
                        else {"value": v})}
                    for labels, v in fam["metrics"]],
            }
        return doc

    def prometheus_text(self) -> str:
        return render_prometheus([(self, {})])


# ---------------------------------------------------------------------------
# Prometheus text rendering
# ---------------------------------------------------------------------------
def _escape(v) -> str:
    return (str(v).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _labelstr(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _num(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def render_prometheus(pairs: list[tuple[MetricsRegistry, dict]]) -> str:
    """Merge several registries into one Prometheus text exposition;
    each registry's samples gain its ``extra`` labels (the gateway
    labels per-scheduler registries with ``graph=<name>``).  Duplicate
    registry objects are emitted once (first extra-labels win)."""
    fams: dict[str, dict] = {}       # name -> {kind, help, samples}
    seen: set[int] = set()
    for reg, extra in pairs:
        if id(reg) in seen:
            continue
        seen.add(id(reg))
        for fam in reg.collect():
            slot = fams.setdefault(
                fam["name"], {"kind": fam["kind"], "help": fam["help"],
                              "samples": []})
            if slot["kind"] != fam["kind"]:
                raise ValueError(
                    f"metric {fam['name']!r} exported as both "
                    f"{slot['kind']} and {fam['kind']}")
            for labels, v in fam["metrics"]:
                slot["samples"].append(({**labels, **extra}, v))
    lines = []
    for name in sorted(fams):
        fam = fams[name]
        if fam["help"]:
            lines.append(f"# HELP {name} {fam['help']}")
        lines.append(f"# TYPE {name} {fam['kind']}")
        for labels, v in fam["samples"]:
            if fam["kind"] == "histogram":
                for le, cum in v["buckets"]:
                    le_s = "+Inf" if le == "+Inf" else _num(le)
                    lines.append(
                        f"{name}_bucket"
                        f"{_labelstr({**labels, 'le': le_s})} {cum}")
                lines.append(f"{name}_sum{_labelstr(labels)} "
                             f"{_num(v['sum'])}")
                lines.append(f"{name}_count{_labelstr(labels)} "
                             f"{v['count']}")
            else:
                lines.append(f"{name}{_labelstr(labels)} {_num(v)}")
    return "\n".join(lines) + "\n"
