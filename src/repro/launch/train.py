"""Production train launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 30 --ckpt-dir /tmp/ckpt

On the CPU box ``--smoke`` scales the config down (the full configs are
exercised via the dry-run); on a real TPU fleet this same entry point
runs the full config over ``make_production_mesh()``.  Features wired
here: mesh + logical sharding rules, gradient accumulation, checkpoint/
resume (atomic, elastic), failure injection for restart drills,
straggler watchdog, int8 error-feedback gradient compression.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from ..configs import get
from ..data.tokens import synthetic_lm_batches
from ..models import transformer as tf
from ..optim import AdamW, cosine_schedule
from ..train.trainer import Trainer, TrainerConfig
from ..train import compression
from . import sharding as shlib
from .mesh import make_host_mesh, make_production_mesh


def build_step_and_state(cfg, *, lr=3e-4, warmup=100, total=10_000,
                         num_microbatches=1, compress_grads=False,
                         seed=0):
    opt = AdamW(lr=cosine_schedule(lr, warmup, total))
    base_step = tf.make_train_step(cfg, opt,
                                   num_microbatches=num_microbatches)
    params = tf.init_lm(cfg, jax.random.key(seed))
    opt_state = opt.init(params)

    if not compress_grads:
        step = jax.jit(base_step, donate_argnums=(0, 1))
        return step, (params, opt_state)

    # int8 error-feedback compression around the grad all-reduce: the
    # EF accumulator rides inside opt_state's pytree via closure state.
    def step_with_compression(params, opt_state, batch):
        (params_o, opt_o), ef = opt_state
        grad_fn = jax.value_and_grad(
            lambda p: tf.lm_loss(p, cfg, batch["tokens"],
                                 batch["labels"])[0])
        loss, grads = grad_fn(params_o if params is None else params)
        grads, ef = compression.compressed_gradients(grads, ef)
        new_params, new_opt, gnorm = opt.update(grads, opt_o, params)
        return new_params, ((new_params, new_opt), ef), \
            {"loss": loss, "gnorm": gnorm}

    ef = compression.init_ef_state(params)
    step = jax.jit(step_with_compression, donate_argnums=(0, 1))
    return step, (params, ((params, opt_state), ef))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of the same family (CPU box)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (restart drill)")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.smoke:
        cfg = cfg.scaled()
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())

    with shlib.use_rules(mesh), mesh:
        step, state = build_step_and_state(
            cfg, lr=args.lr, total=args.steps * 10,
            num_microbatches=args.microbatches,
            compress_grads=args.compress_grads)
        data = synthetic_lm_batches(cfg.vocab, args.global_batch,
                                    args.seq_len)

        def failure_hook(step_idx):
            if args.fail_at is not None and step_idx == args.fail_at:
                raise RuntimeError(
                    f"injected failure at step {step_idx}")

        trainer = Trainer(
            TrainerConfig(total_steps=args.steps,
                          checkpoint_every=args.checkpoint_every,
                          ckpt_dir=args.ckpt_dir),
            step, state, data,
            failure_hook=failure_hook if args.fail_at else None)
        if args.resume:
            trainer.try_resume()
        report = trainer.run()
    losses = [m["loss"] for m in report["history"] if "loss" in m]
    print(f"done: step={report['final_step']} "
          f"first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f} "
          f"stragglers={len(report['stragglers'])}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
