"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Single-pod: one TPU v5e pod, 16x16 = 256
chips, axes (data, model).  Multi-pod: 2 pods x 256 = 512 chips with a
leading "pod" axis (DCN-connected).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int | None = None, model: int = 1):
    """Test mesh over however many (host) devices exist."""
    n = jax.device_count()
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (roofline, DESIGN.md §6)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link (~axis)
DCN_BW = 25e9                   # bytes/s per host, pod axis
HBM_BYTES = 16 * 2 ** 30        # v5e HBM capacity
