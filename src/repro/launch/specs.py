"""Dry-run cell specs: (architecture x input shape) -> a lowering-ready
(step_fn, abstract args, in_shardings) triple for a given mesh.

Every argument is a ShapeDtypeStruct (weak-type-correct, shardable, no
device allocation); param/optimizer shapes come from jax.eval_shape over
the real initializers so the dry-run exercises exactly the production
pytrees.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get, LMConfig, GNNConfig, RecSysConfig
from ..configs.base import ShapeSpec
from ..models import transformer as tf
from ..models import gnn as gnn_lib
from ..models import recsys as recsys_lib
from ..models.gnn import GraphBatch
from ..optim import AdamW, cosine_schedule
from . import sharding as shlib
from ..graphs.sampler import _max_nodes


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    fn: Callable                 # positional-args step function
    args: tuple                  # abstract args (ShapeDtypeStruct pytrees)
    in_shardings: tuple
    skip: Optional[str] = None   # reason if the cell is skipped
    # roofline bookkeeping
    loop_trip: int = 1           # layer-scan trip count in compile mode
    model_flops: float = 0.0     # analytic 6*N*D (or family equivalent)
    donate: tuple = ()           # argnums donated (train: params+opt)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shard_like(tree_shapes, logical_tree):
    """NamedSharding pytree for abstract args via logical rules."""
    def one(s, ax):
        return shlib.named_sharding(*ax, dims=s.shape)
    return jax.tree.map(one, tree_shapes, logical_tree,
                        is_leaf=lambda x: x is None)


def _replicated(tree_shapes):
    return jax.tree.map(
        lambda s: shlib.named_sharding(*([None] * len(s.shape))),
        tree_shapes)


# ------------------------------------------------------------------- LM
def _lm_opt(cfg: LMConfig):
    from .. import perf_flags
    # >100B params: bf16 mu/nu (f32 state alone would exceed the 256-
    # chip HBM budget: grok at 314B needs 9.8 GB/chip of f32 moments).
    default_sd = ("bfloat16" if cfg.param_count() > 1e11 else "float32")
    return AdamW(lr=cosine_schedule(3e-4, 2000, 100_000),
                 state_dtype=perf_flags.value("opt_dtype", default_sd))


def _lm_opt_logical(cfg: LMConfig):
    pl = tf.param_logical(cfg)
    return ("adamw_state", pl)  # marker handled below


def _lm_cell(cfg: LMConfig, shape: ShapeSpec, *, mode: str,
             layers: int | None = None) -> CellSpec:
    """mode: 'compile' (scan) or 'cost' (python-unrolled)."""
    work_cfg = cfg if layers is None else dataclasses.replace(
        cfg, n_layers=layers)
    unroll = mode == "cost"
    b, s = shape.global_batch, shape.seq_len
    params_s = tf.param_shapes(work_cfg)
    params_sh = _shard_like(params_s, tf.param_logical(work_cfg))
    n_active = cfg.active_param_count()
    skip = None
    if shape.kind == "long_decode" and not cfg.sub_quadratic:
        skip = ("full-attention arch: 500k decode designated "
                "sub-quadratic-only (DESIGN.md §4)")

    if shape.kind == "train":
        opt = _lm_opt(work_cfg)
        opt_s = jax.eval_shape(opt.init, params_s)
        opt_sh = type(opt_s)(
            shlib.named_sharding(),
            _shard_like(opt_s.mu, tf.param_logical(work_cfg)),
            _shard_like(opt_s.nu, tf.param_logical(work_cfg)))
        batch_s = {"tokens": _sds((b, s), jnp.int32),
                   "labels": _sds((b, s), jnp.int32)}
        batch_sh = {k: shlib.named_sharding("batch", None,
                                            dims=(b, s))
                    for k in batch_s}
        attn = "chunked_unroll" if unroll else "chunked"
        # compile pass: 8 microbatches (B/dev 16 -> 2/step) keeps the
        # remat+activation temps inside HBM; cost pass: single microbatch
        # so depth-1/2 FLOP extrapolation stays linear.
        from .. import perf_flags
        default_nm = 16 if cfg.param_count() > 1e11 else 8
        nm = perf_flags.value("microbatches", default_nm, int)
        # each microbatch must still divide the DP width, or the
        # strided split silently drops data-axis sharding (grok on the
        # multi-pod mesh: mb16 -> 16-seq microbatches unshardable over
        # 32 DP shards -> 8x activation blowup; Perf log)
        mesh = shlib.current_mesh()
        dp = 1
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for a in shlib.current_rules().get("batch", ()):
                dp *= sizes.get(a, 1)
        while nm > 1 and (b % nm or (b // nm) % dp):
            nm //= 2
        step = tf.make_train_step(work_cfg, opt, attn_path=attn,
                                  unroll_layers=unroll,
                                  num_microbatches=1 if unroll else nm)
        return CellSpec(cfg.name, shape.name, step,
                        (params_s, opt_s, batch_s),
                        (params_sh, opt_sh, batch_sh), skip,
                        loop_trip=work_cfg.n_layers,
                        model_flops=6.0 * n_active * b * s,
                        donate=(0, 1))

    if shape.kind == "prefill":
        def prefill_fn(params, tokens):
            return tf.prefill(params, work_cfg, tokens,
                              unroll_layers=unroll)
        tokens_s = _sds((b, s), jnp.int32)
        tok_sh = shlib.named_sharding("batch", None, dims=(b, s))
        return CellSpec(cfg.name, shape.name, prefill_fn,
                        (params_s, tokens_s), (params_sh, tok_sh), skip,
                        loop_trip=work_cfg.n_layers,
                        model_flops=2.0 * n_active * b * s)

    # decode / long_decode
    cache_s = tf.cache_shapes(work_cfg, b, s)
    cache_logical = tf._cache_logical(work_cfg)
    cache_sh = {k: shlib.named_sharding(None, *cache_logical,
                                        dims=v.shape)
                for k, v in cache_s.items()}
    tokens_s = _sds((b, 1), jnp.int32)
    tok_sh = shlib.named_sharding("batch", None, dims=(b, 1))
    t_s = _sds((), jnp.int32)
    t_sh = shlib.named_sharding()

    def decode_fn(params, cache, tokens, t):
        return tf.decode_step(params, work_cfg, cache, tokens, t,
                              unroll_layers=unroll)

    return CellSpec(cfg.name, shape.name, decode_fn,
                    (params_s, cache_s, tokens_s, t_s),
                    (params_sh, cache_sh, tok_sh, t_sh), skip,
                    loop_trip=work_cfg.n_layers,
                    model_flops=2.0 * n_active * b, donate=(1,))


# ------------------------------------------------------------------ GNN
def _pad512(x: int) -> int:
    """Production graphs are padded at load time so node/edge streams
    divide every mesh axis product (512 covers 16x16 and 2x16x16);
    without this the divisibility-checking rules silently replicate
    (e.g. ogb's 2,449,029 nodes -> 2.9 TB/device)."""
    return -(-x // 512) * 512


def _gnn_batch_shapes(cfg: GNNConfig, shape: ShapeSpec):
    if shape.kind == "batched_graphs":
        n = shape.n_nodes * shape.global_batch
        e = shape.n_edges * shape.global_batch
        n_graphs = shape.global_batch
    elif shape.kind == "minibatch":
        n = _max_nodes(shape.batch_nodes, shape.fanout)
        e = sum(shape.batch_nodes
                * int(np.prod(shape.fanout[:i + 1]))
                for i in range(len(shape.fanout)))
        n_graphs = 1
    else:
        n, e, n_graphs = shape.n_nodes, shape.n_edges, 1
    n, e = _pad512(n), _pad512(e)
    d_feat = shape.d_feat or 32
    gb = GraphBatch(
        _sds((e,), jnp.int32), _sds((e,), jnp.int32),
        _sds((e,), jnp.float32), _sds((n, d_feat), jnp.float32),
        _sds((n, 3), jnp.float32), _sds((n,), jnp.float32),
        _sds((n,), jnp.int32), n_graphs, _sds((n,), jnp.int32))
    sh = GraphBatch(
        shlib.named_sharding("edges", dims=(e,)),
        shlib.named_sharding("edges", dims=(e,)),
        shlib.named_sharding("edges", dims=(e,)),
        shlib.named_sharding("nodes", None, dims=(n, d_feat)),
        shlib.named_sharding("nodes", None, dims=(n, 3)),
        shlib.named_sharding("nodes", dims=(n,)),
        shlib.named_sharding("nodes", dims=(n,)), n_graphs,
        shlib.named_sharding("nodes", dims=(n,)))
    return gb, sh, n, e, d_feat


def _gnn_cell(cfg: GNNConfig, shape: ShapeSpec, *, mode: str,
              layers: int | None = None) -> CellSpec:
    # production cells run mixed precision (bf16 messages, f32 masters);
    # smoke tests keep the f32 default for tight numeric assertions.
    work_cfg = dataclasses.replace(
        cfg, act_dtype="bfloat16",
        **({} if layers is None else {"n_layers": layers}))
    gb, gb_sh, n, e, d_feat = _gnn_batch_shapes(work_cfg, shape)
    n_out = work_cfg.n_vars or 16
    # eager init, then abstract: the equivariant inits compute CG/Wigner
    # coefficients through host-side numpy (fails under eval_shape
    # tracing), and GNN params are small enough to materialize.
    params_c = gnn_lib.init_gnn(work_cfg, jax.random.key(0), d_feat,
                                n_out)
    params_s = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params_c)
    del params_c
    params_sh = _replicated(params_s)
    opt = AdamW(lr=1e-3)
    opt_s = jax.eval_shape(opt.init, params_s)
    opt_sh = type(opt_s)(shlib.named_sharding(),
                         _replicated(opt_s.mu), _replicated(opt_s.nu))
    step = gnn_lib.make_gnn_train_step(work_cfg, opt, n_out=n_out,
                                       unroll_layers=mode == "cost")
    # GNN "model flops" proxy: edges x d_hidden^2 x layers x 6
    mf = 6.0 * e * work_cfg.d_hidden ** 2 * work_cfg.n_layers
    return CellSpec(cfg.name, shape.name, step, (params_s, opt_s, gb),
                    (params_sh, opt_sh, gb_sh), None,
                    loop_trip=1, model_flops=mf, donate=(0, 1))


# --------------------------------------------------------------- recsys
def _recsys_cell(cfg: RecSysConfig, shape: ShapeSpec, *,
                 mode: str) -> CellSpec:
    params_s = recsys_lib.param_shapes(cfg)
    params_sh = {
        "table": shlib.named_sharding("rows", None,
                                      dims=(cfg.vocab, cfg.embed_dim)),
        "bilinear": shlib.named_sharding(None, None),
        "route_init": shlib.named_sharding(None, None),
        "out_proj": shlib.named_sharding(None, None),
    }
    b = shape.global_batch
    hist_s = _sds((b, cfg.hist_len), jnp.int32)
    hist_sh = shlib.named_sharding("batch", None,
                                   dims=(b, cfg.hist_len))
    mf = 2.0 * b * cfg.hist_len * cfg.embed_dim ** 2 * cfg.capsule_iters

    if shape.kind == "recsys_train":
        opt = AdamW(lr=1e-3)
        opt_s = jax.eval_shape(opt.init, params_s)
        opt_sh = type(opt_s)(
            shlib.named_sharding(),
            jax.tree.map(lambda s, sh: sh, opt_s.mu, params_sh),
            jax.tree.map(lambda s, sh: sh, opt_s.nu, params_sh))
        batch_s = {"hist": hist_s, "target": _sds((b,), jnp.int32)}
        batch_sh = {"hist": hist_sh,
                    "target": shlib.named_sharding("batch", dims=(b,))}
        step = recsys_lib.make_train_step(cfg, opt)
        return CellSpec(cfg.name, shape.name, step,
                        (params_s, opt_s, batch_s),
                        (params_sh, opt_sh, batch_sh), None,
                        model_flops=mf + 2.0 * b * b * cfg.embed_dim,
                        donate=(0, 1))

    if shape.kind == "retrieval":
        nc = shape.n_candidates
        cand_s = _sds((nc,), jnp.int32)
        cand_sh = shlib.named_sharding("cand", dims=(nc,))

        def retr(params, hist, cand):
            return recsys_lib.retrieval_step(params, cfg, hist, cand)
        return CellSpec(cfg.name, shape.name, retr,
                        (params_s, hist_s, cand_s),
                        (params_sh, hist_sh, cand_sh), None,
                        model_flops=mf + 2.0 * b * nc * cfg.embed_dim
                        * cfg.n_interests)

    def serve(params, hist):
        return recsys_lib.serve_step(params, cfg, hist)
    return CellSpec(cfg.name, shape.name, serve, (params_s, hist_s),
                    (params_sh, hist_sh), None, model_flops=mf)


# --------------------------------------------------------------- lookup
def rule_overrides(arch: str, shape_name: str) -> dict:
    """Per-cell logical-rule overrides (activate in use_rules BEFORE
    make_cell).

    Serving re-shards weights: with the training FSDP rules, every
    decoded token all-gathers every layer's weights (measured: decode
    cells 30-600x collective-over-compute).  When the weights fit
    model-sharded (bf16/16-way < 12 GB/chip), serve cells keep them
    RESIDENT: fsdp/vocab collapse to the model axis only.
    """
    cfg = get(arch)
    if cfg.family == "lm" and not shape_name.startswith("train"):
        # resident-weight budget: <= 4 GB/chip leaves room for KV cache
        # + activations (mixtral's 5.9 GB resident measured 158% HBM at
        # prefill_32k — reverted to FSDP gathering for it; §Perf log).
        if cfg.param_count() * 2 / 16 < 4e9:
            return {"fsdp": (), "batch": ("pod", "data")}
    return {}


def _gnn_pcpm_cell(cfg: GNNConfig, shape: ShapeSpec, *, mode: str,
                   layers: int | None = None) -> CellSpec:
    """GNN full-graph cell over the PCPM-distributed engine (the
    paper's technique as the message-passing transport; §Perf)."""
    from ..models import gnn_dist
    work_cfg = dataclasses.replace(
        cfg, act_dtype="bfloat16",
        **({} if layers is None else {"n_layers": layers}))
    mesh = shlib.current_mesh()
    s_count = int(mesh.devices.size)
    n, e = _pad512(shape.n_nodes), _pad512(shape.n_edges)
    ssz = -(-n // s_count)
    u_max = gnn_dist.estimate_u_max(n, e, s_count, skew=2.0)
    e_max = max(128, int(-(-(e // s_count) * 1.5 // 128) * 128))
    d_feat = shape.d_feat or 32
    n_out = work_cfg.n_vars or 16
    g = gnn_dist.DistGraph.abstract(s_count, ssz, u_max, e_max, d_feat)
    g_sh = gnn_dist.dist_graph_shardings(mesh, g)
    params_c = gnn_dist.init_graphcast(work_cfg, jax.random.key(0),
                                       d_feat, n_out)
    params_s = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params_c)
    del params_c
    params_sh = _replicated(params_s)
    opt = AdamW(lr=1e-3)
    opt_s = jax.eval_shape(opt.init, params_s)
    opt_sh = type(opt_s)(shlib.named_sharding(),
                         _replicated(opt_s.mu), _replicated(opt_s.nu))
    step = gnn_dist.make_dist_train_step(work_cfg, opt, mesh,
                                         n_out=n_out,
                                         unroll_layers=mode == "cost")
    mf = 6.0 * e * work_cfg.d_hidden ** 2 * work_cfg.n_layers
    return CellSpec(cfg.name + "+pcpm", shape.name, step,
                    (params_s, opt_s, g), (params_sh, opt_sh, g_sh),
                    None, loop_trip=1, model_flops=mf, donate=(0, 1))


def make_cell(arch: str, shape_name: str, *, mode: str = "compile",
              layers: int | None = None,
              engine: str = "xla") -> CellSpec:
    """Requires an active shlib.use_rules(mesh) context.

    ``engine="pcpm"`` swaps the GNN message-passing transport for the
    PCPM-distributed exchange (graphcast full-graph cells only).
    """
    cfg = get(arch)
    shape = next(s for s in cfg.shapes if s.name == shape_name)
    if engine == "pcpm":
        assert cfg.family == "gnn" and shape.kind == "full_graph", \
            "pcpm engine variant: GNN full-graph cells only"
        return _gnn_pcpm_cell(cfg, shape, mode=mode, layers=layers)
    if cfg.family == "lm":
        return _lm_cell(cfg, shape, mode=mode, layers=layers)
    if cfg.family == "gnn":
        return _gnn_cell(cfg, shape, mode=mode, layers=layers)
    return _recsys_cell(cfg, shape, mode=mode)


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in ["mixtral-8x7b", "grok-1-314b", "stablelm-1.6b",
                 "tinyllama-1.1b", "deepseek-67b", "graphcast",
                 "nequip", "mace", "equiformer-v2", "mind"]:
        for s in get(arch).shapes:
            out.append((arch, s.name))
    return out
