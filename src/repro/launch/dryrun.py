import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run driver (deliverable e).
#
# Lowers + compiles every (architecture x input-shape) cell against the
# production meshes — 16x16 single-pod and 2x16x16 multi-pod — and
# extracts the roofline inputs (deliverable g):
#
#   compile pass : full config, scan-over-layers, chunked attention.
#                  Proves shardability, records memory_analysis()
#                  (per-device bytes -> "fits in 16 GB HBM") and the
#                  collective-op census of the compiled module.
#   cost pass    : python-unrolled layers at depth 1 and 2 (LM/GNN),
#                  dense cost_analysis() FLOPs/bytes + collective
#                  operand bytes parsed from compiled.as_text();
#                  extrapolated linearly to the full depth
#                  (HloCostAnalysis counts a while body once, hence the
#                  unroll — see EXPERIMENTS.md §Roofline method).
#
# Output: one JSON line per (cell x mesh) appended to --out, consumed by
# benchmarks/roofline.py and EXPERIMENTS.md.
#
# The 512-device XLA_FLAGS override above MUST precede every other
# import (jax locks the device count on first init) and is deliberately
# local to this module: tests and benches see the 1 real CPU device.
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax

from . import sharding as shlib
from .mesh import make_production_mesh, HBM_BYTES
from .specs import make_cell, all_cells, rule_overrides

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute", "collective-broadcast",
                "ragged-all-to-all")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    total = nbytes
    for d in dims.split(","):
        if d:
            total *= int(d)
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))       # [num_groups, group_size]<=...
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    return default


# per-device wire bytes for ring algorithms, as a function of the
# RESULT payload bytes (post-SPMD shapes are per-device local shapes;
# the optimized-HLO printer omits operand shapes, so the result shape
# is the robust thing to parse).
def _wire_bytes(op: str, result_bytes: int, k: int) -> float:
    if k <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * result_bytes * (k - 1) / k
    if op == "reduce-scatter":          # operand = result * k
        return float(result_bytes) * (k - 1)
    if op == "collective-permute":
        return float(result_bytes)
    # all-gather / all-to-all / broadcast-like
    return float(result_bytes) * (k - 1) / k


def collective_stats(hlo_text: str, num_partitions: int = 1) -> dict:
    """Census of collective ops in (post-SPMD) HLO text.

    Per op: count, result payload bytes, and estimated per-device wire
    bytes (ring-algorithm model, group size parsed from replica_groups).
    Counts plain and ``-start`` forms once; skips ``-done``/``-update``.
    While-loop bodies are printed (and counted) once — use unrolled
    modules for trip-count-correct totals.
    """
    per_op: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            if f" {op}(" in line:
                lhs = line.split(f" {op}(", 1)[0]
            elif f" {op}-start(" in line:
                lhs = line.split(f" {op}-start(", 1)[0]
            else:
                continue
            # result shape(s) live on the LHS of the assignment; for
            # -start tuple results take the LAST element (the output).
            shapes = _SHAPE_RE.findall(lhs)
            if not shapes:
                break
            d, dims = shapes[-1]
            nbytes = _shape_bytes(d, dims)
            k = _group_size(line, num_partitions)
            slot = per_op.setdefault(
                op, {"count": 0, "bytes": 0, "wire_bytes": 0.0})
            slot["count"] += 1
            slot["bytes"] += nbytes
            slot["wire_bytes"] += _wire_bytes(op, nbytes, k)
            break
    return {"per_op": per_op,
            "bytes": sum(v["bytes"] for v in per_op.values()),
            "wire_bytes": sum(v["wire_bytes"] for v in per_op.values())}


def _memory_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": repr(e)}
    out = {}
    for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    live = (out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0))
    out["live_bytes"] = int(live)
    out["hbm_fraction"] = live / HBM_BYTES
    return out


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": repr(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def _lower(cell, mesh):
    """jit().lower().compile() one cell; returns (lowered, compiled)."""
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate or None)
    with mesh:
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape: str, mesh, mesh_name: str, *,
             do_compile: bool = True, do_cost: bool = True,
             verbose: bool = True, engine: str = "xla") -> dict:
    rec: dict = {"arch": arch + ("+pcpm" if engine == "pcpm" else ""),
                 "shape": shape, "mesh": mesh_name,
                 "devices": int(mesh.devices.size)}
    with shlib.use_rules(mesh, rule_overrides(arch, shape)):
        cell = make_cell(arch, shape, mode="compile", engine=engine)
        rec["loop_trip"] = cell.loop_trip
        rec["model_flops"] = cell.model_flops
        if cell.skip:
            rec["skip"] = cell.skip
            return rec

        if do_compile:
            t0 = time.time()
            _, compiled = _lower(cell, mesh)
            txt = compiled.as_text()
            rec["compile"] = {
                "seconds": round(time.time() - t0, 1),
                "memory": _memory_analysis(compiled),
                "collectives": collective_stats(txt, mesh.devices.size),
                "cost": _cost_analysis(compiled),
            }
            del compiled, txt
            if verbose:
                m = rec["compile"]["memory"]
                print(f"  compile ok {rec['compile']['seconds']}s  "
                      f"live/dev={m.get('live_bytes', 0)/2**30:.2f} GiB "
                      f"({m.get('hbm_fraction', 0)*100:.0f}% HBM)",
                      flush=True)

        if do_cost:
            # depth-1 and depth-2 unrolled cost passes -> per-layer delta
            costs = {}
            depths = (1, 2) if cell.loop_trip > 1 else (None,)
            for depth in depths:
                c = make_cell(arch, shape, mode="cost", layers=depth,
                              engine=engine)
                t0 = time.time()
                _, compiled = _lower(c, mesh)
                txt = compiled.as_text()
                costs[depth or 0] = {
                    "seconds": round(time.time() - t0, 1),
                    "cost": _cost_analysis(compiled),
                    "collectives": collective_stats(txt, mesh.devices.size),
                }
                del compiled, txt
            rec["cost_passes"] = {str(k): v for k, v in costs.items()}
            rec["extrapolated"] = _extrapolate(costs, cell.loop_trip)
            if verbose:
                e = rec["extrapolated"]
                print(f"  cost ok  flops/dev={e['flops']:.3e}  "
                      f"bytes/dev={e['bytes']:.3e}  "
                      f"coll/dev={e['collective_bytes']:.3e}", flush=True)
    return rec


def _extrapolate(costs: dict, loop_trip: int) -> dict:
    """total(L) = c1 + (c2 - c1) * (L - 1); single-pass cells as-is."""
    def field(c, name):
        if name == "collective_bytes":
            return c["collectives"]["wire_bytes"]
        return c["cost"].get(name, 0.0)

    out = {}
    for name in ("flops", "bytes", "collective_bytes"):
        if 0 in costs:                      # single-pass (loop_trip == 1)
            out[name] = field(costs[0], name)
        else:
            c1, c2 = field(costs[1], name), field(costs[2], name)
            out[name] = c1 + (c2 - c1) * (loop_trip - 1)
    out["per_layer_flops"] = (
        0.0 if 0 in costs
        else field(costs[2], "flops") - field(costs[1], "flops"))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--engine", choices=["xla", "pcpm"], default="xla")
    ap.add_argument("--skip-compile", action="store_true")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--out", default=None,
                    help="append JSONL records here")
    args = ap.parse_args(argv)

    if args.all or not args.arch:
        cells = all_cells()
    else:
        from ..configs import get as get_cfg
        shapes = ([args.shape] if args.shape else
                  [sp.name for sp in get_cfg(args.arch).shapes])
        cells = [(args.arch, s) for s in shapes]

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single-pod-16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi-pod-2x16x16",
                       make_production_mesh(multi_pod=True)))

    failures = 0
    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            print(f"[{mesh_name}] {arch} x {shape}", flush=True)
            try:
                rec = run_cell(arch, shape, mesh, mesh_name,
                               do_compile=not args.skip_compile,
                               do_cost=not args.skip_cost,
                               engine=args.engine)
            except Exception:
                failures += 1
                rec = {"arch": arch + ("+pcpm" if args.engine == "pcpm"
                                       else ""),
                       "shape": shape, "mesh": mesh_name,
                       "error": traceback.format_exc()}
                print(f"  FAILED\n{rec['error']}", flush=True)
            if "skip" in rec:
                print(f"  SKIP: {rec['skip']}", flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
            jax.clear_caches()   # keep the 40-cell sweep's RSS bounded
    print(f"done; {failures} failures", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
