from .mesh import (make_production_mesh, make_host_mesh, PEAK_FLOPS_BF16,
                   HBM_BW, ICI_BW_PER_LINK, HBM_BYTES)
from . import sharding

__all__ = ["make_production_mesh", "make_host_mesh", "sharding",
           "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW_PER_LINK", "HBM_BYTES"]
