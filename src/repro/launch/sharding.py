"""Logical-axis sharding rules (MaxText-style, minimal).

Models annotate activations/params with LOGICAL axis names; the rules
active for the current mesh map them to physical mesh axes.  The same
model code then runs on the single-pod (data, model) mesh, the
multi-pod (pod, data, model) mesh, and the 1-device CPU test mesh.

``shard`` silently drops a physical axis whenever the dim is not
divisible by it (e.g. batch=1 long-decode on a data=16 mesh, or 8 KV
heads on model=16) — the shardability decisions stay in one place and
the model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical axis -> tuple of candidate physical axes (used if present)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),          # DP / FSDP data axis
    "fsdp": ("pod", "data"),           # parameter shard axis (FSDP)
    "model": ("model",),               # TP: heads / d_ff / vocab
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "embed": (),                       # d_model replicated
    "seq": (),                         # sequence replicated by default
    "kv_seq": ("model",),              # long KV caches: sequence-shard
    "expert": (),                      # experts replicated (TP in-expert)
    # GNN: graph dims over every axis.  (A 2D nodes x channels layout
    # was tried and REGRESSED: sharding the MLP contraction dim makes
    # XLA materialize full-channel edge tensors around every matmul —
    # see EXPERIMENTS.md §Perf hypothesis log.)
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
    "chan": (),
    "rows": ("pod", "data", "model"),   # embedding-table rows
    "cand": ("pod", "data", "model"),   # retrieval candidates
    "graphs": ("pod", "data"),         # batched small graphs
}

_state = threading.local()


def current_rules() -> dict[str, tuple[str, ...]]:
    return getattr(_state, "rules", DEFAULT_RULES)


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_rules(mesh, overrides: dict | None = None):
    """Activate sharding rules bound to ``mesh``."""
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    prev = (getattr(_state, "rules", None), getattr(_state, "mesh", None))
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def _axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def logical_to_spec(*logical, dims: tuple[int, ...] | None = None) -> P:
    """Map logical dim names to a PartitionSpec under the active rules.

    With ``dims`` given, physical axes that do not divide the dim are
    dropped.  Each physical axis is used at most once per spec.
    """
    mesh = current_mesh()
    if mesh is None:
        return P(*([None] * len(logical)))
    rules = current_rules()
    used: set[str] = set()
    out = []
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        phys = []
        for a in rules.get(name, ()):
            if a not in mesh.axis_names or a in used:
                continue
            if dims is not None:
                size = _axis_size(mesh, a)
                cur = 1
                for p in phys:
                    cur *= _axis_size(mesh, p)
                if dims[i] % (cur * size) != 0:
                    continue
            phys.append(a)
        used.update(phys)
        out.append(None if not phys else
                   (phys[0] if len(phys) == 1 else tuple(phys)))
    return P(*out)


def shard(x, *logical):
    """with_sharding_constraint by logical names (no-op off-mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(*logical, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*logical, dims=None) -> NamedSharding:
    mesh = current_mesh()
    assert mesh is not None, "named_sharding requires use_rules(mesh)"
    return NamedSharding(mesh, logical_to_spec(*logical, dims=dims))


def divides(dim: int, *logical: str) -> bool:
    """True iff the full candidate axis product of `logical` divides dim."""
    mesh = current_mesh()
    if mesh is None:
        return False
    rules = current_rules()
    size = 1
    for name in logical:
        for a in rules.get(name, ()):
            if a in mesh.axis_names:
                size *= _axis_size(mesh, a)
    return size > 1 and dim % size == 0
