"""Incremental plan patching (DESIGN.md §9).

Every single-device plan layout in this repo is *partition-major*:
partitions are contiguous destination-ID ranges and each backend's
streams are primarily sorted by destination partition — so the segment
of a layout belonging to partition p depends ONLY on the edges whose
destination lands in p.  An edge delta therefore dirties exactly the
partitions ``{dst // part_size}`` of its changed edges, and a new plan
can be assembled by

  1. recovering the dirty partitions' edges FROM THE OLD PLAN (the PNG
     stores src via ``update_src[edge_update_idx]``; pdpr/bvgas store
     the raw streams),
  2. applying the delta (multiset removal + insertion) to those edges
     only,
  3. re-running the per-partition build — the ONLY sorting work, over
     dirty edges instead of all M — and
  4. splicing rebuilt segments between untouched ones (clean segments
     are memcpy + a per-partition pointer shift).

The splice is exact: the patched arrays are ``np.array_equal`` to a
from-scratch build (asserted property-style in tests/test_stream.py),
so a patched plan is not an approximation — it IS the plan.

Derived schedules (blocked gather runs, BlockedPNG re-layout) are
re-derived from the spliced streams: both are sort-free vectorized
O(M) passes, noise next to the lexsorts they replace.

``patch_plan`` is the front door: it consults the plan cache, applies
the registered backend patcher, falls back to a full rebuild past a
dirtiness threshold (or for backends without a patcher, e.g.
pcpm_sharded whose all-to-all wire layout is global), stamps the
``parent_fp`` chain and installs the result so every consumer — the
Session, schedulers, shims — warm-starts from it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import plan as plan_mod
from ..core.backends import get_backend
from ..core.plan import GraphPlan, graph_fingerprint, install_plan
from ..core.png import PNGLayout, build_gather_schedule, block_png
from ..graphs.formats import Graph
from .delta import GraphDelta, gather_ranges, multiset_keep_mask

# Past this fraction of dirty partitions a full rebuild is cheaper
# than recovering + splicing (measured crossover is flat between 0.3
# and 0.7 at bench scale; the win we chase is the <<1% regime anyway).
DIRTY_THRESHOLD = 0.5


def _dirty_edges(delta: GraphDelta, old_src: np.ndarray,
                 old_dst: np.ndarray, num_nodes: int
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Apply the delta to the dirty partitions' recovered edge set."""
    if delta.num_removed:
        keep = multiset_keep_mask(old_src, old_dst, delta.rem_src,
                                  delta.rem_dst, num_nodes=num_nodes)
        old_src, old_dst = old_src[keep], old_dst[keep]
    if delta.num_added:
        old_src = np.concatenate([old_src, delta.add_src])
        old_dst = np.concatenate([old_dst, delta.add_dst])
    return old_src, old_dst


def _splice(old_vals: np.ndarray, old_offsets: np.ndarray,
            dirty: np.ndarray, dirty_vals: np.ndarray,
            dirty_counts: np.ndarray,
            ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Replace the ``dirty`` partitions' segments of a partition-major
    stream with ``dirty_vals`` (concatenated in ascending-partition
    order, per-partition sizes ``dirty_counts``).

    Returns ``(new_vals, new_offsets, clean_positions)`` where
    ``clean_positions`` are the destination indices the old clean
    values were copied to (callers needing a per-partition fixup on
    clean entries — e.g. the PNG's update-pointer shift — apply it
    there).
    """
    k = len(old_offsets) - 1
    counts = np.diff(old_offsets)
    new_counts = counts.copy()
    new_counts[dirty] = dirty_counts
    new_offsets = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(new_counts, out=new_offsets[1:])
    clean = np.ones(k, dtype=bool)
    clean[dirty] = False
    clean_idx = np.flatnonzero(clean)
    new_vals = np.empty(int(new_offsets[-1]), dtype=old_vals.dtype)
    clean_pos = gather_ranges(new_offsets[clean_idx], counts[clean_idx])
    new_vals[clean_pos] = old_vals[
        gather_ranges(old_offsets[clean_idx], counts[clean_idx])]
    new_vals[gather_ranges(new_offsets[dirty], dirty_counts)] = dirty_vals
    return new_vals, new_offsets, clean_pos


def patch_png(png: PNGLayout, delta: GraphDelta) -> PNGLayout:
    """Splice-rebuild the PNG for the delta's dirty partitions only.

    Exactly equals ``build_png(apply_delta(g), part)``: clean
    partitions keep their segments verbatim (edge pointers shifted by
    the preceding partitions' update-count change), dirty partitions
    re-run the paper's compress+transpose scans locally.
    """
    part = png.partitioning
    psz = part.part_size
    n = png.num_nodes
    dirty = delta.dirty_partitions(psz)

    # 1. recover the dirty partitions' edges from the old layout
    e_counts = np.diff(png.edge_offsets)
    idx = gather_ranges(png.edge_offsets[dirty], e_counts[dirty])
    old_src = png.update_src[png.edge_update_idx[idx]]
    old_dst = png.edge_dst[idx]

    # 2. delta on those edges only
    src2, dst2 = _dirty_edges(delta, old_src, old_dst, n)

    # 3. per-partition PNG build over the dirty edges (paper §IV-B
    #    scans, restricted): sort by (dstp, src, dst), dedup updates,
    #    then re-sort the edge stream by destination
    dstp2 = dst2.astype(np.int64) // psz
    order = np.lexsort((dst2, src2, dstp2))
    src_s, dst_s, dstp_s = src2[order], dst2[order], dstp2[order]
    pair_key = dstp_s * np.int64(n) + src_s
    new_update = np.empty(len(pair_key), dtype=bool)
    if len(pair_key):
        new_update[0] = True
        np.not_equal(pair_key[1:], pair_key[:-1], out=new_update[1:])
    upd_of_edge = (np.cumsum(new_update) - 1).astype(np.int64)
    upd_src_d = src_s[new_update].astype(np.int32)
    upd_dstp_d = dstp_s[new_update]

    # per-dirty-partition counts (aligned with ``dirty``'s order)
    d_pos = np.searchsorted(dirty, upd_dstp_d)
    u_cnt_d = np.bincount(d_pos, minlength=len(dirty)).astype(np.int64)
    e_pos = np.searchsorted(dirty, dstp_s)
    e_cnt_d = np.bincount(e_pos, minlength=len(dirty)).astype(np.int64)

    # 4a. splice the update stream
    new_update_src, new_uo, _ = _splice(
        png.update_src, png.update_offsets, dirty, upd_src_d, u_cnt_d)

    # global new index of each dirty update: partition base offset +
    # rank within its partition's dirty segment
    dirty_uo = np.zeros(len(dirty) + 1, dtype=np.int64)
    np.cumsum(u_cnt_d, out=dirty_uo[1:])
    upd_global = (new_uo[dirty[d_pos]]
                  + np.arange(len(upd_src_d), dtype=np.int64)
                  - dirty_uo[d_pos]).astype(np.int32)

    # 4b. splice the gather stream (dst-sorted; partitions are
    #     contiguous dst ranges, so the stable per-dirty re-sort
    #     composes into the global dst order)
    gorder = np.argsort(dst_s, kind="stable")
    eui_d = upd_global[upd_of_edge[gorder]]
    dst_d = dst_s[gorder].astype(np.int32)
    new_edge_dst, new_eo, _ = _splice(
        png.edge_dst, png.edge_offsets, dirty, dst_d, e_cnt_d)
    new_eui, _, clean_pos = _splice(
        png.edge_update_idx, png.edge_offsets, dirty, eui_d, e_cnt_d)
    # clean partitions' pointers still index the OLD update stream —
    # shift each by its partition's change in preceding update counts
    k = part.num_partitions
    clean = np.ones(k, dtype=bool)
    clean[dirty] = False
    clean_idx = np.flatnonzero(clean)
    shift = (new_uo[clean_idx] - png.update_offsets[clean_idx])
    e_counts_clean = e_counts[clean_idx]
    if len(clean_pos):
        new_eui[clean_pos] = (
            new_eui[clean_pos]
            + np.repeat(shift, e_counts_clean).astype(np.int32))

    return PNGLayout(part, new_update_src, new_uo, new_eui,
                     new_edge_dst, new_eo, n, int(new_eo[-1]))


# ---------------------------------------------------------------------------
# Backend patchers (registered as Backend.patch_plan in core/backends.py)
# ---------------------------------------------------------------------------
def _patched_fields(plan: GraphPlan, g_new: Graph, m_new: int) -> dict:
    return dict(config=plan.config, num_nodes=plan.num_nodes,
                num_edges=m_new, partitioning=plan.partitioning,
                graph_fp=graph_fingerprint(g_new),
                parent_fp=plan.graph_fp)


def _shared_patched_png(plan: GraphPlan, g_new: Graph,
                        delta: GraphDelta) -> PNGLayout:
    """One spliced PNG per (new graph, part_size): if the sibling
    pcpm/pcpm_pallas backend already patched it, reuse that layout."""
    fp = graph_fingerprint(g_new)
    png = plan_mod.peek_shared_png(fp, plan.part_size)
    if png is None:
        png = patch_png(plan.png, delta)
    return png


def patch_pcpm_plan(plan: GraphPlan, g_new: Graph,
                    delta: GraphDelta) -> GraphPlan:
    png = _shared_patched_png(plan, g_new, delta)
    sched = build_gather_schedule(png, block=plan.config.gather_block)
    return GraphPlan(png=png, schedule=sched,
                     **_patched_fields(plan, g_new, png.num_edges))


def patch_pcpm_pallas_plan(plan: GraphPlan, g_new: Graph,
                           delta: GraphDelta) -> GraphPlan:
    png = _shared_patched_png(plan, g_new, delta)
    return GraphPlan(png=png, blocked=block_png(png),
                     **_patched_fields(plan, g_new, png.num_edges))


def _partition_bounds(dstp: np.ndarray, k: int) -> np.ndarray:
    """Offsets (k+1,) of a dst-partition-major stream."""
    return np.searchsorted(dstp, np.arange(k + 1)).astype(np.int64)


def patch_pdpr_plan(plan: GraphPlan, g_new: Graph,
                    delta: GraphDelta) -> GraphPlan:
    """The pull stream is dst-sorted, hence partition-major: splice
    per-dirty re-sorted segments, then re-derive the blocked gather
    schedule (sort-free O(M))."""
    from ..core.backends import pdpr_schedule
    psz = plan.part_size
    k = plan.partitioning.num_partitions
    n = plan.num_nodes
    dirty = delta.dirty_partitions(psz)
    offsets = _partition_bounds(plan.csc_dst.astype(np.int64) // psz, k)
    e_counts = np.diff(offsets)
    idx = gather_ranges(offsets[dirty], e_counts[dirty])
    src2, dst2 = _dirty_edges(delta, plan.csc_src[idx],
                              plan.csc_dst[idx], n)
    order = np.lexsort((src2, dst2))     # dst-major, matches the build
    src_d, dst_d = src2[order], dst2[order]
    e_cnt_d = np.bincount(
        np.searchsorted(dirty, dst_d.astype(np.int64) // psz),
        minlength=len(dirty)).astype(np.int64)
    new_src, _, _ = _splice(plan.csc_src, offsets, dirty, src_d, e_cnt_d)
    new_dst, _, _ = _splice(plan.csc_dst, offsets, dirty, dst_d, e_cnt_d)
    return GraphPlan(csc_src=new_src, csc_dst=new_dst,
                     schedule=pdpr_schedule(
                         new_src, new_dst, num_nodes=n,
                         block=plan.config.gather_block),
                     **_patched_fields(plan, g_new, len(new_src)))


def patch_bvgas_plan(plan: GraphPlan, g_new: Graph,
                     delta: GraphDelta) -> GraphPlan:
    """BVGAS streams are (dstp, src, dst)-sorted — partition-major by
    construction.  The gather permutation (bins position per dst-
    sorted edge) is itself partition-segmented, so clean partitions
    keep their permutation entries up to a scalar base shift and only
    dirty partitions re-sort."""
    from ..core.png import GatherSchedule, flat_gather_schedule
    psz = plan.part_size
    k = plan.partitioning.num_partitions
    n = plan.num_nodes
    dirty = delta.dirty_partitions(psz)
    offsets = _partition_bounds(plan.bv_dst.astype(np.int64) // psz, k)
    e_counts = np.diff(offsets)
    idx = gather_ranges(offsets[dirty], e_counts[dirty])
    src2, dst2 = _dirty_edges(delta, plan.bv_src[idx],
                              plan.bv_dst[idx], n)
    dstp2 = dst2.astype(np.int64) // psz
    order = np.lexsort((dst2, src2, dstp2))
    src_d, dst_d = src2[order], dst2[order]
    e_cnt_d = np.bincount(np.searchsorted(dirty, dstp2[order]),
                          minlength=len(dirty)).astype(np.int64)
    new_src, new_offsets, _ = _splice(plan.bv_src, offsets, dirty,
                                      src_d, e_cnt_d)
    new_dst, _, _ = _splice(plan.bv_dst, offsets, dirty, dst_d, e_cnt_d)

    # gather permutation: recover the old one from the schedule (its
    # un-padded prefix), rebase clean segments, re-sort dirty ones
    old_perm = plan.schedule.edge_update_idx_padded[:plan.num_edges]
    perm_local_d = np.argsort(dst_d, kind="stable").astype(np.int64)
    # positions within the dirty concatenation -> global bins positions
    dirty_eo = np.zeros(len(dirty) + 1, dtype=np.int64)
    np.cumsum(e_cnt_d, out=dirty_eo[1:])
    part_of = np.repeat(np.arange(len(dirty)), e_cnt_d)
    perm_d = (perm_local_d + new_offsets[dirty[part_of[perm_local_d]]]
              - dirty_eo[part_of[perm_local_d]]).astype(np.int64)
    new_perm, _, clean_pos = _splice(
        old_perm.astype(np.int64), offsets, dirty, perm_d, e_cnt_d)
    clean = np.ones(k, dtype=bool)
    clean[dirty] = False
    clean_idx = np.flatnonzero(clean)
    if len(clean_pos):
        new_perm[clean_pos] = new_perm[clean_pos] + np.repeat(
            new_offsets[clean_idx] - offsets[clean_idx],
            e_counts[clean_idx])
    new_perm = new_perm.astype(np.int32)
    eui, starts, ends, pdst = flat_gather_schedule(
        new_perm, new_dst[new_perm], num_nodes=n,
        block=plan.config.gather_block)
    sched = GatherSchedule(plan.config.gather_block, len(new_dst), eui,
                           starts, ends, pdst)
    return GraphPlan(bv_src=new_src, bv_dst=new_dst, schedule=sched,
                     **_patched_fields(plan, g_new, len(new_src)))


# ---------------------------------------------------------------------------
# Front door
# ---------------------------------------------------------------------------
def patch_plan(plan: GraphPlan, delta: GraphDelta, g_new: Graph, *,
               dirty_threshold: float = DIRTY_THRESHOLD) -> GraphPlan:
    """Produce (and cache) the plan for ``g_new = g_old + delta`` from
    ``plan``.

    Dispatch: cache hit on the new graph's fingerprint wins; then the
    backend's registered incremental patcher, unless the delta dirties
    more than ``dirty_threshold`` of the partitions (or the backend has
    none), in which case a full rebuild runs — either way the result
    carries ``parent_fp = plan.graph_fp`` so the version chain is
    evictable as a unit, and is installed in the process plan cache.
    """
    if delta.is_empty:
        return plan
    backend = get_backend(plan.method)
    cfg = plan.config
    fp_new = graph_fingerprint(g_new)
    if plan.graph_fp is not None:
        from .delta import shifted_fingerprint
        expected = shifted_fingerprint(plan.graph_fp, delta)
        if fp_new != expected:
            raise ValueError(
                "patch_plan: g_new is not g_old + delta (content "
                f"fingerprint {fp_new[:20]}… != expected "
                f"{expected[:20]}…) — a plan patched against it would "
                "silently serve wrong preprocessing")
    cached = plan_mod.peek_plan(fp_new, cfg)
    if cached is not None:
        return cached
    k = plan.partitioning.num_partitions
    dirty_frac = len(delta.dirty_partitions(plan.part_size)) / max(k, 1)
    # reordered plans always rebuild: the ordering itself is a function
    # of the graph, and the delta's dirty partitions are original-space
    # ids while the plan's layouts live in relabeled space — a splice
    # would patch the wrong partitions.  build_plan recomputes the
    # permutation for g_new; the parent_fp chain is preserved.
    rebuilt = (backend.patch_plan is None or cfg.reorder != "none"
               or dirty_frac > dirty_threshold)
    if rebuilt:
        from ..core.plan import build_plan
        new_plan = dataclasses.replace(build_plan(g_new, cfg),
                                       parent_fp=plan.graph_fp)
    else:
        plan_mod.plan_cache_stats().plan_patches += 1
        new_plan = backend.patch_plan(plan, g_new, delta)
    plan_mod.notify_plan_event(
        "plan_patch", method=cfg.method, rebuilt=rebuilt,
        adds=len(delta.add_src), removes=len(delta.rem_src),
        dirty_frac=dirty_frac)
    return install_plan(g_new, new_plan)
