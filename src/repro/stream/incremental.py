"""Residual-push incremental PageRank (DESIGN.md §9).

PageRank is the solution of the linear system

    pr = base + d · Op(pr),       Op(x) = Aᵀ D⁻¹ x  (+ sink term)

so a converged vector for the OLD graph is an excellent approximation
for the NEW one: define the residual

    r₀ = F_new(pr_old) − pr_old = d · (Op_new − Op_old)(pr_old)

and the exact new solution is  pr_old + Σ_k (d·Op_new)ᵏ r₀ .  Two
properties make this the right warm start (arXiv:2302.03245,
arXiv:2109.09527):

- **Sparse seed.**  (Op_new − Op_old) is non-zero only in the operator
  columns of sources whose out-edge set changed, so r₀ is computed
  host-side from the CSR rows of the touched sources — O(changed
  degree), never O(M).
- **Geometric push.**  ‖d·Op(r)‖₁ ≤ d‖r‖₁ (out-going mass is split,
  never amplified), so pushing the WHOLE residual each sweep — one
  SpMV on the residual vector, the dense analogue of forward-push —
  contracts ‖r‖₁ by ≥ d per sweep and the iteration count is
  log(tol/‖r₀‖₁)/log(d), independent of graph size.  After a 0.1%
  delta that is a handful of sweeps instead of a full power iteration.

Mass invariant: every sweep moves ‖r‖₁ of mass from the residual into
the ranks and re-emits at most d of it, so ``sum(pr) + sum(r)/(1-d)``
is conserved along the push — the DESIGN.md §9 conservation argument
and the bound behind ``tol``: stopping at ‖r‖₁ < tol leaves at most
tol·d/(1−d) L1 error in the final ranks.  That stopping rule is the
exact analogue of the fused driver's (its per-step L1 change IS the
pushed residual), so ``tol`` means the same thing warm and cold.

The push loop itself — ONE donated jitted ``lax.while_loop`` over the
plan's ``spmv_fn`` (same zero-host-transfer structure as the §4 fused
driver, cached per plan in the fused-loop cache) — lives in
``core/push.py`` since PR 7 made it a seedable shared home: the serve
path (serve/push.py) re-seeds it per personalized QUERY instead of per
delta.  This module keeps the delta-seeding half and re-exports the
loop for back-compat.  When the seed is too heavy — a delta so large
the geometric argument buys nothing — ``update_ranks`` falls back to
the §4 fused stepper itself, warm-started at ``prev_pr``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.pagerank import (PageRankResult, _inv_degree,
                             fused_power_iteration)
from ..core.plan import internal_graph, reorder_inverse, validate_plan
from ..core.push import (MAX_PUSH_BUF, PUSH_PAD,  # noqa: F401 re-export
                         _bucket, _pad_to, _pcpm_push,
                         _pcpm_push_streams, _push_while,
                         residual_push_loop)
from ..core.spmv import SpMVEngine
from ..graphs.formats import Graph
from .delta import GraphDelta, apply_delta, gather_ranges

# Seeds heavier than this (L1) go to the dense fused warm start: the
# push still converges, but at ~0.1 of total rank mass displaced the
# sweep count approaches a full power iteration's and the fused loop's
# tighter body wins.
DENSE_FALLBACK_L1 = 0.1


def seed_residual(g_old: Graph, g_new: Graph, delta: GraphDelta,
                  prev_pr: np.ndarray, *, damping: float = 0.85,
                  dangling: str = "none") -> np.ndarray:
    """r₀ = d·(Op_new − Op_old)(prev), computed sparsely.

    Only the operator columns of the delta's touched sources differ,
    and the new out-neighbour multiset of a touched u is
    ``N_old(u) − rem(u) + add(u)``, so with per-source weights
    ``w = d·prev[u]/deg[u]``:

        r₀ = Σ_{N_old(u)} (w_new − w_old)   over touched sources' CSR
           + w_new at every added edge's destination
           − w_new at every removed edge's destination

    which needs the OLD graph's CSR only — O(changed degree + |delta|)
    host work, no O(M) pass over the new graph.  (``delta`` may be a
    plain concatenation of several batches: a removal matching an
    earlier insertion cancels term-for-term.)  Accumulated f64,
    returned f32.
    """
    if dangling not in ("none", "redistribute"):
        raise ValueError(f"unknown dangling policy {dangling!r}")
    n = g_new.num_nodes
    prev = np.asarray(prev_pr, dtype=np.float64).reshape(n)
    r = np.zeros(n, dtype=np.float64)
    touched = np.asarray(delta.touched_sources(), dtype=np.int64)
    if touched.size == 0:
        return r.astype(np.float32)
    deg_old = g_old.out_degree[touched]
    deg_new = g_new.out_degree[touched]
    pv = damping * prev[touched]
    w_old = np.where(deg_old > 0, pv / np.maximum(deg_old, 1), 0.0)
    w_new = np.where(deg_new > 0, pv / np.maximum(deg_new, 1), 0.0)
    # over the old neighbour lists: weight change of surviving edges
    offs, idx = g_old.csr
    cnt = (offs[touched + 1] - offs[touched]).astype(np.int64)
    targets = idx[gather_ranges(offs[touched], cnt)]
    np.add.at(r, targets, np.repeat(w_new - w_old, cnt))
    # inserted / removed edges carry the NEW weight of their source
    # (touched is sorted-unique, so searchsorted is an exact lookup)
    if delta.num_added:
        pos = np.searchsorted(touched, delta.add_src)
        np.add.at(r, delta.add_dst, w_new[pos])
    if delta.num_removed:
        pos = np.searchsorted(touched, delta.rem_src)
        np.add.at(r, delta.rem_dst, -w_new[pos])
    if dangling == "redistribute":
        sink_shift = damping * (
            prev[touched[(deg_new == 0) & (deg_old > 0)]].sum()
            - prev[touched[(deg_old == 0) & (deg_new > 0)]].sum())
        if sink_shift != 0.0:
            r += sink_shift / n
    return r.astype(np.float32)


def update_ranks(plan, delta: GraphDelta, prev_pr, *,
                 g_old: Graph, g_new: Graph | None = None,
                 damping: float = 0.85, dangling: str = "none",
                 tol: float = 1e-8, max_push: int = 200,
                 dense_threshold: float = DENSE_FALLBACK_L1
                 ) -> PageRankResult:
    """Patch ``prev_pr`` (converged ranks of ``g_old``) into the ranks
    of ``g_new`` = ``g_old`` + ``delta``.

    ``plan`` must already be the NEW graph's plan (see
    ``stream.patch.patch_plan`` / ``Session.apply_delta``); ``delta``
    may be a concatenation of several batches relative to ``g_old``
    (``GraphDelta.__add__``).  ``tol`` is the L1 stopping residual —
    the same per-step L1-change rule the fused cold driver uses, so
    equal tolerances mean equal stopping accuracy warm and cold
    (final L1 distance to the fixed point ≤ tol·d/(1−d) either way).
    """
    if g_new is None:
        g_new = apply_delta(g_old, delta)
    validate_plan(g_new, plan)

    # one host fetch serves both the f64 seed accumulation and the
    # fresh (donatable) f32 device copy
    prev_host = np.asarray(prev_pr, dtype=np.float32)
    r0 = seed_residual(g_old, g_new, delta, prev_host,
                       damping=damping, dangling=dangling)
    r1 = float(np.abs(r0, dtype=np.float64).sum())
    # locality-reordered plans (core/plan.py): the plan's streams index
    # the RELABELED graph, so the push/fused loops iterate in internal
    # space.  The graphs and the residual seed stay original — only the
    # VECTORS permute in, and the ranks gather back once at the end.
    perm = plan.reorder_perm
    if perm is not None:
        inv = reorder_inverse(plan)
        prev_host, r0 = prev_host[inv], r0[inv]
        g_iter = internal_graph(g_new, plan)
    else:
        g_iter = g_new

    def _out(ranks):
        return (jnp.take(ranks, jnp.asarray(perm))
                if perm is not None else ranks)

    prev = jnp.asarray(prev_host)
    if r1 < tol:
        # already inside the stopping rule; still fold the first-order
        # correction in (free accuracy, one vector add)
        ranks = prev + jnp.asarray(r0) if r1 > 0.0 else prev
        return PageRankResult(_out(ranks), 0, [r1])

    if r1 > dense_threshold:
        # delta too heavy for the geometric-push argument — run the §4
        # fused driver, still warm-started at the previous ranks
        eng = SpMVEngine(g_new, plan=plan)
        run = fused_power_iteration(eng, damping=damping,
                                    num_iterations=max_push, tol=tol,
                                    check_every=1, dangling=dangling)
        n = g_new.num_nodes
        base = jnp.full((n,), (1.0 - damping) / n, dtype=jnp.float32)
        pr, it, res = run(prev, _inv_degree(g_iter), base)
        res_host = np.asarray(res)[:int(it)]
        return PageRankResult(_out(pr), int(it),
                              [float(x) for x in res_host if x >= 0.0])

    run = residual_push_loop(plan, damping=damping, dangling=dangling)
    pr, r_dev = prev, jnp.asarray(r0)
    inv_deg = _inv_degree(g_iter)
    sweeps, remaining, res_list = 0, max_push, []
    while True:
        # the device loop holds a MAX_PUSH_BUF residual ring; larger
        # budgets re-invoke it with the carried residual vector, so
        # max_push means exactly what num_iterations means cold
        pr, it, res, r_dev = run(pr, r_dev, inv_deg, tol,
                                 min(remaining, MAX_PUSH_BUF))
        it = int(it)
        sweeps += it
        remaining -= it
        res_list += [float(x) for x in np.asarray(res)[:it]
                     if x >= 0.0]
        final = float(jnp.abs(r_dev).sum())
        if final < tol or remaining <= 0 or it == 0:
            break
    # append the post-push norm so residuals[-1] reads like the cold
    # driver's: < tol iff converged (not merely budget-exhausted)
    return PageRankResult(_out(pr), sweeps, res_list + [final])
