"""Dynamic-graph subsystem (DESIGN.md §9): streaming edge deltas,
incremental plan patching, residual-push PageRank.

    from repro.stream import GraphDelta
    sess = repro.open(g)
    sess.pagerank()                                  # cold solve
    sess.apply_delta(GraphDelta.insert(new_edges))   # patch the plan
    sess.pagerank(warm=True)                         # residual push
"""
from .delta import DynamicGraph, GraphDelta, apply_delta
from .incremental import residual_push_loop, seed_residual, update_ranks
from .patch import patch_plan, patch_png

__all__ = [
    "DynamicGraph", "GraphDelta", "apply_delta",
    "seed_residual", "residual_push_loop", "update_ranks",
    "patch_plan", "patch_png",
]
