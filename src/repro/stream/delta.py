"""Streaming edge deltas (DESIGN.md §9).

A production graph gains and loses edges continuously; rebuilding the
whole ``GraphPlan`` and re-running full power iteration per batch would
throw away the paper's preprocess-once amortization exactly where it
matters most.  This module owns the *data model* of change:

- ``GraphDelta``: one batch of edge insertions and removals (COO
  arrays, multiset semantics — removing one copy of a multi-edge
  removes exactly one).  Immutable and composable.
- ``apply_delta``: pure edge-list update ``(Graph, delta) -> Graph``
  with loud failure on removing a non-existent edge.
- ``DynamicGraph``: a mutable handle over a stream of deltas.  It
  tracks which *destination partitions* the accumulated deltas touch —
  the unit of incremental plan patching (stream/patch.py): partitions
  are contiguous destination-ID ranges, every per-partition layout
  segment (PNG bins, gather runs, blocked rows) depends only on the
  edges landing in that partition, so a delta dirties exactly
  ``{dst // part_size}`` of its edges.  It also tracks the *touched
  sources* — the support of the residual seed (stream/incremental.py):
  the PageRank operator column of node u changes iff u's out-edge set
  changed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..graphs.formats import Graph


_EMPTY = np.empty(0, dtype=np.int32)


def _as_edges(edges) -> tuple[np.ndarray, np.ndarray]:
    e = np.asarray(edges)
    if e.size == 0:
        return _EMPTY, _EMPTY
    if e.dtype.kind not in "iu":
        raise ValueError(
            f"delta edges must be integer-typed; got dtype {e.dtype} "
            "(converting floats would silently truncate node ids)")
    if e.ndim != 2 or e.shape[1] != 2:
        raise ValueError(f"edges must be (m, 2) (src, dst) pairs; "
                         f"got shape {e.shape}")
    e = e.astype(np.int32, copy=False)
    return (np.ascontiguousarray(e[:, 0]), np.ascontiguousarray(e[:, 1]))


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """One batch of edge changes: ``add_*`` are inserted, ``rem_*``
    removed (one multi-edge copy per entry)."""
    add_src: np.ndarray = _EMPTY
    add_dst: np.ndarray = _EMPTY
    rem_src: np.ndarray = _EMPTY
    rem_dst: np.ndarray = _EMPTY

    # ------------------------------------------------------ constructors
    @staticmethod
    def insert(edges) -> "GraphDelta":
        src, dst = _as_edges(edges)
        return GraphDelta(add_src=src, add_dst=dst)

    @staticmethod
    def remove(edges) -> "GraphDelta":
        src, dst = _as_edges(edges)
        return GraphDelta(rem_src=src, rem_dst=dst)

    @staticmethod
    def of(add=None, remove=None) -> "GraphDelta":
        a_src, a_dst = _as_edges(add if add is not None else [])
        r_src, r_dst = _as_edges(remove if remove is not None else [])
        return GraphDelta(a_src, a_dst, r_src, r_dst)

    # ------------------------------------------------------------- views
    @property
    def num_added(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def num_removed(self) -> int:
        return int(self.rem_src.shape[0])

    @property
    def size(self) -> int:
        return self.num_added + self.num_removed

    @property
    def is_empty(self) -> bool:
        return self.size == 0

    def __add__(self, other: "GraphDelta") -> "GraphDelta":
        """Concatenate two batches.  The result describes the combined
        edge-multiset change relative to the ORIGINAL graph; the
        residual-seed algebra (stream/incremental.py) treats an
        insertion later removed as a term-for-term no-op, so no
        cancellation is needed there.  (Do not feed a concatenated
        batch back through ``apply_delta`` — its removals are matched
        against the base graph, which may not yet contain the first
        batch's insertions.)"""
        return GraphDelta(
            np.concatenate([self.add_src, other.add_src]),
            np.concatenate([self.add_dst, other.add_dst]),
            np.concatenate([self.rem_src, other.rem_src]),
            np.concatenate([self.rem_dst, other.rem_dst]))

    def touched_sources(self) -> np.ndarray:
        """Unique source ids whose out-edge set this delta changes —
        the support of the residual seed (their operator columns are
        the only ones that differ)."""
        return np.unique(np.concatenate([self.add_src, self.rem_src]))

    def dirty_partitions(self, part_size: int) -> np.ndarray:
        """Sorted unique destination partitions this delta touches —
        the only partitions whose plan segments need rebuilding."""
        dst = np.concatenate([self.add_dst, self.rem_dst])
        return np.unique(dst.astype(np.int64) // part_size)

    def validate(self, g: Graph) -> None:
        """Bounds-check endpoints against ``g`` (removal existence is
        checked edge-by-edge inside ``apply_delta``)."""
        for name, arr in (("add_src", self.add_src),
                          ("add_dst", self.add_dst),
                          ("rem_src", self.rem_src),
                          ("rem_dst", self.rem_dst)):
            if arr.size and (arr.min() < 0 or arr.max() >= g.num_nodes):
                raise ValueError(
                    f"delta {name} ids out of range [0, {g.num_nodes})")


def multiset_keep_mask(src: np.ndarray, dst: np.ndarray,
                       rem_src: np.ndarray, rem_dst: np.ndarray, *,
                       num_nodes: int) -> np.ndarray:
    """Boolean keep-mask over the ``(src, dst)`` edge arrays with one
    edge dropped per removal entry (multiset semantics).  Raises on a
    removal that has no remaining match.  Shared by whole-graph
    ``apply_delta`` and the per-dirty-partition patcher."""
    n = np.int64(num_nodes)
    keys = src.astype(np.int64) * n + dst
    order = np.argsort(keys, kind="stable")
    sorted_keys = keys[order]
    rem_keys, rem_counts = np.unique(
        rem_src.astype(np.int64) * n + rem_dst, return_counts=True)
    lo = np.searchsorted(sorted_keys, rem_keys, side="left")
    hi = np.searchsorted(sorted_keys, rem_keys, side="right")
    short = rem_counts > hi - lo
    if short.any():
        i = int(np.flatnonzero(short)[0])
        u, v = divmod(int(rem_keys[i]), int(n))
        raise ValueError(
            f"cannot remove edge ({u}, {v}) x{int(rem_counts[i])}: "
            f"only {int(hi[i] - lo[i])} present")
    # flat positions (in sorted order) of the removed copies: the first
    # ``count`` occurrences of each key
    flat = (np.repeat(lo, rem_counts)
            + _intra_group_arange(rem_counts))
    keep = np.ones(len(keys), dtype=bool)
    keep[order[flat]] = False
    return keep


def _intra_group_arange(counts: np.ndarray) -> np.ndarray:
    """[0..c0), [0..c1), ... as one flat array."""
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    starts = np.repeat(np.cumsum(counts) - counts, counts)
    return np.arange(total, dtype=np.int64) - starts


def gather_ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Flat indices [s0..s0+c0) ++ [s1..s1+c1) ++ ... — the vectorized
    slice-concatenation used throughout the patcher."""
    counts = np.asarray(counts, dtype=np.int64)
    return np.repeat(np.asarray(starts, dtype=np.int64),
                     counts) + _intra_group_arange(counts)


def apply_delta(g: Graph, delta: GraphDelta) -> Graph:
    """Pure edge-list update.  The result's edge order is kept
    partition-stable (survivors first, insertions appended) but plans
    never depend on it — every backend sorts, and the content
    fingerprint hashes the edge multiset.

    If ``g``'s plan fingerprint is already memoized, the new graph's
    is derived incrementally (the multiset hash is a commutative-
    invertible sum/xor pair — O(|delta|), core/plan.py) so a delta
    stream never re-hashes the full edge list."""
    delta.validate(g)
    if delta.num_removed:
        keep = multiset_keep_mask(g.src, g.dst, delta.rem_src,
                                  delta.rem_dst, num_nodes=g.num_nodes)
        src, dst = g.src[keep], g.dst[keep]
    else:
        src, dst = g.src, g.dst
    if delta.num_added:
        src = np.concatenate([src, delta.add_src])
        dst = np.concatenate([dst, delta.add_dst])
    g_new = Graph(g.num_nodes, np.ascontiguousarray(src),
                  np.ascontiguousarray(dst))
    parts = g.__dict__.get("_fp_parts")
    if parts is not None:
        from ..core.plan import _edge_hash64
        u64 = np.uint64
        h_add = _edge_hash64(delta.add_src, delta.add_dst)
        h_rem = _edge_hash64(delta.rem_src, delta.rem_dst)
        s = (parts[0] + int(h_add.sum(dtype=u64))
             - int(h_rem.sum(dtype=u64))) % (1 << 64)
        x = (parts[1]
             ^ int(np.bitwise_xor.reduce(h_add, initial=u64(0)))
             ^ int(np.bitwise_xor.reduce(h_rem, initial=u64(0))))
        g_new.__dict__["_fp_parts"] = (s, x)
    return g_new


def shifted_fingerprint(fp: str, delta: GraphDelta) -> str:
    """The content fingerprint of ``g + delta`` derived from ``g``'s
    fingerprint alone — O(|delta|), via the commutative sum/xor hash
    (core/plan.py).  ``patch_plan`` uses it to REQUIRE that a
    caller-supplied ``g_new`` really equals ``g_old + delta`` before
    stamping spliced arrays with ``g_new``'s fingerprint."""
    from ..core.plan import _edge_hash64, _fp_string
    n_hex, m_hex, digest = fp.split(".")
    h_add = _edge_hash64(delta.add_src, delta.add_dst)
    h_rem = _edge_hash64(delta.rem_src, delta.rem_dst)
    u64 = np.uint64
    s = (int(digest[:16], 16) + int(h_add.sum(dtype=u64))
         - int(h_rem.sum(dtype=u64))) % (1 << 64)
    x = (int(digest[16:], 16)
         ^ int(np.bitwise_xor.reduce(h_add, initial=u64(0)))
         ^ int(np.bitwise_xor.reduce(h_rem, initial=u64(0))))
    m_new = int(m_hex, 16) + delta.num_added - delta.num_removed
    return _fp_string(int(n_hex, 16), m_new, (s, x))


class DynamicGraph:
    """Mutable handle over a stream of deltas.

    ``apply`` advances the current graph; the handle accumulates which
    partitions are dirty and which sources are touched SINCE THE LAST
    ``mark_clean()`` — the consumer (Session warm state, patch
    batching) decides when accumulated changes have been folded into a
    plan / rank vector and resets the dirty sets.
    """

    def __init__(self, g: Graph):
        self.graph = g
        self.version = 0
        self._base_graph = g
        self._touched: list[np.ndarray] = []
        self._dirty_dst: list[np.ndarray] = []

    @property
    def base_graph(self) -> Graph:
        """The graph as of the last ``mark_clean`` (construction if
        never cleaned) — what accumulated dirtiness is relative to."""
        return self._base_graph

    def apply(self, delta: GraphDelta) -> Graph:
        self.graph = apply_delta(self.graph, delta)
        self.version += 1
        self._touched.append(np.concatenate([delta.add_src,
                                             delta.rem_src]))
        self._dirty_dst.append(np.concatenate([delta.add_dst,
                                               delta.rem_dst]))
        return self.graph

    def touched_sources(self) -> np.ndarray:
        return np.unique(np.concatenate(self._touched or [_EMPTY]))

    def dirty_partitions(self, part_size: int) -> np.ndarray:
        dst = np.concatenate(self._dirty_dst or [_EMPTY])
        return np.unique(dst.astype(np.int64) // part_size)

    def dirty_fraction(self, part_size: int, num_partitions: int) -> float:
        return len(self.dirty_partitions(part_size)) / max(
            num_partitions, 1)

    def mark_clean(self) -> None:
        """Accumulated changes have been folded (plan patched, ranks
        updated) — restart dirtiness tracking from the current graph."""
        self._base_graph = self.graph
        self._touched.clear()
        self._dirty_dst.clear()
