"""The front door: ``repro.open(g, EngineConfig(...))`` (DESIGN.md §8).

One ``EngineConfig`` unifies the method / part_size / num_shards /
damping / tol / iters / dangling / slots knobs that used to be
duplicated across four constructors (``SpMVEngine``, ``pagerank()``,
``PageRankServer``, ``SlotScheduler``).  A ``Session`` resolves the
graph's ``GraphPlan`` ONCE through the process-level plan cache and
serves every workload from it:

    sess = repro.open(g, repro.EngineConfig(method="pcpm"))
    res  = sess.pagerank()                  # fused while_loop driver
    y    = sess.spmv(x)                     # one A^T x pass
    sch  = sess.serve()                     # continuous-batching pool
    srv  = sess.server(batch=8)             # AOT lockstep batch server
    sess.plan.save("web.plan.npz")          # persist the preprocessing

Dynamic graphs (DESIGN.md §9): a session is a live handle, not a
snapshot —

    sess.apply_delta(GraphDelta.insert(edges))   # incremental plan patch
    res = sess.pagerank(warm=True)               # residual-push update

``apply_delta`` patches the plan for the delta's dirty partitions only
(stream/patch.py) and ``warm=True`` pushes the residual seeded at the
changed edges' endpoints instead of re-running full power iteration
(stream/incremental.py).

The old entry points keep working as thin shims over the same plan
cache and backend registry, so both paths stay test-covered.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax.numpy as jnp

from .core.pagerank import PageRankResult, pagerank
from .core.plan import (DEFAULT_GATHER_BLOCK, GraphPlan, PlanConfig,
                        build_plan)
from .core.spmv import SpMVEngine
from .graphs.formats import Graph


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Every knob of the plan AND run layers in one hashable value.

    Plan-layer fields (select the ``GraphPlan``): ``method``,
    ``part_size``, ``num_shards``, ``gather_block``.
    Run-layer fields are the iteration/serving defaults a ``Session``
    applies; each method accepts per-call overrides.
    """
    # plan layer
    method: str = "pcpm"
    part_size: int = 65536
    num_shards: Optional[int] = None      # sharding backends; None = all
    gather_block: int = DEFAULT_GATHER_BLOCK
    two_phase: bool = False               # rejected by Session (fused)
    # locality-enhancing node relabeling (paper §VI-D1): "none",
    # "degree", "bfs" or "hybrid" — the plan's layouts are built on the
    # relabeled graph; every Session/serve result is mapped back to the
    # original ids transparently
    reorder: str = "none"
    # run layer: iteration
    damping: float = 0.85
    num_iterations: int = 20
    tol: float = 0.0
    check_every: int = 1
    dangling: str = "none"
    # run layer: serving
    slots: int = 4
    chunk: int = 8
    # observability (DESIGN.md §14): OFF by default — when True the
    # session owns an ``obs.Observability`` bundle (span tracer +
    # flight recorder + metrics registry + comm accountant) and every
    # workload it fans out reports through it
    observe: bool = False

    def plan_config(self) -> PlanConfig:
        return PlanConfig(method=self.method, part_size=self.part_size,
                          num_shards=self.num_shards,
                          gather_block=self.gather_block,
                          reorder=self.reorder)

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


class Session:
    """One graph, one plan, every workload.

    Construction resolves (or builds, exactly once per process) the
    ``GraphPlan`` for ``(g, config)``; ``pagerank``/``spmv``/``serve``/
    ``server`` all run from that single plan — the build count stays 1
    no matter how many workloads the session fans out (asserted in
    tests/test_api.py).
    """

    def __init__(self, g: Graph, config: EngineConfig | None = None,
                 *, idmap=None, **overrides):
        cfg = config or EngineConfig()
        if overrides:
            cfg = cfg.replace(**overrides)
        if cfg.two_phase:
            raise ValueError(
                "two_phase=True cannot be combined with the Session's "
                "fused consumers (pagerank/serve run under jit, where "
                "the host-side phase barrier does not exist); build a "
                "two-phase SpMVEngine directly for phase timing.")
        self.graph = g
        self.config = cfg
        # external-id mapping for ingested real graphs (ingest/
        # idmap.py) — threaded through to serve results and
        # ``top_ranked``; None for synthetic dense-id graphs
        self.idmap = idmap
        # observability bundle (DESIGN.md §14) — None until
        # ``observe()`` is called or ``cfg.observe`` asks for it
        self._obs = None
        if cfg.observe:
            self.observe()
        # build_plan validates the graph at entry (crisp ValueError on
        # out-of-range ids / bad dtypes, DESIGN.md §10)
        self.plan: GraphPlan = build_plan(g, cfg.plan_config())
        self.engine = SpMVEngine(g, plan=self.plan)
        # warm-start state (DESIGN.md §9): the graph and ranks of the
        # last solve, the L1 step-residual it achieved, and the
        # concatenated deltas applied since
        self._solved_graph = None
        self._solved_ranks = None
        self._solved_key = None            # (damping, dangling)
        self._solved_res = np.inf
        self._delta_acc = None

    # --------------------------------------------------- observability
    def observe(self, *, capacity: int = 8192, dump_dir=None):
        """Attach (or return) this session's ``Observability`` bundle
        (DESIGN.md §14).  Idempotent: the first call creates the
        bundle — span tracer over a bounded flight recorder, typed
        metrics registry, and the measured-comm accountant — and every
        later call returns the same one.  Handles created AFTER the
        bundle exists (``serve()``/``gateway()``) report through it;
        ``pagerank``/``apply_delta`` on this session do too."""
        if self._obs is None:
            from .obs import Observability
            self._obs = Observability(capacity=capacity,
                                      dump_dir=dump_dir)
        return self._obs

    @property
    def obs(self):
        """The session's ``Observability`` bundle, or None when
        observation was never requested."""
        return self._obs

    def stats(self) -> dict:
        """One dict joining every cache/observability surface the
        session can see: process-level plan-cache counters, and — when
        observing — the metrics registry, comm summary and flight-
        recorder occupancy."""
        from .core.plan import plan_cache_stats
        out = {"plan_cache": dataclasses.asdict(plan_cache_stats()),
               "method": self.config.method,
               "n": self.plan.num_nodes, "m": self.plan.num_edges}
        if self._obs is not None:
            out["obs"] = self._obs.stats()
        return out

    # ---------------------------------------------------------- deltas
    def apply_delta(self, delta) -> "Session":
        """Advance the session's graph by one edge-delta batch: the
        plan is patched incrementally (dirty partitions only, full
        rebuild past the dirtiness threshold — stream/patch.py) and
        the engine rebound to it.  Accumulates warm-start state so a
        following ``pagerank(warm=True)`` costs a residual push, not a
        full power iteration.  Serving handles created before the
        delta keep running on the old plan; call their
        ``apply_delta``/construct new ones for the updated graph."""
        from .stream.delta import apply_delta as apply_edges
        from .stream.patch import patch_plan
        sp = (self._obs.tracer.start("session_delta", trace="plan",
                                     adds=len(delta.add_src),
                                     removes=len(delta.rem_src))
              if self._obs is not None else None)
        try:
            g_new = apply_edges(self.graph, delta)
            self.plan = patch_plan(self.plan, delta, g_new)
        except Exception as e:
            if sp is not None:
                sp.end(status="error", error=repr(e))
            raise
        self.graph = g_new
        self.engine = SpMVEngine(g_new, plan=self.plan)
        if sp is not None:
            sp.end(n=g_new.num_nodes, m=int(g_new.src.shape[0]))
        if self._solved_graph is not None:
            self._delta_acc = (delta if self._delta_acc is None
                               else self._delta_acc + delta)
        return self

    # ------------------------------------------------------------- run
    def spmv(self, x) -> jnp.ndarray:
        """One y = A^T x pass ((n,) or (n, d)) on the plan's backend."""
        return self.engine(jnp.asarray(x))

    def pagerank(self, *, warm: bool = False,
                 **overrides) -> PageRankResult:
        """Run the fused power iteration with the session defaults;
        keyword overrides (num_iterations/tol/damping/check_every/
        dangling/driver) apply per call.

        ``warm=True`` after ``apply_delta`` patches the PREVIOUS
        result through the residual-push driver (seeded only at the
        changed edges' endpoints) instead of iterating from scratch.
        The sparse seed is only exact when the stored ranks are a
        converged fixed point of the old graph, so the warm path runs
        iff the previous solve achieved an L1 step-residual <= this
        call's ``tol`` (and damping/dangling match); otherwise it
        falls back to a cold run rather than silently under-deliver
        accuracy.  ``tol`` and ``num_iterations`` mean exactly what
        they mean cold: same stopping rule, ``num_iterations`` bounds
        the push sweeps.  Either way the result is stored as the next
        warm-start point."""
        cfg = self.config
        kw = dict(num_iterations=cfg.num_iterations, damping=cfg.damping,
                  tol=cfg.tol, check_every=cfg.check_every,
                  dangling=cfg.dangling)
        kw.update(overrides)
        key = (kw["damping"], kw["dangling"])
        tol, budget = kw["tol"], kw["num_iterations"]
        # reordered plans warm-start too: update_ranks composes the
        # stored original-space ranks through ``reorder_perm`` into the
        # plan's internal space and gathers the result back, so only
        # the labeling differs — the honest fallback below remains for
        # unconverged/mismatched state, never for reordering alone
        warm_hit = (warm and self._solved_ranks is not None
                    and self._solved_key == key
                    and 0.0 < tol and self._solved_res <= tol)
        sp = (self._obs.tracer.start(
                  "solve", trace="plan", method=self.config.method,
                  warm=bool(warm_hit), n=self.plan.num_nodes)
              if self._obs is not None else None)
        try:
            if warm_hit:
                from .stream.delta import GraphDelta
                from .stream.incremental import update_ranks
                res = update_ranks(
                    self.plan, self._delta_acc or GraphDelta.of(),
                    self._solved_ranks, g_old=self._solved_graph,
                    g_new=self.graph, damping=kw["damping"],
                    dangling=kw["dangling"], tol=tol, max_push=budget)
            else:
                res = pagerank(self.graph, engine=self.engine, **kw)
        except Exception as e:
            if sp is not None:
                sp.end(status="error", error=repr(e))
            raise
        if self._obs is not None:
            if not warm_hit:
                # measured comm: one full gather/scatter pass per
                # executed power iteration (warm pushes are sparse and
                # don't stream the whole edge structure)
                self._obs.comm.record_solve(self.plan, res.iterations)
            sp.end(iterations=res.iterations,
                   residual=float((res.residuals or [np.inf])[-1]))
        achieved = (res.residuals or [np.inf])[-1]
        self._solved_graph = self.graph
        self._solved_ranks = res.ranks
        self._solved_key = key
        self._solved_res = float(achieved)
        self._delta_acc = None
        return res

    def top_ranked(self, k: int = 10):
        """``(ids, scores)`` of the ``k`` highest-ranked nodes from the
        last ``pagerank()`` solve; ids are the graph's EXTERNAL labels
        when the session carries a ``NodeIdMapping`` (ingested real
        graphs), original dense ids otherwise."""
        if self._solved_ranks is None:
            raise ValueError("no solve yet: run pagerank() first")
        ranks = np.asarray(self._solved_ranks)
        k = min(int(k), ranks.shape[0])
        part = np.argpartition(-ranks, k - 1)[:k]
        ids = part[np.lexsort((part, -ranks[part]))]   # score desc, id asc
        scores = ranks[ids]
        if self.idmap is not None:
            return self.idmap.to_external(ids), scores
        return ids.astype(np.int64), scores

    # ----------------------------------------------------- checkpoints
    def save_checkpoint(self, path: str) -> None:
        """Persist the last solve as a fingerprint-stamped rank
        checkpoint (reliability/snapshot.py) — what a restarted
        process hands to ``load_checkpoint`` to warm-start instead of
        recomputing.  Requires a prior ``pagerank()`` on this
        session."""
        if self._solved_ranks is None:
            raise ValueError("nothing to checkpoint: run pagerank() "
                             "first")
        from .reliability.snapshot import save_rank_checkpoint
        save_rank_checkpoint(
            path, self._solved_graph, np.asarray(self._solved_ranks),
            residual=self._solved_res, damping=self._solved_key[0],
            dangling=self._solved_key[1])

    def load_checkpoint(self, path: str, *, g_old: Graph | None = None,
                        delta=None) -> "Session":
        """Warm-start this session from a rank checkpoint.

        - Checkpoint fingerprint == this session's graph: the ranks
          become the warm state directly — the next
          ``pagerank(warm=True)`` is (near-)free.
        - Checkpoint taken on ``g_old`` with ``delta`` applied since
          (the restart-across-a-delta-chain case): pass both.  The
          lineage is PROVEN by fingerprints — ``g_old`` must hash to
          the checkpoint's fingerprint and ``g_old + delta`` to this
          session's graph — then ``pagerank(warm=True)`` routes
          through the residual-push updater (stream/incremental.py)
          instead of a cold solve.
        - Anything else: crisp ``ValueError``; a checkpoint for the
          wrong graph must never silently seed answers."""
        from .core.plan import graph_fingerprint
        from .reliability.snapshot import load_rank_checkpoint
        ckpt = load_rank_checkpoint(path)
        fp_here = graph_fingerprint(self.graph)
        if ckpt.graph_fp == fp_here:
            self._solved_graph = self.graph
            self._delta_acc = None
        elif g_old is not None and delta is not None:
            from .stream.delta import shifted_fingerprint
            if graph_fingerprint(g_old) != ckpt.graph_fp:
                raise ValueError(
                    "checkpoint mismatch: g_old does not hash to the "
                    "checkpoint's graph fingerprint "
                    f"({ckpt.graph_fp[:12]}…)")
            if shifted_fingerprint(ckpt.graph_fp, delta) != fp_here:
                raise ValueError(
                    "checkpoint mismatch: g_old + delta is not this "
                    "session's graph (shifted fingerprint differs) — "
                    "the delta chain does not connect the checkpoint "
                    "to the current graph")
            self._solved_graph = g_old
            self._delta_acc = delta
        else:
            raise ValueError(
                "checkpoint is for a different graph (fingerprint "
                f"{ckpt.graph_fp[:12]}… != {fp_here[:12]}…); pass "
                "g_old= and delta= to warm-start across a delta chain")
        self._solved_ranks = jnp.asarray(ckpt.ranks)
        self._solved_key = (ckpt.damping, ckpt.dangling)
        self._solved_res = float(ckpt.residual)
        return self

    def serve(self, *, route: str = "auto", **overrides):
        """A continuous-batching ``SlotScheduler`` sharing this
        session's plan (and compiled device streams).  ``route``
        picks the personalized-query path (DESIGN.md §11):
        ``"auto"`` sends loose-tolerance top-k queries through the
        forward-push backend and the rest to the masked stepper,
        ``"push"``/``"stepper"`` force one side for every query."""
        from .serve.scheduler import SlotScheduler
        cfg = self.config
        kw = dict(slots=cfg.slots, damping=cfg.damping, chunk=cfg.chunk,
                  dangling=cfg.dangling, route=route, idmap=self.idmap,
                  obs=self._obs)
        kw.update(overrides)
        return SlotScheduler(self.graph, engine=self.engine, **kw)

    def gateway(self, *, config=None, autotune: bool = True,
                **overrides):
        """An async serving front door over this session's plan
        (DESIGN.md §13): a dedicated device thread steps the slot
        pool, a worker pool answers push-eligible queries inline, and
        ``submit()`` returns a future immediately with a warm-result
        LRU serving repeats in O(k).

        ``autotune=True`` (default) probes the engine's measured
        multi-vector SpMV cost and sizes the slot pool against
        ``config.target_chunk_s`` instead of the session's static
        ``slots``; an explicit ``slots=`` override always wins.  The
        chosen size and the probe curve are attached as
        ``gateway.autotune_report``."""
        from .gateway import Gateway, GatewayConfig, autotune_slots
        cfg = config or GatewayConfig()
        report = None
        if autotune and "slots" not in overrides:
            report = autotune_slots(
                self.engine, chunk=overrides.get("chunk",
                                                 self.config.chunk),
                target_chunk_s=cfg.target_chunk_s,
                candidates=cfg.autotune_candidates,
                default=self.config.slots)
            overrides["slots"] = report.chosen
        sch = self.serve(**overrides)
        gw = Gateway(sch, config=cfg)
        gw.autotune_report = report
        return gw

    def server(self, *, batch: int = 1, **overrides):
        """An AOT-compiled lockstep ``PageRankServer`` sharing this
        session's plan (batched personalized queries)."""
        from .serve.engine import PageRankServer
        cfg = self.config
        kw = dict(damping=cfg.damping, num_iterations=cfg.num_iterations,
                  tol=cfg.tol, check_every=cfg.check_every,
                  dangling=cfg.dangling)
        kw.update(overrides)
        return PageRankServer(self.graph, engine=self.engine,
                              batch=batch, **kw)


def open(g: Graph, config: EngineConfig | None = None, *,
         idmap=None, **overrides) -> Session:
    """Open a :class:`Session` on ``g`` — the public front door.
    ``overrides`` are ``EngineConfig`` fields applied on top of
    ``config`` (or the defaults): ``repro.open(g, method="pdpr")``.
    ``idmap`` attaches a ``NodeIdMapping`` (ingest/idmap.py) so serve
    and ``top_ranked`` results carry the graph's external ids."""
    return Session(g, config, idmap=idmap, **overrides)
