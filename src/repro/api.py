"""The front door: ``repro.open(g, EngineConfig(...))`` (DESIGN.md §8).

One ``EngineConfig`` unifies the method / part_size / num_shards /
damping / tol / iters / dangling / slots knobs that used to be
duplicated across four constructors (``SpMVEngine``, ``pagerank()``,
``PageRankServer``, ``SlotScheduler``).  A ``Session`` resolves the
graph's ``GraphPlan`` ONCE through the process-level plan cache and
serves every workload from it:

    sess = repro.open(g, repro.EngineConfig(method="pcpm"))
    res  = sess.pagerank()                  # fused while_loop driver
    y    = sess.spmv(x)                     # one A^T x pass
    sch  = sess.serve()                     # continuous-batching pool
    srv  = sess.server(batch=8)             # AOT lockstep batch server
    sess.plan.save("web.plan.npz")          # persist the preprocessing

The old entry points keep working as thin shims over the same plan
cache and backend registry, so both paths stay test-covered.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from .core.pagerank import PageRankResult, pagerank
from .core.plan import (DEFAULT_GATHER_BLOCK, GraphPlan, PlanConfig,
                        build_plan)
from .core.spmv import SpMVEngine
from .graphs.formats import Graph


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Every knob of the plan AND run layers in one hashable value.

    Plan-layer fields (select the ``GraphPlan``): ``method``,
    ``part_size``, ``num_shards``, ``gather_block``.
    Run-layer fields are the iteration/serving defaults a ``Session``
    applies; each method accepts per-call overrides.
    """
    # plan layer
    method: str = "pcpm"
    part_size: int = 65536
    num_shards: Optional[int] = None      # sharding backends; None = all
    gather_block: int = DEFAULT_GATHER_BLOCK
    two_phase: bool = False               # rejected by Session (fused)
    # run layer: iteration
    damping: float = 0.85
    num_iterations: int = 20
    tol: float = 0.0
    check_every: int = 1
    dangling: str = "none"
    # run layer: serving
    slots: int = 4
    chunk: int = 8

    def plan_config(self) -> PlanConfig:
        return PlanConfig(method=self.method, part_size=self.part_size,
                          num_shards=self.num_shards,
                          gather_block=self.gather_block)

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


class Session:
    """One graph, one plan, every workload.

    Construction resolves (or builds, exactly once per process) the
    ``GraphPlan`` for ``(g, config)``; ``pagerank``/``spmv``/``serve``/
    ``server`` all run from that single plan — the build count stays 1
    no matter how many workloads the session fans out (asserted in
    tests/test_api.py).
    """

    def __init__(self, g: Graph, config: EngineConfig | None = None,
                 **overrides):
        cfg = config or EngineConfig()
        if overrides:
            cfg = cfg.replace(**overrides)
        if cfg.two_phase:
            raise ValueError(
                "two_phase=True cannot be combined with the Session's "
                "fused consumers (pagerank/serve run under jit, where "
                "the host-side phase barrier does not exist); build a "
                "two-phase SpMVEngine directly for phase timing.")
        self.graph = g
        self.config = cfg
        self.plan: GraphPlan = build_plan(g, cfg.plan_config())
        self.engine = SpMVEngine(g, plan=self.plan)

    # ------------------------------------------------------------- run
    def spmv(self, x) -> jnp.ndarray:
        """One y = A^T x pass ((n,) or (n, d)) on the plan's backend."""
        return self.engine(jnp.asarray(x))

    def pagerank(self, **overrides) -> PageRankResult:
        """Run the fused power iteration with the session defaults;
        keyword overrides (num_iterations/tol/damping/check_every/
        dangling/driver) apply per call."""
        cfg = self.config
        kw = dict(num_iterations=cfg.num_iterations, damping=cfg.damping,
                  tol=cfg.tol, check_every=cfg.check_every,
                  dangling=cfg.dangling)
        kw.update(overrides)
        return pagerank(self.graph, engine=self.engine, **kw)

    def serve(self, **overrides):
        """A continuous-batching ``SlotScheduler`` sharing this
        session's plan (and compiled device streams)."""
        from .serve.scheduler import SlotScheduler
        cfg = self.config
        kw = dict(slots=cfg.slots, damping=cfg.damping, chunk=cfg.chunk,
                  dangling=cfg.dangling)
        kw.update(overrides)
        return SlotScheduler(self.graph, engine=self.engine, **kw)

    def server(self, *, batch: int = 1, **overrides):
        """An AOT-compiled lockstep ``PageRankServer`` sharing this
        session's plan (batched personalized queries)."""
        from .serve.engine import PageRankServer
        cfg = self.config
        kw = dict(damping=cfg.damping, num_iterations=cfg.num_iterations,
                  tol=cfg.tol, check_every=cfg.check_every,
                  dangling=cfg.dangling)
        kw.update(overrides)
        return PageRankServer(self.graph, engine=self.engine,
                              batch=batch, **kw)


def open(g: Graph, config: EngineConfig | None = None,
         **overrides) -> Session:
    """Open a :class:`Session` on ``g`` — the public front door.
    ``overrides`` are ``EngineConfig`` fields applied on top of
    ``config`` (or the defaults): ``repro.open(g, method="pdpr")``."""
    return Session(g, config, **overrides)
