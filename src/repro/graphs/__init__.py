from .formats import Graph, from_edge_list
from . import generators, reorder, sampler, io

__all__ = ["Graph", "from_edge_list", "generators", "reorder", "sampler",
           "io"]
