"""Synthetic graph generators.

``rmat`` mirrors the Graph500 Kronecker generator used for the paper's
*kron* dataset (scale 25, edge factor ~31).  All generators are
deterministic given ``seed``.
"""
from __future__ import annotations

import numpy as np

from .formats import Graph, from_edge_list


def rmat(scale: int, edge_factor: int = 16, *, a: float = 0.57,
         b: float = 0.19, c: float = 0.19, seed: int = 0,
         dedup: bool = False) -> Graph:
    """R-MAT / Graph500 Kronecker graph: 2**scale nodes."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        # quadrant choice per Graph500 reference
        go_right = r >= ab            # column bit set
        go_down = ((r >= a) & (r < ab)) | (r >= abc)  # row bit set
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    # permute vertex labels so degree is not correlated with ID
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    if dedup:
        key = src * n + dst
        _, idx = np.unique(key, return_index=True)
        src, dst = src[idx], dst[idx]
    return Graph(n, src.astype(np.int32), dst.astype(np.int32))


def uniform_random(num_nodes: int, num_edges: int, *, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_nodes, num_edges, dtype=np.int64)
    dst = rng.integers(0, num_nodes, num_edges, dtype=np.int64)
    return Graph(num_nodes, src.astype(np.int32), dst.astype(np.int32))


def power_law(num_nodes: int, avg_degree: int, *, exponent: float = 2.1,
              seed: int = 0) -> Graph:
    """Chung-Lu style power-law graph (degree ~ pareto)."""
    rng = np.random.default_rng(seed)
    w = rng.pareto(exponent - 1.0, num_nodes) + 1.0
    p = w / w.sum()
    m = num_nodes * avg_degree
    src = rng.choice(num_nodes, size=m, p=p).astype(np.int32)
    dst = rng.choice(num_nodes, size=m, p=p).astype(np.int32)
    return Graph(num_nodes, src, dst)


def grid_2d(rows: int, cols: int) -> Graph:
    """4-neighbor grid, both directions (high locality — the paper's
    *web*-like regime when labeled row-major)."""
    idx = np.arange(rows * cols, dtype=np.int32).reshape(rows, cols)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:, 1:].ravel(), idx[:, :-1].ravel()], 1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    e.append(np.stack([idx[1:, :].ravel(), idx[:-1, :].ravel()], 1))
    return from_edge_list(rows * cols, np.concatenate(e, 0))


# --------------------------------------------------------------------------
# Icosahedral multimesh (GraphCast substrate)
# --------------------------------------------------------------------------
def icosahedron() -> tuple[np.ndarray, np.ndarray]:
    phi = (1.0 + np.sqrt(5.0)) / 2.0
    v = np.array([[-1, phi, 0], [1, phi, 0], [-1, -phi, 0], [1, -phi, 0],
                  [0, -1, phi], [0, 1, phi], [0, -1, -phi], [0, 1, -phi],
                  [phi, 0, -1], [phi, 0, 1], [-phi, 0, -1], [-phi, 0, 1]],
                 dtype=np.float64)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    f = np.array([[0, 11, 5], [0, 5, 1], [0, 1, 7], [0, 7, 10], [0, 10, 11],
                  [1, 5, 9], [5, 11, 4], [11, 10, 2], [10, 7, 6], [7, 1, 8],
                  [3, 9, 4], [3, 4, 2], [3, 2, 6], [3, 6, 8], [3, 8, 9],
                  [4, 9, 5], [2, 4, 11], [6, 2, 10], [8, 6, 7], [9, 8, 1]],
                 dtype=np.int64)
    return v, f


def _subdivide(verts: np.ndarray, faces: np.ndarray):
    """One loop-subdivision step on a triangle mesh over the unit sphere."""
    edges = np.concatenate([faces[:, [0, 1]], faces[:, [1, 2]],
                            faces[:, [2, 0]]], 0)
    edges = np.sort(edges, axis=1)
    uniq, inv = np.unique(edges, axis=0, return_inverse=True)
    mid = verts[uniq[:, 0]] + verts[uniq[:, 1]]
    mid /= np.linalg.norm(mid, axis=1, keepdims=True)
    mid_id = len(verts) + np.arange(len(uniq))
    new_verts = np.concatenate([verts, mid], 0)
    nf = len(faces)
    m01 = mid_id[inv[:nf]]
    m12 = mid_id[inv[nf:2 * nf]]
    m20 = mid_id[inv[2 * nf:]]
    a, b, c = faces[:, 0], faces[:, 1], faces[:, 2]
    new_faces = np.concatenate([
        np.stack([a, m01, m20], 1), np.stack([b, m12, m01], 1),
        np.stack([c, m20, m12], 1), np.stack([m01, m12, m20], 1)], 0)
    return new_verts, new_faces


def icosahedral_multimesh(refine: int = 6) -> tuple[np.ndarray, Graph]:
    """GraphCast multimesh: union of edges from all refinement levels.

    Returns (vertex positions on unit sphere, bidirectional edge Graph).
    refine=6 gives 40962 nodes (10*4^6 + 2).
    """
    verts, faces = icosahedron()
    all_edges = []
    for _ in range(refine + 1):
        e = np.concatenate([faces[:, [0, 1]], faces[:, [1, 2]],
                            faces[:, [2, 0]]], 0)
        all_edges.append(np.sort(e, axis=1))
        verts, faces = _subdivide(verts, faces)
    # verts/faces after loop are one level past `refine`; rebuild verts
    # by re-running to the requested level is wasteful — instead note the
    # vertex array only grows, and level-L edges only reference the first
    # 10*4^L+2 vertices.  Use vertices up to the finest requested level.
    n = 10 * 4 ** refine + 2
    edges = np.unique(np.concatenate(all_edges, 0), axis=0)
    edges = np.concatenate([edges, edges[:, ::-1]], 0)
    g = from_edge_list(n, edges)
    return verts[:n], g


def batched_molecules(n_mols: int, atoms_per_mol: int, edges_per_mol: int,
                      *, seed: int = 0) -> tuple[Graph, np.ndarray]:
    """Disjoint union of small random molecular graphs.

    Returns (graph, mol_id per node) — the `molecule` shape regime.
    """
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for i in range(n_mols):
        base = i * atoms_per_mol
        s = rng.integers(0, atoms_per_mol, edges_per_mol)
        d = (s + 1 + rng.integers(0, atoms_per_mol - 1,
                                  edges_per_mol)) % atoms_per_mol
        srcs.append(base + s)
        dsts.append(base + d)
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    mol_id = np.repeat(np.arange(n_mols, dtype=np.int32), atoms_per_mol)
    return Graph(n_mols * atoms_per_mol, src, dst), mol_id
