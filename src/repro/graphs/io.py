"""Graph + plan persistence (npz).

``save``/``load`` persist the raw edge set; ``load_plan`` reads back a
preprocessing artifact persisted with ``GraphPlan.save`` (core/plan.py)
so a server process warm-loads both the graph AND its sorted layouts —
million-node plans come back as one ``.npz`` read instead of an edge
re-sort (the paper's preprocess-once amortization, §VI-D3).
"""
from __future__ import annotations

import numpy as np

from .formats import Graph


def save(path: str, g: Graph) -> None:
    np.savez_compressed(path, num_nodes=g.num_nodes, src=g.src, dst=g.dst)


def load(path: str) -> Graph:
    z = np.load(path)
    return Graph(int(z["num_nodes"]), z["src"], z["dst"])


def load_plan(path: str):
    """Load a persisted ``GraphPlan``; pair with
    ``core.plan.install_plan`` to seed the process plan cache."""
    from ..core.plan import GraphPlan
    return GraphPlan.load(path)


def nbytes(path: str) -> int:
    """UNCOMPRESSED in-memory footprint of a persisted graph or plan
    npz — summed from the zip members' declared sizes WITHOUT loading
    any array.  What a registry operator uses to capacity-plan a
    ``GraphRegistry(memory_budget_bytes=...)`` before warm-loading:
    the budget accounts resident plan bytes (``core.plan.plan_nbytes``),
    and this is the same number read off disk."""
    import zipfile
    with zipfile.ZipFile(path) as zf:
        return sum(info.file_size for info in zf.infolist()
                   if not info.filename.startswith("__meta__"))
