"""Graph persistence (npz)."""
from __future__ import annotations

import numpy as np

from .formats import Graph


def save(path: str, g: Graph) -> None:
    np.savez_compressed(path, num_nodes=g.num_nodes, src=g.src, dst=g.dst)


def load(path: str) -> Graph:
    z = np.load(path)
    return Graph(int(z["num_nodes"]), z["src"], z["dst"])
