"""Layered neighbor sampler (GraphSAGE-style) for the ``minibatch_lg``
shape regime: batch_nodes seeds, fanout per hop, fixed-size padded output
so the sampled subgraph has a static shape for XLA.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .formats import Graph


@dataclasses.dataclass(frozen=True)
class SampledSubgraph:
    """Static-shape padded subgraph.

    nodes:      (max_nodes,)  global node ids (pad = 0, masked)
    node_mask:  (max_nodes,)  validity
    edge_src/edge_dst: (max_edges,) LOCAL indices into `nodes`
    edge_mask:  (max_edges,)
    seed_count: number of seed (layer-0 output) nodes == batch_nodes
    """
    nodes: np.ndarray
    node_mask: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    seed_count: int


def sample_neighbors(g: Graph, seeds: np.ndarray, fanouts: tuple[int, ...],
                     *, rng: np.random.Generator) -> SampledSubgraph:
    """In-neighbor sampling: hop h samples ``fanouts[h]`` in-neighbors of
    the current frontier.  Output sizes are the deterministic maxima
    implied by (len(seeds), fanouts), independent of the draw."""
    offsets, indices = g.csc  # in-neighbors
    layers = [np.asarray(seeds, dtype=np.int64)]
    edge_chunks = []  # (src_global, dst_global) per hop
    frontier = layers[0]
    for f in fanouts:
        deg = offsets[frontier + 1] - offsets[frontier]
        # sample f in-neighbors (with replacement where deg>0)
        draw = rng.integers(0, np.maximum(deg, 1), size=(len(frontier), f))
        src = indices[offsets[frontier, None] + draw]          # (|F|, f)
        valid = (deg > 0)[:, None] & np.ones_like(draw, dtype=bool)
        dst = np.broadcast_to(frontier[:, None], src.shape)
        edge_chunks.append((src[valid], dst[valid], len(frontier) * f))
        frontier = np.unique(src[valid])
        layers.append(frontier)

    max_nodes = _max_nodes(len(seeds), fanouts)
    max_edges = sum(c[2] for c in edge_chunks)

    all_src = np.concatenate([c[0] for c in edge_chunks])
    all_dst = np.concatenate([c[1] for c in edge_chunks])
    nodes, inv = np.unique(np.concatenate([layers[0], all_src, all_dst]),
                           return_inverse=True)
    # remap seeds to the front so layer-0 outputs are nodes[:seed_count]
    seed_local = inv[:len(seeds)]
    perm = np.full(len(nodes), -1, dtype=np.int64)
    perm[seed_local] = np.arange(len(seeds))
    rest = np.where(perm < 0)[0]
    perm[rest] = len(seeds) + np.arange(len(rest))
    nodes_out = np.zeros(max_nodes, dtype=np.int32)
    node_mask = np.zeros(max_nodes, dtype=bool)
    nodes_out[perm] = nodes
    node_mask[:len(nodes)] = True

    e_src = np.zeros(max_edges, dtype=np.int32)
    e_dst = np.zeros(max_edges, dtype=np.int32)
    e_mask = np.zeros(max_edges, dtype=bool)
    ne = len(all_src)
    e_src[:ne] = perm[inv[len(seeds):len(seeds) + ne]]
    e_dst[:ne] = perm[inv[len(seeds) + ne:]]
    e_mask[:ne] = True
    return SampledSubgraph(nodes_out, node_mask, e_src, e_dst, e_mask,
                           len(seeds))


def _max_nodes(n_seeds: int, fanouts: tuple[int, ...]) -> int:
    total, frontier = n_seeds, n_seeds
    for f in fanouts:
        frontier *= f
        total += frontier
    return total


def minibatch_stream(g: Graph, batch_nodes: int, fanouts: tuple[int, ...],
                     *, seed: int = 0):
    """Infinite deterministic stream of sampled minibatches."""
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    while True:
        seeds = rng.choice(n, size=batch_nodes, replace=False)
        yield sample_neighbors(g, seeds, fanouts, rng=rng)
