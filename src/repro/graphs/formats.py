"""Graph containers.

Host-side (numpy) representations used for pre-processing — CSR build,
partitioning, PNG construction — plus device (jnp) views for compute.
The paper assumes CSR is given (§VI-D3); we build it once at load time.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph in COO form with lazily-built CSR/CSC views.

    ``src``/``dst`` are int32 numpy arrays of equal length (one entry per
    edge).  Self-loops and multi-edges are permitted (multi-edges matter:
    PNG compression dedups (src, dst-partition) pairs, and we report the
    achieved compression ratio r against the raw edge count, as the paper
    does).
    """

    num_nodes: int
    src: np.ndarray
    dst: np.ndarray

    def __post_init__(self):
        assert self.src.dtype == np.int32 and self.dst.dtype == np.int32
        assert self.src.shape == self.dst.shape

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    # ---------------------------------------------------------------- CSR
    @cached_property
    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """(offsets[n+1], indices[m]) with edges sorted by src then dst."""
        order = np.lexsort((self.dst, self.src))
        offsets = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.add.at(offsets, self.src + 1, 1)
        np.cumsum(offsets, out=offsets)
        return offsets, self.dst[order].astype(np.int32)

    @cached_property
    def csc(self) -> tuple[np.ndarray, np.ndarray]:
        """(offsets[n+1], indices[m]) with edges sorted by dst then src."""
        order = np.lexsort((self.src, self.dst))
        offsets = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.add.at(offsets, self.dst + 1, 1)
        np.cumsum(offsets, out=offsets)
        return offsets, self.src[order].astype(np.int32)

    @cached_property
    def out_degree(self) -> np.ndarray:
        deg = np.zeros(self.num_nodes, dtype=np.int64)
        np.add.at(deg, self.src, 1)
        return deg

    @cached_property
    def in_degree(self) -> np.ndarray:
        deg = np.zeros(self.num_nodes, dtype=np.int64)
        np.add.at(deg, self.dst, 1)
        return deg

    # ------------------------------------------------------------- device
    def device_coo(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.asarray(self.src), jnp.asarray(self.dst)

    def relabel(self, perm: np.ndarray) -> "Graph":
        """Apply a node relabeling: new_id = perm[old_id]."""
        perm = perm.astype(np.int32)
        return Graph(self.num_nodes, perm[self.src], perm[self.dst])

    def reverse(self) -> "Graph":
        return Graph(self.num_nodes, self.dst, self.src)


def from_edge_list(num_nodes: int, edges: np.ndarray) -> Graph:
    """edges: (m, 2) array of (src, dst)."""
    e = np.asarray(edges, dtype=np.int32)
    return Graph(num_nodes, np.ascontiguousarray(e[:, 0]),
                 np.ascontiguousarray(e[:, 1]))
