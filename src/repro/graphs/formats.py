"""Graph containers.

Host-side (numpy) representations used for pre-processing — CSR build,
partitioning, PNG construction — plus device (jnp) views for compute.
The paper assumes CSR is given (§VI-D3); we build it once at load time.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph in COO form with lazily-built CSR/CSC views.

    ``src``/``dst`` are int32 numpy arrays of equal length (one entry per
    edge).  Self-loops and multi-edges are permitted (multi-edges matter:
    PNG compression dedups (src, dst-partition) pairs, and we report the
    achieved compression ratio r against the raw edge count, as the paper
    does).
    """

    num_nodes: int
    src: np.ndarray
    dst: np.ndarray

    def __post_init__(self):
        for name, arr in (("src", self.src), ("dst", self.dst)):
            if not isinstance(arr, np.ndarray) or arr.dtype != np.int32:
                raise ValueError(
                    f"Graph.{name} must be an int32 numpy array; got "
                    f"{getattr(arr, 'dtype', type(arr).__name__)} "
                    "(float/int64 edge arrays must be converted "
                    "explicitly — silent truncation hides bad ids)")
            if arr.ndim != 1:
                raise ValueError(f"Graph.{name} must be 1-D (one entry "
                                 f"per edge); got shape {arr.shape}")
        if self.src.shape != self.dst.shape:
            raise ValueError(
                f"Graph src/dst must have equal length; got "
                f"{self.src.shape[0]} vs {self.dst.shape[0]}")
        if int(self.num_nodes) < 1:
            raise ValueError(
                f"Graph needs num_nodes >= 1; got {self.num_nodes}")

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    # ---------------------------------------------------------------- CSR
    @cached_property
    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """(offsets[n+1], indices[m]) with edges sorted by src then dst."""
        order = np.lexsort((self.dst, self.src))
        offsets = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.add.at(offsets, self.src + 1, 1)
        np.cumsum(offsets, out=offsets)
        return offsets, self.dst[order].astype(np.int32)

    @cached_property
    def csc(self) -> tuple[np.ndarray, np.ndarray]:
        """(offsets[n+1], indices[m]) with edges sorted by dst then src."""
        order = np.lexsort((self.src, self.dst))
        offsets = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.add.at(offsets, self.dst + 1, 1)
        np.cumsum(offsets, out=offsets)
        return offsets, self.src[order].astype(np.int32)

    @cached_property
    def out_degree(self) -> np.ndarray:
        deg = np.zeros(self.num_nodes, dtype=np.int64)
        np.add.at(deg, self.src, 1)
        return deg

    @cached_property
    def in_degree(self) -> np.ndarray:
        deg = np.zeros(self.num_nodes, dtype=np.int64)
        np.add.at(deg, self.dst, 1)
        return deg

    # ------------------------------------------------------------- device
    def device_coo(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.asarray(self.src), jnp.asarray(self.dst)

    def relabel(self, perm: np.ndarray) -> "Graph":
        """Apply a node relabeling: new_id = perm[old_id]."""
        perm = perm.astype(np.int32)
        return Graph(self.num_nodes, perm[self.src], perm[self.dst])

    def reverse(self) -> "Graph":
        return Graph(self.num_nodes, self.dst, self.src)


def validate_graph(g: Graph) -> Graph:
    """Front-door id-range check (DESIGN.md §10): every edge endpoint
    must lie in ``[0, num_nodes)``.  Out-of-range ids otherwise
    surface as obscure index errors (or, worse, silent wraparound)
    deep inside partitioning — O(m) on first call, memoized on the
    instance so every front door (``build_plan``, ``Session``,
    ``SlotScheduler``) can call it for free afterwards."""
    if g.__dict__.get("_validated"):
        return g
    for name, arr in (("src", g.src), ("dst", g.dst)):
        if arr.size:
            lo, hi = int(arr.min()), int(arr.max())
            if lo < 0 or hi >= g.num_nodes:
                raise ValueError(
                    f"graph {name} ids span [{lo}, {hi}], outside "
                    f"[0, {g.num_nodes}) — negative or out-of-range "
                    "node ids")
    g.__dict__["_validated"] = True   # frozen-safe: dict write
    return g


def from_edge_list(num_nodes: int, edges: np.ndarray) -> Graph:
    """edges: (m, 2) array of (src, dst)."""
    e = np.asarray(edges)
    if e.size and e.dtype.kind not in "iu":
        raise ValueError(
            f"edge list must be integer-typed; got dtype {e.dtype} "
            "(converting floats would silently truncate node ids)")
    e = e.astype(np.int32, copy=False)
    if e.ndim != 2 or e.shape[1] != 2:
        raise ValueError(f"edges must be (m, 2) (src, dst) pairs; got "
                         f"shape {e.shape}")
    return Graph(num_nodes, np.ascontiguousarray(e[:, 0]),
                 np.ascontiguousarray(e[:, 1]))
