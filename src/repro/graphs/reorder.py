"""Node relabeling for locality (paper §VI-D1, GOrder experiments).

GOrder itself (Wei et al., SIGMOD'16) optimizes a sliding-window score
and is out of scope; we provide the locality knob the paper studies via
two cheaper orderings that move compression ratio r the same direction:

- ``degree_order``:   hub-first labeling (helps skewed graphs)
- ``bfs_order``:      BFS from max-degree seed (clusters neighborhoods)
- ``hybrid_order``:   BFS over a degree-bucketed queue — our default
                      GOrder stand-in; on RMAT graphs it raises r by
                      1.5-2.5x like table V reports for GOrder.
"""
from __future__ import annotations

import numpy as np

from .formats import Graph


def degree_order(g: Graph) -> np.ndarray:
    """perm[old_id] = new_id, descending total degree."""
    rank = np.argsort(-(g.out_degree + g.in_degree), kind="stable")
    perm = np.empty(g.num_nodes, dtype=np.int32)
    perm[rank] = np.arange(g.num_nodes, dtype=np.int32)
    return perm


def bfs_order(g: Graph) -> np.ndarray:
    """BFS labeling over the undirected view, restarting at the
    highest-degree unvisited node (handles disconnected graphs)."""
    n = g.num_nodes
    offsets, indices = _undirected_csr(g)
    visited = np.zeros(n, dtype=bool)
    perm = np.empty(n, dtype=np.int32)
    order_seed = np.argsort(-(g.out_degree + g.in_degree), kind="stable")
    label = 0
    for seed in order_seed:
        if visited[seed]:
            continue
        queue = [int(seed)]
        visited[seed] = True
        while queue:
            next_queue = []
            for u in queue:
                perm[u] = label
                label += 1
                nbrs = indices[offsets[u]:offsets[u + 1]]
                fresh = np.unique(nbrs[~visited[nbrs]])  # dedupe multi-edges
                visited[fresh] = True
                next_queue.extend(fresh.tolist())
            queue = next_queue
    return perm


def hybrid_order(g: Graph) -> np.ndarray:
    """Degree-bucketed BFS: BFS traversal, but each frontier is visited
    hub-first so high-degree nodes land near their followers."""
    n = g.num_nodes
    offsets, indices = _undirected_csr(g)
    deg = (g.out_degree + g.in_degree).astype(np.int64)
    visited = np.zeros(n, dtype=bool)
    perm = np.empty(n, dtype=np.int32)
    label = 0
    for seed in np.argsort(-deg, kind="stable"):
        if visited[seed]:
            continue
        frontier = np.array([seed], dtype=np.int64)
        visited[seed] = True
        while frontier.size:
            frontier = frontier[np.argsort(-deg[frontier], kind="stable")]
            perm[frontier] = np.arange(label, label + frontier.size)
            label += frontier.size
            nxt = []
            for u in frontier:
                nbrs = indices[offsets[u]:offsets[u + 1]]
                fresh = np.unique(nbrs[~visited[nbrs]])  # dedupe multi-edges
                visited[fresh] = True
                nxt.append(fresh)
            frontier = (np.concatenate(nxt) if nxt
                        else np.array([], dtype=np.int64))
    return perm


# ---------------------------------------------------------------------------
# Registry — what PlanConfig(reorder=...) resolves through (core/plan.py)
# ---------------------------------------------------------------------------
ORDERINGS = {
    "degree": degree_order,
    "bfs": bfs_order,
    "hybrid": hybrid_order,
}


def available_orderings() -> tuple[str, ...]:
    """Every valid ``PlanConfig.reorder`` value (``"none"`` included)."""
    return ("none",) + tuple(sorted(ORDERINGS))


def reorder_permutation(g: Graph, name: str) -> np.ndarray:
    """The ``perm[old_id] = new_id`` permutation for ordering ``name``
    (memoized on the graph instance — a pcpm and a pcpm_pallas plan of
    the same reordered graph compute the BFS once)."""
    if name not in ORDERINGS:
        raise ValueError(f"unknown ordering {name!r}; valid: "
                         f"{available_orderings()}")
    key = f"_reorder_perm_{name}"
    perm = g.__dict__.get(key)
    if perm is None:
        perm = ORDERINGS[name](g).astype(np.int32)
        g.__dict__[key] = perm       # frozen-safe: dict write
    return perm


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    """``inv[new_id] = old_id`` — maps internal-space vectors/ids back
    to the original labeling (``x_orig = x_int[perm]``,
    ``id_orig = inv[id_int]``)."""
    inv = np.empty(len(perm), dtype=np.int32)
    inv[perm] = np.arange(len(perm), dtype=np.int32)
    return inv


def _undirected_csr(g: Graph) -> tuple[np.ndarray, np.ndarray]:
    src = np.concatenate([g.src, g.dst])
    dst = np.concatenate([g.dst, g.src])
    order = np.argsort(src, kind="stable")
    offsets = np.zeros(g.num_nodes + 1, dtype=np.int64)
    np.add.at(offsets, src + 1, 1)
    np.cumsum(offsets, out=offsets)
    return offsets, dst[order]
