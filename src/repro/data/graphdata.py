"""Graph data for the assigned GNN shape regimes."""
from __future__ import annotations

import numpy as np

from ..configs.base import ShapeSpec
from ..graphs import generators
from ..graphs.sampler import sample_neighbors, _max_nodes
from ..models.gnn import GraphBatch, random_graph_batch


def graph_for_shape(shape: ShapeSpec, *, seed: int = 0):
    """A synthetic stand-in graph with the shape's node/edge counts."""
    return generators.uniform_random(shape.n_nodes, shape.n_edges,
                                     seed=seed)


def batch_for_shape(shape: ShapeSpec, *, seed: int = 0,
                    d_feat: int | None = None,
                    n_classes: int = 16) -> GraphBatch:
    rng = np.random.default_rng(seed)
    d = d_feat or shape.d_feat
    if shape.kind == "batched_graphs":
        return random_graph_batch(
            rng, shape.n_nodes * shape.global_batch,
            shape.n_edges * shape.global_batch, d,
            n_graphs=shape.global_batch, n_classes=n_classes)
    if shape.kind == "minibatch":
        n = _max_nodes(shape.batch_nodes, shape.fanout)
        e = sum(shape.batch_nodes * int(np.prod(shape.fanout[:i + 1]))
                for i in range(len(shape.fanout)))
        return random_graph_batch(rng, n, e, d, n_classes=n_classes)
    return random_graph_batch(rng, shape.n_nodes, shape.n_edges, d,
                              n_classes=n_classes)
