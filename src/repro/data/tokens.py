"""Deterministic synthetic LM data pipeline.

Token streams are a keyed hash of (stream seed, step, position) so any
worker can materialize its shard of any batch independently — the
restart/elastic property the trainer relies on (no data-loader state to
checkpoint beyond the step counter).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def _hash_tokens(seed: int, step: int, batch: int, seq: int,
                 vocab: int) -> np.ndarray:
    # splitmix64-style mixing, vectorized
    with np.errstate(over="ignore"):
        idx = (np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15)
               + np.uint64(step) * np.uint64(0xBF58476D1CE4E5B9)
               + np.arange(batch * seq, dtype=np.uint64))
    z = idx
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    z = z ^ (z >> np.uint64(31))
    return (z % np.uint64(vocab)).astype(np.int32).reshape(batch, seq)


def synthetic_lm_batches(vocab: int, batch: int, seq: int, *,
                         seed: int = 0, start_step: int = 0):
    """Infinite iterator of {tokens, labels} (labels = next token)."""
    step = start_step
    while True:
        toks = _hash_tokens(seed, step, batch, seq + 1, vocab)
        yield {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:])}
        step += 1
