from .tokens import synthetic_lm_batches
from .graphdata import graph_for_shape, batch_for_shape

__all__ = ["synthetic_lm_batches", "graph_for_shape", "batch_for_shape"]
