"""Perf-experiment toggles (EXPERIMENTS.md §Perf hypothesis loop).

Flags are read from the REPRO_PERF env var (comma-separated,
``name`` or ``name=value``) so a dry-run cell can be re-lowered under a
candidate optimization without forking the model code:

    REPRO_PERF=gather_weights,attn_chunk=2048 \
        python -m repro.launch.dryrun --arch mixtral-8x7b ...

Flags that win graduate to defaults; the flag stays as the off-switch
documenting the before/after.
"""
from __future__ import annotations

import os


def _parse() -> dict:
    out = {}
    for item in os.environ.get("REPRO_PERF", "").split(","):
        item = item.strip()
        if not item:
            continue
        if "=" in item:
            k, v = item.split("=", 1)
            out[k] = v
        else:
            out[item] = "1"
    return out


def enabled(name: str) -> bool:
    return _parse().get(name, "0") not in ("0", "", "false")


def value(name: str, default=None, cast=str):
    raw = _parse().get(name)
    return default if raw is None else cast(raw)
