from .trainer import Trainer, TrainerConfig
from . import checkpoint, compression

__all__ = ["Trainer", "TrainerConfig", "checkpoint", "compression"]
