"""Gradient compression: int8 error-feedback quantization.

At 1000+ node scale the (pod, data) gradient all-reduce crosses DCN;
int8 with error feedback cuts its bytes 4x with no asymptotic loss in
convergence (error accumulator re-injects the quantization residual the
next step).  ``compress``/``decompress`` are shape-preserving and
jit-friendly; the trainer threads an ``ef_state`` pytree through steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_ef_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress(x: jnp.ndarray, ef: jnp.ndarray):
    """x (+ carried error) -> (int8 q, f32 scale, new error)."""
    xc = x.astype(jnp.float32) + ef
    scale = jnp.max(jnp.abs(xc)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xc / scale), -127, 127).astype(jnp.int8)
    err = xc - q.astype(jnp.float32) * scale
    return q, scale, err


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, ef_state):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    qs, scales, errs = zip(*[compress(g, e)
                             for g, e in zip(flat_g, flat_e)])
    return (treedef.unflatten(qs), treedef.unflatten(scales),
            treedef.unflatten(errs))


def decompress_tree(qs, scales):
    return jax.tree.map(lambda q, s: decompress(q, s), qs, scales)


def compressed_gradients(grads, ef_state):
    """Round-trip grads through int8 EF quantization (the collective
    itself is inserted by SPMD partitioning of the optimizer step; this
    shapes WHAT crosses the wire)."""
    qs, scales, errs = compress_tree(grads, ef_state)
    return decompress_tree(qs, scales), errs
