"""Training loop with checkpoint/restart, failure injection, straggler
accounting, and optional gradient compression.

Fault-tolerance model (1000+ node posture, DESIGN.md §5):
- checkpoint every N steps (atomic; async off the critical path),
- any step may raise (preemption / node loss) -> restart resumes from
  the last checkpoint with BIT-IDENTICAL state (tested),
- elastic restarts may use a different device mesh: restore() places
  host arrays against the new mesh's shardings,
- stragglers: per-step wall-time watchdog; steps slower than
  ``straggler_factor`` x the running median are counted and surfaced
  (on a real fleet this signal drives re-scheduling; here it feeds the
  metrics so the policy layer is exercised end-to-end).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import numpy as np

from . import checkpoint as ckpt_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    checkpoint_every: int = 50
    ckpt_dir: str = "/tmp/repro-ckpt"
    keep_checkpoints: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: TrainerConfig, step_fn: Callable,
                 init_state: tuple, data: Iterator, *,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 log_fn: Callable = print):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = init_state          # (params, opt_state)
        self.data = data
        self.failure_hook = failure_hook
        self.log_fn = log_fn
        self.step = 0
        self.metrics_history: list[dict] = []
        self.straggler_steps: list[int] = []
        self._durations: list[float] = []

    # ------------------------------------------------------------ resume
    def try_resume(self) -> bool:
        last = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return False
        self.state, self.step = ckpt_lib.restore(
            self.cfg.ckpt_dir, self.state, step=last)[0], last
        self.log_fn(f"[trainer] resumed from step {last}")
        return True

    # -------------------------------------------------------------- run
    def run(self) -> dict:
        c = self.cfg
        while self.step < c.total_steps:
            batch = next(self.data)
            if self.failure_hook is not None:
                self.failure_hook(self.step)     # may raise (preemption)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(
                self.state[0], self.state[1], batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.state = (params, opt_state)
            self.step += 1
            self._watch_stragglers(dt)
            metrics["step_time_s"] = dt
            self.metrics_history.append(metrics)
            if self.step % c.log_every == 0:
                self.log_fn(f"[trainer] step {self.step} "
                            f"loss={metrics.get('loss', float('nan')):.4f} "
                            f"({dt * 1e3:.0f} ms)")
            if self.step % c.checkpoint_every == 0:
                ckpt_lib.save(c.ckpt_dir, self.step, self.state,
                              keep=c.keep_checkpoints)
        ckpt_lib.save(c.ckpt_dir, self.step, self.state,
                      keep=c.keep_checkpoints)
        return {"final_step": self.step,
                "stragglers": list(self.straggler_steps),
                "history": self.metrics_history}

    def _watch_stragglers(self, dt: float):
        self._durations.append(dt)
        if len(self._durations) >= 8:
            med = float(np.median(self._durations[-64:]))
            if dt > self.cfg.straggler_factor * med:
                self.straggler_steps.append(self.step)
                self.log_fn(f"[trainer] straggler step {self.step}: "
                            f"{dt:.3f}s vs median {med:.3f}s")
