"""Sharded checkpointing: atomic, manifest-based, elastic on restore.

Arrays are gathered to host and written as npz with tree-path keys plus
a manifest (step, keys, shapes).  Writes go to a temp file + atomic
rename, so a failure mid-write never corrupts the latest checkpoint.
``restore`` accepts any target sharding — loading a checkpoint written
on one mesh onto a different mesh (elastic scale-up/down) is just a
``device_put`` against the new sharding.
"""
from __future__ import annotations

import json
import os
import re
import threading

import numpy as np
import jax


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         blocking: bool = True) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    keys, vals, _ = _flatten(tree)

    def to_host(v):
        a = np.asarray(v)
        if a.dtype.kind == "V" or "bfloat16" in str(a.dtype):
            # npz cannot round-trip ml_dtypes; f32 is a lossless
            # superset of bf16 so the restore cast is bit-identical.
            a = np.asarray(v, np.float32)
        return a

    arrays = {f"a{i}": to_host(v) for i, v in enumerate(vals)}

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp-{step}.npz")
        final = os.path.join(ckpt_dir, f"step-{step:08d}.npz")
        np.savez(tmp, **arrays)
        os.replace(tmp, final)                       # atomic
        manifest = {"step": step, "keys": keys,
                    "shapes": [list(a.shape) for a in arrays.values()],
                    "dtypes": [str(a.dtype) for a in arrays.values()]}
        mtmp = os.path.join(ckpt_dir, ".tmp-manifest.json")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, os.path.join(ckpt_dir,
                                      f"step-{step:08d}.json"))
        _gc(ckpt_dir, keep)

    if blocking:
        _write()
    else:
        threading.Thread(target=_write, daemon=True).start()
    return os.path.join(ckpt_dir, f"step-{step:08d}.npz")


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        for ext in ("npz", "json"):
            try:
                os.remove(os.path.join(ckpt_dir, f"step-{s:08d}.{ext}"))
            except FileNotFoundError:
                pass


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for f in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step-(\d+)\.npz", f)
        if m and os.path.exists(os.path.join(
                ckpt_dir, f"step-{m.group(1)}.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, target_tree, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``target_tree``; ``shardings`` may
    be a matching pytree of jax.sharding.Sharding for elastic placement
    on the current mesh."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    z = np.load(os.path.join(ckpt_dir, f"step-{step:08d}.npz"))
    keys, vals, treedef = _flatten(target_tree)
    loaded = [z[f"a{i}"] for i in range(len(vals))]
    for k, a, v in zip(keys, loaded, vals):
        want = tuple(np.shape(v))
        if tuple(a.shape) != want:
            raise ValueError(f"shape mismatch for {k}: "
                             f"{a.shape} vs {want}")
    out = [np.asarray(a).astype(
        getattr(v, "dtype", np.asarray(v).dtype))
        for a, v in zip(loaded, vals)]
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step
