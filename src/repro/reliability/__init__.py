"""Serving resilience layer (DESIGN.md §10).

The serve path (serve/scheduler.py) is built for the happy path: every
query converges, every rank stays finite, every delta patches cleanly
and the process never dies.  This package adds the failure model:

- ``admission``: the ``ResilienceConfig`` knob set — bounded admission
  queue, deadlines/priorities, tolerance degradation under SLO
  pressure, quarantine/retry policy.
- ``faults``: a deterministic, seedable fault plan (NaN/Inf poisoning
  of slot columns, device-step exceptions, failing deltas, corrupted
  plan arrays) threaded through the scheduler via a test-only hook —
  what the chaos suite drives.
- ``guardrails``: host-side structural integrity checks over a
  ``GraphPlan``'s index arrays — a corrupted plan fails loudly at
  rebind instead of silently serving wrong preprocessing.
- ``snapshot``: crash-safe recovery — scheduler snapshot/restore
  (in-flight query specs + slot rank columns) and rank-vector
  checkpoints keyed by the plan content fingerprint (core/plan.py), so
  a restarted process warm-starts instead of recomputing, including
  across a ``GraphDelta`` chain via ``stream/incremental``.
"""
from .admission import ResilienceConfig
from .faults import (FaultInjector, FaultPlan, FaultSpec, InjectedFault,
                     corrupt_plan_arrays)
from .guardrails import check_plan_integrity
from .snapshot import (RankCheckpoint, load_rank_checkpoint,
                       restore_scheduler, save_rank_checkpoint,
                       snapshot_scheduler)

__all__ = [
    "ResilienceConfig",
    "FaultInjector", "FaultPlan", "FaultSpec", "InjectedFault",
    "corrupt_plan_arrays", "check_plan_integrity",
    "RankCheckpoint", "load_rank_checkpoint", "save_rank_checkpoint",
    "snapshot_scheduler", "restore_scheduler",
]
