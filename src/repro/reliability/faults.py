"""Deterministic, seedable fault injection for the chaos suite
(DESIGN.md §10).

A ``FaultPlan`` is a declarative list of ``FaultSpec``s — *what* goes
wrong and *when* (scheduler step index / delta index).  The scheduler
threads a ``FaultInjector`` through its step and rebind paths via a
test-only hook; with no injector attached the hook costs one ``is
None`` check.  Everything is deterministic: the same plan and seed
produce the same faults at the same steps, so chaos tests can compare
a faulted run against a fault-free one query-by-query.

Fault kinds:

- ``nan_slot`` / ``inf_slot``: overwrite one active slot column of the
  (n, B) rank pool with NaN/Inf before the next stepper dispatch —
  models device memory corruption / overflow in one query's state.
- ``step_error``: raise ``InjectedFault`` in place of the stepper
  dispatch — models a failed device launch.
- ``delta_error``: raise ``InjectedFault`` inside ``apply_delta``
  before any mutation — models a failing plan patch.
- ``corrupt_plan``: hand ``apply_delta`` a structurally corrupted copy
  of the patched plan (``corrupt_plan_arrays``) — what the
  ``guardrails`` integrity check exists to catch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


KINDS = ("nan_slot", "inf_slot", "step_error", "delta_error",
         "corrupt_plan")
_POISON = ("nan_slot", "inf_slot")


class InjectedFault(RuntimeError):
    """A fault raised by the injector (never by real serving code)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault: ``kind`` at scheduler ``step`` (1-based; for
    ``delta_error``/``corrupt_plan`` it is the 1-based ``apply_delta``
    call index).  ``slot`` pins a poison fault to a column; ``None``
    picks deterministically among the active slots."""
    kind: str
    step: int = 1
    slot: Optional[int] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.step < 1:
            raise ValueError(f"fault step must be >= 1; got {self.step}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic batch of faults + the seed for any unpinned
    choices (e.g. which active slot a poison lands on)."""
    specs: tuple[FaultSpec, ...] = ()
    seed: int = 0

    @staticmethod
    def of(specs: Sequence[FaultSpec], *, seed: int = 0) -> "FaultPlan":
        return FaultPlan(tuple(specs), seed)


class FaultInjector:
    """Stateful executor of one ``FaultPlan``: each spec fires exactly
    once.  ``fired`` records what actually triggered, so tests can
    assert full plan coverage."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired: list[FaultSpec] = []

    def _pending(self, kinds: tuple[str, ...], step: int):
        return [s for s in self.plan.specs
                if s.kind in kinds and s.step == step
                and s not in self.fired]

    # ------------------------------------------------- scheduler hooks
    def poisons(self, step: int,
                active_slots: Sequence[int]) -> list[tuple[int, str]]:
        """(slot, kind) poison writes due before stepper dispatch
        ``step``.  Unpinned specs pick among ``active_slots``
        deterministically from the plan seed; a spec with no eligible
        slot stays pending for a later step."""
        out = []
        for spec in self._pending(_POISON, step):
            slot = spec.slot
            if slot is None:
                if not active_slots:
                    continue
                rng = np.random.default_rng(self.plan.seed + step)
                slot = int(rng.choice(np.asarray(active_slots)))
            self.fired.append(spec)
            out.append((slot, spec.kind))
        return out

    def check_step(self, step: int) -> None:
        """Raise ``InjectedFault`` in place of stepper dispatch
        ``step`` when the plan schedules a ``step_error`` there."""
        for spec in self._pending(("step_error",), step):
            self.fired.append(spec)
            raise InjectedFault(f"injected stepper failure at step "
                                f"{step}")

    # --------------------------------------------------- rebind hooks
    def check_delta(self, idx: int) -> None:
        for spec in self._pending(("delta_error",), idx):
            self.fired.append(spec)
            raise InjectedFault(f"injected apply_delta failure at "
                                f"delta {idx}")

    def wants_corrupt(self, idx: int) -> bool:
        for spec in self._pending(("corrupt_plan",), idx):
            self.fired.append(spec)
            return True
        return False

    @property
    def exhausted(self) -> bool:
        return len(self.fired) == len(self.plan.specs)


def corrupt_plan_arrays(plan):
    """A structurally corrupted COPY of ``plan``: the first populated
    index-array family gets an out-of-range entry (the original's
    arrays and device cache are never touched — plans are shared
    through the process cache).  What ``check_plan_integrity`` must
    catch before a rebind serves it."""
    bad_id = plan.num_nodes + 7
    kw: dict = {"_device": {}}
    if plan.png is not None:
        upd = plan.png.update_src.copy()
        upd[: max(1, upd.size // 64)] = bad_id
        kw["png"] = dataclasses.replace(plan.png, update_src=upd)
    elif plan.csc_src is not None:
        src = plan.csc_src.copy()
        src[:1] = -5
        kw["csc_src"] = src
    elif plan.bv_src is not None:
        src = plan.bv_src.copy()
        src[:1] = bad_id
        kw["bv_src"] = src
    elif plan.sharded is not None:
        send = plan.sharded.send_ids.copy()
        send.reshape(-1)[:1] = plan.sharded.shard_size + 7
        kw["sharded"] = dataclasses.replace(plan.sharded, send_ids=send)
    else:
        raise ValueError("plan has no index arrays to corrupt")
    return dataclasses.replace(plan, **kw)
