"""Host-side structural integrity checks over a ``GraphPlan``
(DESIGN.md §10).

The plan's index arrays are what the device gather/scatter kernels
trust blindly — an out-of-range update pointer or destination id does
not crash XLA, it silently reads/writes the wrong rank, which is the
worst possible failure mode for a serving system.  ``check_plan_
integrity`` re-derives the cheap bounds invariants every backend's
layout must satisfy (one O(M) vectorized min/max pass per stream, no
device work) so a corrupted plan — bad npz, bad patch splice, injected
fault — fails loudly at rebind/install time while the previous plan
keeps serving.
"""
from __future__ import annotations

import numpy as np


def _bounds(name: str, arr: np.ndarray, lo: int, hi: int) -> None:
    """Require every entry of ``arr`` in [lo, hi] (inclusive)."""
    if arr is None or arr.size == 0:
        return
    amin, amax = int(arr.min()), int(arr.max())
    if amin < lo or amax > hi:
        raise ValueError(
            f"plan integrity: {name} has entries in [{amin}, {amax}], "
            f"outside the valid range [{lo}, {hi}]")


def _offsets(name: str, off: np.ndarray, total: int) -> None:
    if off is None or off.size == 0:
        return
    if int(off[0]) != 0 or int(off[-1]) != total or (np.diff(off) < 0).any():
        raise ValueError(
            f"plan integrity: {name} is not a monotone offset array "
            f"starting at 0 and ending at {total}")


def _check_schedule(sched, *, pointer_hi: int, num_nodes: int) -> None:
    mp = len(sched.edge_update_idx_padded)
    _bounds("schedule.edge_update_idx_padded",
            sched.edge_update_idx_padded, 0, pointer_hi)
    _bounds("schedule.piece_dst", sched.piece_dst, 0, num_nodes)
    _bounds("schedule.piece_start", sched.piece_start, 0, max(mp - 1, 0))
    _bounds("schedule.piece_end", sched.piece_end, 0, max(mp - 1, 0))
    if sched.piece_start.size and \
            (sched.piece_end < sched.piece_start).any():
        raise ValueError("plan integrity: schedule has pieces with "
                         "end < start")


def check_plan_integrity(plan) -> "object":
    """Raise ``ValueError`` unless every populated index stream of
    ``plan`` satisfies its layout's bounds invariants; returns the
    plan unchanged otherwise.  Complements ``core.plan.validate_plan``
    (which checks the plan belongs to a graph, not that its arrays are
    internally sane)."""
    n = plan.num_nodes
    if n <= 0:
        raise ValueError(f"plan integrity: num_nodes={n} must be > 0")

    if plan.csc_src is not None:                      # pdpr
        _bounds("csc_src", plan.csc_src, 0, n - 1)
        _bounds("csc_dst", plan.csc_dst, 0, n - 1)
        if plan.schedule is not None:
            # the pointer stream is x itself: pointers are source ids
            _check_schedule(plan.schedule, pointer_hi=n - 1,
                            num_nodes=n)

    if plan.bv_src is not None:                       # bvgas
        _bounds("bv_src", plan.bv_src, 0, n - 1)
        _bounds("bv_dst", plan.bv_dst, 0, n - 1)
        if plan.schedule is not None:
            # pointers permute the per-edge bins (length M)
            m = len(plan.bv_src)
            _check_schedule(plan.schedule, pointer_hi=max(m - 1, 0),
                            num_nodes=n)

    if plan.png is not None:                          # pcpm / pallas
        png = plan.png
        u = png.num_updates
        _bounds("png.update_src", png.update_src, 0, n - 1)
        _bounds("png.edge_dst", png.edge_dst, 0, n - 1)
        _bounds("png.edge_update_idx", png.edge_update_idx, 0,
                max(u - 1, 0))
        _offsets("png.update_offsets", png.update_offsets, u)
        _offsets("png.edge_offsets", png.edge_offsets,
                 len(png.edge_update_idx))
        if plan.schedule is not None:
            # pointers index the scattered update bins (length U)
            _check_schedule(plan.schedule, pointer_hi=max(u - 1, 0),
                            num_nodes=n)

    if plan.blocked is not None:                      # pcpm_pallas
        blk = plan.blocked
        max_u = int(blk.update_src.shape[1])   # pad slot = max_u
        _bounds("blocked.update_src", blk.update_src, -1, n - 1)
        _bounds("blocked.edge_update_local", blk.edge_update_local,
                0, max_u)
        _bounds("blocked.edge_dst_local", blk.edge_dst_local,
                0, blk.part_size)

    if plan.sharded is not None:                      # pcpm_sharded
        sh = plan.sharded
        recv = sh.num_shards * sh.send_ids.shape[2]   # S*U zero slot
        _bounds("sharded.send_ids", sh.send_ids, -1, sh.shard_size - 1)
        _bounds("sharded.edge_upd", sh.edge_upd, 0, recv)
        _bounds("sharded.edge_dst", sh.edge_dst, 0, sh.shard_size)
        _bounds("sharded.eui_padded", sh.eui_padded, 0, recv)
        _bounds("sharded.piece_dst", sh.piece_dst, 0, sh.shard_size)
        if (sh.piece_end < sh.piece_start).any():
            raise ValueError("plan integrity: sharded schedule has "
                             "pieces with end < start")
    return plan
