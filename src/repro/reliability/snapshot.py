"""Crash-safe recovery (DESIGN.md §10).

Two artifacts, both one-``.npz``-read warm starts keyed by the graph's
CONTENT fingerprint (core/plan.py) following the graphs/io.py
conventions:

- ``snapshot_scheduler``/``restore_scheduler``: the serving state of a
  ``SlotScheduler`` — every in-flight query's spec + its CURRENT slot
  rank column, and every queued query's spec.  Power iteration is
  memoryless given (pr column, base seed), so a restored scheduler
  continues each in-flight query from its exact iterate: same final
  iteration count, same ranks as the uninterrupted run — no cold
  recompute.
- ``save_rank_checkpoint``/``load_rank_checkpoint``: one converged
  rank vector + the residual it achieved, fingerprint-stamped.
  ``Session.load_checkpoint`` (repro/api.py) accepts it directly when
  fingerprints match, or across a ``GraphDelta`` chain (the delta's
  shifted fingerprint proves the lineage) by warm-starting the
  residual-push updater (stream/incremental.py) from it.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

SNAPSHOT_VERSION = 1
CHECKPOINT_VERSION = 1


# ---------------------------------------------------------------------------
# Rank-vector checkpoints
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RankCheckpoint:
    """A persisted solve: ranks + the L1 step-residual they achieved,
    stamped with the content fingerprint of the graph they solve."""
    graph_fp: str
    ranks: np.ndarray
    residual: float
    damping: float
    dangling: str


def save_rank_checkpoint(path: str, g, ranks, *, residual: float,
                         damping: float, dangling: str) -> None:
    from ..core.plan import graph_fingerprint
    meta = {"version": CHECKPOINT_VERSION,
            "graph_fp": graph_fingerprint(g),
            "residual": float(residual), "damping": float(damping),
            "dangling": dangling}
    np.savez_compressed(path, __meta__=json.dumps(meta),
                        ranks=np.asarray(ranks, dtype=np.float32))


def load_rank_checkpoint(path: str) -> RankCheckpoint:
    z = np.load(path)
    meta = json.loads(str(z["__meta__"]))
    if meta.get("version") != CHECKPOINT_VERSION:
        raise ValueError(
            f"unsupported rank-checkpoint version {meta.get('version')!r}"
            f" in {path!r}")
    return RankCheckpoint(meta["graph_fp"], z["ranks"],
                          meta["residual"], meta["damping"],
                          meta["dangling"])


# ---------------------------------------------------------------------------
# Scheduler snapshot / restore
# ---------------------------------------------------------------------------
def snapshot_scheduler(sch, path: str) -> None:
    """Persist ``sch``'s serving state: per in-flight query its spec,
    iteration count and CURRENT (n_pad,) rank column (extracted with
    the compiled column read — no retrace), and per queued query its
    spec.  Deadlines are stored as REMAINING seconds and re-based on
    the restoring process's clock.  Completed results are not included
    — they were already delivered."""
    from ..core.plan import graph_fingerprint
    import jax.numpy as jnp  # noqa: F401  (sch executables live on jax)
    # consistent cut under live gateway traffic: hold the step lock so
    # no chunk advances mid-snapshot (a half-stepped pool would pair
    # pre-step iteration counts with post-step columns) and the intake
    # lock so the queue doesn't shift while it's being walked.  Lock
    # order (step, then intake) matches step()/apply_delta.
    with sch._step_lock, sch._lock:
        now = sch.clock()
        specs, seeds, cols = [], [], []
        for slot, q in enumerate(sch._slot_query):
            if q is None:
                continue
            col = np.asarray(sch._extract_c(
                sch._pr, sch._put_small(np.int32(slot))),
                dtype=np.float32)
            specs.append((q, int(sch._iters[slot]), True))
            seeds.append(q.seed if q.seed is not None
                         else np.zeros(sch._n_pad, np.float32))
            cols.append(col)
        for q in sch._queue:
            specs.append((q, 0, False))
            seeds.append(q.seed if q.seed is not None
                         else np.zeros(sch._n_pad, np.float32))
            cols.append(np.zeros(sch._n_pad, np.float32))
    k = len(specs)
    meta = {"version": SNAPSHOT_VERSION,
            "graph_fp": graph_fingerprint(sch.g),
            "damping": sch.damping, "dangling": sch.dangling,
            "n_pad": sch._n_pad,
            # slot columns and seeds are INTERNAL-space vectors when
            # the plan is reordered — the restoring scheduler must use
            # the same ordering or it would misread every column
            "reorder": sch.engine.plan.config.reorder,
            "uid_floor": (max(q.uid for q, _, _ in specs) + 1
                          if specs else 0)}
    np.savez_compressed(
        path, __meta__=json.dumps(meta),
        q_uid=np.array([q.uid for q, _, _ in specs], np.int64),
        q_tol=np.array([q.tol for q, _, _ in specs], np.float64),
        q_max_iters=np.array([q.max_iters for q, _, _ in specs],
                             np.int64),
        q_iters=np.array([it for _, it, _ in specs], np.int64),
        q_top_k=np.array([q.top_k if q.top_k is not None else -1
                          for q, _, _ in specs], np.int64),
        q_priority=np.array([q.priority for q, _, _ in specs],
                            np.int64),
        q_deadline_rem=np.array(
            [q.deadline - now if q.deadline is not None else np.nan
             for q, _, _ in specs], np.float64),
        q_retries=np.array([q.retries for q, _, _ in specs], np.int64),
        q_degraded=np.array([q.degraded for q, _, _ in specs], bool),
        q_inflight=np.array([fl for _, _, fl in specs], bool),
        q_has_seed=np.array([q.seed is not None for q, _, _ in specs],
                            bool),
        seeds=(np.stack(seeds) if k else
               np.zeros((0, sch._n_pad), np.float32)),
        cols=(np.stack(cols) if k else
              np.zeros((0, sch._n_pad), np.float32)))
    obs = getattr(sch, "obs", None)
    if obs is not None:
        # the snapshot IS the crash/quarantine forensics moment
        # (DESIGN.md §14): park the flight recorder next to the state
        obs.tracer.event("snapshot", trace="plan", path=str(path),
                         in_flight=int(sum(1 for _, _, fl in specs
                                           if fl)), queued=len(sch._queue))
        obs.recorder.dump(f"{path}.trace.jsonl")


def restore_scheduler(path: str, g, **scheduler_kwargs):
    """Rebuild a ``SlotScheduler`` on ``g`` from a snapshot: compile
    fresh (device executables never serialize), then re-admit each
    in-flight query and overwrite its slot column with the snapshotted
    iterate, so serving resumes mid-query.  ``scheduler_kwargs`` must
    describe the same serving configuration (damping/dangling are
    cross-checked against the snapshot; a mismatch would silently
    converge to different answers).  If the restored pool has fewer
    slots than there were in-flight queries, the overflow re-enters
    the queue (losing only its iteration progress, never the query).
    Restored uids are preserved; the process uid counter is advanced
    past them."""
    import jax.numpy as jnp
    import jax
    from ..core.plan import graph_fingerprint
    from ..serve.scheduler import (Query, SlotScheduler,
                                   ensure_uid_floor)
    z = np.load(path)
    meta = json.loads(str(z["__meta__"]))
    if meta.get("version") != SNAPSHOT_VERSION:
        raise ValueError(
            f"unsupported scheduler-snapshot version "
            f"{meta.get('version')!r} in {path!r}")
    fp = graph_fingerprint(g)
    if meta["graph_fp"] != fp:
        raise ValueError(
            "snapshot/graph mismatch: snapshot was taken on a graph "
            f"with content fingerprint {meta['graph_fp'][:12]}…, got "
            f"{fp[:12]}… — restoring would serve wrong answers")
    sch = SlotScheduler(g, **scheduler_kwargs)
    if (sch.damping, sch.dangling) != (meta["damping"],
                                       meta["dangling"]):
        raise ValueError(
            "snapshot/scheduler mismatch: snapshot ran damping="
            f"{meta['damping']}, dangling={meta['dangling']!r}; the "
            f"restored scheduler has damping={sch.damping}, "
            f"dangling={sch.dangling!r}")
    if sch.engine.plan.config.reorder != meta.get("reorder", "none"):
        raise ValueError(
            "snapshot/scheduler mismatch: snapshot slot state is in "
            f"reorder={meta.get('reorder', 'none')!r} internal space; "
            f"the restored scheduler uses "
            f"reorder={sch.engine.plan.config.reorder!r}")
    if sch._n_pad != meta["n_pad"]:
        raise ValueError(
            f"snapshot/scheduler mismatch: snapshot state is padded "
            f"to {meta['n_pad']} rows, scheduler to {sch._n_pad} "
            "(different sharding?)")
    ensure_uid_floor(int(meta["uid_floor"]))
    now = sch.clock()
    free = [s for s in range(sch.slots)]
    for i in range(len(z["q_uid"])):
        rem = float(z["q_deadline_rem"][i])
        top_k = int(z["q_top_k"][i])
        q = Query(
            uid=int(z["q_uid"][i]),
            seed=(z["seeds"][i] if bool(z["q_has_seed"][i]) else None),
            top_k=(top_k if top_k >= 0 else None),
            tol=float(z["q_tol"][i]),
            max_iters=int(z["q_max_iters"][i]),
            deadline=(now + rem if np.isfinite(rem) else None),
            priority=int(z["q_priority"][i]),
            degraded=bool(z["q_degraded"][i]),
            retries=int(z["q_retries"][i]))
        sch.metrics.submitted(q.uid)
        if bool(z["q_inflight"][i]) and free:
            slot = free.pop(0)
            sch._admit(slot, q)       # seeds base + resets bookkeeping
            if q.max_iters == 0:
                continue              # _admit already finished it
            col = jnp.asarray(z["cols"][i])
            if sch.sharded:
                col = jax.device_put(col, sch._vec_sharding)
            # overwrite the freshly-seeded column with the snapshotted
            # iterate; base is deterministic from the seed, so the
            # iteration continues exactly where it stopped
            sch._pr = sch._restore_c(sch._pr, col,
                                     sch._put_small(np.int32(slot)))
            sch._iters[slot] = int(z["q_iters"][i])
        else:
            sch._queue.append(q)
    return sch
