"""Admission policy knobs for the resilient serve path (DESIGN.md §10).

One frozen config value carries every resilience knob of
``SlotScheduler``; the defaults reproduce the legacy behaviour exactly
(unbounded FIFO queue, no deadlines, one quarantine retry), so handing
``ResilienceConfig()`` to an existing scheduler changes nothing
observable on the happy path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Resilience knobs of one ``SlotScheduler``.

    Admission / backpressure:

    - ``max_queue``: bound on the admission queue.  A submit past the
      bound is REJECTED EXPLICITLY — the query completes immediately
      with ``QueryResult.error`` set and the rejection counted — never
      silently queued into a timeout.  ``None`` keeps the legacy
      unbounded queue.
    - ``default_deadline_s``: deadline applied to queries submitted
      without one (``None`` = no deadline).  Deadlines are absolute
      wall-clock budgets covering queue wait AND service.

    Graceful degradation (the Fused-PageRank license: an approximate
    answer beats a dropped one):

    - ``degrade_tol``: under measured SLO pressure — the scheduler's
      EWMA service-time model predicts the query cannot finish inside
      its deadline at its requested tolerance — the query's tolerance
      is loosened to this value at admission (counted, and flagged on
      the result).  A query that still overruns its deadline mid-
      flight is finished with its CURRENT iterate as an approximate
      answer rather than cancelled.

    Quarantine / fault policy:

    - ``max_retries``: how many times a NaN/Inf-poisoned slot is
      re-admitted from a clean seed before the query is failed
      explicitly.
    - ``max_step_retries``: transient stepper-dispatch failures
      tolerated (the dispatch is retried next ``step()``) before the
      in-flight pool is declared lost and its queries failed.
    - ``verify_plans``: run ``guardrails.check_plan_integrity`` on
      every plan swapped in by ``apply_delta`` — a corrupted plan is
      rejected at rebind while the old plan keeps serving.
    """
    max_queue: Optional[int] = None
    default_deadline_s: Optional[float] = None
    degrade_tol: float = 1e-3
    max_retries: int = 1
    max_step_retries: int = 1
    verify_plans: bool = True

    def replace(self, **kw) -> "ResilienceConfig":
        return dataclasses.replace(self, **kw)
