"""PCPM-distributed GraphCast: message passing over the sharded PNG.

The baseline GNN forward (gnn.py) lets XLA implement ``h[edge_src]`` as
an ALL-GATHER of the full node tensor (N x C per device) and the
segment-sum as an ALL-REDUCE of full-size partials — the distributed
analogue of BVGAS (one value per cross-shard edge, plus full
materialization).  This module is the paper's technique applied instead:

  scatter phase   each shard sends h[u] ONCE per destination shard that
                  needs it (the deduplicated ``send_ids`` update list of
                  core/distributed.ShardedPNG) via one all-to-all of
                  dense compressed buffers;
  gather phase    each shard expands its receive buffer over its local
                  edge list (``edge_upd`` indices — the branch-free
                  analogue of the paper's MSB stream) and segment-sums
                  into LOCAL destinations only.

Per-device transient: S*U*C (receive buffer) instead of N*C
(all-gather); wire bytes divide by the wire compression r.  Used by the
dry-run ``--engine pcpm`` GNN cells and the §Perf hillclimb.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..configs.base import GNNConfig
from ..core.distributed import ShardedPNG, build_sharded_png
from .gnn import mlp, init_graphcast


def _axis_names(mesh: Mesh):
    return tuple(mesh.axis_names)


@dataclasses.dataclass(frozen=True)
class DistGraph:
    """Per-shard static-shape graph structures (leading axis = shard)."""
    num_shards: int
    shard_size: int          # nodes per shard
    u_max: int               # updates per (src, dst) shard pair
    e_max: int               # edges per destination shard
    send_ids: jnp.ndarray    # (S, S, U) local src ids, pad -1
    edge_upd: jnp.ndarray    # (S, E) recv-buffer index, pad S*U
    edge_dst: jnp.ndarray    # (S, E) local dst ids, pad shard_size
    node_feat: jnp.ndarray   # (S*shard_size, d_feat)
    positions: jnp.ndarray   # (S*shard_size, 3)
    labels: jnp.ndarray      # (S*shard_size,)

    @staticmethod
    def from_png(layout: ShardedPNG, node_feat, positions, labels
                 ) -> "DistGraph":
        return DistGraph(
            layout.num_shards, layout.shard_size,
            int(layout.send_ids.shape[2]), int(layout.edge_upd.shape[1]),
            jnp.asarray(layout.send_ids), jnp.asarray(layout.edge_upd),
            jnp.asarray(layout.edge_dst), jnp.asarray(node_feat),
            jnp.asarray(positions), jnp.asarray(labels))

    @staticmethod
    def abstract(n_shards: int, shard_size: int, u_max: int, e_max: int,
                 d_feat: int) -> "DistGraph":
        """ShapeDtypeStruct stand-in for the dry run.  u_max/e_max are
        the padded layout sizes a production loader computes from the
        real graph (see EXPERIMENTS.md §Perf for the ogb estimate)."""
        sds = jax.ShapeDtypeStruct
        n = n_shards * shard_size
        return DistGraph(
            n_shards, shard_size, u_max, e_max,
            sds((n_shards, n_shards, u_max), jnp.int32),
            sds((n_shards, e_max), jnp.int32),
            sds((n_shards, e_max), jnp.int32),
            sds((n, d_feat), jnp.float32),
            sds((n, 3), jnp.float32),
            sds((n,), jnp.int32))


jax.tree_util.register_pytree_node(
    DistGraph,
    lambda d: ((d.send_ids, d.edge_upd, d.edge_dst, d.node_feat,
                d.positions, d.labels),
               (d.num_shards, d.shard_size, d.u_max, d.e_max)),
    lambda aux, ch: DistGraph(aux[0], aux[1], aux[2], aux[3], *ch))


def dist_graph_shardings(mesh: Mesh, like: DistGraph) -> DistGraph:
    """NamedSharding pytree matching DistGraph (vertex axis over ALL
    mesh axes; per-shard tables sharded on the leading shard dim).
    Pytree aux metadata is copied from ``like`` (jit requires the
    sharding prefix tree's metadata to match the argument's)."""
    ax = _axis_names(mesh)
    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    return DistGraph(
        like.num_shards, like.shard_size, like.u_max, like.e_max,
        ns(ax, None, None), ns(ax, None), ns(ax, None),
        ns(ax, None), ns(ax, None), ns(ax))


def graphcast_dist_forward(params: dict, cfg: GNNConfig, g: DistGraph,
                           mesh: Mesh,
                           unroll_layers: bool = False) -> jnp.ndarray:
    """GraphCast forward with PCPM-exchange message passing.

    Same math as gnn.graphcast_forward for a graph whose edges are the
    sharded-PNG streams; returns (N, n_out) node outputs.  Layers scan
    (memory-bounded; see gnn._scan_gnn_layers) with per-layer remat;
    activations follow cfg.act_dtype.
    """
    ax = _axis_names(mesh)
    S, ssz, U = g.num_shards, g.shard_size, g.u_max
    d = cfg.d_hidden
    ad = jnp.dtype(cfg.act_dtype)

    def local(node_feat, positions, labels, send_ids, edge_upd,
              edge_dst, lparams):
        # shapes here are PER-DEVICE: node_feat (ssz, d_feat), tables
        # (1, ...) on their leading shard dim.
        send_ids, edge_upd, edge_dst = (send_ids[0], edge_upd[0],
                                        edge_dst[0])
        if ad != jnp.float32:
            cast = (lambda x: x.astype(ad)
                    if x.dtype == jnp.float32 else x)
            lparams = jax.tree.map(cast, lparams)
            node_feat, positions = cast(node_feat), cast(positions)
        h = mlp(lparams["node_enc"], node_feat)            # (ssz, d)

        def exchange(x):
            """PCPM scatter: dedup'd per-pair buffers, one all-to-all.
            x (ssz, c) -> recv (S*U + 1, c), last row = zero pad slot."""
            ids = send_ids                                  # (S, U)
            bufs = x[jnp.clip(ids, 0, ssz - 1)] \
                * (ids >= 0)[..., None].astype(x.dtype)     # (S, U, c)
            recv = jax.lax.all_to_all(bufs, ax, 0, 0, tiled=True)
            recv = recv.reshape(S * U, x.shape[-1])
            return jnp.concatenate(
                [recv, jnp.zeros((1, x.shape[-1]), x.dtype)], 0)

        # edge geometry from exchanged positions
        pos_recv = exchange(positions)                      # (S*U+1, 3)
        pos_src = pos_recv[edge_upd]                        # (E, 3)
        pos_dst = positions[jnp.clip(edge_dst, 0, ssz - 1)]
        rel = pos_src - pos_dst
        dist = jnp.sqrt(jnp.sum(rel * rel, -1, keepdims=True) + 1e-18)
        e0 = mlp(lparams["edge_enc"], jnp.concatenate([dist, rel], -1))
        valid = (edge_dst < ssz)[:, None].astype(e0.dtype)  # pad mask

        def layer(carry, lyr):
            h, e = carry
            hs = exchange(h)[edge_upd]                      # (E, d)
            hd = h[jnp.clip(edge_dst, 0, ssz - 1)]
            e = e + mlp(lyr["edge_mlp"],
                        jnp.concatenate([e, hs, hd], -1))
            agg = jax.ops.segment_sum(e * valid, edge_dst,
                                      num_segments=ssz + 1)[:ssz]
            h = h + mlp(lyr["node_mlp"], jnp.concatenate([h, agg], -1))
            return (h, e)

        from .gnn import _scan_gnn_layers
        h, _ = _scan_gnn_layers(layer, (h, e0), lparams["layers"],
                                unroll_layers)
        return mlp(lparams["dec"], h)                       # (ssz, n_out)

    vec = P(ax)
    mat1 = P(ax, None)
    mat2 = P(ax, None, None)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(mat1, mat1, vec, mat2, mat1, mat1, P()),
                   out_specs=mat1)
    return fn(g.node_feat, g.positions, g.labels, g.send_ids,
              g.edge_upd, g.edge_dst, params)


def make_dist_train_step(cfg: GNNConfig, optimizer, mesh: Mesh, *,
                         n_out: int, unroll_layers: bool = False):
    def loss_fn(params, g: DistGraph):
        out = graphcast_dist_forward(params, cfg, g, mesh,
                                     unroll_layers)
        logp = jax.nn.log_softmax(out.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, g.labels[:, None], -1)[:, 0]
        return nll.mean()

    def step(params, opt_state, g: DistGraph):
        loss, grads = jax.value_and_grad(loss_fn)(params, g)
        params, opt_state, gnorm = optimizer.update(grads, opt_state,
                                                    params)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}
    return step


# --------------------------------------------------- layout estimation
def estimate_u_max(n: int, e: int, s: int, *, skew: float = 4.0) -> int:
    """Padded updates per shard pair for a uniform-ish graph: unique
    sources u_p = Ns(1 - exp(-m_p/Ns)), padded by ``skew`` for degree
    skew, rounded to 128."""
    ns, mp = n / s, e / (s * s)
    u = ns * (1.0 - np.exp(-mp / ns)) * skew
    return max(128, int(-(-u // 128) * 128))
