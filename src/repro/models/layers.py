"""Shared neural-net layers (pure functional JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..launch.sharding import shard


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale).astype(dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 1e6) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = shard(h, "batch", None, "ff")
    return h @ w_down


def dense_init(key, shape, *, scale: float | None = None,
               dtype=jnp.bfloat16) -> jnp.ndarray:
    fan_in = shape[-2] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32)
            * scale).astype(dtype)


def chunked_attention(q, k, v, *, causal=True, window=None, chunk=1024,
                      kv_len=None, unroll=False):
    """Online-softmax attention, lax.scan over KV chunks.

    Pure-XLA flash attention: O(S) live memory in the compiled program
    (the S^2 score matrix never materializes).  This is the TPU dry-run
    path for long sequences; the Pallas kernel is the on-chip version.

    ``unroll=True`` replaces the scan with a python loop over the same
    chunk bodies — used by the dry-run COST pass, where HloCostAnalysis
    counts a while body once regardless of trip count.

    q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D).  Sq == Skv (prefill/train).
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    assert skv % chunk == 0, "pad kv to chunk multiple"
    group = hq // hkv
    scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(sq) + (skv - sq)

    kc = k.reshape(b, skv // chunk, chunk, hkv, d)
    vc = v.reshape(b, skv // chunk, chunk, hkv, d)

    def step(carry, inputs):
        m_prev, l_prev, acc = carry
        kb, vb, c_idx = inputs
        kb = jnp.repeat(kb.astype(jnp.float32), group, axis=2)
        vb = jnp.repeat(vb.astype(jnp.float32), group, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb)
        k_pos = c_idx * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        if kv_len is not None:
            mask &= k_pos[None, :] < kv_len
        s = jnp.where(mask[None, None], s, -1e30)
        m_cur = s.max(-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask[None, None], jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, hq, sq), -1e30, jnp.float32),
            jnp.zeros((b, hq, sq), jnp.float32),
            jnp.zeros((b, hq, sq, d), jnp.float32))
    if unroll:
        carry = init
        for c_idx in range(skv // chunk):
            carry, _ = step(carry, (kc[:, c_idx], vc[:, c_idx],
                                    jnp.int32(c_idx)))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            step, init,
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             jnp.arange(skv // chunk)))
    out = acc / jnp.where(l[..., None] == 0, 1.0, l[..., None])
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def dense_attention(q, k, v, *, causal=True, window=None, kv_len=None):
    """Plain masked attention (short sequences / decode)."""
    from ..kernels.flash_attention.ref import mha_ref
    out = mha_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                  v.transpose(0, 2, 1, 3), causal=causal, window=window,
                  kv_len=kv_len)
    return out.transpose(0, 2, 1, 3)
