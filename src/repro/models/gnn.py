"""GNN architectures: GraphCast (interaction-network MPNN), NequIP and
MACE (CG tensor-product equivariant), EquiformerV2 (eSCN SO(2) attention).

All message passing goes through ``aggregate`` = segment-sum over a
destination-sorted edge list — the single-device view of the PCPM
schedule (distributed: edges are grouped by destination shard and source
features cross the interconnect once per (src, dst-shard) pair via the
PNG update stream; see core/distributed.py).

Graphs arrive as a ``GraphBatch`` with static shapes (padded edges are
masked).  Equivariant models additionally use ``positions``; generic
benchmark graphs (cora/ogbn) synthesize unit-sphere positions — the
architecture is exercised as assigned even where the dataset is not
molecular (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import GNNConfig
from ..launch.sharding import shard
from .equivariant import (sh_basis, wigner_d, rotation_to_z, cg_real,
                          bessel_rbf)


# ------------------------------------------------------------------ data
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    edge_src: jnp.ndarray          # (E,) int32
    edge_dst: jnp.ndarray          # (E,) int32
    edge_mask: jnp.ndarray         # (E,) f32
    node_feat: jnp.ndarray         # (N, d_feat)
    positions: jnp.ndarray         # (N, 3)
    node_mask: jnp.ndarray         # (N,) f32
    graph_id: jnp.ndarray          # (N,) int32 (0 for single graph)
    n_graphs: int
    labels: jnp.ndarray            # (N,) int32 node labels

    @property
    def num_nodes(self) -> int:
        return self.node_feat.shape[0]


jax.tree_util.register_pytree_node(
    GraphBatch,
    lambda g: ((g.edge_src, g.edge_dst, g.edge_mask, g.node_feat,
                g.positions, g.node_mask, g.graph_id, g.labels),
               (g.n_graphs,)),
    lambda aux, ch: GraphBatch(ch[0], ch[1], ch[2], ch[3], ch[4], ch[5],
                               ch[6], aux[0], ch[7]))


def random_graph_batch(rng: np.random.Generator, n_nodes: int,
                       n_edges: int, d_feat: int, *, n_graphs: int = 1,
                       n_classes: int = 8) -> GraphBatch:
    if n_graphs > 1:
        per = n_nodes // n_graphs
        gid = np.repeat(np.arange(n_graphs), per).astype(np.int32)
        src = (rng.integers(0, per, n_edges)
               + np.repeat(np.arange(n_graphs),
                           n_edges // n_graphs) * per)
        dst = (rng.integers(0, per, n_edges)
               + np.repeat(np.arange(n_graphs),
                           n_edges // n_graphs) * per)
    else:
        gid = np.zeros(n_nodes, np.int32)
        src = rng.integers(0, n_nodes, n_edges)
        dst = rng.integers(0, n_nodes, n_edges)
    pos = rng.standard_normal((n_nodes, 3))
    pos /= np.linalg.norm(pos, axis=1, keepdims=True)
    return GraphBatch(
        jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
        jnp.ones(n_edges, jnp.float32),
        jnp.asarray(rng.standard_normal((n_nodes, d_feat)), jnp.float32),
        jnp.asarray(pos, jnp.float32), jnp.ones(n_nodes, jnp.float32),
        jnp.asarray(gid), n_graphs,
        jnp.asarray(rng.integers(0, n_classes, n_nodes), jnp.int32))


def aggregate(values: jnp.ndarray, dst: jnp.ndarray, num_nodes: int,
              mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """PCPM-schedule aggregation: segment-sum by destination."""
    if mask is not None:
        values = values * mask.reshape(mask.shape + (1,) *
                                       (values.ndim - 1))
    return jax.ops.segment_sum(values, dst, num_segments=num_nodes)


def _scan_gnn_layers(layer_fn, carry, layers_list, unroll: bool):
    """Run identical per-layer bodies via lax.scan over stacked params.

    scan (not a python loop) is load-bearing for memory: each body's
    all-gathered node tensors live only inside one loop iteration, so
    the scheduler cannot hoist 16 layers' worth of 5 GB transients into
    flight at once.  ``unroll=True`` keeps the python loop for the
    dry-run COST pass (HloCostAnalysis counts a while body once).
    """
    wrapped = jax.checkpoint(layer_fn)
    if unroll or len(layers_list) == 1:
        for lyr in layers_list:
            carry = wrapped(carry, lyr)
        return carry
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers_list)

    def body(c, lp):
        return wrapped(c, lp), None

    carry, _ = jax.lax.scan(body, carry, stacked)
    return carry


# ------------------------------------------------------------------ MLPs
def init_mlp(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {"w": (jax.random.normal(k, (i, o), jnp.float32)
               * (i ** -0.5)).astype(dtype),
         "b": jnp.zeros((o,), dtype)}
        for k, i, o in zip(ks, dims[:-1], dims[1:])]


def mlp(params, x):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            x = jax.nn.silu(x)
    return x


# ============================================================= GraphCast
def init_graphcast(cfg: GNNConfig, key, d_feat: int, n_out: int) -> dict:
    d = cfg.d_hidden
    ks = jax.random.split(key, 4 + 2 * cfg.n_layers)
    p = {
        "node_enc": init_mlp(ks[0], (d_feat, d, d)),
        "edge_enc": init_mlp(ks[1], (4, d, d)),       # [dist, unit vec]
        "dec": init_mlp(ks[2], (d, d, n_out)),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        p["layers"].append({
            "edge_mlp": init_mlp(ks[3 + 2 * i], (3 * d, d, d)),
            "node_mlp": init_mlp(ks[4 + 2 * i], (2 * d, d, d)),
        })
    return p


def graphcast_forward(params: dict, cfg: GNNConfig, g: GraphBatch,
                      unroll_layers: bool = False) -> jnp.ndarray:
    n = g.num_nodes
    h = mlp(params["node_enc"], g.node_feat)
    h = shard(h, "nodes", "chan")
    rel = g.positions[g.edge_src] - g.positions[g.edge_dst]
    dist = jnp.sqrt(jnp.sum(rel * rel, -1, keepdims=True) + 1e-18)
    e = mlp(params["edge_enc"], jnp.concatenate([dist, rel], -1))
    e = shard(e, "edges", "chan")
    def layer(carry, lyr):
        h, e = carry
        hs = shard(h[g.edge_src], "edges", "chan")  # PCPM-deduped gather
        hd = shard(h[g.edge_dst], "edges", "chan")
        e = e + mlp(lyr["edge_mlp"], jnp.concatenate([e, hs, hd], -1))
        e = shard(e, "edges", "chan")
        agg = shard(aggregate(e, g.edge_dst, n, g.edge_mask),
                    "nodes", "chan")
        h = h + mlp(lyr["node_mlp"], jnp.concatenate([h, agg], -1))
        return shard(h, "nodes", "chan"), e

    h, e = _scan_gnn_layers(layer, (h, e), params["layers"],
                            unroll_layers)
    return mlp(params["dec"], h)                 # (N, n_out)


# ====================================================== irreps utilities
def _irreps_cat(xs: list, n: int) -> jnp.ndarray:
    """Concat per-l (N, C, 2l+1) irreps into one (N, C*sum(2l+1))."""
    return jnp.concatenate([x.reshape(n, -1) for x in xs], -1)


def _irreps_split(x: jnp.ndarray, c: int, l_max: int) -> list:
    out, off = [], 0
    for l in range(l_max + 1):
        d = c * (2 * l + 1)
        out.append(x[:, off:off + d].reshape(-1, c, 2 * l + 1))
        off += d
    return out


def _paths(l_max: int):
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l_max, l1 + l2) + 1):
                if cg_real(l1, l2, l3) is not None:
                    out.append((l1, l2, l3))
    return out


def _zeros_irreps(n: int, c: int, l_max: int, dtype=jnp.float32):
    return [jnp.zeros((n, c, 2 * l + 1), dtype)
            for l in range(l_max + 1)]


def _edge_geometry(g: GraphBatch, cfg: GNNConfig):
    rel = g.positions[g.edge_src] - g.positions[g.edge_dst]
    dist = jnp.sqrt(jnp.sum(rel * rel, -1) + 1e-18)
    unit = rel / jnp.maximum(dist[..., None], 1e-9)
    # degenerate (zero-length / self-loop) edges carry no direction:
    # zero their radial weights so every geometric message path vanishes
    # (keeps SO(3) equivariance exact — SH of a zero vector is undefined).
    valid = (dist > 1e-6).astype(dist.dtype)
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff or 5.0) * valid[:, None]
    return rel, dist, unit, rbf


# ================================================================ NequIP
def init_nequip(cfg: GNNConfig, key, d_feat: int, n_out: int) -> dict:
    c, lm = cfg.d_hidden, cfg.l_max
    paths = _paths(lm)
    ks = jax.random.split(key, 3 + 2 * cfg.n_layers)
    p = {"embed": init_mlp(ks[0], (d_feat, c)),
         "readout": init_mlp(ks[1], (c, c, n_out)), "layers": []}
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[2 + i])
        p["layers"].append({
            "radial": init_mlp(k1, (cfg.n_rbf, c, len(paths) * c)),
            "mix": [(jax.random.normal(jax.random.fold_in(k2, l),
                                       (c, c), jnp.float32) * c ** -0.5)
                    for l in range(lm + 1)],
            "gate": init_mlp(jax.random.fold_in(k2, 99), (c, lm * c)),
        })
    return p


def nequip_forward(params: dict, cfg: GNNConfig, g: GraphBatch,
                   unroll_layers: bool = False) -> jnp.ndarray:
    n, c, lm = g.num_nodes, cfg.d_hidden, cfg.l_max
    paths = _paths(lm)
    _, dist, unit, rbf = _edge_geometry(g, cfg)
    sh = sh_basis(unit, lm)                      # per l: (E, 2l+1)
    ad = params["embed"][0]["w"].dtype
    h = _zeros_irreps(n, c, lm, ad)
    h[0] = mlp(params["embed"], g.node_feat)[..., None]  # (N, C, 1)

    def layer(h, lyr):
        rw = mlp(lyr["radial"], rbf).reshape(-1, len(paths), c)  # (E,P,C)
        # ONE fused gather and ONE fused aggregate per layer: the
        # node-space tensors are the big all-gathered/all-reduced ones,
        # so all l's travel concatenated; per-path work stays edge-local.
        hs = _irreps_split(
            shard(_irreps_cat(h, n)[g.edge_src], "edges", "chan"), c, lm)
        msg_e: list = [None] * (lm + 1)
        for pi, (l1, l2, l3) in enumerate(paths):
            cgt = jnp.asarray(cg_real(l1, l2, l3), ad)
            m = jnp.einsum("eci,ej,ijk->eck", hs[l1], sh[l2], cgt)
            m = m * rw[:, pi, :, None]
            msg_e[l3] = m if msg_e[l3] is None else msg_e[l3] + m
        e_cnt = g.edge_src.shape[0]
        agg = aggregate(_irreps_cat(msg_e, e_cnt), g.edge_dst, n,
                        g.edge_mask)
        msg = _irreps_split(shard(agg, "nodes", "chan"), c, lm)
        # self-interaction + gated nonlinearity
        gates = jax.nn.sigmoid(mlp(lyr["gate"], msg[0][..., 0])
                               ).reshape(n, lm, c) if lm else None
        out = list(h)
        for l in range(lm + 1):
            mixed = jnp.einsum("eci,cd->edi", msg[l], lyr["mix"][l])
            if l == 0:
                out[0] = h[0] + jax.nn.silu(mixed)
            else:
                out[l] = h[l] + mixed * gates[:, l - 1, :, None]
            out[l] = shard(out[l], "nodes", "chan", None)
        return out

    h = _scan_gnn_layers(layer, h, params["layers"], unroll_layers)
    return mlp(params["readout"], h[0][..., 0])          # (N, n_out)


# ================================================================== MACE
def init_mace(cfg: GNNConfig, key, d_feat: int, n_out: int) -> dict:
    c, lm = cfg.d_hidden, cfg.l_max
    paths = _paths(lm)
    ks = jax.random.split(key, 3 + cfg.n_layers)
    p = {"embed": init_mlp(ks[0], (d_feat, c)),
         "readout": init_mlp(ks[1], (c, c, n_out)), "layers": []}
    for i in range(cfg.n_layers):
        k = ks[2 + i]
        p["layers"].append({
            "radial": init_mlp(jax.random.fold_in(k, 0),
                               (cfg.n_rbf, c, (lm + 1) * c)),
            # product-basis weights per correlation order nu=2,3
            "b2": [(jax.random.normal(jax.random.fold_in(k, 10 + l),
                                      (c, c), jnp.float32) * c ** -0.5)
                   for l in range(lm + 1)],
            "b3": [(jax.random.normal(jax.random.fold_in(k, 20 + l),
                                      (c, c), jnp.float32) * c ** -0.5)
                   for l in range(lm + 1)],
            "mix": [(jax.random.normal(jax.random.fold_in(k, 30 + l),
                                       (c, c), jnp.float32) * c ** -0.5)
                    for l in range(lm + 1)],
        })
    return p


def mace_forward(params: dict, cfg: GNNConfig, g: GraphBatch,
                 unroll_layers: bool = False) -> jnp.ndarray:
    """Higher-order (ACE) message passing, correlation order 3:
    A-basis = neighbor sum of radial x SH x src scalars;
    B-basis  = A, CG(A,A), CG(CG(A,A),A) — symmetrized products."""
    n, c, lm = g.num_nodes, cfg.d_hidden, cfg.l_max
    nu = cfg.correlation_order
    _, dist, unit, rbf = _edge_geometry(g, cfg)
    sh = sh_basis(unit, lm)
    ad = params["embed"][0]["w"].dtype
    h0 = mlp(params["embed"], g.node_feat)              # (N, C)

    def layer(h0, lyr):
        rw = mlp(lyr["radial"], rbf).reshape(-1, lm + 1, c)   # (E, L, C)
        # A-basis: A^l_i = sum_j R_l(r) Y_l(r̂) * h0_j — node-space
        # tensors are the big ones, so all l's aggregate in ONE fused
        # segment-sum and shard immediately.
        hs = shard(h0[g.edge_src], "edges", "chan")
        m_e = [rw[:, l, :, None] * hs[:, :, None] * sh[l][:, None, :]
               for l in range(lm + 1)]
        e_cnt = g.edge_src.shape[0]
        agg = aggregate(_irreps_cat(m_e, e_cnt), g.edge_dst, n,
                        g.edge_mask)
        A = _irreps_split(shard(agg, "nodes", "chan"), c, lm)
        out0 = jnp.einsum("nci,cd->ndi", A[0], lyr["mix"][0])
        if nu >= 2:
            # B2^0 via CG(A^l, A^l -> 0); higher outputs folded to l=0
            for l in range(lm + 1):
                cgt = cg_real(l, l, 0)
                if cgt is None:
                    continue
                b2 = jnp.einsum("nci,ncj,ijk->nck", A[l], A[l],
                                jnp.asarray(cgt, ad))
                out0 = out0 + jnp.einsum("nci,cd->ndi", b2, lyr["b2"][l])
        if nu >= 3:
            for l in range(1, lm + 1):
                # CG(A^l, A^l -> l) then CG(. , A^l -> 0)
                c1 = cg_real(l, l, l)
                c2 = cg_real(l, l, 0)
                if c1 is None or c2 is None:
                    continue
                t = jnp.einsum("nci,ncj,ijk->nck", A[l], A[l],
                               jnp.asarray(c1, ad))
                b3 = jnp.einsum("nci,ncj,ijk->nck", t, A[l],
                                jnp.asarray(c2, ad))
                out0 = out0 + jnp.einsum("nci,cd->ndi", b3, lyr["b3"][l])
        return shard(h0 + jax.nn.silu(out0[..., 0]), "nodes", "chan")

    h0 = _scan_gnn_layers(layer, h0, params["layers"], unroll_layers)
    return mlp(params["readout"], h0)                    # (N, n_out)


# ========================================================= EquiformerV2
def init_equiformer(cfg: GNNConfig, key, d_feat: int, n_out: int) -> dict:
    c, lm, mm = cfg.d_hidden, cfg.l_max, cfg.m_max
    ks = jax.random.split(key, 3 + cfg.n_layers)
    p = {"embed": init_mlp(ks[0], (d_feat, c)),
         "readout": init_mlp(ks[1], (c, c, n_out)), "layers": []}
    lsz = lm + 1
    for i in range(cfg.n_layers):
        k = ks[2 + i]
        lyr = {
            "radial": init_mlp(jax.random.fold_in(k, 0),
                               (cfg.n_rbf, c, c)),
            "attn": init_mlp(jax.random.fold_in(k, 1),
                             (2 * c, c, cfg.n_heads)),
            "ffn": init_mlp(jax.random.fold_in(k, 2), (c, 2 * c, c)),
            "w0": (jax.random.normal(jax.random.fold_in(k, 3),
                                     (lsz, c, lsz, c)) / (lsz * c) ** 0.5
                   ).astype(jnp.float32),
        }
        for m in range(1, mm + 1):
            lyr[f"w{m}_re"] = (jax.random.normal(
                jax.random.fold_in(k, 4 + 2 * m), (lsz, c, lsz, c))
                / (lsz * c) ** 0.5).astype(jnp.float32)
            lyr[f"w{m}_im"] = (jax.random.normal(
                jax.random.fold_in(k, 5 + 2 * m), (lsz, c, lsz, c))
                / (lsz * c) ** 0.5).astype(jnp.float32)
        p["layers"].append(lyr)
    return p


def _segment_softmax(logits, seg, num_segments):
    """Edge-softmax per destination; logits (E, ...) segments on axis 0."""
    mx = jax.ops.segment_max(logits, seg, num_segments=num_segments)
    e = jnp.exp(logits - mx[seg])
    den = jax.ops.segment_sum(e, seg, num_segments=num_segments)
    return e / jnp.maximum(den[seg], 1e-9)


def _scan_chunks(f, init, xs, unroll: bool):
    """lax.scan over leading chunk axis, or python loop for the dry-run
    cost pass (HloCostAnalysis counts a while body once)."""
    if not unroll:
        return jax.lax.scan(f, init, xs)
    carry, ys = init, []
    for i in range(jax.tree.leaves(xs)[0].shape[0]):
        carry, y = f(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    y_stack = (jax.tree.map(lambda *a: jnp.stack(a), *ys)
               if ys and ys[0] is not None else None)
    return carry, y_stack


def equiformer_forward(params: dict, cfg: GNNConfig, g: GraphBatch,
                       unroll_layers: bool = False) -> jnp.ndarray:
    """eSCN attention: rotate source irreps into the edge frame (Wigner
    D), SO(2)-convolve the |m| <= m_max components (O(L^3) instead of the
    O(L^6) dense tensor product), rotate back, edge-softmax aggregate.

    Edges are processed in CHUNKS (lax.scan, strided so each chunk stays
    sharded): at l_max=6 the per-edge irreps are 128x49 floats, so a
    62M-edge graph holds 1.5 TB of live edge features if materialized at
    once — the chunked schedule is the paper's partition-wise streaming
    applied as a memory bound.  The edge-softmax becomes online (carry
    running max / rescaled denominator across chunks); the weighted
    aggregate is a second chunked pass that recomputes the edge math
    (checkpoint-style) and accumulates into node space.
    """
    n, c, lm, mm = g.num_nodes, cfg.d_hidden, cfg.l_max, cfg.m_max
    nh = cfg.n_heads
    _, dist, unit, rbf = _edge_geometry(g, cfg)
    e_cnt = g.edge_src.shape[0]
    # chunk count is a PEAK-MEMORY knob only (totals are linear in
    # edges), so the unrolled cost pass uses one chunk — the 8-chunk
    # unroll at ogb scale OOMs the compiler host.
    nch = (8 if e_cnt >= (1 << 23) and e_cnt % 8 == 0
           and not unroll_layers else 1)
    dims_tot = sum(2 * l + 1 for l in range(lm + 1))

    def chunked(x):
        """(E, ...) -> (nch, E/nch, ...), chunks strided so each chunk
        keeps the full edge sharding."""
        if nch == 1:
            return x[None]
        y = jnp.moveaxis(x.reshape(e_cnt // nch, nch, *x.shape[1:]), 1, 0)
        return shard(y, None, "edges", *([None] * (x.ndim - 1)))

    ch = {k: chunked(v) for k, v in
          dict(src=g.edge_src, dst=g.edge_dst, mask=g.edge_mask,
               rbf=rbf, unit=unit).items()}

    ad = params["embed"][0]["w"].dtype
    h = _zeros_irreps(n, c, lm, ad)
    h[0] = mlp(params["embed"], g.node_feat)[..., None]
    h = [shard(x, "nodes", "chan", None) for x in h]

    def edge_block(lyr, hcat, h0row, src, dst, mask, rbf_k, unit_k):
        """Heavy per-chunk math -> (out irreps, dmats, logits)."""
        rot = rotation_to_z(unit_k)
        dmats = [wigner_d(l, rot) for l in range(lm + 1)]
        rw = mlp(lyr["radial"], rbf_k)                # (Ek, C)
        hs = _irreps_split(shard(hcat[src], "edges", "chan"), c, lm)
        xr = [jnp.einsum("eij,ecj->eci", dmats[l], hs[l])
              for l in range(lm + 1)]
        # SO(2) conv: m=0 real mix across (l, c)
        x0 = jnp.stack([xr[l][:, :, l] for l in range(lm + 1)], 1)
        y0 = jnp.einsum("elc,lckd->ekd", x0, lyr["w0"]) * rw[:, None, :]
        out = [jnp.zeros_like(x) for x in xr]
        for l in range(lm + 1):
            out[l] = out[l].at[:, :, l].set(y0[:, l, :])
        for m in range(1, mm + 1):
            ls = [l for l in range(lm + 1) if l >= m]
            xp = jnp.stack([xr[l][:, :, l + m] for l in ls], 1)
            xm = jnp.stack([xr[l][:, :, l - m] for l in ls], 1)
            wre = lyr[f"w{m}_re"][:len(ls), :, :len(ls), :]
            wim = lyr[f"w{m}_im"][:len(ls), :, :len(ls), :]
            yp = (jnp.einsum("elc,lckd->ekd", xp, wre)
                  - jnp.einsum("elc,lckd->ekd", xm, wim))
            ym = (jnp.einsum("elc,lckd->ekd", xp, wim)
                  + jnp.einsum("elc,lckd->ekd", xm, wre))
            for li, l in enumerate(ls):
                out[l] = out[l].at[:, :, l + m].set(yp[:, li] * rw)
                out[l] = out[l].at[:, :, l - m].set(ym[:, li] * rw)
        inv = jnp.concatenate([out[0][:, :, 0], h0row[dst]], -1)
        logits = (mlp(lyr["attn"], inv)
                  + jnp.log(jnp.maximum(mask, 1e-9))[:, None])  # (Ek, nh)
        return out, dmats, logits

    def layer(h, lyr):
        hcat = shard(_irreps_cat(h, n), "nodes", "chan")
        h0row = h[0][:, :, 0]                          # (N, C)

        # pass 1: online edge-softmax statistics (running max + denom)
        def p1(carry, inp):
            mx, den = carry
            out, _, logits = edge_block(lyr, hcat, h0row, *inp)
            mx_k = jax.ops.segment_max(logits, inp[1], num_segments=n)
            mx_new = jnp.maximum(mx, mx_k)
            scale = jnp.exp(mx - mx_new)
            e_k = jnp.exp(logits - mx_new[inp[1]])
            den_new = den * scale + jax.ops.segment_sum(
                e_k, inp[1], num_segments=n)
            return (mx_new, den_new), logits

        init = (jnp.full((n, nh), -1e30, jnp.float32),
                jnp.zeros((n, nh), jnp.float32))
        chunks = (ch["src"], ch["dst"], ch["mask"], ch["rbf"], ch["unit"])
        (mx, den), logits_all = _scan_chunks(
            jax.checkpoint(p1), init, chunks, unroll_layers)

        # pass 2: recompute edge math, weight by softmax, aggregate
        def p2(acc, inp):
            *edge_in, logits = inp
            out, dmats, _ = edge_block(lyr, hcat, h0row, *edge_in)
            dst, mask = edge_in[1], edge_in[2]
            alpha = jnp.exp(logits - mx[dst]) / jnp.maximum(den[dst],
                                                            1e-9)
            w_edge = alpha.mean(-1) * mask
            m_back = [jnp.einsum("eji,ecj->eci", dmats[l], out[l])
                      * w_edge[:, None, None] for l in range(lm + 1)]
            part = aggregate(_irreps_cat(m_back, m_back[0].shape[0]),
                             dst, n)
            return acc + shard(part, "nodes", "chan").astype(acc.dtype), \
                None

        acc0 = shard(jnp.zeros((n, c * dims_tot), jnp.float32),
                     "nodes", "chan")
        acc, _ = _scan_chunks(jax.checkpoint(p2), acc0,
                              chunks + (logits_all,), unroll_layers)
        msg = _irreps_split(acc, c, lm)
        hn = [shard(h[l] + msg[l].astype(h[l].dtype), "nodes", "chan",
                    None)
              for l in range(lm + 1)]
        hn[0] = hn[0] + mlp(lyr["ffn"], hn[0][..., 0])[..., None]
        return hn

    h = _scan_gnn_layers(layer, h, params["layers"], unroll_layers)
    return mlp(params["readout"], h[0][..., 0])


# ---------------------------------------------------------------- driver
FORWARDS = {"graphcast": graphcast_forward, "nequip": nequip_forward,
            "mace": mace_forward, "equiformer-v2": equiformer_forward}
INITS = {"graphcast": init_graphcast, "nequip": init_nequip,
         "mace": init_mace, "equiformer-v2": init_equiformer}


def init_gnn(cfg: GNNConfig, key, d_feat: int, n_out: int) -> dict:
    return INITS[cfg.name.replace("-smoke", "")](cfg, key, d_feat, n_out)


def gnn_forward(params, cfg: GNNConfig, g: GraphBatch,
                unroll_layers: bool = False) -> jnp.ndarray:
    ad = jnp.dtype(cfg.act_dtype)
    if ad != jnp.float32:
        # mixed precision: bf16 compute copies of params + float inputs
        # (grads flow through the casts back to the f32 masters).
        def cast(x):
            return (x.astype(ad)
                    if hasattr(x, "dtype") and x.dtype == jnp.float32
                    else x)
        params = jax.tree.map(cast, params)
        g = GraphBatch(g.edge_src, g.edge_dst, cast(g.edge_mask),
                       cast(g.node_feat), cast(g.positions),
                       cast(g.node_mask), g.graph_id, g.n_graphs,
                       g.labels)
    return FORWARDS[cfg.name.replace("-smoke", "")](
        params, cfg, g, unroll_layers)


def gnn_loss(params, cfg: GNNConfig, g: GraphBatch, *, n_out: int,
             unroll_layers: bool = False):
    out = gnn_forward(params, cfg, g, unroll_layers)  # (N, n_out)
    logp = jax.nn.log_softmax(out.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, g.labels[:, None], -1)[:, 0]
    return jnp.sum(nll * g.node_mask) / jnp.maximum(g.node_mask.sum(), 1)


def make_gnn_train_step(cfg: GNNConfig, optimizer, *, n_out: int,
                        unroll_layers: bool = False):
    def step(params, opt_state, g: GraphBatch):
        loss, grads = jax.value_and_grad(
            lambda p: gnn_loss(p, cfg, g, n_out=n_out,
                               unroll_layers=unroll_layers))(params)
        params, opt_state, gnorm = optimizer.update(grads, opt_state,
                                                    params)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}
    return step
