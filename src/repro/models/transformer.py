"""LM transformer (dense + MoE): GQA, RoPE, RMSNorm, SwiGLU, sliding-
window attention, scan-over-layers, KV-cache decode.

Functional: params are a plain pytree; ``init_lm`` materializes them,
``param_shapes`` (via jax.eval_shape) gives ShapeDtypeStructs for the
dry run.  Distribution is expressed through logical-axis sharding
constraints (launch/sharding.py) — FSDP over (pod, data), TP over model.

MoE dispatch is PCPM-inspired (DESIGN.md §4): tokens are routed with a
capacity-bounded scatter that groups them contiguously per destination
expert — the partition-centric ordering — so the all-to-all moves dense
buffers, not per-token scatters.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import LMConfig
from ..launch.sharding import shard, divides
from .. import perf_flags
from .layers import (rms_norm, rope, dense_init, chunked_attention,
                     dense_attention)

PARAM_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------- params
def init_lm(cfg: LMConfig, key) -> dict:
    l, d, dh = cfg.n_layers, cfg.d_model, cfg.dh
    hq, hkv, f, v = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab
    ks = jax.random.split(key, 16)
    layer = {
        "attn_norm": jnp.ones((l, d), PARAM_DTYPE),
        "ffn_norm": jnp.ones((l, d), PARAM_DTYPE),
        "wq": dense_init(ks[0], (l, d, hq * dh)),
        "wk": dense_init(ks[1], (l, d, hkv * dh)),
        "wv": dense_init(ks[2], (l, d, hkv * dh)),
        "wo": dense_init(ks[3], (l, hq * dh, d)),
    }
    if cfg.moe:
        e = cfg.n_experts
        layer.update(
            router=dense_init(ks[4], (l, d, e), dtype=jnp.float32),
            w_gate=dense_init(ks[5], (l, e, d, f)),
            w_up=dense_init(ks[6], (l, e, d, f)),
            w_down=dense_init(ks[7], (l, e, f, d)))
    else:
        layer.update(
            w_gate=dense_init(ks[5], (l, d, f)),
            w_up=dense_init(ks[6], (l, d, f)),
            w_down=dense_init(ks[7], (l, f, d)))
    return {
        "embed": dense_init(ks[8], (v, d), scale=1.0),
        "unembed": dense_init(ks[9], (d, v)),
        "final_norm": jnp.ones((d,), PARAM_DTYPE),
        "layers": layer,
    }


def param_shapes(cfg: LMConfig):
    return jax.eval_shape(lambda: init_lm(cfg, jax.random.key(0)))


def param_logical(cfg: LMConfig) -> dict:
    """Logical axes per param (leading scan axis = None)."""
    layer = {
        "attn_norm": (None, None), "ffn_norm": (None, None),
        "wq": (None, "fsdp", "model"), "wk": (None, "fsdp", "model"),
        "wv": (None, "fsdp", "model"), "wo": (None, "model", "fsdp"),
    }
    if cfg.moe:
        layer.update(router=(None, "fsdp", None),
                     w_gate=(None, "expert", "fsdp", "ff"),
                     w_up=(None, "expert", "fsdp", "ff"),
                     w_down=(None, "expert", "ff", "fsdp"))
    else:
        layer.update(w_gate=(None, "fsdp", "ff"),
                     w_up=(None, "fsdp", "ff"),
                     w_down=(None, "ff", "fsdp"))
    return {"embed": ("vocab", "fsdp"), "unembed": ("fsdp", "vocab"),
            "final_norm": (None,), "layers": layer}


def shard_params(params: dict, cfg: LMConfig) -> dict:
    return jax.tree.map(lambda p, ax: shard(p, *ax), params,
                        param_logical(cfg), is_leaf=lambda x: x is None)


# ----------------------------------------------------------------- blocks
def _attention_block(x, p, cfg: LMConfig, positions, attn_path: str):
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(b, s, hq, dh)
    k = (h @ p["wk"]).reshape(b, s, hkv, dh)
    v = (h @ p["wv"]).reshape(b, s, hkv, dh)
    q = shard(rope(q, positions, cfg.rope_theta), "batch", None, "heads",
              None)
    k = shard(rope(k, positions, cfg.rope_theta), "batch", None,
              "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    if attn_path.startswith("chunked"):
        o = chunked_attention(
            q, k, v, causal=True, window=cfg.window,
            chunk=min(perf_flags.value("attn_chunk", 1024, int), s),
            unroll=attn_path == "chunked_unroll")
    else:
        o = dense_attention(q, k, v, causal=True, window=cfg.window)
    o = shard(o, "batch", None, "heads", None)
    return x + o.reshape(b, s, hq * dh) @ p["wo"]


def _moe_ffn(h, p, cfg: LMConfig):
    """Capacity-bounded top-k MoE with PARTITION-LOCAL dispatch.

    Dispatch/combine are vmapped per sequence (the batch shard is the
    partition), so every gather/scatter index is local to a device and
    the only cross-device movement is the expert einsum's sharded
    contraction.  The earlier global-token-index dispatch made XLA move
    full (T, d) f32 buffers through all-reduce/collective-permute —
    ~30 GiB/layer on mixtral train (§Perf hillclimb A, confirmed).
    Capacity is per-sequence (GShard-style group capacity).
    Returns (out, aux_loss).
    """
    b, s, d = h.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(cfg.capacity_factor * s * k / e), 1)
    cap = -(-cap // 128) * 128 if cap > 128 else cap

    logits = (h.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))             # (B, S, E)
    gate_vals, experts = jax.lax.top_k(logits, k)            # (B, S, K)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    f_e = jnp.mean(jax.nn.one_hot(experts[..., 0], e), axis=(0, 1))
    aux = e * jnp.sum(f_e * probs.mean((0, 1)))

    def dispatch(xt, expert_s, gate_s):
        """One sequence: xt (S, d); returns this sequence's expert
        buffers and combine metadata — all indices local."""
        e_flat = expert_s.reshape(-1)                        # (S*K,)
        g_flat = gate_s.reshape(-1).astype(xt.dtype)
        t_flat = jnp.repeat(jnp.arange(s), k)
        oh = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)      # (SK, E)
        pos = jnp.sum(jnp.cumsum(oh, 0) * oh, -1) - 1        # slot
        keep = (pos < cap).astype(xt.dtype)
        xin = jnp.zeros((e, cap, d), xt.dtype)
        xin = xin.at[e_flat, pos].add(xt[t_flat] * keep[:, None],
                                      mode="drop")
        return xin, (e_flat, pos, g_flat * keep, t_flat)

    def combine(xout, meta):
        e_flat, pos, w_flat, t_flat = meta
        vals = xout[e_flat, jnp.clip(pos, 0, cap - 1)]       # (SK, d)
        yt = jnp.zeros((s, d), xout.dtype)
        return yt.at[t_flat].add(vals * w_flat[:, None])

    xin, meta = jax.vmap(dispatch)(h, experts, gates)        # (B,E,C,d)
    xin = shard(xin, "batch", "expert", None, None)
    act = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, p["w_gate"]))
    act = act * jnp.einsum("becd,edf->becf", xin, p["w_up"])
    act = shard(act, "batch", "expert", None, "ff")
    xout = jnp.einsum("becf,efd->becd", act, p["w_down"])
    xout = shard(xout, "batch", "expert", None, None)
    y = jax.vmap(combine)(xout, meta)                        # (B, S, d)
    return y, aux


def _ffn_block(x, p, cfg: LMConfig):
    h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    if cfg.moe:
        out, aux = _moe_ffn(h, p, cfg)
        return x + out, aux
    act = jax.nn.silu(h @ p["w_gate"]) * (h @ p["w_up"])
    act = shard(act, "batch", None, "ff")
    return x + act @ p["w_down"], jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------- scan
def _sqrt_block(n: int) -> int:
    """Divisor of n closest to sqrt(n) (sqrt-remat block size)."""
    best, target = 1, n ** 0.5
    for b in range(1, n + 1):
        if n % b == 0 and abs(b - target) < abs(best - target):
            best = b
    return best


def _scan_layers(body, carry, xs, n_layers: int, unroll: bool):
    """lax.scan over stacked layer params, or a python unroll.

    With the ``sqrt_remat`` perf flag, layers scan as (outer x block)
    nested scans with the checkpoint at the OUTER level: the residual
    stack holds L/b + b carries instead of L, at zero extra recompute
    (the per-layer checkpoint already recomputes each forward once) —
    §Perf hillclimb on the deep LMs (deepseek 95L, grok 64L).

    The unrolled form exists for the dry-run COST pass: XLA's
    HloCostAnalysis counts a while body once regardless of trip count,
    so roofline terms are derived from small unrolled programs
    (EXPERIMENTS.md §Roofline method)."""
    if not unroll:
        block = _sqrt_block(n_layers)
        if (not perf_flags.enabled("no_sqrt_remat")
                and 1 < block < n_layers):
            outer = n_layers // block
            xs_b = jax.tree.map(
                lambda a: a.reshape(outer, block, *a.shape[1:]), xs)

            def outer_body(c, xb):
                c, ys = jax.lax.scan(body, c, xb)
                return c, ys

            carry, ys = jax.lax.scan(
                jax.checkpoint(outer_body), carry, xs_b)
            if ys is not None:
                ys = jax.tree.map(
                    lambda a: a.reshape(n_layers, *a.shape[2:]), ys)
            return carry, ys
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(n_layers):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        y_stack = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        y_stack = None
    return carry, y_stack


# ---------------------------------------------------------------- forward
def forward(params: dict, cfg: LMConfig, tokens: jnp.ndarray, *,
            attn_path: str = "auto",
            unroll_layers: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> (logits (B, S, V), aux_loss)."""
    b, s = tokens.shape
    if attn_path == "auto":
        attn_path = "chunked" if s >= 2048 else "dense"
    x = shard(params["embed"][tokens], "batch", None, None)
    positions = jnp.arange(s)

    def body(carry, lp):
        x, aux = carry
        if perf_flags.enabled("gather_weights"):
            # FSDP discipline: un-shard THIS layer's weights up front so
            # no matmul contracts over a sharded dim (otherwise XLA
            # all-reduces activation-sized partials; §Perf hillclimb A).
            lp = jax.tree.map(
                lambda w: shard(w, *([None] * w.ndim)), lp)
        x = _attention_block(x, lp, cfg, positions, attn_path)
        x = shard(x, "batch", None, None)
        x, aux_l = _ffn_block(x, lp, cfg)
        x = shard(x, "batch", None, None)
        return (x, aux + aux_l), None

    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if perf_flags.enabled("remat_dots")
              else jax.checkpoint_policies.nothing_saveable)
    body = jax.checkpoint(body, policy=policy)
    (x, aux), _ = _scan_layers(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"], cfg.n_layers,
                               unroll_layers)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = shard(x @ params["unembed"], "batch", None, "vocab")
    return logits, aux / cfg.n_layers


def lm_loss(params: dict, cfg: LMConfig, tokens, labels, *,
            attn_path: str = "auto", aux_weight: float = 0.01,
            unroll_layers: bool = False):
    logits, aux = forward(params, cfg, tokens, attn_path=attn_path,
                          unroll_layers=unroll_layers)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: the gather would
    # force an all-gather of the vocab-sharded f32 logits; the one-hot
    # product keeps the vocab axis sharded end-to-end.
    onehot = shard(jax.nn.one_hot(labels, cfg.vocab, dtype=logits.dtype),
                   "batch", None, "vocab")
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = (lse - gold).mean()
    return nll + aux_weight * aux, (nll, aux)


def make_train_step(cfg: LMConfig, optimizer, *, attn_path: str = "auto",
                    unroll_layers: bool = False,
                    num_microbatches: int = 1):
    """Train step with optional gradient accumulation.

    ``num_microbatches > 1`` scans the global batch in slices, keeping
    activation temps 1/num_microbatches the size (the standard fit-in-HBM
    lever for the train_4k cells) and accumulating grads in f32.  The
    microbatch slicing is strided (B -> (micro, num_micro) reshape) so
    each microbatch stays fully sharded over the data axes.
    """
    def grad_fn(params, tokens, labels):
        return jax.value_and_grad(
            lambda p: lm_loss(p, cfg, tokens, labels,
                              attn_path=attn_path,
                              unroll_layers=unroll_layers),
            has_aux=True)(params)

    def train_step(params, opt_state, batch):
        params = shard_params(params, cfg)
        if num_microbatches == 1:
            (loss, (nll, aux)), grads = grad_fn(
                params, batch["tokens"], batch["labels"])
        else:
            b, s = batch["tokens"].shape
            nm = num_microbatches
            assert b % nm == 0, (b, nm)

            def mb(x):  # (B, S) -> (nm, B/nm, S), microbatches strided
                x = x.reshape(b // nm, nm, s).swapaxes(0, 1)
                return shard(x, None, "batch", None)
            toks, labs = mb(batch["tokens"]), mb(batch["labels"])

            def acc_step(carry, mb_batch):
                g_acc, l_acc, n_acc, a_acc = carry
                (loss, (nll, aux)), g = grad_fn(params, *mb_batch)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss, n_acc + nll, a_acc + aux), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            z = jnp.zeros((), jnp.float32)
            (grads, loss, nll, aux), _ = jax.lax.scan(
                acc_step, (g0, z, z, z), (toks, labs))
            grads = jax.tree.map(lambda g: g / nm, grads)
            loss, nll, aux = loss / nm, nll / nm, aux / nm
        grads = shard_params(grads, cfg)
        new_params, new_state, gnorm = optimizer.update(grads, opt_state,
                                                        params)
        new_params = shard_params(new_params, cfg)
        metrics = {"loss": loss, "nll": nll, "aux": aux, "gnorm": gnorm}
        return new_params, new_state, metrics
    return train_step


# ----------------------------------------------------------------- serve
def _cache_logical(cfg: LMConfig) -> tuple:
    """KV cache (B, S, Hkv, D): shard heads if divisible, else sequence."""
    if divides(cfg.n_kv_heads, "kv_heads"):
        return ("batch", None, "kv_heads", None)
    return ("batch", "kv_seq", None, None)


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    slots = min(max_len, cfg.window) if cfg.window else max_len
    shape = (cfg.n_layers, batch, slots, cfg.n_kv_heads, cfg.dh)
    return {"k": jnp.zeros(shape, PARAM_DTYPE),
            "v": jnp.zeros(shape, PARAM_DTYPE)}


def cache_shapes(cfg: LMConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def prefill(params: dict, cfg: LMConfig, tokens: jnp.ndarray, *,
            unroll_layers: bool = False):
    """Prefill: logits for all positions + KV cache (window-sized if SWA).

    serve_step for the `prefill_*` shape cells."""
    b, s = tokens.shape
    x = shard(params["embed"][tokens], "batch", None, None)
    positions = jnp.arange(s)
    slots = min(s, cfg.window) if cfg.window else s

    def body(x, lp):
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
        q = (h @ lp["wq"]).reshape(b, s, hq, dh)
        k = (h @ lp["wk"]).reshape(b, s, hkv, dh)
        v = (h @ lp["wv"]).reshape(b, s, hkv, dh)
        q = shard(rope(q, positions, cfg.rope_theta), "batch", None,
                  "heads", None)
        k = shard(rope(k, positions, cfg.rope_theta), "batch", None,
                  "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
        o = chunked_attention(
            q, k, v, causal=True, window=cfg.window,
            chunk=min(perf_flags.value("attn_chunk", 1024, int), s),
            unroll=unroll_layers)
        x = x + o.reshape(b, s, hq * dh) @ lp["wo"]
        x, _ = _ffn_block(x, lp, cfg)
        x = shard(x, "batch", None, None)
        kc = shard(k[:, -slots:], *_cache_logical(cfg))
        vc = shard(v[:, -slots:], *_cache_logical(cfg))
        return x, {"k": kc, "v": vc}

    x, cache = _scan_layers(body, x, params["layers"], cfg.n_layers,
                            unroll_layers)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = shard(x[:, -1:] @ params["unembed"], "batch", None, "vocab")
    return logits, cache


def decode_step(params: dict, cfg: LMConfig, cache: dict,
                tokens: jnp.ndarray, t: jnp.ndarray, *,
                unroll_layers: bool = False):
    """One token for every sequence in the batch.

    tokens (B, 1); t = current position — scalar (lockstep batch) or
    (B,) per-slot positions (continuous batching, serve/engine.py).
    serve_step for the `decode_*`/`long_*` cells."""
    b = tokens.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    slots = cache["k"].shape[2]
    t = jnp.asarray(t)
    per_slot = t.ndim == 1
    slot = (t % slots).astype(jnp.int32)
    kv_len = jnp.minimum(t + 1, slots)
    x = shard(params["embed"][tokens], "batch", None, None)
    positions = t.reshape(b, 1) if per_slot else jnp.full((1,), t,
                                                          jnp.int32)

    def write_cache(c, new, slot):
        if per_slot:
            return jax.vmap(lambda cb, nb, sb: jax.lax.dynamic_update_slice(
                cb, nb, (sb, 0, 0)))(c, new.astype(c.dtype), slot)
        return jax.lax.dynamic_update_slice(c, new.astype(c.dtype),
                                            (0, slot, 0, 0))

    def body(x, layer):
        lp, kc, vc = layer
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = (h @ lp["wq"]).reshape(b, 1, hq, dh)
        k = (h @ lp["wk"]).reshape(b, 1, hkv, dh)
        v = (h @ lp["wv"]).reshape(b, 1, hkv, dh)
        q = shard(rope(q, positions, cfg.rope_theta), "batch", None,
                  "heads", None)
        k = rope(k, positions, cfg.rope_theta)
        kc = shard(write_cache(kc, k, slot), *_cache_logical(cfg))
        vc = shard(write_cache(vc, v, slot), *_cache_logical(cfg))
        o = dense_attention(q, kc, vc, causal=False, kv_len=kv_len)
        x = x + o.reshape(b, 1, hq * dh) @ lp["wo"]
        x, _ = _ffn_block(x, lp, cfg)
        return shard(x, "batch", None, None), {"k": kc, "v": vc}

    x, new_cache = _scan_layers(
        body, x, (params["layers"], cache["k"], cache["v"]),
        cfg.n_layers, unroll_layers)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = shard(x @ params["unembed"], "batch", None, "vocab")
    return logits, new_cache
