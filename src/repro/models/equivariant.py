"""E(3)/SO(3)-equivariant substrate: real spherical harmonics, Wigner-D
matrices, Clebsch-Gordan couplings (NequIP / MACE / EquiformerV2).

Numerics strategy (no e3nn dependency):
- real SH up to l_max via associated-Legendre recurrences (jnp, traced);
- real Wigner-D per rotation via the sampling identity
  Y_l(R p_i) = D_l(R) Y_l(p_i)  =>  D_l(R) = Y_l(R P) pinv(Y_l(P)),
  with a fixed well-conditioned point set P (pinv precomputed, numpy);
- real CG tensors as the exact nullspace of the equivariance constraint
  (D1(R)⊗D2(R)) C D3(R)^T = C stacked over a few generic rotations
  (numpy SVD at build time; cached).  Couplings are SO(3)-exact; parity
  (O(3) pseudo-tensors) is not tracked — noted in DESIGN.md.
"""
from __future__ import annotations

import functools
import math

import numpy as np
import jax
import jax.numpy as jnp


# --------------------------------------------------------------- real SH
def sh_basis(vec, l_max: int, xp=jnp):
    """Real spherical harmonics for unit vectors.

    vec: (..., 3) -> list of arrays per l, each (..., 2l+1), index m+l.
    Convention: orthonormal on the sphere, Condon–Shortley included in
    the Legendre recurrence (consistent basis is all we need).

    ``xp=np`` computes in pure numpy — used by the Wigner/CG constant
    builders so they stay trace-safe (a jnp op inside a jit trace is
    staged, and np.asarray on the staged value would throw).
    """
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    r_xy = xp.sqrt(xp.maximum(x * x + y * y, 1e-24))
    cos_t = z
    sin_t = r_xy
    cos_p = x / r_xy
    sin_p = y / r_xy

    # associated Legendre P_l^m(cos_t) with sin_t supplied separately
    P = {}
    P[(0, 0)] = xp.ones_like(cos_t)
    for m in range(1, l_max + 1):
        P[(m, m)] = (-(2 * m - 1)) * sin_t * P[(m - 1, m - 1)]
    for m in range(0, l_max):
        P[(m + 1, m)] = (2 * m + 1) * cos_t * P[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = ((2 * l - 1) * cos_t * P[(l - 1, m)]
                         - (l + m - 1) * P[(l - 2, m)]) / (l - m)

    # cos(m phi), sin(m phi) by recurrence
    cos_m = [xp.ones_like(cos_p), cos_p]
    sin_m = [xp.zeros_like(sin_p), sin_p]
    for m in range(2, l_max + 1):
        cos_m.append(2 * cos_p * cos_m[-1] - cos_m[-2])
        sin_m.append(2 * cos_p * sin_m[-1] - sin_m[-2])

    out = []
    for l in range(l_max + 1):
        comps = []
        for m in range(-l, l + 1):
            am = abs(m)
            norm = math.sqrt((2 * l + 1) / (4 * math.pi)
                             * math.factorial(l - am)
                             / math.factorial(l + am))
            base = norm * P[(l, am)]
            if m == 0:
                comps.append(base)
            elif m > 0:
                comps.append(math.sqrt(2.0) * base * cos_m[am])
            else:
                comps.append(math.sqrt(2.0) * base * sin_m[am])
        out.append(xp.stack(comps, axis=-1))
    return out


def _sh_numpy(vec: np.ndarray, l_max: int):
    return sh_basis(np.asarray(vec, np.float64), l_max, xp=np)


# ------------------------------------------------------------- Wigner D
@functools.lru_cache(maxsize=None)
def _sample_points(l: int) -> tuple[np.ndarray, np.ndarray]:
    """(points P, pinv(Y_l(P))) for the Wigner-D sampling identity."""
    rng = np.random.default_rng(1234 + l)
    npts = 4 * l + 6
    pts = rng.standard_normal((npts, 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    y = _sh_numpy(pts, l)[l]                       # (P, 2l+1)
    return pts, np.linalg.pinv(y)


def wigner_d_np(l: int, rot: np.ndarray) -> np.ndarray:
    """Pure-numpy Wigner-D (constant builders; trace-safe)."""
    if l == 0:
        return np.ones(rot.shape[:-2] + (1, 1), np.float64)
    pts, pinv = _sample_points(l)
    rp = np.einsum("...ij,pj->...pi", rot, pts)
    y_rot = _sh_numpy(rp, l)[l]
    return np.einsum("mp,...pn->...nm", pinv, y_rot)


def wigner_d(l: int, rot: jnp.ndarray) -> jnp.ndarray:
    """Real Wigner-D for SO(3) rotation matrices rot: (..., 3, 3)
    -> (..., 2l+1, 2l+1), acting on real-SH coefficient vectors."""
    if l == 0:
        return jnp.ones(rot.shape[:-2] + (1, 1), rot.dtype)
    pts, pinv = _sample_points(l)
    rp = jnp.einsum("...ij,pj->...pi", rot, jnp.asarray(pts, rot.dtype))
    y_rot = sh_basis(rp, l)[l]                     # (..., P, 2l+1)
    # D such that Y(R p) = Y(p) D^T  (row-vector convention) =>
    # coefficients transform c' = D c with D = (pinv @ y_rot)^T
    return jnp.einsum("mp,...pn->...nm", jnp.asarray(pinv, rot.dtype),
                      y_rot)


def rotation_to_z(vec: jnp.ndarray) -> jnp.ndarray:
    """Rotation R with R @ v_hat = z_hat (rows = edge frame axes)."""
    v = vec / jnp.maximum(jnp.linalg.norm(vec, axis=-1, keepdims=True),
                          1e-12)
    aux = jnp.where(jnp.abs(v[..., 2:3]) < 0.9,
                    jnp.asarray([0.0, 0.0, 1.0], v.dtype),
                    jnp.asarray([1.0, 0.0, 0.0], v.dtype))
    x = aux - jnp.sum(aux * v, -1, keepdims=True) * v
    x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    y = jnp.cross(v, x)
    return jnp.stack([x, y, v], axis=-2)           # rows


# ------------------------------------------------------ Clebsch-Gordan
@functools.lru_cache(maxsize=None)
def cg_real(l1: int, l2: int, l3: int) -> np.ndarray | None:
    """Real-basis CG tensor C: (2l1+1, 2l2+1, 2l3+1) with
    (D1 ⊗ D2) C = C D3 for all rotations; None if coupling is empty.
    Exact nullspace over a few generic rotations, normalized so that
    sum C^2 = 2l3+1."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return None
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    rng = np.random.default_rng(7)
    mats = []
    for _ in range(3):
        q = rng.standard_normal(4)
        q /= np.linalg.norm(q)
        w, xq, yq, zq = q
        rot = np.array([
            [1 - 2 * (yq * yq + zq * zq), 2 * (xq * yq - zq * w),
             2 * (xq * zq + yq * w)],
            [2 * (xq * yq + zq * w), 1 - 2 * (xq * xq + zq * zq),
             2 * (yq * zq - xq * w)],
            [2 * (xq * zq - yq * w), 2 * (yq * zq + xq * w),
             1 - 2 * (xq * xq + yq * yq)]])
        D1 = wigner_d_np(l1, rot)
        D2 = wigner_d_np(l2, rot)
        D3 = wigner_d_np(l3, rot)
        # constraint: (D1⊗D2) C - C D3 = 0, C flattened (d1 d2, d3)
        A = np.kron(D1, D2)
        # vec-form: (A ⊗ I - I ⊗ D3^T) vec(C) = 0
        mats.append(np.kron(A, np.eye(d3))
                    - np.kron(np.eye(d1 * d2), D3.T))
    big = np.concatenate(mats, axis=0)
    _, s, vt = np.linalg.svd(big)
    null = vt[s.size - np.sum(s < 1e-8):] if np.sum(s < 1e-8) else vt[-1:]
    if np.sum(s < 1e-8) == 0 and s[-1] > 1e-6:
        return None
    c = null[-1].reshape(d1, d2, d3)
    c *= math.sqrt(d3) / np.linalg.norm(c)
    return c


def couple(x1: jnp.ndarray, x2: jnp.ndarray, l1: int, l2: int,
           l3: int) -> jnp.ndarray | None:
    """CG contraction: x1 (..., 2l1+1) ⊗ x2 (..., 2l2+1) -> (..., 2l3+1)."""
    c = cg_real(l1, l2, l3)
    if c is None:
        return None
    return jnp.einsum("...i,...j,ijk->...k", x1, x2,
                      jnp.asarray(c, x1.dtype))


# ------------------------------------------------------------ radial
def bessel_rbf(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """NequIP/DimeNet Bessel radial basis with smooth cutoff envelope."""
    r = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(
        n * jnp.pi * r[..., None] / cutoff) / r[..., None]
    u = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1.0 - 10.0 * u ** 3 + 15.0 * u ** 4 - 6.0 * u ** 5
    return basis * env[..., None]
