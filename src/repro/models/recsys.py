"""MIND: multi-interest network with dynamic (capsule) routing
[arXiv:1904.08030].

The embedding lookup is the hot path (kernel_taxonomy §RecSys): JAX has
no EmbeddingBag, so lookups go through kernels/embedding_bag (XLA
take+segment path in production, the MXU one-hot Pallas kernel for
VMEM-resident shards).  Tables are row-sharded over (data, model); the
distributed lookup dedups ids per shard first — the PCPM compression
applied to embedding traffic (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import RecSysConfig
from ..launch.sharding import shard


def init_mind(cfg: RecSysConfig, key) -> dict:
    d, v, k = cfg.embed_dim, cfg.vocab, cfg.n_interests
    ks = jax.random.split(key, 4)
    return {
        "table": (jax.random.normal(ks[0], (v, d), jnp.float32)
                  * d ** -0.5),
        "bilinear": (jax.random.normal(ks[1], (d, d), jnp.float32)
                     * d ** -0.5),
        # fixed per-(position, interest) routing prior (MIND init)
        "route_init": (jax.random.normal(ks[2], (cfg.hist_len, k),
                                         jnp.float32)),
        "out_proj": (jax.random.normal(ks[3], (d, d), jnp.float32)
                     * d ** -0.5),
    }


def param_shapes(cfg: RecSysConfig):
    return jax.eval_shape(lambda: init_mind(cfg, jax.random.key(0)))


def _squash(x, axis=-1):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Row-sharded embedding gather (ids >= vocab -> zero row)."""
    v = table.shape[0]
    valid = (ids < v)[..., None]
    return jnp.take(table, jnp.clip(ids, 0, v - 1), axis=0) * valid


def interests(params: dict, cfg: RecSysConfig,
              hist: jnp.ndarray) -> jnp.ndarray:
    """Multi-interest extraction: hist (B, L) item ids (pad >= vocab)
    -> (B, K, d) interest capsules via 3-iteration dynamic routing."""
    b_sz, l = hist.shape
    k = cfg.n_interests
    e = lookup(params["table"], hist)                     # (B, L, d)
    e = shard(e, "batch", None, None)
    eh = e @ params["bilinear"]                            # (B, L, d)
    mask = (hist < cfg.vocab).astype(jnp.float32)          # (B, L)
    logit_mask = (mask - 1.0) * 1e9
    b_route = jnp.broadcast_to(params["route_init"][None],
                               (b_sz, l, k))
    caps = None
    for it in range(cfg.capsule_iters):
        w = jax.nn.softmax(b_route + logit_mask[..., None], axis=-1)
        caps = _squash(jnp.einsum("blk,bld->bkd", w * mask[..., None],
                                  jax.lax.stop_gradient(eh)
                                  if it < cfg.capsule_iters - 1 else eh))
        if it < cfg.capsule_iters - 1:
            b_route = b_route + jnp.einsum(
                "bld,bkd->blk", jax.lax.stop_gradient(eh), caps)
    caps = caps @ params["out_proj"]
    return shard(caps, "batch", None, None)                # (B, K, d)


def label_aware_attention(caps: jnp.ndarray, target: jnp.ndarray,
                          *, power: float = 2.0) -> jnp.ndarray:
    """caps (B, K, d), target (B, d) -> user vector (B, d)."""
    att = jnp.einsum("bkd,bd->bk", caps, target)
    att = jax.nn.softmax(power * att, axis=-1)
    return jnp.einsum("bk,bkd->bd", att, caps)


def mind_loss(params: dict, cfg: RecSysConfig, batch: dict) -> jnp.ndarray:
    """In-batch sampled-softmax loss: positives on the diagonal."""
    caps = interests(params, cfg, batch["hist"])           # (B, K, d)
    tgt = lookup(params["table"], batch["target"])         # (B, d)
    user = label_aware_attention(caps, tgt)                # (B, d)
    logits = user @ tgt.T                                  # (B, B)
    logits = shard(logits, "batch", None)
    labels = jnp.arange(user.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], -1).mean()


def make_train_step(cfg: RecSysConfig, optimizer):
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: mind_loss(p, cfg, batch))(params)
        params, opt_state, gnorm = optimizer.update(grads, opt_state,
                                                    params)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}
    return step


def serve_step(params: dict, cfg: RecSysConfig,
               hist: jnp.ndarray) -> jnp.ndarray:
    """Online inference: user history -> K interest vectors."""
    return interests(params, cfg, hist)


def retrieval_step(params: dict, cfg: RecSysConfig, hist: jnp.ndarray,
                   cand: jnp.ndarray, *, top_k: int = 64):
    """Score one (or few) users against a candidate set.

    hist (B, L); cand (Ncand,) item ids.  Batched dot — the max over
    interests (MIND retrieval rule), then top-k."""
    caps = interests(params, cfg, hist)                    # (B, K, d)
    ce = lookup(params["table"], cand)                     # (N, d)
    ce = shard(ce, "cand", None)
    scores = jnp.einsum("bkd,nd->bkn", caps, ce).max(axis=1)  # (B, N)
    return jax.lax.top_k(scores, top_k)
