from . import layers, transformer

__all__ = ["layers", "transformer"]
