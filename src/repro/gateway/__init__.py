"""Async serving gateway (DESIGN.md §13).

``Gateway`` is the threaded front door over the synchronous
``SlotScheduler`` core: one device thread owns all stepping, a worker
pool answers push-eligible queries inline, ``submit()`` returns a
future immediately, and a warm-result LRU serves repeats in O(k).
``GraphRegistry.gateway()`` / ``Session.gateway()`` are the usual
constructors.
"""
from .autotune import AutotuneReport, autotune_slots
from .cache import ResultCache, seed_digest
from .frontdoor import Gateway, GatewayConfig
from .qos import WeightedFair

__all__ = ["Gateway", "GatewayConfig", "ResultCache", "seed_digest",
           "AutotuneReport", "autotune_slots", "WeightedFair"]
