"""Slot-pool size autotune: pick B from measured stepper cost.

The scheduler's hardcoded ``slots=4`` is a guess.  The real tradeoff:
a chunk dispatch costs roughly ``chunk * t_pass(B)`` where
``t_pass(B)`` is one multi-vector SpMV pass over an (n, B) state —
sublinear in B on wide hardware (the PCPM batching property), so
bigger pools amortize better per query.  But every query admitted
into the pool waits a full chunk between drain opportunities, so
chunk latency IS the serving latency floor.  The tuner measures
``t_pass`` at each candidate B and picks the LARGEST pool whose
predicted chunk time stays under ``target_chunk_s`` — maximum
amortization that still honors the latency target.

The probe runs the engine's multi-vector SpMV directly (the dominant
term of a chunk step; the damping/residual epilogue is O(nB) and
shared), so probing never compiles a throwaway stepper — the real
stepper is compiled ONCE at the chosen B, keeping the scheduler's
``trace_count == 1`` invariant intact.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np


@dataclasses.dataclass
class AutotuneReport:
    """What the tuner measured and chose — attached to gateway stats
    and to ``Session.gateway()`` so the decision is auditable."""
    target_chunk_s: float
    chunk: int
    probes: dict[int, float]          # B -> min measured chunk seconds
    chosen: int

    def summary(self) -> dict:
        return {"target_chunk_s": self.target_chunk_s,
                "chunk": self.chunk, "chosen": self.chosen,
                "probes_ms": {str(b): t * 1e3
                              for b, t in self.probes.items()}}


def autotune_slots(engine, *, chunk: int,
                   target_chunk_s: float = 0.025,
                   candidates: tuple = (2, 4, 8, 16, 32, 64),
                   repeats: int = 3, default: int = 4) -> AutotuneReport:
    """Measure ``chunk`` * t_pass(B) for ascending candidate pool
    sizes and return the largest B under ``target_chunk_s``.

    Min-of-``repeats`` timing after one warmup dispatch per candidate
    (compile + first-touch excluded); probing stops early once a
    candidate exceeds the target — t_pass is monotone in B, larger
    pools can only be worse.  Falls back to ``default`` untouched for
    backends without multi-vector support (nothing to amortize)."""
    if not engine.backend.multi_vector:
        return AutotuneReport(target_chunk_s, chunk, {}, default)
    n = engine.num_nodes
    fn = jax.jit(engine.spmv_fn())
    rng = np.random.default_rng(0)
    probes: dict[int, float] = {}
    for b in sorted(set(int(b) for b in candidates)):
        if b < 1 or b > n:
            continue
        x = rng.random((n, b), dtype=np.float32)
        jax.block_until_ready(fn(x))              # warmup: compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            best = min(best, time.perf_counter() - t0)
        probes[b] = best * chunk
        if probes[b] > target_chunk_s:
            break                                 # monotone — stop
    passing = [b for b, t in probes.items() if t <= target_chunk_s]
    chosen = (max(passing) if passing
              else min(probes) if probes else default)
    return AutotuneReport(target_chunk_s, chunk, probes, chosen)
