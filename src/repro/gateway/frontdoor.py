"""Async serving gateway: the threaded front door (DESIGN.md §13).

The synchronous ``SlotScheduler`` couples every submitter to the
device loop: admission, stepping and drain all run on the caller's
thread, so one slow stepper chunk stalls every client.  The gateway
decouples them with a strict thread-ownership split:

- ONE device thread per gateway owns every ``step()`` and every
  ``apply_delta`` across all attached schedulers (the scheduler's
  ``_step_lock`` enforces this); it drains a bounded pending queue
  into the schedulers each round and interleaves stepper chunks
  across graphs weighted-fair (qos.py).
- A small worker pool serves PUSH-ELIGIBLE queries inline — they
  never touch the device thread, so loose-tolerance top-k traffic
  scales with workers while the stepper grinds full-vector queries.
- ``submit()`` runs on the CALLER's thread: validation (same errors
  as the scheduler, raised synchronously), cache lookup, and routing;
  it returns a ``concurrent.futures.Future`` immediately.

All PR 6 admission semantics survive the async split: priority (the
device thread hands the WHOLE backlog to the scheduler each round, so
its priority queue orders admission globally), deadlines (made
ABSOLUTE at gateway intake — queue time in the gateway counts against
the budget), degrade-under-pressure, and explicit rejection (a full
gateway backlog rejects immediately with a terminal, counted result —
never a silent drop, never an unbounded queue).

Results flow back through a futures table keyed ``(graph, uid)``; a
push worker can lose the registration race with the device thread's
drain, so unmatched results park in an orphan buffer until their
future registers — exactly-once delivery either way.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor

from ..serve.scheduler import QueryResult, SlotScheduler, next_uid
from .autotune import autotune_slots
from .cache import ResultCache, seed_digest
from .qos import WeightedFair


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    """Knobs for the async front door."""
    max_pending: int = 4096       # gateway backlog bound (per gateway)
    push_workers: int = 2         # inline push-serving threads
    cache_entries: int = 1024     # warm-result LRU capacity (0 = off)
    target_chunk_s: float = 0.025          # autotune latency target
    autotune_candidates: tuple = (2, 4, 8, 16, 32, 64)
    retune_on_rebind: bool = False    # re-probe B after apply_delta
    idle_wait_s: float = 0.002    # device-thread sleep when idle


class Gateway:
    """Threaded front door over one or more compiled schedulers.

    ``schedulers`` is a single ``SlotScheduler`` or a ``{name: sch}``
    dict (``GraphRegistry.gateway()`` builds the latter).  Queries
    submitted directly to a wrapped scheduler bypass the futures
    table; don't mix the two intake paths on one scheduler.
    """

    def __init__(self, schedulers, *, shares: dict | None = None,
                 config: GatewayConfig | None = None,
                 name: str = "default", obs=None):
        if isinstance(schedulers, SlotScheduler):
            schedulers = {name: schedulers}
        if not schedulers:
            raise ValueError("gateway needs at least one scheduler")
        self.config = config or GatewayConfig()
        self._schedulers: dict[str, SlotScheduler] = dict(schedulers)
        # observability: explicit bundle, or inherit the first
        # attached scheduler's (Session wires the scheduler, the
        # gateway follows — one bundle end to end)
        self.obs = obs if obs is not None else next(
            (s.obs for s in self._schedulers.values()
             if s.obs is not None), None)
        # gateway-level gauges/counters live in their own registry so
        # metrics_endpoint() can merge them with every scheduler's
        from ..obs.metrics import MetricsRegistry
        self._gw_registry = MetricsRegistry()
        self._fair = WeightedFair(
            {n: 1.0 for n in self._schedulers} if shares is None
            else {n: shares.get(n, 1.0) for n in self._schedulers})
        self.cache = ResultCache(self.config.cache_entries)
        self.autotune_report = None       # set by Session.gateway()
        self.retune_reports: list = []
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._pending: collections.deque = collections.deque()
        self._control: collections.deque = collections.deque()
        self._futures: dict[tuple, tuple] = {}    # (name,uid) -> (fut,key)
        self._orphans: dict[tuple, QueryResult] = {}
        self._inflight = 0
        self._cursors = {n: len(s.completed)
                         for n, s in self._schedulers.items()}
        self._loop_error: BaseException | None = None
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, self.config.push_workers),
            thread_name_prefix="gateway-push")
        self._device = threading.Thread(target=self._loop, daemon=True,
                                        name="gateway-device")
        self._device.start()

    # ------------------------------------------------------------ intake
    def _resolve(self, graph: str | None) -> tuple[str, SlotScheduler]:
        if graph is None:
            if len(self._schedulers) != 1:
                raise ValueError(
                    f"gateway serves {sorted(self._schedulers)}; pass "
                    f"graph=<name>")
            graph = next(iter(self._schedulers))
        try:
            return graph, self._schedulers[graph]
        except KeyError:
            raise KeyError(f"unknown graph {graph!r}; serving: "
                           f"{sorted(self._schedulers)}") from None

    def submit(self, seeds=None, *, graph: str | None = None,
               top_k: int | None = None, tol: float = 1e-6,
               max_iters: int = 100, deadline_s: float | None = None,
               priority: int = 0, route: str | None = None,
               use_cache: bool = True) -> Future:
        """Submit one query; returns a Future[QueryResult] immediately.

        Same request surface as ``SlotScheduler.submit`` — and the
        same validation errors, raised HERE on the caller's thread, so
        a malformed request never costs a queue slot or a dead future.
        The future always resolves to a terminal ``QueryResult``
        (possibly with ``.error`` set); it only raises if the push
        worker itself crashed."""
        if self._stop.is_set():
            raise RuntimeError("gateway is closed")
        name, sch = self._resolve(graph)
        route, use_push = sch.validate_request(
            seeds is not None, top_k=top_k, tol=tol,
            max_iters=max_iters, route=route)
        spans = None
        if self.obs is not None:
            # root opens HERE, on the caller's thread — the recorded
            # interval is the client-observed latency (intake through
            # future resolution); the uid binds later, in the
            # scheduler's intake lock
            from ..obs.trace import QuerySpans
            spans = QuerySpans(
                self.obs.tracer,
                self.obs.tracer.start("query", graph=name, route=route),
                gateway_owned=True)
            spans.event("intake", push=use_push)
        kw = dict(top_k=top_k, tol=tol, max_iters=max_iters,
                  priority=priority, route=route)
        key = None
        if use_cache and self.cache.capacity > 0:
            key = (name, sch.engine.plan.graph_fp, seed_digest(seeds),
                   float(tol), top_k, int(max_iters), route)
            hit = self.cache.get(key)
            if hit is not None:
                return self._serve_cached(sch, hit, spans)
            sch.metrics.incr("cache_misses")
        if deadline_s is None:
            deadline_s = sch.resilience.default_deadline_s
        deadline = (sch.clock() + deadline_s
                    if deadline_s is not None else None)
        fut: Future = Future()
        if use_push:
            with self._lock:
                self._inflight += 1
            self._pool.submit(self._push_job, name, sch, seeds, kw,
                              deadline, fut, key, spans)
            return fut
        with self._lock:
            if len(self._pending) >= self.config.max_pending:
                self._reject(sch, fut,
                             f"rejected: gateway backlog full "
                             f"({self.config.max_pending})",
                             spans)
                return fut
            if spans is not None:
                spans.start_child("backlog")
            self._pending.append((name, seeds, kw, deadline, fut, key,
                                  spans))
            self._inflight += 1
        self._wake.set()
        return fut

    def _serve_cached(self, sch, hit: QueryResult, spans=None) -> Future:
        """A warm-result hit: mint a real uid and a full metrics trace
        (submitted/admitted/completed — the audit sees exactly one
        terminal per uid) and answer with the CACHED solve's arrays —
        bit-identical, O(k)."""
        uid = next_uid()
        m = sch.metrics
        m.submitted(uid)
        m.admitted(uid)
        m.completed(uid, iterations=hit.iterations, converged=True,
                    route="cached")
        m.incr("cache_hits")
        if spans is not None:
            spans.bind(uid)
            spans.event("cache_hit")
            spans.finish(served="cached")
            spans.resolve()
        fut: Future = Future()
        fut.set_result(dataclasses.replace(
            hit, uid=uid, latency_s=m.traces[uid].latency_s,
            cached=True))
        return fut

    def _reject(self, sch, fut: Future, err: str, spans=None) -> None:
        """Terminal gateway-side rejection: a real uid, a full trace,
        the rejection counted — indistinguishable in the accounting
        from a scheduler-side shed."""
        uid = next_uid()
        m = sch.metrics
        m.submitted(uid)
        m.incr("rejected")
        m.completed(uid, iterations=0, converged=False, error=err)
        if spans is not None:
            spans.bind(uid)
            spans.finish(status="error", error=err)
            spans.resolve(error=True)
        fut.set_result(QueryResult(uid, 0, False, None,
                                   m.traces[uid].latency_s, error=err))

    def _push_job(self, name, sch, seeds, kw, deadline, fut, key,
                  spans=None):
        """Worker-pool body: serve a push-eligible query inline via
        the scheduler's thread-safe submit (per-thread push engines).
        A push fallback lands in the scheduler's stepper queue — wake
        the device thread so it gets admitted."""
        try:
            remaining = (deadline - sch.clock()
                         if deadline is not None else None)
            uid = sch.submit(seeds, deadline_s=remaining,
                             _spans=spans, **kw)
            self._register(name, sch, uid, fut, key, spans)
            self._wake.set()
        except BaseException as exc:   # noqa: BLE001 — surface, don't hang
            with self._lock:
                self._inflight -= 1
                self._idle.notify_all()
            fut.set_exception(exc)

    # --------------------------------------------------- result delivery
    def _register(self, name, sch, uid, fut, key, spans=None) -> None:
        with self._lock:
            orphan = self._orphans.pop((name, uid), None)
            if orphan is None:
                self._futures[(name, uid)] = (fut, key, spans)
                return
        self._deliver(orphan, fut, key, spans)

    def _deliver(self, result: QueryResult, fut: Future, key,
                 spans=None) -> None:
        if (key is not None and result.converged
                and result.error is None and not result.degraded):
            self.cache.put(key, result)
        if spans is not None:
            # ends the gateway-owned root: the recorded query interval
            # is intake -> future resolution, the client's view
            spans.resolve(error=result.error is not None)
        with self._lock:
            self._inflight -= 1
            if self._inflight == 0:
                self._idle.notify_all()
        fut.set_result(result)

    def _drain_completed(self) -> None:
        """Device thread: match newly completed scheduler results to
        their futures; results whose registration hasn't landed yet
        (push-worker race) park in the orphan buffer."""
        for name, sch in self._schedulers.items():
            done = sch.completed
            cur = self._cursors[name]
            if cur >= len(done):
                continue
            fresh = done[cur:]
            self._cursors[name] = cur + len(fresh)
            for res in fresh:
                with self._lock:
                    entry = self._futures.pop((name, res.uid), None)
                    if entry is None:
                        self._orphans[(name, res.uid)] = res
                        continue
                self._deliver(res, *entry)

    # ------------------------------------------------------- device loop
    def _drain_pending(self) -> None:
        """Hand the ENTIRE gateway backlog to the schedulers each
        round — their priority/deadline admission then orders it
        globally, exactly as under synchronous submission."""
        while True:
            with self._lock:
                if not self._pending:
                    return
                name, seeds, kw, deadline, fut, key, spans = \
                    self._pending.popleft()
            sch = self._schedulers[name]
            try:
                remaining = (deadline - sch.clock()
                             if deadline is not None else None)
                if spans is not None:
                    spans.end_child("backlog")
                uid = sch.submit(seeds, deadline_s=remaining,
                                 _spans=spans, **kw)
                self._register(name, sch, uid, fut, key, spans)
            except BaseException as exc:  # noqa: BLE001
                with self._lock:
                    self._inflight -= 1
                    self._idle.notify_all()
                fut.set_exception(exc)

    def _run_control(self) -> None:
        while True:
            with self._lock:
                if not self._control:
                    return
                op, fut = self._control.popleft()
            try:
                fut.set_result(op())
            except BaseException as exc:  # noqa: BLE001
                fut.set_exception(exc)

    def _busy_graphs(self) -> list[str]:
        return [n for n, s in self._schedulers.items()
                if s.queued > 0 or s.active_slots > 0]

    def _loop(self) -> None:
        try:
            while True:
                self._run_control()
                self._drain_pending()
                self._drain_completed()
                busy = self._busy_graphs()
                if busy:
                    self._schedulers[self._fair.pick(busy)].step()
                    self._drain_completed()
                    continue
                if self._stop.is_set():
                    with self._lock:
                        quiet = (not self._pending
                                 and not self._control)
                    if quiet:
                        return
                    continue
                self._wake.wait(self.config.idle_wait_s)
                self._wake.clear()
        except BaseException as exc:   # noqa: BLE001 — fail loud
            self._loop_error = exc
            with self._lock:
                stranded = ([e[4] for e in self._pending]
                            + [e[0] for e in self._futures.values()])
                self._pending.clear()
                self._futures.clear()
                self._inflight = 0
                self._idle.notify_all()
            for fut in stranded:
                if not fut.done():
                    fut.set_exception(exc)

    # ----------------------------------------------------- control plane
    def apply_delta(self, delta, *, graph: str | None = None,
                    g_new=None) -> Future:
        """Rebind one scheduler onto a delta-updated graph WITHOUT
        stopping traffic: the swap runs as a control op on the device
        thread (between chunks — in-flight columns carry over exactly
        as in the synchronous path), then the warm-result cache drops
        every entry keyed on the outgoing plan fingerprint.  Returns a
        future resolving when the rebind committed (or carrying the
        rebind's exception — a failed delta leaves the old plan
        serving, cache intact)."""
        name, sch = self._resolve(graph)

        def op():
            old_fp = sch.engine.plan.graph_fp
            sch.apply_delta(delta, g_new=g_new)
            dropped = self.cache.invalidate_fp(old_fp)
            if self.config.retune_on_rebind:
                self.retune_reports.append(autotune_slots(
                    sch.engine, chunk=sch.chunk,
                    target_chunk_s=self.config.target_chunk_s,
                    candidates=self.config.autotune_candidates,
                    default=sch.slots))
            return dropped

        fut: Future = Future()
        with self._lock:
            self._control.append((op, fut))
        self._wake.set()
        return fut

    def snapshot(self, path: str, *, graph: str | None = None) -> Future:
        """Persist one scheduler's serving state (reliability/
        snapshot.py) as a control op on the device thread — the only
        thread allowed to hold the step lock, so the cut is consistent
        without quiescing traffic.  Never call ``snapshot_scheduler``
        directly on a gateway-driven scheduler from another thread: it
        takes the step lock, which the device loop treats as proof of
        a second stepping thread."""
        _, sch = self._resolve(graph)

        def op():
            from ..reliability.snapshot import snapshot_scheduler
            snapshot_scheduler(sch, path)

        fut: Future = Future()
        with self._lock:
            self._control.append((op, fut))
        self._wake.set()
        return fut

    # --------------------------------------------------------- lifecycle
    def drain(self, timeout: float | None = None) -> bool:
        """Block until every in-flight query's future has resolved.
        Returns False on timeout."""
        self._wake.set()
        with self._idle:
            return self._idle.wait_for(
                lambda: self._inflight == 0 or self._loop_error,
                timeout=timeout) and self._loop_error is None

    def close(self, *, drain: bool = True,
              timeout: float | None = None) -> None:
        """Stop the gateway.  ``drain=True`` (default) serves the
        backlog to completion first; ``drain=False`` abandons
        unresolved futures (their queries may still be in a
        scheduler's queue)."""
        if drain and not self._stop.is_set():
            self.drain(timeout=timeout)
        self._stop.set()
        self._wake.set()
        self._device.join(timeout=timeout)
        self._pool.shutdown(wait=True)
        if self._loop_error is not None:
            raise RuntimeError("gateway device loop failed") \
                from self._loop_error

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close(drain=exc[0] is None)

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._lock:
            out = {
                "pending": len(self._pending),
                "inflight": self._inflight,
                "orphans": len(self._orphans),
            }
        out["cache"] = {"entries": len(self.cache),
                        "capacity": self.cache.capacity,
                        "hits": self.cache.hits,
                        "misses": self.cache.misses,
                        "evictions": self.cache.evictions,
                        "invalidated": self.cache.invalidated}
        out["graphs"] = {
            n: {"queued": s.queued, "active_slots": s.active_slots,
                "completed": len(s.completed),
                "rebind_count": s.rebind_count}
            for n, s in self._schedulers.items()}
        if self.autotune_report is not None:
            out["autotune"] = self.autotune_report.summary()
        return out

    def metrics_endpoint(self) -> str:
        """Prometheus text exposition of the whole gateway: every
        scheduler's event/terminal counters (labeled ``graph=<name>``),
        gateway backlog/cache/per-graph gauges, and — when an
        observability bundle is attached — its cross-cutting registry
        (plan events, comm accounting, crash dumps).  This is the
        scrape hook a real deployment would mount at ``/metrics``;
        gauges are synced at scrape time, so the text is a consistent
        point-in-time snapshot."""
        from ..obs.metrics import render_prometheus
        reg = self._gw_registry
        with self._lock:
            reg.gauge("gateway_pending",
                      "backlog depth").set(len(self._pending))
            reg.gauge("gateway_inflight",
                      "unresolved futures").set(self._inflight)
            reg.gauge("gateway_orphans",
                      "results awaiting registration"
                      ).set(len(self._orphans))
        c = self.cache
        reg.gauge("gateway_cache_entries", "warm results held").set(len(c))
        for nm, v in (("hits", c.hits), ("misses", c.misses),
                      ("evictions", c.evictions),
                      ("invalidated", c.invalidated)):
            reg.gauge("gateway_cache_events",
                      "warm-result cache accounting", event=nm).set(v)
        for n, s in self._schedulers.items():
            reg.gauge("scheduler_queued", "queued queries",
                      graph=n).set(s.queued)
            reg.gauge("scheduler_active_slots", "occupied slots",
                      graph=n).set(s.active_slots)
            reg.gauge("scheduler_trace_count",
                      "stepper traces (must stay 1)",
                      graph=n).set(s.trace_count)
            reg.gauge("scheduler_rebind_count", "plan rebinds",
                      graph=n).set(s.rebind_count)
        pairs = [(reg, {})]
        pairs += [(s.metrics.registry, {"graph": n})
                  for n, s in self._schedulers.items()]
        if self.obs is not None:
            pairs.append((self.obs.registry, {}))
        return render_prometheus(pairs)
