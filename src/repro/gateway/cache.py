"""Warm-result cache: bounded LRU of solved queries (DESIGN.md §13).

Real personalized-PageRank traffic repeats: the same seed at the same
tolerance against the same graph version.  A repeat is a pure function
of ``(graph name, plan fingerprint, seed, tol, top_k, max_iters)`` —
the plan fingerprint already IS the graph-version key the rest of the
repo uses (core/plan.py fingerprint chains), so a cached answer is
served in O(k) with the ORIGINAL result arrays (bit-identical, no
recompute, no copy).

Invalidation rule: ``apply_delta`` flips the scheduler's plan
fingerprint inside its locked rebind commit, so entries keyed on the
old fingerprint can never be MISTAKEN for current — the gateway still
drops them eagerly (``invalidate_fp``) so a delta releases the dead
entries' memory immediately instead of waiting for LRU pressure.

Only unconditionally-correct results are cached: converged,
error-free, non-degraded.  A degraded or deadline-expired answer is
an artifact of the moment's load, not of the query.
"""
from __future__ import annotations

import collections
import hashlib
import threading

import numpy as np


def seed_digest(seeds) -> str:
    """Stable key for a teleport distribution: blake2b over the raw
    float32 bytes (the same normalization ``submit`` applies happens
    downstream, so byte-equal inputs hit; ``None`` = uniform)."""
    if seeds is None:
        return "uniform"
    arr = np.ascontiguousarray(np.asarray(seeds, dtype=np.float32)
                               .reshape(-1))
    return hashlib.blake2b(arr.tobytes(), digest_size=16).hexdigest()


class ResultCache:
    """Thread-safe bounded LRU mapping query keys to QueryResults.

    ``capacity <= 0`` disables caching (every ``get`` misses, ``put``
    is a no-op) — one code path, no conditionals at call sites."""

    def __init__(self, capacity: int = 1024):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidated = 0
        self.evictions = 0            # capacity-pressure LRU drops

    def get(self, key):
        with self._lock:
            res = self._entries.get(key)
            if res is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return res

    def put(self, key, result) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            self._entries[key] = result
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1

    def invalidate_fp(self, plan_fp: str) -> int:
        """Drop every entry solved against plan fingerprint
        ``plan_fp`` — called by the gateway right after a scheduler's
        ``apply_delta`` rebind commits.  Returns the number dropped."""
        with self._lock:
            dead = [k for k in self._entries if k[1] == plan_fp]
            for k in dead:
                del self._entries[k]
            self.invalidated += len(dead)
            return len(dead)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)
