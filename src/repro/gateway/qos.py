"""Weighted-fair stride scheduling over named graphs.

The gateway's device loop (and ``GraphRegistry.run_until_drained``)
must interleave stepper chunks across graphs so one hot graph cannot
starve the others.  Classic stride scheduling does exactly that with
O(1) state per graph: each graph advances a virtual "pass" by
``1/share`` per chunk served, and the next chunk goes to the eligible
graph with the smallest pass — over any window, graph i receives
chunks in proportion ``share_i / sum(shares)`` among the graphs that
had work.

A graph that was idle rejoins at the MINIMUM eligible pass (not its
stale own), so it cannot burn banked credit into a monopolizing burst
— the standard lag-capping rule.
"""
from __future__ import annotations


class WeightedFair:
    """Stride scheduler: ``pick(eligible)`` returns the next name to
    serve and charges it ``1/share``.  Deterministic (ties break by
    name) so tests can assert exact interleavings."""

    def __init__(self, shares: dict[str, float]):
        for name, s in shares.items():
            if not s > 0:
                raise ValueError(f"share for {name!r} must be > 0; "
                                 f"got {s}")
        self._shares = dict(shares)
        self._pass: dict[str, float] = {}

    def pick(self, eligible: list[str]) -> str:
        if not eligible:
            raise ValueError("pick() needs at least one eligible name")
        known = [self._pass[n] for n in eligible if n in self._pass]
        floor = min(known) if known else 0.0
        for n in eligible:
            if n not in self._pass:
                self._pass[n] = floor     # rejoin without banked credit
        chosen = min(eligible, key=lambda n: (self._pass[n], n))
        self._pass[chosen] += 1.0 / self._shares.get(chosen, 1.0)
        return chosen
