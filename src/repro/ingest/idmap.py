"""External-id <-> dense-internal-id mapping (the simpleflow design).

Real edge lists label nodes with arbitrary 64-bit integers or strings;
every layout in this system (CSR, PNG, plans, slot pools) wants dense
``[0, n)`` int32.  ``NodeIdMapping`` assigns internal ids in
first-seen order during ingest and persists alongside the plan
``.npz`` so a restarted server maps queries and results without
re-reading the edge list.

Internal ids here are the graph's ORIGINAL dense ids — the plan
layer's locality relabeling (``PlanConfig.reorder``) is a second,
invisible layer below this one; nothing in this module ever sees it.
"""
from __future__ import annotations

import json

import numpy as np

INT32_MAX = np.iinfo(np.int32).max


class NodeIdMapping:
    """Bidirectional external <-> dense int32 internal node ids.

    External ids are python ints (any 64-bit value) or strings; one
    mapping holds exactly one kind.  ``map_chunk`` grows the mapping
    (ingest side); ``to_internal``/``to_external`` translate without
    growing (query/result side).
    """

    def __init__(self):
        self._ids: dict = {}          # external -> internal (dense)
        self._ext_cache: np.ndarray | None = None

    # ------------------------------------------------------------ views
    @property
    def num_nodes(self) -> int:
        return len(self._ids)

    def __len__(self) -> int:
        return len(self._ids)

    def __contains__(self, ext) -> bool:
        return self._normalize(ext) in self._ids

    @property
    def external_ids(self) -> np.ndarray:
        """(n,) array of external ids, indexed by internal id (dict
        insertion order IS assignment order)."""
        if self._ext_cache is None or len(self._ext_cache) != len(self):
            if not self._ids:
                self._ext_cache = np.array([], dtype=np.int64)
            else:
                self._ext_cache = np.array(list(self._ids))
        return self._ext_cache

    @staticmethod
    def _normalize(ext):
        return ext.item() if isinstance(ext, np.generic) else ext

    # ---------------------------------------------------------- mapping
    def map_chunk(self, ext) -> np.ndarray:
        """Translate one chunk of external ids to internal ids,
        ASSIGNING fresh dense ids to unseen externals (int32-bounded —
        >2^31-1 distinct nodes raises instead of wrapping)."""
        ext = np.asarray(ext)
        out = np.empty(ext.shape[0], dtype=np.int32)
        ids = self._ids
        nxt = len(ids)
        for i, e in enumerate(ext.tolist()):
            v = ids.get(e)
            if v is None:
                if nxt > INT32_MAX:
                    raise ValueError(
                        "graph exceeds int32 node capacity "
                        f"({INT32_MAX + 1} distinct ids)")
                v = ids[e] = nxt
                nxt += 1
            out[i] = v
        return out

    def to_internal(self, ext, *, missing: str = "raise") -> np.ndarray:
        """Translate external -> internal WITHOUT growing the mapping.
        ``missing="raise"`` fails on unknown ids; ``missing="mark"``
        returns -1 for them (virtual-link interpretation uses this —
        a filtered neighbour may not be in the graph at all)."""
        if missing not in ("raise", "mark"):
            raise ValueError(f"missing must be 'raise' or 'mark'; got "
                             f"{missing!r}")
        ext = np.asarray(ext)
        scalar = ext.ndim == 0
        out = np.empty(1 if scalar else ext.shape[0], dtype=np.int32)
        ids = self._ids
        it = [ext.item()] if scalar else ext.tolist()
        for i, e in enumerate(it):
            v = ids.get(e)
            if v is None:
                if missing == "raise":
                    raise KeyError(f"unknown external id {e!r}")
                v = -1
            out[i] = v
        return out[0] if scalar else out

    def to_external(self, internal) -> np.ndarray:
        """Translate internal ids -> external labels (vectorized)."""
        return self.external_ids[np.asarray(internal)]

    @classmethod
    def identity(cls, n: int) -> "NodeIdMapping":
        """The trivial mapping for graphs already labeled 0..n-1
        (synthetic generators) — lets code paths stay uniform."""
        m = cls()
        m._ids = {i: i for i in range(n)}
        return m

    # ---------------------------------------------------- serialization
    def save(self, path: str) -> None:
        """One ``.npz`` next to the plan file: the external-id array
        (int64 or unicode) is the whole state."""
        meta = {"version": 1, "num_nodes": self.num_nodes}
        np.savez_compressed(path, __meta__=json.dumps(meta),
                            external=self.external_ids)

    @classmethod
    def load(cls, path: str) -> "NodeIdMapping":
        z = np.load(path, allow_pickle=False)
        if "__meta__" not in z or "external" not in z:
            raise ValueError(f"{path!r} is not a NodeIdMapping file")
        meta = json.loads(str(z["__meta__"]))
        if meta.get("version") != 1:
            raise ValueError(f"unsupported NodeIdMapping version "
                             f"{meta.get('version')!r} in {path!r}")
        ext = z["external"]
        m = cls()
        m._ids = {e: i for i, e in enumerate(ext.tolist())}
        if len(m._ids) != int(meta["num_nodes"]):
            raise ValueError(
                f"{path!r} is corrupt: {len(m._ids)} distinct external "
                f"ids for {meta['num_nodes']} declared nodes")
        return m
