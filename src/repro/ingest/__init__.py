"""Real-graph ingest (DESIGN.md §12): bytes on disk -> served plan.

Everything the synthetic generators never needed: streaming SNAP/TSV
edge-list parsing (plain or gzip, never materializing the file),
arbitrary 64-bit / string external ids mapped to dense int32 internal
ids (``NodeIdMapping``, persisted alongside the plan ``.npz``), and
composable pipeline stages — predicate link filters, self-loop and
duplicate policy, virtual-link extraction so filtered edges' PageRank
mass is reported instead of silently dropped (the Agyar/simpleflow
pipeline shape, SNIPPETS.md).

    from repro.ingest import ingest_edge_list, LinkFilter
    res = ingest_edge_list("web.txt.gz",
                           filters=[LinkFilter("offsite",
                                               lambda s, d: d < 10**6)],
                           self_loops="drop", dedup=True)
    sess = res.open(reorder="hybrid")       # Session with external ids
    sess.pagerank()
    sess.top_ranked(10)                     # ids in the FILE's labels
"""
from .idmap import NodeIdMapping
from .parse import ParseError, iter_edge_chunks, read_edge_list
from .pipeline import (IngestResult, IngestStats, LinkFilter,
                       VirtualLinks, ingest_edge_list)

__all__ = [
    "NodeIdMapping", "ParseError", "iter_edge_chunks", "read_edge_list",
    "IngestResult", "IngestStats", "LinkFilter", "VirtualLinks",
    "ingest_edge_list",
]
