"""Composable ingest pipeline: chunks -> filters -> id map -> Graph.

Stage order per chunk (everything here runs in EXTERNAL id space, so
string-labeled graphs work identically):

1. link filters (:class:`LinkFilter`) — predicate keep masks; dropped
   edges are counted and, per filter, optionally routed to
   :class:`VirtualLinks` instead of vanishing;
2. self-loop policy (``keep`` / ``drop`` / ``virtual``);
3. ``NodeIdMapping.map_chunk`` — AFTER filtering, so nodes reachable
   only through removed links never claim a dense id and the node
   space stays compact;
4. accumulate; optional exact dedup at the end (packed-int64 unique).

Filtered edges are not just discarded: the web-graph practice (Agyar,
SNIPPETS.md) is to solve PageRank on the kept subgraph, then report
how much rank mass WOULD have flowed down the removed links —
:meth:`VirtualLinks.interpret` computes exactly that.
"""
from __future__ import annotations

import dataclasses
from dataclasses import field
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from ..graphs.formats import Graph
from .idmap import NodeIdMapping
from .parse import DEFAULT_CHUNK_EDGES, DEFAULT_COMMENTS, iter_edge_chunks

SELF_LOOP_POLICIES = ("keep", "drop", "virtual")
SELF_LOOP_CATEGORY = "self_loops"


@dataclasses.dataclass(frozen=True)
class LinkFilter:
    """Predicate over external ``(src, dst)`` chunk arrays.

    ``keep(src, dst)`` returns a boolean mask (True = keep the edge).
    Dropped edges are counted under ``name``; with ``virtual=True``
    (default) they are also retained as virtual links so their rank
    mass can be reported after the solve.
    """

    name: str
    keep: Callable[[np.ndarray, np.ndarray], np.ndarray]
    virtual: bool = True

    def __call__(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        mask = np.asarray(self.keep(src, dst), dtype=bool)
        if mask.shape != src.shape:
            raise ValueError(
                f"filter {self.name!r} returned mask of shape "
                f"{mask.shape} for {src.shape[0]} edges")
        return mask


class VirtualLinks:
    """Edges removed during ingest, bucketed by filter name, kept in
    EXTERNAL id space (their endpoints may not exist in the graph)."""

    def __init__(self):
        self._chunks: Dict[str, list] = {}

    def add(self, category: str, src: np.ndarray, dst: np.ndarray):
        if src.size:
            self._chunks.setdefault(category, []).append((src, dst))

    @property
    def categories(self) -> tuple:
        return tuple(self._chunks)

    @property
    def counts(self) -> Dict[str, int]:
        return {c: sum(s.size for s, _ in ch)
                for c, ch in self._chunks.items()}

    def edges(self, category: str) -> tuple:
        ch = self._chunks.get(category, [])
        if not ch:
            e = np.array([], dtype=np.int64)
            return e, e.copy()
        return (np.concatenate([s for s, _ in ch]),
                np.concatenate([d for _, d in ch]))

    def interpret(self, ranks, idmap: NodeIdMapping, graph: Graph,
                  damping: float = 0.85) -> Dict[str, float]:
        """Per-category PageRank mass the removed links would carry.

        After solving on the kept subgraph, node ``u`` would have
        distributed ``damping * pr[u] / (deg_kept(u) + deg_virt(u))``
        along EACH of its links had the virtual ones stayed; summing
        that share over a category's edges estimates the mass flowing
        out of the graph through it.  Virtual edges whose source never
        made it into the graph contribute nothing (their rank is
        unknown).
        """
        ranks = np.asarray(ranks, dtype=np.float64)
        if ranks.shape[0] != graph.num_nodes:
            raise ValueError(
                f"ranks has {ranks.shape[0]} entries for a graph of "
                f"{graph.num_nodes} nodes")
        # total virtual out-degree per in-graph source, all categories
        virt_deg = np.zeros(graph.num_nodes, dtype=np.int64)
        mapped = {}
        for cat in self._chunks:
            src, _ = self.edges(cat)
            s_int = idmap.to_internal(src, missing="mark")
            mapped[cat] = s_int
            known = s_int[s_int >= 0]
            np.add.at(virt_deg, known, 1)
        kept_deg = np.zeros(graph.num_nodes, dtype=np.int64)
        np.add.at(kept_deg, graph.src, 1)
        total_deg = kept_deg + virt_deg
        out = {}
        for cat, s_int in mapped.items():
            known = s_int[s_int >= 0]
            out[cat] = float(
                damping * np.sum(ranks[known] / total_deg[known]))
        return out


@dataclasses.dataclass
class IngestStats:
    edges_read: int = 0
    edges_kept: int = 0
    self_loops_removed: int = 0
    duplicates_removed: int = 0
    filtered: Dict[str, int] = field(default_factory=dict)
    num_nodes: int = 0

    def summary(self) -> str:
        parts = [f"{self.edges_read} edges read",
                 f"{self.edges_kept} kept",
                 f"{self.num_nodes} nodes"]
        for cat, n in self.filtered.items():
            parts.append(f"{n} filtered[{cat}]")
        if self.self_loops_removed:
            parts.append(f"{self.self_loops_removed} self-loops removed")
        if self.duplicates_removed:
            parts.append(f"{self.duplicates_removed} duplicates removed")
        return ", ".join(parts)


@dataclasses.dataclass
class IngestResult:
    graph: Graph
    idmap: NodeIdMapping
    stats: IngestStats
    virtual: VirtualLinks

    def open(self, config=None, **overrides):
        """A :class:`repro.Session` on the ingested graph, with the id
        mapping attached so every output surface (``top_ranked``,
        serve top-k) speaks the file's original labels."""
        from .. import api
        return api.open(self.graph, config, idmap=self.idmap,
                        **overrides)

    def virtual_mass(self, ranks, damping: float = 0.85) -> Dict[str, float]:
        return self.virtual.interpret(ranks, self.idmap, self.graph,
                                      damping)


def ingest_edge_list(source, *,
                     filters: Sequence[LinkFilter] = (),
                     self_loops: str = "keep",
                     dedup: bool = False,
                     delimiter: Optional[str] = None,
                     comments: Sequence[str] = DEFAULT_COMMENTS,
                     chunk_edges: int = DEFAULT_CHUNK_EDGES,
                     idmap: Optional[NodeIdMapping] = None,
                     ) -> IngestResult:
    """Stream ``source`` through the full pipeline into an
    :class:`IngestResult`.

    ``self_loops``: ``"keep"`` leaves them in the graph, ``"drop"``
    removes and counts them, ``"virtual"`` removes them and tracks
    them under the ``"self_loops"`` virtual category.  Pass an
    existing ``idmap`` to ingest into an established id space
    (incremental loads); by default a fresh mapping is built.
    """
    if self_loops not in SELF_LOOP_POLICIES:
        raise ValueError(f"self_loops must be one of "
                         f"{SELF_LOOP_POLICIES}; got {self_loops!r}")
    names = [f.name for f in filters]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate filter names: {names}")
    if idmap is None:
        idmap = NodeIdMapping()
    stats = IngestStats(filtered={f.name: 0 for f in filters})
    virtual = VirtualLinks()
    int_src: list = []
    int_dst: list = []

    for src, dst in iter_edge_chunks(source, delimiter=delimiter,
                                     comments=comments,
                                     chunk_edges=chunk_edges):
        stats.edges_read += src.size
        for f in filters:
            mask = f(src, dst)
            if not mask.all():
                stats.filtered[f.name] += int((~mask).sum())
                if f.virtual:
                    virtual.add(f.name, src[~mask], dst[~mask])
                src, dst = src[mask], dst[mask]
            if not src.size:
                break
        if self_loops != "keep" and src.size:
            loops = src == dst
            if loops.any():
                stats.self_loops_removed += int(loops.sum())
                if self_loops == "virtual":
                    virtual.add(SELF_LOOP_CATEGORY, src[loops],
                                dst[loops])
                src, dst = src[~loops], dst[~loops]
        if src.size:
            int_src.append(idmap.map_chunk(src))
            int_dst.append(idmap.map_chunk(dst))

    if idmap.num_nodes == 0:
        raise ValueError(
            "ingest produced an empty graph: no edges survived "
            "parsing + filtering (check the source file, the filter "
            "predicates, and the self-loop policy)")
    s = np.concatenate(int_src).astype(np.int32, copy=False)
    d = np.concatenate(int_dst).astype(np.int32, copy=False)
    if dedup:
        packed = (s.astype(np.int64) << 32) | d.astype(np.int64)
        uniq = np.unique(packed)
        if uniq.size != packed.size:
            stats.duplicates_removed = int(packed.size - uniq.size)
            s = (uniq >> 32).astype(np.int32)
            d = (uniq & 0xFFFFFFFF).astype(np.int32)
    stats.edges_kept = int(s.size)
    stats.num_nodes = idmap.num_nodes
    graph = Graph(idmap.num_nodes, s, d)
    return IngestResult(graph=graph, idmap=idmap, stats=stats,
                        virtual=virtual)
