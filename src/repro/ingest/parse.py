"""Streaming edge-list parsers (SNAP / TSV / CSV, plain or gzip).

The contract is STREAMING: the text is read through a bounded buffer
(line iteration over a possibly-gzip-wrapped binary stream) and handed
out as fixed-size numpy chunks — a multi-GB edge list never
materializes as one string or one list.  Id dtype is sniffed from the
first data line: all-numeric files yield int64 chunks (SNAP graphs use
ids far beyond int32 — the dense mapping happens later, in
``idmap.NodeIdMapping``), anything else yields string chunks.

Format rules (SNAP conventions):
- lines starting with a comment prefix (default ``#`` or ``%``) and
  blank lines are skipped anywhere in the file;
- each data line is ``src <delim> dst [extra columns ignored]`` —
  SNAP files often carry weights/timestamps in columns 3+;
- ``delimiter=None`` splits on any whitespace run (tabs or spaces);
  pass e.g. ``","`` for CSV-ish exports.

Malformed lines raise :class:`ParseError` with the 1-based line number
— a truncated download must fail loudly, not load a half graph.
"""
from __future__ import annotations

import gzip
import io
from typing import Iterator, Optional, Sequence

import numpy as np

GZIP_MAGIC = b"\x1f\x8b"
DEFAULT_COMMENTS = ("#", "%")
DEFAULT_CHUNK_EDGES = 1 << 16


class ParseError(ValueError):
    """Malformed edge-list input (carries file context + line number)."""


def _open_text(source):
    """``source`` -> (text-mode iterable, needs_close, display name).

    Accepts a path (str/``os.PathLike``; gzip sniffed from magic
    bytes, not the extension) or an already-open file object (binary
    or text)."""
    if hasattr(source, "read"):
        name = getattr(source, "name", "<stream>")
        first = source.read(0)
        if isinstance(first, bytes):
            buf = source if hasattr(source, "peek") else \
                io.BufferedReader(source)
            if buf.peek(2)[:2] == GZIP_MAGIC:
                buf = gzip.open(buf, "rb")
            return io.TextIOWrapper(buf, encoding="utf-8"), False, name
        return source, False, name
    path = str(source)
    raw = io.open(path, "rb")
    if raw.peek(2)[:2] == GZIP_MAGIC:
        return io.TextIOWrapper(gzip.open(raw, "rb"),
                                encoding="utf-8"), True, path
    return io.TextIOWrapper(raw, encoding="utf-8"), True, path


def _to_int64(tokens: list, start_line: int, name: str) -> np.ndarray:
    try:
        return np.array(tokens, dtype=np.int64)
    except (ValueError, OverflowError):
        for i, t in enumerate(tokens):     # slow path: name the culprit
            try:
                int(t)
            except ValueError:
                raise ParseError(
                    f"{name}: line {start_line + i}: non-numeric id "
                    f"{t!r} in a numeric edge list (first data line "
                    "was numeric — mixed id types are not supported)"
                    ) from None
        raise


def iter_edge_chunks(source, *, delimiter: Optional[str] = None,
                     comments: Sequence[str] = DEFAULT_COMMENTS,
                     chunk_edges: int = DEFAULT_CHUNK_EDGES,
                     ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield ``(src, dst)`` external-id chunks of at most
    ``chunk_edges`` edges each (int64 for numeric files, unicode
    otherwise — both sides always share one dtype)."""
    if chunk_edges < 1:
        raise ValueError(f"chunk_edges must be >= 1; got {chunk_edges}")
    text, needs_close, name = _open_text(source)
    prefixes = tuple(comments)
    numeric: Optional[bool] = None
    srcs: list = []
    dsts: list = []
    lines: list = []          # 1-based line number per buffered edge

    def emit():
        if numeric:
            s = _to_int64(srcs, lines[0], name)
            d = _to_int64(dsts, lines[0], name)
        else:
            s, d = np.array(srcs, dtype=str), np.array(dsts, dtype=str)
        srcs.clear(), dsts.clear(), lines.clear()
        return s, d

    try:
        for lineno, line in enumerate(text, start=1):
            t = line.strip()
            if not t or (prefixes and t.startswith(prefixes)):
                continue
            fields = t.split(delimiter)
            # empty strings from repeated explicit delimiters ("a,,b")
            if delimiter is not None:
                fields = [f for f in fields if f]
            if len(fields) < 2:
                raise ParseError(
                    f"{name}: line {lineno}: expected at least 2 "
                    f"fields (src, dst), got {len(fields)}: {t!r}")
            if numeric is None:            # sniff dtype once, first line
                numeric = True
                for f in fields[:2]:
                    try:
                        int(f)
                    except ValueError:
                        numeric = False
            srcs.append(fields[0])
            dsts.append(fields[1])
            lines.append(lineno)
            if len(srcs) >= chunk_edges:
                yield emit()
        if srcs:
            yield emit()
    finally:
        if needs_close:
            text.close()


def read_edge_list(source, **kw) -> tuple[np.ndarray, np.ndarray]:
    """Convenience: concatenate every chunk (small files / tests).
    Returns empty int64 arrays for an edge-free file."""
    chunks = list(iter_edge_chunks(source, **kw))
    if not chunks:
        empty = np.array([], dtype=np.int64)
        return empty, empty.copy()
    return (np.concatenate([s for s, _ in chunks]),
            np.concatenate([d for _, d in chunks]))
