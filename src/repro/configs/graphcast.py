"""GraphCast encoder-processor-decoder mesh GNN [arXiv:2212.12794]."""
from .base import GNNConfig, register

CONFIG = GNNConfig(
    name="graphcast", n_layers=16, d_hidden=512, flavor="mpnn",
    mesh_refinement=6, aggregator="sum", n_vars=227,
    source="arXiv:2212.12794")
register(CONFIG)
