"""EquiformerV2: equivariant graph attention via eSCN [arXiv:2306.12059]."""
from .base import GNNConfig, register

CONFIG = GNNConfig(
    name="equiformer-v2", n_layers=12, d_hidden=128, flavor="escn",
    l_max=6, m_max=2, n_heads=8, n_rbf=8, cutoff=5.0,
    source="arXiv:2306.12059")
register(CONFIG)
