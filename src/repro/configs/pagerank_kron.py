"""The paper's own workload: PageRank on the Graph500 kron graph
(scale 25, |E| ~ 1.07e9, partition size 256 KB = 64K nodes)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PageRankConfig:
    name: str = "pagerank-kron"
    family: str = "pagerank"
    scale: int = 25
    edge_factor: int = 31
    part_size: int = 65536           # 256 KB / 4 B values (paper VI-C)
    method: str = "pcpm"
    num_iterations: int = 20
    damping: float = 0.85

    def scaled(self, scale: int = 12, edge_factor: int = 8,
               part_size: int = 512):
        return dataclasses.replace(
            self, name=self.name + "-smoke", scale=scale,
            edge_factor=edge_factor, part_size=part_size)


CONFIG = PageRankConfig()
