"""DeepSeek 67B (llama-arch) [arXiv:2401.02954; hf]."""
from .base import LMConfig, register

CONFIG = LMConfig(
    name="deepseek-67b", n_layers=95, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=22016, vocab=102400, source="arXiv:2401.02954")
register(CONFIG)
