"""MIND multi-interest recsys network [arXiv:1904.08030]."""
from .base import RecSysConfig, register

CONFIG = RecSysConfig(
    name="mind", embed_dim=64, n_interests=4, capsule_iters=3,
    vocab=10_000_000, hist_len=50, source="arXiv:1904.08030")
register(CONFIG)
