"""MACE higher-order equivariant message passing [arXiv:2206.07697]."""
from .base import GNNConfig, register

CONFIG = GNNConfig(
    name="mace", n_layers=2, d_hidden=128, flavor="equivariant",
    l_max=2, correlation_order=3, n_rbf=8, cutoff=5.0,
    source="arXiv:2206.07697")
register(CONFIG)
