"""Config system: one frozen dataclass per architecture family, a shape
registry (each arch carries ITS OWN input-shape set), and the global
``--arch`` registry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

_REGISTRY: dict[str, object] = {}


def register(cfg) -> None:
    assert cfg.name not in _REGISTRY, f"duplicate arch {cfg.name}"
    _REGISTRY[cfg.name] = cfg


def get(name: str):
    if name not in _REGISTRY:
        # import side-effect registration
        from . import ALL_ARCHS  # noqa: F401
    return _REGISTRY[name]


def all_archs() -> list[str]:
    from . import ALL_ARCHS  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode | long_decode |
                         # full_graph | minibatch | batched_graphs |
                         # recsys_train | recsys_serve | retrieval
    seq_len: int = 0
    global_batch: int = 0
    # graph shapes
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple = ()
    # recsys shapes
    n_candidates: int = 0


LM_SHAPES = (
    ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeSpec("long_500k", "long_decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "full_graph", n_nodes=2708, n_edges=10556,
              d_feat=1433),
    ShapeSpec("minibatch_lg", "minibatch", n_nodes=232965,
              n_edges=114_615_892, batch_nodes=1024, fanout=(15, 10),
              d_feat=602),
    ShapeSpec("ogb_products", "full_graph", n_nodes=2_449_029,
              n_edges=61_859_140, d_feat=100),
    ShapeSpec("molecule", "batched_graphs", n_nodes=30, n_edges=64,
              global_batch=128, d_feat=32),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "recsys_train", global_batch=65536),
    ShapeSpec("serve_p99", "recsys_serve", global_batch=512),
    ShapeSpec("serve_bulk", "recsys_serve", global_batch=262144),
    ShapeSpec("retrieval_cand", "retrieval", global_batch=1,
              n_candidates=1_000_000),
)


# --------------------------------------------------------------- configs
@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    family: str = "lm"
    head_dim: Optional[int] = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # attention
    window: Optional[int] = None       # sliding window (SWA)
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    source: str = ""

    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def shapes(self):
        return LM_SHAPES

    @property
    def sub_quadratic(self) -> bool:
        """long_500k eligibility: SWA bounds the KV working set."""
        return self.window is not None

    def param_count(self) -> int:
        d, dh = self.d_model, self.dh
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) \
            + (self.n_heads * dh) * d
        if self.moe:
            ffn = self.n_experts * 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return (self.n_layers * per_layer + 2 * self.vocab * d + d)

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dead = (self.n_experts - self.top_k) * 3 * d * self.d_ff
        return self.param_count() - self.n_layers * dead

    def scaled(self, *, n_layers=2, d_model=128, n_heads=4, n_kv_heads=None,
               d_ff=256, vocab=512, n_experts=None, window=None):
        """Reduced config of the same family for CPU smoke tests."""
        return dataclasses.replace(
            self, name=self.name + "-smoke", n_layers=n_layers,
            d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv_heads or max(1, n_heads // 2), d_ff=d_ff,
            vocab=vocab, head_dim=None,
            n_experts=(self.n_experts and (n_experts or 4)),
            top_k=min(self.top_k, 2) if self.moe else 0,
            capacity_factor=8.0,   # no token drops at smoke-test scale
            window=window if window is not None else
            (64 if self.window else None))


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    family: str = "gnn"
    flavor: str = "mpnn"           # mpnn | equivariant | escn
    # graphcast
    mesh_refinement: int = 0
    aggregator: str = "sum"
    n_vars: int = 0
    # equivariant
    l_max: int = 0
    m_max: int = 0
    n_rbf: int = 0
    cutoff: float = 0.0
    correlation_order: int = 1
    n_heads: int = 0
    act_dtype: str = "float32"     # activation/message dtype (mixed
                                   # precision: bf16 on the big cells)
    source: str = ""

    @property
    def shapes(self):
        return GNN_SHAPES

    def scaled(self, **kw):
        return dataclasses.replace(
            self, name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_hidden=min(self.d_hidden, 32),
            l_max=min(self.l_max, 2), m_max=min(self.m_max, 1),
            mesh_refinement=min(self.mesh_refinement, 2),
            n_vars=min(self.n_vars, 8) if self.n_vars else 0,
            n_heads=min(self.n_heads, 2) if self.n_heads else 0, **kw)


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    embed_dim: int
    n_interests: int
    capsule_iters: int
    family: str = "recsys"
    vocab: int = 10_000_000        # item vocabulary (embedding rows)
    hist_len: int = 50             # user behaviour sequence length
    source: str = ""

    @property
    def shapes(self):
        return RECSYS_SHAPES

    def scaled(self, **kw):
        return dataclasses.replace(
            self, name=self.name + "-smoke", embed_dim=32, vocab=1000,
            hist_len=8, **kw)
