"""NequIP O(3)-equivariant interatomic potential [arXiv:2101.03164]."""
from .base import GNNConfig, register

CONFIG = GNNConfig(
    name="nequip", n_layers=5, d_hidden=32, flavor="equivariant",
    l_max=2, n_rbf=8, cutoff=5.0, source="arXiv:2101.03164")
register(CONFIG)
