from .base import (LMConfig, GNNConfig, RecSysConfig, ShapeSpec, get,
                   all_archs, LM_SHAPES, GNN_SHAPES, RECSYS_SHAPES)
from . import (mixtral_8x7b, grok_1_314b, stablelm_1_6b, tinyllama_1_1b,
               deepseek_67b, graphcast, nequip, mace, equiformer_v2, mind,
               pagerank_kron)

ALL_ARCHS = [
    mixtral_8x7b.CONFIG, grok_1_314b.CONFIG, stablelm_1_6b.CONFIG,
    tinyllama_1_1b.CONFIG, deepseek_67b.CONFIG, graphcast.CONFIG,
    nequip.CONFIG, mace.CONFIG, equiformer_v2.CONFIG, mind.CONFIG,
]
PAGERANK = pagerank_kron.CONFIG

__all__ = ["LMConfig", "GNNConfig", "RecSysConfig", "ShapeSpec", "get",
           "all_archs", "ALL_ARCHS", "PAGERANK", "LM_SHAPES",
           "GNN_SHAPES", "RECSYS_SHAPES"]
