"""AdamW + schedules, functional optax-style (init/update), pure JAX."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # storage dtype for mu/nu (math stays f32): "bfloat16" halves the
    # optimizer footprint — the lightweight cousin of blockwise 8-bit
    # Adam, needed to fit grok-scale state (Perf hillclimb).
    state_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.dtype(self.state_dtype))
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        # global-norm clip
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        sd = jnp.dtype(self.state_dtype)
        mu = jax.tree.map(
            lambda m, g: (self.b1 * m.astype(jnp.float32)
                          + (1 - self.b1) * g).astype(sd),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: (self.b2 * v.astype(jnp.float32)
                          + (1 - self.b2) * g * g).astype(sd),
            state.nu, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            m, v = m.astype(jnp.float32), v.astype(jnp.float32)
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu), gnorm


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr
