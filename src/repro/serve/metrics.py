"""Latency / throughput recorder for the PageRank query scheduler.

One ``QueryTrace`` per query: submit -> admit (queue wait) -> done
(service).  ``summary()`` reduces the traces to the open-loop serving
headline numbers — p50/p99 end-to-end latency and queries/sec over the
span between the first submit and the last completion — which is what
``benchmarks/serve_load.py`` reports and CI freezes as
``BENCH_serve.json``.

Resilience accounting (DESIGN.md §10): traces carry terminal ``error``
and ``degraded`` flags, and the recorder keeps named event counters
(rejections, queue expiries, degradations, quarantines, stepper/delta
failures) so every shed or degraded query is visible in the summary —
nothing fails silently.  Latency percentiles are computed over the
queries actually SERVED (error-free completions): a rejected query
completes in microseconds and would otherwise drag p50 down exactly
when the system is under the most stress.

Edge-case contract: an empty recorder reports ``None`` for every
statistic that has no defined value (percentiles, mean, qps) instead
of fabricating 0.0 — and ``qps`` is ``None`` (not ``inf``) when the
observed span is zero, keeping summaries JSON-clean.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional


@dataclasses.dataclass
class QueryTrace:
    uid: int
    t_submit: float
    t_admit: float | None = None
    t_done: float | None = None
    iterations: int = 0
    converged: bool = False
    error: Optional[str] = None     # terminal failure (reject/fault)
    degraded: bool = False          # served approximate under pressure

    @property
    def latency_s(self) -> float | None:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def queue_wait_s(self) -> float | None:
        if self.t_admit is None:
            return None
        return self.t_admit - self.t_submit


def _percentile(sorted_vals: list[float], q: float) -> Optional[float]:
    """Nearest-rank percentile over an already-sorted list; ``None``
    when there is no data to take a percentile of."""
    if not sorted_vals:
        return None
    idx = min(len(sorted_vals) - 1,
              max(0, round(q / 100.0 * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


class ServeMetrics:
    """Per-query trace collection with an aggregate summary.

    The clock is injectable so tests can drive deterministic times;
    schedulers share it for deadline arithmetic so a fake clock drives
    the whole admission path.

    Thread-safe: the recorder is shared between a scheduler's device
    loop and the gateway's submit/worker threads (repro.gateway), so
    every mutation — trace writes and counter increments — happens
    under one internal lock.  ``Counter[name] += 1`` in particular is
    a read-modify-write that silently loses updates under free-running
    threads (the pre-gateway accounting bug).
    """

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.traces: dict[int, QueryTrace] = {}
        self.counters: collections.Counter = collections.Counter()
        self._lock = threading.Lock()

    def submitted(self, uid: int) -> None:
        with self._lock:
            self.traces[uid] = QueryTrace(uid, self.clock())

    def admitted(self, uid: int) -> None:
        """Record FIRST admission only: a quarantine re-admission (or
        a push fallback re-entering the stepper) re-runs the admit
        path, and letting it overwrite ``t_admit`` would under-report
        queue wait exactly for the queries that needed retries."""
        with self._lock:
            tr = self.traces[uid]
            if tr.t_admit is None:
                tr.t_admit = self.clock()

    def completed(self, uid: int, *, iterations: int, converged: bool,
                  error: Optional[str] = None,
                  degraded: bool = False) -> None:
        with self._lock:
            tr = self.traces[uid]
            tr.t_done = self.clock()
            tr.iterations = iterations
            tr.converged = converged
            tr.error = error
            tr.degraded = degraded

    def incr(self, name: str, n: int = 1) -> None:
        """Count one resilience event (rejection, expiry, degradation,
        quarantine, ...)."""
        with self._lock:
            self.counters[name] += n

    def _trace_snapshot(self) -> list[QueryTrace]:
        """Consistent read of the trace table — iterating the live dict
        while a submit thread inserts would raise mid-iteration."""
        with self._lock:
            return list(self.traces.values())

    @property
    def completed_count(self) -> int:
        return sum(tr.t_done is not None
                   for tr in self._trace_snapshot())

    def percentile(self, q: float, *, of: str = "latency"
                   ) -> Optional[float]:
        """Nearest-rank percentile (seconds) over served completions;
        ``of`` is ``"latency"`` (submit->done) or ``"queue"``
        (submit->admit).  ``None`` on an empty recorder — the honest
        answer, not 0.0."""
        done = [tr for tr in self._trace_snapshot()
                if tr.t_done is not None and tr.error is None]
        if of == "latency":
            vals = sorted(tr.latency_s for tr in done)
        elif of == "queue":
            vals = sorted(tr.queue_wait_s for tr in done
                          if tr.t_admit is not None)
        else:
            raise ValueError(f"unknown percentile kind {of!r}")
        return _percentile(vals, q)

    def summary(self) -> dict:
        with self._lock:
            traces = list(self.traces.values())
            counters = dict(self.counters)
        done = [tr for tr in traces if tr.t_done is not None]
        served = [tr for tr in done if tr.error is None]
        base = {
            "count": len(done),
            "served_count": len(served),
            "error_count": len(done) - len(served),
            "degraded_count": sum(tr.degraded for tr in done),
            "counters": counters,
        }
        if not served:
            base.update({"qps": None, "p50_ms": None, "p99_ms": None,
                         "mean_ms": None, "queue_p50_ms": None,
                         "mean_iterations": None,
                         "converged_frac": None})
            return base
        lats = sorted(tr.latency_s for tr in served)
        waits = sorted(tr.queue_wait_s for tr in served
                       if tr.t_admit is not None)
        span = (max(tr.t_done for tr in served)
                - min(tr.t_submit for tr in served))
        p50, p99 = _percentile(lats, 50), _percentile(lats, 99)
        qw = _percentile(waits, 50)
        base.update({
            "qps": len(served) / span if span > 0 else None,
            "p50_ms": p50 * 1e3,
            "p99_ms": p99 * 1e3,
            "mean_ms": sum(lats) / len(lats) * 1e3,
            "queue_p50_ms": qw * 1e3 if qw is not None else None,
            "mean_iterations": (sum(tr.iterations for tr in served)
                                / len(served)),
            "converged_frac": (sum(tr.converged for tr in served)
                               / len(served)),
        })
        return base
