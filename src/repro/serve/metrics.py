"""Latency / throughput recorder for the PageRank query scheduler.

One ``QueryTrace`` per query: submit -> admit (queue wait) -> done
(service).  ``summary()`` reduces the traces to the open-loop serving
headline numbers — p50/p99 end-to-end latency and queries/sec over the
span between the first submit and the last completion — which is what
``benchmarks/serve_load.py`` reports and CI freezes as
``BENCH_serve.json``.

Resilience accounting (DESIGN.md §10): traces carry terminal ``error``
and ``degraded`` flags, and the recorder keeps named event counters
(rejections, queue expiries, degradations, quarantines, stepper/delta
failures) so every shed or degraded query is visible in the summary —
nothing fails silently.  Latency percentiles are computed over the
queries actually SERVED (error-free completions): a rejected query
completes in microseconds and would otherwise drag p50 down exactly
when the system is under the most stress.

Single-home rule (DESIGN.md §14): every named event lives in exactly
one place — the ``obs.metrics.MetricsRegistry`` each recorder owns
(``serve_events_total{event=...}``).  The old ``collections.Counter``
surface survives as a read-only VIEW (the ``counters`` property), so
the pre-obs double-home drift — scheduler attributes and recorder
counters updated at different points — is structurally impossible.
``completed()`` additionally enforces the terminal contract at the
choke point: a second completion for the same uid raises, and
``reconcile()`` cross-checks the event counters against the trace
table (the exactly-once audit in tests/test_serve_accounting.py).

Edge-case contract: an empty recorder reports ``None`` for every
statistic that has no defined value (percentiles, mean, qps) instead
of fabricating 0.0 — and ``qps`` is ``None`` (not ``inf``) when the
observed span is zero, keeping summaries JSON-clean.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

from ..obs.metrics import MetricsRegistry

EVENT_FAMILY = "serve_events_total"
TERMINAL_FAMILY = "serve_terminals_total"


@dataclasses.dataclass
class QueryTrace:
    uid: int
    t_submit: float
    t_admit: float | None = None
    t_done: float | None = None
    iterations: int = 0
    converged: bool = False
    error: Optional[str] = None     # terminal failure (reject/fault)
    degraded: bool = False          # served approximate under pressure
    route: Optional[str] = None     # "push" / "cached" / None (stepper)

    @property
    def latency_s(self) -> float | None:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def queue_wait_s(self) -> float | None:
        if self.t_admit is None:
            return None
        return self.t_admit - self.t_submit


def _percentile(sorted_vals: list[float], q: float) -> Optional[float]:
    """Nearest-rank percentile over an already-sorted list; ``None``
    when there is no data to take a percentile of."""
    if not sorted_vals:
        return None
    idx = min(len(sorted_vals) - 1,
              max(0, round(q / 100.0 * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


class ServeMetrics:
    """Per-query trace collection with an aggregate summary.

    The clock is injectable so tests can drive deterministic times;
    schedulers share it for deadline arithmetic so a fake clock drives
    the whole admission path.

    Thread-safe: the recorder is shared between a scheduler's device
    loop and the gateway's submit/worker threads (repro.gateway).
    Trace writes happen under one internal lock; event counters are
    registry metrics with their own per-metric locks, so increments
    from free-running threads never lose updates.

    Each recorder owns its registry by default (reconciliation is a
    per-scheduler property); pass ``registry=`` to aggregate several
    recorders into one scrape surface — their samples stay separable
    because the gateway labels each with its graph name.
    """

    def __init__(self, clock=time.perf_counter,
                 registry: Optional[MetricsRegistry] = None):
        self.clock = clock
        self.registry = registry if registry is not None else MetricsRegistry()
        self.traces: dict[int, QueryTrace] = {}
        self._lock = threading.Lock()

    def submitted(self, uid: int) -> None:
        with self._lock:
            self.traces[uid] = QueryTrace(uid, self.clock())

    def admitted(self, uid: int) -> None:
        """Record FIRST admission only: a quarantine re-admission (or
        a push fallback re-entering the stepper) re-runs the admit
        path, and letting it overwrite ``t_admit`` would under-report
        queue wait exactly for the queries that needed retries."""
        with self._lock:
            tr = self.traces[uid]
            if tr.t_admit is None:
                tr.t_admit = self.clock()

    def completed(self, uid: int, *, iterations: int, converged: bool,
                  error: Optional[str] = None, degraded: bool = False,
                  route: Optional[str] = None) -> None:
        with self._lock:
            tr = self.traces[uid]
            if tr.t_done is not None:
                raise RuntimeError(
                    f"duplicate terminal for uid {uid}: already "
                    f"completed (error={tr.error!r}), second "
                    f"completion (error={error!r}) — every query must "
                    "resolve exactly once")
            tr.t_done = self.clock()
            tr.iterations = iterations
            tr.converged = converged
            tr.error = error
            tr.degraded = degraded
            tr.route = route
        self.registry.counter(
            TERMINAL_FAMILY, "terminal resolutions (exactly one "
            "per query)").inc()

    def incr(self, name: str, n: int = 1) -> None:
        """Count one resilience event (rejection, expiry, degradation,
        quarantine, ...) — single home: the registry."""
        self.registry.counter(
            EVENT_FAMILY, "named scheduler/gateway events",
            event=name).inc(n)

    @property
    def counters(self) -> collections.Counter:
        """Read-only view of the event counters in the legacy
        ``collections.Counter`` shape (missing names read as 0, as
        before).  Mutations go through ``incr``."""
        c = collections.Counter()
        for labels, metric in self.registry.family_items(EVENT_FAMILY):
            c[labels["event"]] = int(metric.value)
        return c

    def _trace_snapshot(self) -> list[QueryTrace]:
        """Consistent read of the trace table — iterating the live dict
        while a submit thread inserts would raise mid-iteration."""
        with self._lock:
            return list(self.traces.values())

    @property
    def completed_count(self) -> int:
        return sum(tr.t_done is not None
                   for tr in self._trace_snapshot())

    def percentile(self, q: float, *, of: str = "latency"
                   ) -> Optional[float]:
        """Nearest-rank percentile (seconds) over served completions;
        ``of`` is ``"latency"`` (submit->done) or ``"queue"``
        (submit->admit).  ``None`` on an empty recorder — the honest
        answer, not 0.0."""
        done = [tr for tr in self._trace_snapshot()
                if tr.t_done is not None and tr.error is None]
        if of == "latency":
            vals = sorted(tr.latency_s for tr in done)
        elif of == "queue":
            vals = sorted(tr.queue_wait_s for tr in done
                          if tr.t_admit is not None)
        else:
            raise ValueError(f"unknown percentile kind {of!r}")
        return _percentile(vals, q)

    def reconcile(self) -> dict:
        """Cross-check event counters against the trace table.

        Every family that is derivable from BOTH surfaces must agree
        exactly: terminals vs completed traces, rejections/expiries vs
        terminal error strings, push/cache serves vs trace routes.  A
        mismatch means a counter was bumped without its terminal (or
        vice versa) — the double-home drift this layer exists to kill.
        Returns the checked values; raises ``AssertionError`` naming
        the first disagreement.
        """
        traces = self._trace_snapshot()
        done = [tr for tr in traces if tr.t_done is not None]
        c = self.counters
        checks = {
            "terminals": (
                int(self.registry.counter_value(TERMINAL_FAMILY)),
                len(done)),
            "rejected": (
                c["rejected"],
                sum(1 for tr in done if tr.error is not None
                    and tr.error.startswith("rejected"))),
            "expired": (
                c["expired"],
                sum(1 for tr in done
                    if tr.error == "deadline expired in queue")),
            "push_served": (
                c["push_served"],
                sum(1 for tr in done
                    if tr.route == "push" and tr.error is None)),
            "cache_hits_served": (
                c["cache_hits"],
                sum(1 for tr in done if tr.route == "cached")),
        }
        for name, (counted, derived) in checks.items():
            assert counted == derived, (
                f"counter/trace drift for {name!r}: counter says "
                f"{counted}, trace table derives {derived}")
        return {k: v[0] for k, v in checks.items()}

    def summary(self) -> dict:
        traces = self._trace_snapshot()
        counters = dict(self.counters)
        done = [tr for tr in traces if tr.t_done is not None]
        served = [tr for tr in done if tr.error is None]
        base = {
            "count": len(done),
            "served_count": len(served),
            "error_count": len(done) - len(served),
            "degraded_count": sum(tr.degraded for tr in done),
            "counters": counters,
        }
        if not served:
            base.update({"qps": None, "p50_ms": None, "p99_ms": None,
                         "mean_ms": None, "queue_p50_ms": None,
                         "mean_iterations": None,
                         "converged_frac": None})
            return base
        lats = sorted(tr.latency_s for tr in served)
        waits = sorted(tr.queue_wait_s for tr in served
                       if tr.t_admit is not None)
        span = (max(tr.t_done for tr in served)
                - min(tr.t_submit for tr in served))
        p50, p99 = _percentile(lats, 50), _percentile(lats, 99)
        qw = _percentile(waits, 50)
        base.update({
            "qps": len(served) / span if span > 0 else None,
            "p50_ms": p50 * 1e3,
            "p99_ms": p99 * 1e3,
            "mean_ms": sum(lats) / len(lats) * 1e3,
            "queue_p50_ms": qw * 1e3 if qw is not None else None,
            "mean_iterations": (sum(tr.iterations for tr in served)
                                / len(served)),
            "converged_frac": (sum(tr.converged for tr in served)
                               / len(served)),
        })
        return base
