"""Latency / throughput recorder for the PageRank query scheduler.

One ``QueryTrace`` per query: submit -> admit (queue wait) -> done
(service).  ``summary()`` reduces the traces to the open-loop serving
headline numbers — p50/p99 end-to-end latency and queries/sec over the
span between the first submit and the last completion — which is what
``benchmarks/serve_load.py`` reports and CI freezes as
``BENCH_serve.json``.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class QueryTrace:
    uid: int
    t_submit: float
    t_admit: float | None = None
    t_done: float | None = None
    iterations: int = 0
    converged: bool = False

    @property
    def latency_s(self) -> float | None:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def queue_wait_s(self) -> float | None:
        if self.t_admit is None:
            return None
        return self.t_admit - self.t_submit


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile over an already-sorted list."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1,
              max(0, round(q / 100.0 * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


class ServeMetrics:
    """Per-query trace collection with an aggregate summary.

    The clock is injectable so tests can drive deterministic times.
    """

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self.traces: dict[int, QueryTrace] = {}

    def submitted(self, uid: int) -> None:
        self.traces[uid] = QueryTrace(uid, self._clock())

    def admitted(self, uid: int) -> None:
        self.traces[uid].t_admit = self._clock()

    def completed(self, uid: int, *, iterations: int,
                  converged: bool) -> None:
        tr = self.traces[uid]
        tr.t_done = self._clock()
        tr.iterations = iterations
        tr.converged = converged

    @property
    def completed_count(self) -> int:
        return sum(tr.t_done is not None for tr in self.traces.values())

    def summary(self) -> dict:
        done = [tr for tr in self.traces.values() if tr.t_done is not None]
        if not done:
            return {"count": 0, "qps": 0.0, "p50_ms": 0.0, "p99_ms": 0.0,
                    "mean_ms": 0.0, "queue_p50_ms": 0.0,
                    "mean_iterations": 0.0, "converged_frac": 0.0}
        lats = sorted(tr.latency_s for tr in done)
        waits = sorted(tr.queue_wait_s for tr in done
                       if tr.t_admit is not None)
        span = (max(tr.t_done for tr in done)
                - min(tr.t_submit for tr in done))
        return {
            "count": len(done),
            "qps": len(done) / span if span > 0 else float("inf"),
            "p50_ms": _percentile(lats, 50) * 1e3,
            "p99_ms": _percentile(lats, 99) * 1e3,
            "mean_ms": sum(lats) / len(lats) * 1e3,
            "queue_p50_ms": _percentile(waits, 50) * 1e3,
            "mean_iterations": (sum(tr.iterations for tr in done)
                                / len(done)),
            "converged_frac": (sum(tr.converged for tr in done)
                               / len(done)),
        }
