"""Forward-push personalized-query backend (DESIGN.md §11).

The masked chunk stepper answers EVERY personalized query with full
(n, B) power iteration.  For a single-seed top-k query that is the
wrong unit of work: forward-push (Zhang et al., arXiv:2302.03245)
propagates only the query's residual, and PR 5's residual-push loop is
already the device half.  This module adds the QUERY seeding and a
host fast path, and ``SlotScheduler.submit`` routes loose-tolerance /
top-k personalized queries here (``core.backends.Backend
.supports_push_query``) with an honest fallback to the stepper.

**Seeding.**  The stepper starts a personalized query at ``x0 = seed``
and iterates ``x_{k+1} = base + d·Op(x_k)`` with
``base = (1−d)·seed``, stopping on the per-step L1 change
``‖x_{k+1} − x_k‖₁ < tol``.  Seeding the push at ``pr0 = x0 = seed``,
``r0 = x1 − x0`` makes the push residuals EXACTLY the stepper's
per-step changes (``r_{k} = x_{k+1} − x_k`` — signed, so opposing mass
cancels), and equal tolerances mean equal stopping accuracy: final L1
distance to the fixed point ≤ tol·d/(1−d) either way.

**Host fast path.**  At serving scale the device loop pays a fixed
dispatch + transfer cost per sweep that dwarfs the O(m) work of a
single-vector push on small/medium graphs, so the default engine runs
the same iteration host-side on a damped scipy CSR over the CORE
subgraph (nodes with out-edges): under ``dangling="none"`` a dangling
node absorbs mass and emits nothing, so its exact rank is
reconstructed AFTER convergence in one matvec —
``x*_d = (1−d)·seed_d + W_dc @ x*_c`` — and the loop never carries the
dangling rows.  The core stop test ``‖r_c‖₁·(1+d) < tol`` conservatively
covers the stepper's full-vector rule (the dangling rows' step change
is ≤ d·‖r_c‖₁).  Once ``‖r_c‖₁`` is within ``aitken_factor·tol`` of
the target, a certified Aitken step extrapolates along the dominant
eigendirection: for this linear iteration the extrapolated residual
``(1+γ)·(W_cc r) − γ·r`` is the EXACT residual of the extrapolated
iterate, so the stop test never leaves the true residual — the cheaper
of (plain, extrapolated) is taken by comparing true residual norms.

**Cost model** (groundwork for slot-pool autotuning, ROADMAP item 2):
``PushResult.work_nnz`` reports edges touched (matvecs × nnz) — the
per-query cost a scheduler can weigh against the stepper's
O(iters × m × B / B) share before picking a route.

**Fallback honesty.**  A query whose push exits above its bound (budget
exhausted) is NOT served from the estimate: the scheduler re-admits it
to the stepper warm-started at the estimate, carrying the consumed
sweeps against its iteration budget, counted in
``metrics.counters["push_fallbacks"]``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.pagerank import _inv_degree
from ..core.push import (MAX_PUSH_BUF, residual_push_loop,
                         seed_query_state)
from ..graphs.formats import Graph, validate_graph
from .topk import host_topk

PUSH_MODES = ("auto", "host", "device")


def _csr_matvec_into(A):
    """``mv(x, out) -> out`` computing ``A @ x`` into a caller-owned
    buffer.  The serving fast path answers thousands of queries/sec,
    each a handful of tiny matvecs — scipy's ``__matmul__`` dispatch
    (type checks, shape plumbing, fresh output allocation) costs more
    than the kernel at that size, so bind the raw sparsetools kernel
    when available and fall back to the operator when not."""
    try:
        from scipy.sparse import _sparsetools
        kernel = _sparsetools.csr_matvec
        m, n = A.shape
        indptr, indices, data = A.indptr, A.indices, A.data

        def mv(x, out):
            out.fill(0.0)                 # kernel accumulates into out
            kernel(m, n, indptr, indices, data, x, out)
            return out
    except (ImportError, AttributeError):  # pragma: no cover - pinned
        def mv(x, out):
            out[:] = A @ x
            return out
    return mv


@dataclasses.dataclass
class PushResult:
    """One answered push query.  ``residual`` is the stepper-comparable
    stopping bound (an upper bound on the equivalent per-step L1
    change), so ``converged`` means what the stepper's flag means."""
    estimate: np.ndarray                     # (n,) personalized ranks
    sweeps: int
    residual: float
    converged: bool
    mode: str                                # "host" | "device"
    work_nnz: int                            # edges touched (cost model)
    top_ids: Optional[np.ndarray] = None     # (k,) int32 when top_k set
    top_scores: Optional[np.ndarray] = None  # (k,) float32


class PushQueryEngine:
    """Per-graph forward-push query answerer.

    ``mode="host"`` runs the core-subgraph scipy loop (the serving fast
    path), ``mode="device"`` re-seeds the shared donated push
    while_loop (core/push.py) per query — one compiled executable for
    every seed and tolerance, the right path once per-sweep O(m) work
    outgrows the per-dispatch overhead.  ``mode="auto"`` picks host
    when scipy is importable, device otherwise.

    Only ``dangling="none"`` is supported: the exact dangling
    reconstruction (and the stepper-iterate equivalence above) relies
    on sinks absorbing mass.  The scheduler routes ``redistribute``
    configurations to the stepper.
    """

    def __init__(self, g: Graph, engine=None, *, damping: float = 0.85,
                 dangling: str = "none", mode: str = "auto",
                 aitken_factor: float = 100.0):
        if dangling != "none":
            raise ValueError(
                "push query backend requires dangling='none' (sink "
                f"reconstruction is exact only there); got {dangling!r}")
        if mode not in PUSH_MODES:
            raise ValueError(f"mode must be one of {PUSH_MODES}; "
                             f"got {mode!r}")
        if engine is not None and mode != "host" \
                and not engine.backend.supports_push_query:
            raise ValueError(
                f"backend {engine.method!r} does not support push "
                "queries (supports_push_query=False)")
        validate_graph(g)
        self.g = g
        self.n = g.num_nodes
        self.damping = float(damping)
        self.dangling = dangling
        self.engine = engine
        self.aitken_factor = float(aitken_factor)
        if mode == "auto":
            try:
                import scipy.sparse  # noqa: F401
                mode = "host"
            except ImportError:          # pragma: no cover - jax ships it
                mode = "device"
        if mode == "device" and engine is None:
            raise ValueError("mode='device' needs an SpMVEngine (the "
                             "push loop runs over its plan)")
        self.mode = mode
        self._host = None                 # (Wcc, Wdc, core_ids, dang_ids)
        self._dev = None                  # (init, run, inv_deg)

    # ------------------------------------------------------------- host
    def _host_state(self):
        if self._host is None:
            import scipy.sparse as sp
            g, d, n = self.g, self.damping, self.n
            deg = np.asarray(g.out_degree)
            core = deg > 0
            core_ids = np.nonzero(core)[0].astype(np.int64)
            dang_ids = np.nonzero(~core)[0].astype(np.int64)
            # position of each node inside its class (valid where the
            # class mask holds)
            core_pos = np.cumsum(core) - 1
            dang_pos = np.cumsum(~core) - 1
            nc, nd = len(core_ids), len(dang_ids)
            w = (d / np.maximum(deg, 1)).astype(np.float32)[g.src]
            to_core = core[g.dst]         # every src is core by def.
            Wcc = sp.csr_matrix(
                (w[to_core], (core_pos[g.dst[to_core]],
                              core_pos[g.src[to_core]])),
                shape=(nc, nc), dtype=np.float32)
            Wdc = sp.csr_matrix(
                (w[~to_core], (dang_pos[g.dst[~to_core]],
                               core_pos[g.src[~to_core]])),
                shape=(nd, nc), dtype=np.float32)
            # R0 = Wcc − d·I seeds the residual in ONE kernel call:
            # rc0 = (Wcc − d·I) @ sc = x1_c − x0_c
            R0 = (Wcc - sp.identity(nc, np.float32, format="csr")
                  * np.float32(d)).tocsr()
            bufs = tuple(np.empty(nc, np.float32) for _ in range(5)) \
                + (np.empty(nd, np.float32),)
            try:                           # BLAS hot-loop primitives:
                # sasum = L1 norm without the |x| temp, saxpy = fused
                # scaled accumulate — one C call each
                from scipy.linalg.blas import sasum, saxpy
            except ImportError:            # pragma: no cover - pinned
                def sasum(x):
                    return float(np.abs(x).sum())

                def saxpy(x, y, a=1.0):
                    y += np.float32(a) * x
                    return y
            self._host = (Wcc, Wdc, core_ids, dang_ids,
                          _csr_matvec_into(Wcc), _csr_matvec_into(Wdc),
                          _csr_matvec_into(R0), bufs, sasum, saxpy)
        return self._host

    def _query_host(self, seed: np.ndarray, *, tol: float,
                    max_sweeps: int):
        (Wcc, Wdc, core_ids, dang_ids, mv_cc, mv_dc, mv_r0,
         bufs, sasum, saxpy) = self._host_state()
        d = self.damping
        # preallocated per-engine scratch — queries are answered one at
        # a time on the serving thread, thousands/sec, so per-query
        # allocations and numpy dispatch are the actual cost here
        sc, xc, rc, y, ext, xd = bufs
        np.take(seed, core_ids, out=sc)
        xc[:] = sc
        # r0 restricted to the core: x1_c − x0_c = (Wcc − d·I)·sc (the
        # damping factor is baked into Wcc's values)
        mv_r0(sc, rc)
        rsum = sasum(rc)
        prev_rsum = None
        sweeps, matvecs = 0, 1
        near = self.aitken_factor * tol
        while rsum * (1.0 + d) >= tol and sweeps < max_sweeps:
            mv_cc(rc, y)
            matvecs += 1
            ay = sasum(y)
            took_ext = False
            if prev_rsum is not None and rsum < near and prev_rsum > 0:
                rho = rsum / prev_rsum
                if 0.05 < rho < 0.95:
                    gam = rho / (1.0 - rho)
                    # ext = (1+gam)·y − gam·rc: the EXACT residual of
                    # the extrapolated iterate (linearity), so picking
                    # the smaller true norm keeps the stop certified
                    np.multiply(rc, np.float32(-gam), out=ext)
                    saxpy(y, ext, a=1.0 + gam)
                    aext = sasum(ext)
                    if aext < ay:
                        saxpy(rc, xc, a=1.0 + gam)
                        prev_rsum, rsum = rsum, aext
                        rc, ext = ext, rc     # ext becomes scratch
                        took_ext = True
            if not took_ext:
                xc += rc
                prev_rsum, rsum = rsum, ay
                rc, y = y, rc                 # swap, no allocation
            sweeps += 1
        xc += rc                          # fold the final residual in
        est = np.zeros(self.n, np.float32)
        est[core_ids] = xc
        if dang_ids.size:
            # exact sink reconstruction — one matvec, never iterated
            mv_dc(xc, xd)
            xd += np.float32(1.0 - d) * seed[dang_ids]
            est[dang_ids] = xd
            matvecs += 1
        bound = rsum * (1.0 + d)
        work = matvecs * int(Wcc.nnz + Wdc.nnz)
        return est, sweeps, bound, bound < tol, work

    # ----------------------------------------------------------- device
    def _device_state(self):
        if self._dev is None:
            import jax.numpy as jnp  # noqa: F401
            plan = self.engine.plan
            init = seed_query_state(plan, damping=self.damping,
                                    dangling=self.dangling)
            run = residual_push_loop(plan, damping=self.damping,
                                     dangling=self.dangling)
            self._dev = (init, run, _inv_degree(self.g))
        return self._dev

    def _query_device(self, seed: np.ndarray, *, tol: float,
                      max_sweeps: int):
        import jax.numpy as jnp
        init, run, inv_deg = self._device_state()
        pr, r = init(jnp.asarray(seed), inv_deg)
        sweeps, remaining = 0, max_sweeps
        while True:
            pr, it, _, r = run(pr, r, inv_deg, tol,
                               min(remaining, MAX_PUSH_BUF))
            it = int(it)
            sweeps += it
            remaining -= it
            final = float(jnp.abs(r).sum())
            if final < tol or remaining <= 0 or it == 0:
                break
        # the full-vector push residual IS the stepper's per-step L1
        # change — no core/sink split, so no (1+d) slack needed
        est = np.asarray(pr + r, dtype=np.float32)
        return (est, sweeps, final, final < tol,
                (sweeps + 1) * self.g.num_edges)

    # ------------------------------------------------------------ query
    def query(self, seed: np.ndarray, *, tol: float,
              max_sweeps: int = 100,
              top_k: int | None = None) -> PushResult:
        """Answer one personalized query.  ``seed`` is an (n,)
        normalized teleport distribution; ``tol``/``max_sweeps`` mean
        exactly what the stepper's ``tol``/``max_iters`` mean.  A
        result with ``converged=False`` (budget exhausted above the
        bound) should be treated as a warm start, not an answer —
        that is what the scheduler's fallback does."""
        if tol <= 0:
            raise ValueError("push queries need tol > 0 (tol=0 is the "
                             "stepper's fixed-budget mode)")
        seed = np.asarray(seed, dtype=np.float32).reshape(self.n)
        if self.mode == "host":
            est, sweeps, bound, conv, work = self._query_host(
                seed, tol=tol, max_sweeps=max_sweeps)
        else:
            est, sweeps, bound, conv, work = self._query_device(
                seed, tol=tol, max_sweeps=max_sweeps)
        ids = scores = None
        if top_k is not None and conv:
            ids, scores = host_topk(est, top_k)
        return PushResult(est, sweeps, bound, conv, self.mode, work,
                          top_ids=ids, top_scores=scores)
