from .engine import PageRankServer, ServeEngine, Request
from .scheduler import (SlotScheduler, GraphRegistry, Query,
                        QueryResult)
from .metrics import ServeMetrics, QueryTrace
from .push import PushQueryEngine, PushResult
from .topk import host_topk, make_slot_topk, topk_ranks

__all__ = [
    "PageRankServer", "ServeEngine", "Request",
    "SlotScheduler", "GraphRegistry", "Query", "QueryResult",
    "ServeMetrics", "QueryTrace", "PushQueryEngine", "PushResult",
    "host_topk", "make_slot_topk", "topk_ranks",
]
