from .engine import PageRankServer, ServeEngine, Request

__all__ = ["PageRankServer", "ServeEngine", "Request"]
