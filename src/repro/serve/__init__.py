from .engine import PageRankServer, ServeEngine, Request
from .scheduler import (SlotScheduler, GraphRegistry, Query,
                        QueryResult)
from .metrics import ServeMetrics, QueryTrace
from .topk import make_slot_topk, topk_ranks

__all__ = [
    "PageRankServer", "ServeEngine", "Request",
    "SlotScheduler", "GraphRegistry", "Query", "QueryResult",
    "ServeMetrics", "QueryTrace", "make_slot_topk", "topk_ranks",
]
