"""Continuous-batching PageRank query scheduler (DESIGN.md §7).

``PageRankServer`` (serve/engine.py) iterates a batch in LOCKSTEP: the
whole (n, B) state runs a fixed shared loop and every query pays for
the slowest column.  Real personalized-PageRank query traffic is the
opposite regime — many independent seed vectors with wildly different
convergence times — so this module turns the slot pool into a
continuous batch, the PCPM property that one multi-vector SpMV pass is
the cheap unit of work doing the heavy lifting:

    queue -> slot -> (chunk steps, per-slot freeze) -> converged -> freed

- ``SlotScheduler`` owns a fixed pool of B seed-vector slots sharing
  ONE (n, B) masked chunk stepper (``core.pagerank.masked_chunk_stepper``
  or its sharded twin).  Each slot carries its own residual and
  convergence mask ON DEVICE: converged columns are frozen (masked out
  of the damping update) while neighbours keep iterating.
- The host side drains finished slots between chunks and admits queued
  requests into freed columns WITHOUT RETRACING: the stepper, the
  column-admit write and the full-column extract are AOT compiled once
  at construction (donated buffers; ``trace_count`` stays fixed) —
  slot index, per-request tol and iteration budget are all data.
- Top-k queries ship (k,) ids+scores from device (serve/topk.py)
  instead of the full n-vector.
- ``GraphRegistry`` holds compiled schedulers for several graphs
  (warm-loaded via graphs/io.py) so one server process serves many
  graphs.
- Forward-push routing (DESIGN.md §11, serve/push.py): with
  ``route="auto"`` the scheduler answers loose-tolerance top-k
  personalized queries INLINE at ``submit`` through the forward-push
  query backend — only backends with ``supports_push_query`` — and
  never occupies a slot for them; a push that stops above its bound
  falls back to the stepper warm-started at the push estimate, its
  sweeps charged against the iteration budget.  The stepper is never
  touched by push traffic, so ``trace_count`` stays 1 across
  interleaved routes.

Resilience (DESIGN.md §10, ``repro.reliability``): a ``ResilienceConfig``
adds deadline/priority admission over a bounded queue (overload sheds
load EXPLICITLY — rejected queries complete immediately with
``QueryResult.error`` set), tolerance degradation under measured SLO
pressure (approximate answers before drops), per-slot NaN/Inf
quarantine (the stepper's freeze rule is finiteness-aware, so a
poisoned column freezes on device and is re-admitted from a clean
seed or failed explicitly while neighbours keep iterating), stepper-
failure recovery, and integrity-checked plan rebinds.  All of it is
host-side policy over the same single compiled stepper —
``trace_count`` stays 1.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..core.backends import resolve_engine
from ..core.plan import (install_plan, internal_graph, plan_nbytes,
                         reorder_inverse)
from ..core.pagerank import _inv_degree, masked_chunk_stepper
from ..core.spmv import SpMVEngine
from ..graphs.formats import Graph, validate_graph
from ..graphs import io as graph_io
from ..reliability.admission import ResilienceConfig
from .engine import (_mesh_shardings, _normalize_teleport,
                     _sharded_inv_degree)
from .metrics import ServeMetrics
from .topk import make_slot_topk

# process-global: uids stay unique even when several schedulers (e.g.
# a GraphRegistry's) share one ServeMetrics, whose traces key on uid
_uid_counter = itertools.count()
_uid_lock = threading.Lock()


def next_uid() -> int:
    """Allocate one process-unique query uid.  The gateway mints uids
    for queries it terminates itself (cache hits, backlog rejections)
    so they share the schedulers' uid space."""
    with _uid_lock:
        return next(_uid_counter)


def ensure_uid_floor(floor: int) -> None:
    """Advance the process-global uid counter to at least ``floor`` —
    snapshot restore keeps the restored queries' uids, so fresh
    submissions must never collide with them."""
    global _uid_counter
    with _uid_lock:
        nxt = next(_uid_counter)
        _uid_counter = itertools.count(max(nxt, floor))


@dataclasses.dataclass
class Query:
    """One PageRank request.  ``seed`` is the normalized (and, when
    sharded, padded) teleport distribution — None means uniform.
    ``deadline`` is an ABSOLUTE time on the scheduler's clock (queue
    wait + service); ``priority`` orders admission (higher first, FIFO
    within a priority)."""
    uid: int
    seed: Optional[np.ndarray] = None
    top_k: Optional[int] = None
    tol: float = 1e-6
    max_iters: int = 100
    deadline: Optional[float] = None
    priority: int = 0
    degraded: bool = False        # tolerance loosened / served approx
    retries: int = 0              # clean-seed re-admissions so far
    # iterations already consumed by earlier admissions (quarantine
    # retries) or by a push attempt — ``max_iters`` bounds the TOTAL
    # work across all of them, and QueryResult.iterations reports it
    iters_done: int = 0
    # one-shot warm start: a push fallback's estimate, written over
    # the admitted column then cleared (a later quarantine retry must
    # re-admit the clean seed, not the possibly-poisoned estimate)
    warm_start: Optional[np.ndarray] = None
    # per-query span bundle (obs/trace.py QuerySpans) when the owning
    # scheduler/gateway has observability attached; None otherwise —
    # every span hook is one ``q.obs is not None`` branch
    obs: Optional[object] = None


@dataclasses.dataclass
class QueryResult:
    uid: int
    iterations: int
    converged: bool
    # last measured stopping residual; None when the query finished
    # before any residual readback (rejection, expiry, max_iters=0,
    # failure) — never a sentinel masquerading as data
    residual: Optional[float]
    latency_s: float
    ranks: Optional[np.ndarray] = None        # (n,) unless top_k set
    top_ids: Optional[np.ndarray] = None      # (k,) int32
    top_scores: Optional[np.ndarray] = None   # (k,) float32
    # external labels for top_ids when the scheduler carries a
    # NodeIdMapping (ingest/idmap.py) — what a real-graph deployment
    # returns to callers (ranks/top_ids are always ORIGINAL graph ids,
    # already mapped back from any reordered plan's internal space)
    top_external: Optional[np.ndarray] = None
    error: Optional[str] = None               # explicit terminal failure
    degraded: bool = False                    # approximate-answer mode
    # served from the gateway's warm-result cache (repro.gateway):
    # the arrays are the cached solve's, bit-identical, O(k) to serve
    cached: bool = False


class SlotScheduler:
    """Request queue + B-slot continuous batch over one AOT stepper.

    Construction does all tracing/compilation (stepper, admit,
    extract, column-restore); serving afterwards is pure data movement
    — the acceptance invariant is ``trace_count == 1`` forever after.
    """

    def __init__(self, g: Graph, *, slots: int = 4,
                 method: str = "pcpm", part_size: int = 65536,
                 damping: float = 0.85, chunk: int = 8,
                 dangling: str = "none", sharded: bool = False,
                 num_shards: int | None = None,
                 engine: SpMVEngine | None = None,
                 metrics: ServeMetrics | None = None,
                 resilience: ResilienceConfig | None = None,
                 fault_injector=None, route: str = "auto",
                 push_tol: float = 1e-4, push_mode: str = "auto",
                 push_max_sweeps: int = 64, idmap=None, obs=None):
        if slots < 1:
            raise ValueError(f"need at least one slot; got {slots}")
        if route not in ("auto", "push", "stepper"):
            raise ValueError(f"route must be 'auto', 'push' or "
                             f"'stepper'; got {route!r}")
        validate_graph(g)
        self.g = g
        self.n = g.num_nodes
        self.slots = slots
        self.damping = damping
        self.chunk = chunk
        self.dangling = dangling
        self.engine = resolve_engine(g, method=method, sharded=sharded,
                                     part_size=part_size,
                                     num_shards=num_shards,
                                     engine=engine)
        self.sharded = self.engine.backend.supports_sharding
        # locality-reordered plans (core/plan.py): the slot pool, the
        # stepper and the push engine all run in the plan's INTERNAL
        # (relabeled) id space — seeds map in at submit, ranks/top ids
        # map back at finish, so per-iteration work never pays a
        # permute.  idmap (ingest/idmap.py) additionally labels top-k
        # results with the graph's external ids.
        self._perm = self.engine.plan.reorder_perm       # old -> new
        self._inv = (reorder_inverse(self.engine.plan)
                     if self._perm is not None else None)
        self._g_int = internal_graph(g, self.engine.plan)
        self.idmap = idmap
        self.metrics = metrics or ServeMetrics()
        self.clock = self.metrics.clock
        # observability bundle (obs/__init__.py) — None keeps every
        # hot-path hook to a single falsy branch.  Set before
        # _build_stepper so the construction compile is recorded.
        self.obs = obs
        self.resilience = resilience or ResilienceConfig()
        self._injector = fault_injector       # test-only chaos hook
        self.trace_count = 0          # stepper traces — must stay 1
        self.admit_trace_count = 0    # column-admit traces — must stay 1
        self.rebind_count = 0         # plan swaps (apply_delta)
        # forward-push query routing (serve/push.py, DESIGN.md §11):
        # route="auto" sends loose-tolerance top-k personalized queries
        # to push, everything else to the stepper; push_tol is the
        # loose/tight boundary.  The engine is built lazily on first
        # use and dropped on apply_delta (it indexes the graph's CSR).
        self.route = route
        self.push_tol = float(push_tol)
        self.push_mode = push_mode
        # push never burns the whole iteration budget: capping its
        # sweeps leaves the fallback stepper real budget to finish a
        # query the push couldn't close (geometric contraction means
        # ~log(tol)/log(d) sweeps suffice at the routed tolerances)
        self.push_max_sweeps = int(push_max_sweeps)
        # threading contract (DESIGN.md §13): ``submit`` is safe from
        # any thread — the intake lock guards the queue, the completed
        # list and the metrics/terminal commit; push COMPUTE runs
        # outside it on per-thread engines (the PushQueryEngine's
        # ping-pong scratch buffers are single-query state), keyed by a
        # generation that ``apply_delta`` bumps so every thread rebuilds
        # on the new CSR.  ``step()`` stays single-caller (enforced via
        # ``_step_lock``): exactly one device thread owns the slot pool.
        self._lock = threading.RLock()
        self._step_lock = threading.Lock()
        self._push_tls = threading.local()
        self._push_gen = 0

        B = slots
        if self.sharded:
            layout = self.engine.sharded_layout
            self._n_pad = layout.padded_nodes
            (self._vec_sharding, self._state_sharding,
             self._rep_sharding) = _mesh_shardings(self.engine)
            state_spec = jax.ShapeDtypeStruct(
                (self._n_pad, B), jnp.float32,
                sharding=self._state_sharding)
            seed_spec = jax.ShapeDtypeStruct(
                (self._n_pad,), jnp.float32, sharding=self._vec_sharding)
            rep = self._rep_sharding
            act_spec = jax.ShapeDtypeStruct((B,), jnp.bool_, sharding=rep)
            tol_spec = jax.ShapeDtypeStruct((B,), jnp.float32,
                                            sharding=rep)
            bud_spec = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=rep)
            col_spec = jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)
        else:
            self._n_pad = self.n
            self._vec_sharding = self._state_sharding = None
            state_spec = jax.ShapeDtypeStruct((self.n, B), jnp.float32)
            seed_spec = jax.ShapeDtypeStruct((self.n,), jnp.float32)
            act_spec = jax.ShapeDtypeStruct((B,), jnp.bool_)
            tol_spec = jax.ShapeDtypeStruct((B,), jnp.float32)
            bud_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
            col_spec = jax.ShapeDtypeStruct((), jnp.int32)
        self._specs = (state_spec, act_spec, tol_spec, bud_spec,
                       seed_spec)
        self._step_c, self._inv_deg = self._build_stepper(self.engine,
                                                          self.g)

        dmp = damping

        def counted_admit(pr, base, seed, col):
            self.admit_trace_count += 1
            pr = jax.lax.dynamic_update_slice(pr, seed[:, None], (0, col))
            base = jax.lax.dynamic_update_slice(
                base, ((1.0 - dmp) * seed)[:, None], (0, col))
            return pr, base

        self._admit_c = (jax.jit(counted_admit, donate_argnums=(0, 1))
                         .lower(state_spec, state_spec, seed_spec,
                                col_spec).compile())

        self._extract_c = (jax.jit(lambda pr, col: pr[:, col])
                           .lower(state_spec, col_spec).compile())
        # one-column overwrite of pr only (base untouched) — shared by
        # fault poisoning and snapshot restore; shape-only like admit
        self._restore_c = (
            jax.jit(lambda pr, vec, col: jax.lax.dynamic_update_slice(
                pr, vec[:, None], (0, col)), donate_argnums=(0,))
            .lower(state_spec, seed_spec, col_spec).compile())
        self._topk_fn = make_slot_topk(self.n)
        self._topk_cache: dict[int, object] = {}
        self._poison_cache: dict[str, object] = {}
        self._state_spec = state_spec
        self._col_spec = col_spec

        # cached uniform teleport seed — admit never donates the seed
        # argument, so one device buffer serves every seeds=None query
        uni = np.zeros(self._n_pad, dtype=np.float32)
        uni[:self.n] = 1.0 / self.n
        self._uniform_seed = (jax.device_put(jnp.asarray(uni),
                                             self._vec_sharding)
                              if self.sharded else jnp.asarray(uni))

        # host-side slot + queue state
        self._active = np.zeros(B, dtype=bool)
        self._iters = np.zeros(B, dtype=np.int64)
        self._tol = np.zeros(B, dtype=np.float32)
        self._max_iters = np.zeros(B, dtype=np.int64)
        self._slot_res = np.full(B, -1.0, dtype=np.float64)
        self._queue: list[Query] = []
        self.completed: list[QueryResult] = []
        self._init_pool_state()

        # SLO pressure model: EWMA seconds-per-iteration of the warm
        # stepper and EWMA iterations-per-served-query — what admission
        # uses to predict whether a query can make its deadline
        self._iter_s: Optional[float] = None
        self._query_iters: Optional[float] = None
        self._step_idx = 0            # monotone; fault-plan time base
        self._delta_idx = 0
        self._step_retries = 0

    def _init_pool_state(self) -> None:
        """(Re)allocate the device slot pool and clear the host slot
        bookkeeping — construction, and recovery after a hard stepper
        failure (donated buffers may be gone)."""
        B = self.slots
        if self.sharded:
            zeros = jax.device_put(
                jnp.zeros((self._n_pad, B), jnp.float32),
                self._state_sharding)
            base = jax.device_put(
                jnp.zeros((self._n_pad, B), jnp.float32),
                self._state_sharding)
        else:
            zeros = jnp.zeros((self.n, B), jnp.float32)
            base = jnp.zeros((self.n, B), jnp.float32)
        # pr donated through step/restore/admit; base donated through
        # admit
        self._pr = zeros
        self._base = base
        self._slot_query: list[Optional[Query]] = [None] * B
        self._active[:] = False
        self._iters[:] = 0
        self._tol[:] = 0.0
        self._max_iters[:] = 0
        self._slot_res[:] = -1.0

    # ----------------------------------------------------- plan binding
    def _build_stepper(self, engine: SpMVEngine, g: Graph):
        """Compile the chunk stepper against ``engine``'s plan and
        build the matching inverse-degree vector — returns both WITHOUT
        touching scheduler state, so ``apply_delta`` can fully validate
        and compile a rebind before committing anything.  Called once
        at construction and once per ``apply_delta``; the admit/
        extract/restore/top-k executables are shape-only and are NOT
        rebuilt."""
        gi = internal_graph(g, engine.plan)   # stepper space (reorder)
        if self.sharded:
            from ..core.distributed import sharded_chunk_stepper
            step = sharded_chunk_stepper(
                engine.sharded_layout, engine.mesh,
                engine.shard_axis, damping=self.damping,
                chunk=self.chunk, dangling=self.dangling)
            inv_deg = _sharded_inv_degree(gi, engine,
                                          self._vec_sharding)
        else:
            step = masked_chunk_stepper(engine, damping=self.damping,
                                        chunk=self.chunk,
                                        dangling=self.dangling)
            inv_deg = _inv_degree(gi)

        def counted_step(pr, base, active, tol_col, budget, inv_deg):
            self.trace_count += 1     # increments only at trace time
            return step.__wrapped__(pr, base, active, tol_col, budget,
                                    inv_deg)

        state_spec, act_spec, tol_spec, bud_spec, inv_spec = self._specs
        t0 = time.perf_counter()
        step_c = (jax.jit(counted_step, donate_argnums=(0,))
                  .lower(state_spec, state_spec, act_spec,
                         tol_spec, bud_spec, inv_spec).compile())
        if self.obs is not None:
            # trace_count/rebind_count were only attributes until now;
            # this makes every XLA stepper compile a recorded event
            self.obs.tracer.event(
                "xla_compile", trace="plan", kind="stepper",
                method=engine.method, slots=self.slots,
                trace_count=self.trace_count,
                duration_s=time.perf_counter() - t0)
        return step_c, inv_deg

    def apply_delta(self, delta, *, g_new: Graph | None = None) -> None:
        """Swap the scheduler onto the delta-updated graph WITHOUT
        dropping in-flight queries: the plan is patched incrementally
        (stream/patch.py), only the stepper is re-lowered against the
        new streams (their shapes changed — one compile, counted in
        ``rebind_count``), and the (n, B) slot state carries over
        as-is.  Active columns continue iterating under the new
        operator — their current state is a warm start, so they
        converge to the NEW graph's answer under their own tolerance;
        the admit/extract/top-k executables are shape-stable and
        survive untouched (``admit_trace_count`` stays 1).  Queued
        queries simply get admitted against the new plan.

        The rebind is ATOMIC: delta validation, plan patch, integrity
        check (``resilience.verify_plans``) and stepper compile all
        happen before any scheduler state changes, so a failing delta
        (bad edges, corrupted plan, patcher bug) leaves the old plan
        serving — the failure is counted and re-raised."""
        from ..stream.delta import apply_delta as apply_edges
        from ..stream.patch import patch_plan
        if self._perm is not None:
            raise ValueError(
                "apply_delta on a reorder-enabled scheduler is not "
                "supported: the locality permutation is a function of "
                "the graph, so the delta would change the slot pool's "
                "internal id space under the in-flight columns — "
                "drain and construct a fresh scheduler for the updated "
                "graph instead")
        self._delta_idx += 1
        rsp = (self.obs.tracer.start("rebind", trace="plan",
                                     delta_idx=self._delta_idx)
               if self.obs is not None else None)
        try:
            if self._injector is not None:
                self._injector.check_delta(self._delta_idx)
            delta.validate(self.g)
            if g_new is None:
                g_new = apply_edges(self.g, delta)
            # patch_plan falls back to a full rebuild for backends
            # without a patcher (pcpm_sharded's all-to-all wire layout
            # is global)
            new_plan = patch_plan(self.engine.plan, delta, g_new)
            if self._injector is not None and \
                    self._injector.wants_corrupt(self._delta_idx):
                from ..reliability.faults import corrupt_plan_arrays
                new_plan = corrupt_plan_arrays(new_plan)
            if self.resilience.verify_plans:
                from ..reliability.guardrails import check_plan_integrity
                check_plan_integrity(new_plan)
            new_engine = SpMVEngine(g_new, plan=new_plan)
            step_c, inv_deg = self._build_stepper(new_engine, g_new)
        except Exception as exc:
            self.metrics.incr("delta_failures")
            if rsp is not None:
                rsp.end(status="error",
                        error=f"{type(exc).__name__}: {exc}")
            raise
        # commit under both locks: the step thread must not dispatch
        # against a half-swapped (plan, stepper, inv_deg) triple, and
        # submit threads must not route against a stale engine.  Lock
        # order (step, then intake) matches step() — no deadlock.
        with self._step_lock, self._lock:
            self.g = g_new
            self.engine = new_engine
            self._step_c, self._inv_deg = step_c, inv_deg
            # push engines index the graph's CSR: refresh the internal
            # graph (it used to go stale here — rebuilt push engines
            # silently answered against the PRE-delta edges) and bump
            # the generation so every thread-local engine rebuilds
            self._g_int = internal_graph(g_new, new_engine.plan)
            self._push_gen += 1
            self.rebind_count += 1
        if rsp is not None:
            rsp.end(rebind_count=self.rebind_count,
                    n=g_new.num_nodes, m=g_new.num_edges)

    # ------------------------------------------------------------ intake
    def submit(self, seeds: np.ndarray | None = None, *,
               top_k: int | None = None, tol: float = 1e-6,
               max_iters: int = 100, deadline_s: float | None = None,
               priority: int = 0, route: str | None = None,
               _spans=None) -> int:
        """Enqueue one query; returns its uid.  ``seeds`` is an (n,)
        teleport distribution (need not be normalized — it is), or None
        for uniform teleport.  ``tol=0`` runs exactly ``max_iters``
        iterations.  ``deadline_s`` is a wall-clock budget from now
        (queue wait + service; defaults to
        ``resilience.default_deadline_s``); ``priority`` orders
        admission, higher first.

        ``route`` overrides the scheduler's default: ``"auto"`` serves
        loose-tolerance (``tol >= push_tol``) top-k personalized
        queries INLINE through the forward-push backend (DESIGN.md
        §11) and queues everything else for the stepper; ``"push"``
        forces push (raising if the configuration can't support it);
        ``"stepper"`` never pushes.  A push that exhausts its budget
        above the stopping bound falls back: the query is queued for
        the stepper warm-started at the push estimate, its consumed
        sweeps counted against ``max_iters``
        (``counters["push_fallbacks"]``).

        When the admission queue is bounded (``resilience.max_queue``)
        and full, the query is REJECTED EXPLICITLY: it completes
        immediately with ``QueryResult.error`` set and the rejection
        counted — the uid is still returned so the caller can find the
        terminal result.

        Thread-safe: intake state commits under the scheduler's lock;
        push compute runs outside it on a per-thread engine, so
        concurrent submitters never serialize behind each other's
        push solves (only behind the microsecond bookkeeping)."""
        route, use_push = self.validate_request(
            seeds is not None, top_k=top_k, tol=tol,
            max_iters=max_iters, route=route)
        seed = None
        if seeds is not None:
            seed = _normalize_teleport(
                np.asarray(seeds, dtype=np.float32).reshape(self.n))
            if self._perm is not None:
                seed = seed[self._inv]        # into internal space
            if self._n_pad != self.n:
                seed = np.pad(seed, (0, self._n_pad - self.n))
        if deadline_s is None:
            deadline_s = self.resilience.default_deadline_s
        spans = _spans
        if spans is None and self.obs is not None:
            from ..obs.trace import QuerySpans
            spans = QuerySpans(self.obs.tracer,
                               self.obs.tracer.start("query",
                                                     route=route))
        with self._lock:
            deadline = (self.clock() + deadline_s
                        if deadline_s is not None else None)
            uid = next_uid()
            q = Query(uid, seed, top_k, float(tol), int(max_iters),
                      deadline, int(priority), obs=spans)
            if spans is not None:
                spans.bind(uid)
            self.metrics.submitted(uid)
        if use_push and self._serve_push(q):
            return uid                # answered inline, never queued
        with self._lock:
            cap = self.resilience.max_queue
            if cap is not None and len(self._queue) >= cap:
                self.metrics.incr("rejected")
                self._terminal(q, error=f"rejected: admission queue "
                                        f"full ({cap})")
                return uid
            if q.obs is not None:
                q.obs.start_child("queue")
            self._queue.append(q)
        return uid

    def validate_request(self, have_seed: bool, *, top_k, tol,
                         max_iters, route=None) -> tuple[str, bool]:
        """Validate a request exactly as ``submit`` will — raising the
        same errors — and resolve its routing WITHOUT allocating a uid
        or touching scheduler state.  Returns ``(route, use_push)``.
        The gateway calls this on the submitter's thread so invalid
        requests fail synchronously instead of poisoning a future."""
        if max_iters < 0:
            raise ValueError(f"max_iters must be >= 0; got {max_iters}")
        if top_k is not None and not 1 <= top_k <= self.n:
            raise ValueError(f"top_k must be in [1, {self.n}]; "
                             f"got {top_k}")
        route = self.route if route is None else route
        if route not in ("auto", "push", "stepper"):
            raise ValueError(f"route must be 'auto', 'push' or "
                             f"'stepper'; got {route!r}")
        if route == "push":
            self._check_push_request(have_seed, tol, max_iters)
        use_push = (route == "push"
                    or (route == "auto"
                        and self._push_eligible(have_seed, top_k, tol,
                                                max_iters)))
        return route, use_push

    # --------------------------------------------------- push routing
    def _push_supported(self) -> bool:
        return (not self.sharded
                and self.engine.backend.supports_push_query
                and self.dangling == "none")

    def _push_eligible(self, have_seed, top_k, tol, max_iters) -> bool:
        """route="auto" rule: push serves single-seed TOP-K queries at
        LOOSE tolerance — the regime where expanding one seed's
        frontier beats a full (n, B) iteration; full-vector and
        tight-tolerance queries keep the stepper's accuracy/amortized
        cost."""
        return (self._push_supported()
                and have_seed and top_k is not None
                and 0.0 < self.push_tol <= tol
                and max_iters > 0)

    def _check_push_request(self, have_seed, tol, max_iters) -> None:
        """route="push" validation — raises BEFORE a uid is allocated,
        so an unservable explicit request never produces a trace."""
        if self.sharded:
            raise ValueError("route='push' is single-device (the push "
                             "state is one (n,) vector)")
        if not self.engine.backend.supports_push_query:
            raise ValueError(
                f"backend {self.engine.method!r} does not support push "
                "queries (supports_push_query=False)")
        if self.dangling != "none":
            raise ValueError("route='push' requires dangling='none'; "
                             f"got {self.dangling!r}")
        if not have_seed:
            raise ValueError("route='push' needs a seed: push expands "
                             "a personalized frontier (uniform "
                             "teleport is a full-vector solve)")
        if tol <= 0 or max_iters <= 0:
            raise ValueError("route='push' needs tol > 0 and "
                             "max_iters > 0 (fixed-budget mode is the "
                             "stepper's)")

    def _push_engine(self):
        """Per-thread push engine: the PushQueryEngine's preallocated
        ping-pong scratch is single-query state, so concurrent
        submitters each get their own, rebuilt when ``apply_delta``
        bumps the generation (the engine indexes the graph's CSR)."""
        tls = self._push_tls
        with self._lock:              # consistent (gen, graph, engine)
            gen, g_int, spmv = self._push_gen, self._g_int, self.engine
        if getattr(tls, "gen", None) != gen:
            from .push import PushQueryEngine
            # built on the INTERNAL graph so push estimates are
            # column-compatible with the stepper's slot space (the
            # warm-start fallback writes them straight into a column)
            tls.engine = PushQueryEngine(
                g_int, spmv, damping=self.damping,
                dangling=self.dangling, mode=self.push_mode)
            tls.gen = gen
        return tls.engine

    # ---------------------------------------------- id-space boundary
    def _vec_to_original(self, vec: np.ndarray) -> np.ndarray:
        """Internal-space (n,) vector -> original node labeling."""
        return vec[self._perm] if self._perm is not None else vec

    def _ids_to_original(self, ids: np.ndarray) -> np.ndarray:
        """Internal-space node ids -> original node ids."""
        return self._inv[ids] if self._perm is not None else ids

    def _externalize(self, ids_orig) -> Optional[np.ndarray]:
        """Original ids -> external labels, when an idmap is attached."""
        return (self.idmap.to_external(ids_orig)
                if self.idmap is not None else None)

    def _serve_push(self, q: Query) -> bool:
        """Answer ``q`` inline through the push backend.  Returns True
        when a terminal result was produced; False falls through to
        the stepper queue — with the push estimate as a warm start and
        the consumed sweeps charged against the budget when the push
        ran but stopped above its bound (honest fallback, counted)."""
        self.metrics.admitted(q.uid)   # service starts now, no queue
        if q.obs is not None:
            q.obs.start_child("push")
        try:
            res = self._push_engine().query(
                q.seed[:self.n], tol=q.tol,
                max_sweeps=min(q.max_iters, self.push_max_sweeps),
                top_k=q.top_k)
        except Exception:             # noqa: BLE001 — fall back, count
            self.metrics.incr("push_failures")
            if q.obs is not None:
                q.obs.end_child("push", status="error")
            return False
        if not res.converged:
            self.metrics.incr("push_fallbacks")
            q.iters_done = res.sweeps
            est = res.estimate
            if self._n_pad != self.n:
                est = np.pad(est, (0, self._n_pad - self.n))
            q.warm_start = est
            if q.obs is not None:
                q.obs.end_child("push", status="fallback",
                                sweeps=res.sweeps)
            return False
        self.metrics.incr("push_served")
        self.metrics.completed(q.uid, iterations=res.sweeps,
                               converged=True, degraded=q.degraded,
                               route="push")
        if q.top_k is not None:
            ids = self._ids_to_original(np.asarray(res.top_ids))
            result = QueryResult(
                q.uid, res.sweeps, True, res.residual,
                self.metrics.traces[q.uid].latency_s,
                top_ids=ids, top_scores=res.top_scores,
                top_external=self._externalize(ids),
                degraded=q.degraded)
        else:
            result = QueryResult(
                q.uid, res.sweeps, True, res.residual,
                self.metrics.traces[q.uid].latency_s,
                ranks=self._vec_to_original(res.estimate),
                degraded=q.degraded)
        if q.obs is not None:
            q.obs.end_child("push", sweeps=res.sweeps)
            q.obs.finish(served="push", iterations=res.sweeps)
        with self._lock:
            self.completed.append(result)
        return True

    @property
    def active_slots(self) -> int:
        return sum(q is not None for q in self._slot_query)

    @property
    def queued(self) -> int:
        return len(self._queue)

    # --------------------------------------------------------- admission
    def _put_small(self, arr):
        """Small (B,)/scalar control arrays: replicate on the mesh when
        sharded so they match the compiled executable's avals."""
        x = jnp.asarray(arr)
        return (jax.device_put(x, self._rep_sharding) if self.sharded
                else x)

    def _terminal(self, q: Query, *, error: str) -> None:
        """Complete a query that never reached a slot (rejection,
        queue expiry) — explicit terminal state, never a silent drop."""
        self.metrics.completed(q.uid, iterations=0, converged=False,
                               error=error, degraded=q.degraded)
        if q.obs is not None:
            q.obs.finish(status="error", error=error)
        self.completed.append(QueryResult(
            q.uid, 0, False, None,
            self.metrics.traces[q.uid].latency_s, error=error,
            degraded=q.degraded))

    def _pop_runnable(self) -> Optional[Query]:
        """Next query to admit: expire queued queries already past
        their deadline (explicit terminal state, counted), then pick
        the highest priority, FIFO within a priority."""
        if not self._queue:
            return None
        if any(q.deadline is not None for q in self._queue):
            now = self.clock()
            live = []
            for q in self._queue:
                if q.deadline is not None and now > q.deadline:
                    self.metrics.incr("expired")
                    self._terminal(q, error="deadline expired in queue")
                else:
                    live.append(q)
            self._queue = live
            if not self._queue:
                return None
        best = max(range(len(self._queue)),
                   key=lambda i: (self._queue[i].priority, -i))
        return self._queue.pop(best)

    def _maybe_degrade(self, q: Query) -> None:
        """Approximate-answer mode (DESIGN.md §10): when the EWMA
        service model predicts the query cannot converge at its
        requested tolerance inside its deadline, loosen the tolerance
        at admission — a degraded answer beats a shed query."""
        cfg = self.resilience
        if (q.deadline is None or q.tol >= cfg.degrade_tol
                or self._iter_s is None or self._query_iters is None):
            return
        remaining = q.deadline - self.clock()
        if self._query_iters * self._iter_s > remaining:
            q.tol = cfg.degrade_tol
            q.degraded = True
            self.metrics.incr("degraded")

    def _admit(self, slot: int, q: Query) -> None:
        was_warm = q.warm_start is not None   # cleared below, one-shot
        seed_dev = (self._uniform_seed if q.seed is None
                    else (jax.device_put(jnp.asarray(q.seed),
                                         self._vec_sharding)
                          if self.sharded else jnp.asarray(q.seed)))
        self._pr, self._base = self._admit_c(
            self._pr, self._base, seed_dev,
            self._put_small(np.int32(slot)))
        if q.warm_start is not None:
            # push-fallback estimate overwrites the column (base stays
            # the seed's, so the iteration targets the same fixed
            # point); one-shot — a quarantine retry re-admits clean
            warm = jnp.asarray(q.warm_start)
            if self.sharded:
                warm = jax.device_put(warm, self._vec_sharding)
            self._pr = self._restore_c(self._pr, warm,
                                       self._put_small(np.int32(slot)))
            q.warm_start = None
        self._slot_query[slot] = q
        self._active[slot] = q.max_iters > q.iters_done
        self._iters[slot] = q.iters_done
        self._tol[slot] = q.tol
        self._max_iters[slot] = q.max_iters
        self._slot_res[slot] = -1.0
        self.metrics.admitted(q.uid)
        if q.obs is not None:
            # a quarantine re-admission closes the previous slot span
            # with status="retry" (QuerySpans.start_child) — the span
            # tree shows each occupancy as its own interval
            q.obs.end_child("queue")
            q.obs.start_child("slot", slot=slot, retries=q.retries,
                              warm=was_warm)
        if q.max_iters <= q.iters_done:
            # degenerate: no budget left — serve the column as-is
            self._finish(slot, q, residual=None)

    def _admit_from_queue(self) -> int:
        admitted = 0
        for slot in range(self.slots):
            if not self._queue:
                break
            if self._slot_query[slot] is None:
                q = self._pop_runnable()
                if q is None:
                    break
                self._maybe_degrade(q)
                self._admit(slot, q)
                admitted += 1
        return admitted

    # ------------------------------------------------------------- serve
    def step(self) -> int:
        """Admit from the queue, advance every active slot by up to
        ``chunk`` masked iterations (ONE stepper dispatch), drain slots
        that froze.  Returns the number of queries completed (including
        any finished at admission, e.g. ``max_iters=0``).

        Single-caller: slot/device state belongs to exactly one
        stepping thread (the gateway's device loop, or the caller in
        synchronous use).  A second concurrent ``step`` is a wiring
        bug, not a race to arbitrate — it raises immediately.  Intake
        state shared with ``submit`` (queue, completed list, metrics)
        is touched under the scheduler lock; the device dispatch
        itself runs OUTSIDE it, so submitters and push workers overlap
        with device time instead of serializing behind it."""
        if not self._step_lock.acquire(blocking=False):
            raise RuntimeError(
                "SlotScheduler.step() called concurrently — the slot "
                "pool has exactly one stepping thread (see DESIGN.md "
                "§13); route concurrent traffic through repro.gateway")
        try:
            return self._step_impl()
        finally:
            self._step_lock.release()

    def _step_impl(self) -> int:
        with self._lock:
            before = len(self.completed)
            self._step_idx += 1
            self._admit_from_queue()
            if not self._active.any():
                return len(self.completed) - before
            if self._injector is not None:
                self._inject_poisons()
            budget = np.minimum(self._max_iters - self._iters,
                                np.iinfo(np.int32).max).astype(np.int32)
        csp = (self.obs.tracer.start(
                   "chunk", trace="device", step=self._step_idx,
                   active=int(self._active.sum()))
               if self.obs is not None else None)
        t0 = time.perf_counter()
        try:
            if self._injector is not None:
                self._injector.check_step(self._step_idx)
            self._pr, active, took, res = self._step_c(
                self._pr, self._base, self._put_small(self._active),
                self._put_small(self._tol),
                self._put_small(np.maximum(budget, 0)), self._inv_deg)
        except Exception as exc:      # noqa: BLE001 — resilience layer
            if csp is not None:
                csp.end(status="error",
                        error=f"{type(exc).__name__}: {exc}")
            with self._lock:
                self._recover_step_failure(exc)
                return len(self.completed) - before
        self._step_retries = 0
        ran = self._active.copy()
        active = np.asarray(active)
        took = np.asarray(took)
        res = np.asarray(res)
        if csp is not None:
            iters = int(took.max()) if took.size else 0
            csp.end(iters=iters)
            # measured bytes: the stepper computes the full (n, B)
            # state per pass regardless of the freeze mask — B columns
            # is the honest ncols (obs/comm.py)
            self.obs.comm.record_pass(self.engine.plan, iters=iters,
                                      ncols=self.slots)
        with self._lock:
            self._iters += took
            self._update_pressure(time.perf_counter() - t0,
                                  int(took.max()))
            requeue: list[int] = []
            for slot in range(self.slots):
                q = self._slot_query[slot]
                if q is None or not ran[slot]:
                    continue          # empty / idle before the call
                if not np.isfinite(res[slot]):
                    # poisoned column: the finiteness-aware freeze rule
                    # stopped it on device; neighbours kept iterating
                    self.metrics.incr("quarantined")
                    if q.retries < self.resilience.max_retries:
                        q.retries += 1
                        requeue.append(slot)
                    else:
                        self._fail_slot(
                            slot, q,
                            error=f"quarantined: non-finite residual "
                                  f"after {int(self._iters[slot])} "
                                  f"iterations")
                    continue
                if res[slot] >= 0.0:
                    self._slot_res[slot] = float(res[slot])
                if active[slot]:
                    continue
                self._finish(slot, q, residual=(
                    float(self._slot_res[slot])
                    if self._slot_res[slot] >= 0.0 else None))
            self._active = active & np.array(
                [q is not None for q in self._slot_query])
            for slot in requeue:
                # clean-seed re-admission overwrites the poisoned
                # column; the iterations the poisoned run burned stay
                # charged against the query's budget (and reported),
                # so retries can never exceed max_iters total work
                q = self._slot_query[slot]
                q.iters_done = int(self._iters[slot])
                if q.iters_done >= q.max_iters:
                    self._fail_slot(
                        slot, q,
                        error=f"quarantined: iteration budget "
                              f"exhausted after {q.retries} retries")
                    continue
                self.metrics.incr("requeued")
                self._admit(slot, q)
            self._sweep_deadlines()
            return len(self.completed) - before

    def _inject_poisons(self) -> None:
        """Test-only chaos hook: overwrite scheduled slot columns with
        NaN/Inf before the next dispatch (via the compiled column-
        restore write — no retrace)."""
        live = [s for s in range(self.slots) if self._active[s]]
        for slot, kind in self._injector.poisons(self._step_idx, live):
            if not self._active[slot]:
                continue
            buf = self._poison_cache.get(kind)
            if buf is None:
                val = np.nan if kind == "nan_slot" else np.inf
                vec = jnp.full((self._n_pad,), val, jnp.float32)
                buf = (jax.device_put(vec, self._vec_sharding)
                       if self.sharded else vec)
                self._poison_cache[kind] = buf
            self._pr = self._restore_c(self._pr, buf,
                                       self._put_small(np.int32(slot)))

    def _update_pressure(self, dt: float, max_took: int) -> None:
        if max_took <= 0:
            return
        per = dt / max_took
        self._iter_s = (per if self._iter_s is None
                        else 0.7 * self._iter_s + 0.3 * per)

    def _recover_step_failure(self, exc: Exception) -> None:
        """A stepper dispatch raised.  Transient failures (within
        ``max_step_retries``, device state intact) are retried on the
        next ``step()``; otherwise the in-flight pool is declared lost
        — every active query fails EXPLICITLY and the pool is
        reallocated so queued queries keep being served."""
        self.metrics.incr("stepper_failures")
        self._step_retries += 1
        lost = getattr(self._pr, "is_deleted", lambda: False)()
        if (self._step_retries <= self.resilience.max_step_retries
                and not lost):
            return                    # retry the same dispatch next step
        for slot in range(self.slots):
            q = self._slot_query[slot]
            if q is not None:
                self._fail_slot(slot, q,
                                error=f"stepper failure: {exc}")
        self._init_pool_state()
        self._step_retries = 0

    def _sweep_deadlines(self) -> None:
        """Finish in-flight queries past their deadline with their
        CURRENT iterate — an explicit approximate answer (flagged
        ``degraded``), not a cancellation."""
        if not any(q is not None and q.deadline is not None
                   for q in self._slot_query):
            return
        now = self.clock()
        for slot in range(self.slots):
            q = self._slot_query[slot]
            if q is None or q.deadline is None or now <= q.deadline:
                continue
            self.metrics.incr("deadline_hits")
            q.degraded = True
            # before the slot's first residual readback there is no
            # measured residual — surface None, never the -1.0 sentinel
            self._finish(slot, q, residual=(
                float(self._slot_res[slot])
                if self._slot_res[slot] >= 0.0 else None))

    def _fail_slot(self, slot: int, q: Query, *, error: str) -> None:
        """Explicit terminal failure of an in-flight query: no ranks
        are extracted (the column may be poisoned), the slot is freed."""
        it = int(self._iters[slot])
        self.metrics.completed(q.uid, iterations=it, converged=False,
                               error=error, degraded=q.degraded)
        if q.obs is not None:
            q.obs.finish(status="error", error=error, iterations=it)
        if self.obs is not None:
            # PR 6's forensics moment: the in-flight query was lost to
            # quarantine or a stepper failure — preserve the ring
            self.obs.crash_dump(f"uid {q.uid}: {error}")
        self.completed.append(QueryResult(
            q.uid, it, False, None,
            self.metrics.traces[q.uid].latency_s, error=error,
            degraded=q.degraded))
        self._slot_query[slot] = None
        self._active[slot] = False

    def _finish(self, slot: int, q: Query, *,
                residual: Optional[float]) -> None:
        it = int(self._iters[slot])
        # a missing residual (None) can never read as converged — the
        # old -1.0 sentinel couldn't either, but only by luck of sign
        converged = residual is not None and 0.0 <= residual < q.tol
        self.metrics.completed(q.uid, iterations=it, converged=converged,
                               degraded=q.degraded)
        if q.obs is not None:
            q.obs.end_child("slot", iterations=it, converged=converged,
                            residual=residual)
        if converged:
            self._query_iters = (float(it) if self._query_iters is None
                                 else 0.7 * self._query_iters + 0.3 * it)
        col = self._put_small(np.int32(slot))
        if q.top_k is not None:
            topk_c = self._topk_cache.get(q.top_k)
            if topk_c is None:
                topk_c = (self._topk_fn
                          .lower(self._state_spec, self._col_spec,
                                 k=q.top_k).compile())
                self._topk_cache[q.top_k] = topk_c
            if q.obs is not None:
                q.obs.event("topk", k=q.top_k)
            ids, scores = topk_c(self._pr, col)
            ids = self._ids_to_original(np.asarray(ids))
            result = QueryResult(
                q.uid, it, converged, residual,
                self.metrics.traces[q.uid].latency_s,
                top_ids=ids, top_scores=np.asarray(scores),
                top_external=self._externalize(ids),
                degraded=q.degraded)
        else:
            if q.obs is not None:
                q.obs.event("readback", n=self.n)
            ranks = np.asarray(self._extract_c(self._pr, col))[:self.n]
            result = QueryResult(
                q.uid, it, converged, residual,
                self.metrics.traces[q.uid].latency_s,
                ranks=self._vec_to_original(ranks),
                degraded=q.degraded)
        if q.obs is not None:
            q.obs.finish(iterations=it, converged=converged,
                         degraded=q.degraded)
        self.completed.append(result)
        self._slot_query[slot] = None
        self._active[slot] = False

    def run_until_drained(self, *, max_chunks: int = 100_000
                          ) -> list[QueryResult]:
        """Serve until the queue and every slot are empty.  Returns the
        results completed during this call, in completion order."""
        start = len(self.completed)
        for _ in range(max_chunks):
            if not self._queue and self.active_slots == 0:
                break
            self.step()
        else:
            raise RuntimeError(
                f"not drained after {max_chunks} chunks "
                f"({self.queued} queued, {self.active_slots} active)")
        return self.completed[start:]


class GraphRegistry:
    """Named collection of compiled ``SlotScheduler``s — one server
    process serving several graphs, each behind its own warm stepper.

    Keyword defaults passed at construction apply to every graph;
    per-graph overrides win.  ``load`` warm-loads a persisted graph
    (graphs/io.py npz) and compiles its scheduler immediately, so the
    first query pays zero trace/compile cost.  Every scheduler
    resolves its preprocessing through the process-level plan cache
    (core/plan.py), so several schedulers over one graph share ONE
    ``GraphPlan`` — and ``load(plan_path=...)`` seeds that cache from
    a persisted plan so even the first build is a warm ``.npz`` read
    instead of an edge sort.

    Multi-graph QoS (DESIGN.md §13): each graph carries a weighted-
    fair admission ``share`` (``run_until_drained`` and the gateway's
    device loop interleave stepper chunks in share proportion — one
    hot graph cannot starve the others), and an optional
    ``memory_budget_bytes`` bounds the summed plan footprint
    (``core.plan.plan_nbytes``): adding a graph past the budget
    evicts least-recently-used IDLE graphs — never one with queued or
    in-flight queries — releasing their plan-cache chains
    (``evict_plans(chain=True)``, the PR 5 LRU hook).
    """

    def __init__(self, *, memory_budget_bytes: int | None = None,
                 **defaults):
        self._defaults = defaults
        self.memory_budget_bytes = memory_budget_bytes
        self._schedulers: dict[str, SlotScheduler] = {}
        self._shares: dict[str, float] = {}
        self._plan_bytes: dict[str, int] = {}
        self._last_used: dict[str, int] = {}
        self._use_clock = itertools.count()   # monotone LRU timestamps
        self.evictions = 0

    def add(self, name: str, g: Graph, *, share: float = 1.0,
            **overrides) -> SlotScheduler:
        if name in self._schedulers:
            raise ValueError(f"graph {name!r} already registered")
        if not share > 0:
            raise ValueError(f"share must be > 0; got {share}")
        kw = {**self._defaults, **overrides}
        sch = SlotScheduler(g, **kw)
        self._schedulers[name] = sch
        self._shares[name] = float(share)
        self._plan_bytes[name] = plan_nbytes(sch.engine.plan)
        self._touch(name)
        self._enforce_budget(protect=name)
        return sch

    def load(self, name: str, path: str, *,
             plan_path: str | None = None, **overrides) -> SlotScheduler:
        g = graph_io.load(path)
        if plan_path is not None:
            # validate + seed the process cache, then hand the
            # scheduler an engine wrapping the loaded plan directly —
            # the plan's full config (incl. gather_block) is honored,
            # never reconstructed from registry defaults
            plan = install_plan(g, graph_io.load_plan(plan_path))
            overrides.setdefault("engine", SpMVEngine(g, plan=plan))
        return self.add(name, g, **overrides)

    def get(self, name: str) -> SlotScheduler:
        try:
            return self._schedulers[name]
        except KeyError:
            raise KeyError(
                f"unknown graph {name!r}; registered: "
                f"{sorted(self._schedulers)}") from None

    def submit(self, name: str, seeds: np.ndarray | None = None,
               **kw) -> int:
        sch = self.get(name)
        self._touch(name)
        return sch.submit(seeds, **kw)

    # -------------------------------------------------- memory budget
    @property
    def total_plan_bytes(self) -> int:
        return sum(self._plan_bytes.values())

    def _touch(self, name: str) -> None:
        self._last_used[name] = next(self._use_clock)

    def _busy(self, name: str) -> bool:
        sch = self._schedulers[name]
        return sch.queued > 0 or sch.active_slots > 0

    def evict(self, name: str) -> None:
        """Retire one graph: drop its scheduler and release its plan-
        cache chain.  Refuses while the graph has queued or in-flight
        queries — eviction is for idle residents, not live traffic."""
        sch = self.get(name)
        if self._busy(name):
            raise ValueError(
                f"cannot evict {name!r}: {sch.queued} queued, "
                f"{sch.active_slots} in flight — drain it first")
        from ..core.plan import evict_plans
        g = sch.g
        for d in (self._schedulers, self._shares, self._plan_bytes,
                  self._last_used):
            d.pop(name, None)
        evict_plans(g, chain=True)
        self.evictions += 1

    def _enforce_budget(self, *, protect: str | None = None) -> None:
        """Evict least-recently-used IDLE graphs until the summed plan
        footprint fits the budget.  A busy victim is skipped — when
        every candidate is busy, enforcement DEFERS (stays over
        budget) rather than dropping live queries; the next add or
        idle moment retries."""
        if self.memory_budget_bytes is None:
            return
        while self.total_plan_bytes > self.memory_budget_bytes:
            victims = [n for n in self._schedulers
                       if n != protect and not self._busy(n)]
            if not victims:
                return                # all busy — defer, stay over
            self.evict(min(victims, key=lambda n: self._last_used[n]))

    # ------------------------------------------------ weighted drain
    def run_until_drained(self, *, max_chunks: int = 100_000
                          ) -> dict[str, list[QueryResult]]:
        """Serve every registered graph to empty, interleaving stepper
        chunks weighted-fair by share (stride scheduling) instead of
        draining graphs serially — matching what the gateway's device
        loop does under live traffic."""
        from ..gateway.qos import WeightedFair
        start = {n: len(s.completed)
                 for n, s in self._schedulers.items()}
        fair = WeightedFair(self._shares)
        for _ in range(max_chunks):
            busy = [n for n in self._schedulers if self._busy(n)]
            if not busy:
                break
            self._schedulers[fair.pick(busy)].step()
        else:
            raise RuntimeError(f"not drained after {max_chunks} chunks")
        return {n: s.completed[start[n]:]
                for n, s in self._schedulers.items()}

    def gateway(self, config=None):
        """Async front door over every registered graph — one device
        thread interleaving schedulers by share (repro.gateway)."""
        from ..gateway import Gateway
        return Gateway(dict(self._schedulers),
                       shares=dict(self._shares), config=config)

    def names(self) -> list[str]:
        return sorted(self._schedulers)

    def __contains__(self, name: str) -> bool:
        return name in self._schedulers

    def __len__(self) -> int:
        return len(self._schedulers)
