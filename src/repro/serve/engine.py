"""Serving engines.

1. ``PageRankServer`` — batched (personalized) PageRank queries over a
   fixed graph: the fused `lax.while_loop` power iteration is AOT
   compiled (``.lower().compile()``) once at construction, so a request
   pays zero trace/compile cost — it is one executable dispatch over
   donated device buffers (DESIGN.md §4).

2. ``ServeEngine`` — batched LM serving with continuous-batching slot
   management: a fixed pool of B slots shares one stacked KV cache
   (static shapes — the TPU constraint).  Requests are admitted into
   free slots; their prompts are prefilled token-by-token into the
   slot's cache region (per-slot positions via the vectorized decode
   path), then all active slots decode in lockstep.  Finished slots
   (EOS or max_new_tokens) free immediately and can be re-admitted
   without disturbing neighbours — the vLLM-style schedule reduced to
   its TPU-static essentials.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..configs.base import LMConfig
from ..core.backends import resolve_engine, reorder_device
from ..core.pagerank import _inv_degree, fused_power_iteration
from ..core.plan import internal_graph, reorder_inverse
from ..core.spmv import SpMVEngine
from ..graphs.formats import Graph
from ..models import transformer as tf


# ---------------------------------------------------------------------------
# PageRank serving
# ---------------------------------------------------------------------------
def _mesh_shardings(engine: SpMVEngine):
    """(vector, matrix, replicated) NamedShardings on a pcpm_sharded
    engine's mesh — shared by both PageRank serving front-ends
    (``PageRankServer`` and ``serve.scheduler.SlotScheduler``)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh, axis = engine.mesh, engine.shard_axis
    return (NamedSharding(mesh, P(axis)),
            NamedSharding(mesh, P(axis, None)),
            NamedSharding(mesh, P()))


def _sharded_inv_degree(g: Graph, engine: SpMVEngine, vec_sharding):
    """Padded inverse out-degree, uploaded vertex-sharded."""
    from ..core.distributed import _padded_inv_degree
    return jax.device_put(
        jnp.asarray(_padded_inv_degree(g, engine.sharded_layout)),
        vec_sharding)


def _normalize_teleport(host: np.ndarray) -> np.ndarray:
    """Validate and column-normalize teleport distributions (a single
    (n,) vector or (n, batch) columns)."""
    if host.ndim == 1:
        # scalar fast path — this sits on the per-query submit path of
        # the push route (thousands of queries/sec), where the array
        # variant's extra reduction passes are measurable
        s = float(host.sum())
        if not (s > 0.0 and np.isfinite(s)):   # NaN fails s > 0.0
            raise ValueError(
                "every seed column must be finite with positive mass; "
                f"got column sums {s!r}")
        return host / np.float32(s)
    sums = host.sum(axis=0)
    if not (np.isfinite(sums).all() and np.all(sums > 0)):
        raise ValueError(
            "every seed column must be finite with positive mass; "
            f"got column sums {sums!r}")
    return host / sums


class PageRankServer:
    """Serve (personalized) PageRank queries from a pre-compiled fused
    iteration loop.

    ``batch`` > 1 serves a batch of personalization (seed) vectors in
    lockstep as one (n, batch) multi-vector iteration — the PCPM SpMV
    engines and the Pallas kernel are multi-vector native, so a batch
    costs one SpMV pass, not ``batch`` passes.

    Construction does all the expensive work once: PNG build, engine
    layout upload, trace + lowering + compilation (``jax.jit(...)
    .lower(...).compile()``).  ``query()`` only stages already-compiled
    device work; it never retraces (``trace_count`` stays fixed, see
    tests/test_fused_pagerank.py).

    ``sharded=True`` serves from the multi-device engine instead: the
    graph is vertex-sharded over ``num_shards`` devices (default all)
    and the sharded fused loop — all-to-all scatter + blocked local
    gather + psum residual under ``shard_map`` (DESIGN.md §6) — is AOT
    compiled against the mesh, with explicitly sharded input avals so
    requests dispatch straight onto device-local buffers.
    """

    def __init__(self, g: Graph, *, method: str = "pcpm_pallas",
                 part_size: int = 65536, batch: int = 1,
                 damping: float = 0.85, num_iterations: int = 20,
                 tol: float = 0.0, check_every: int = 1,
                 dangling: str = "none", sharded: bool = False,
                 num_shards: int | None = None,
                 engine: SpMVEngine | None = None):
        self.g = g
        self.n = g.num_nodes
        self.batch = batch
        self.damping = damping
        self.engine = resolve_engine(g, method=method, sharded=sharded,
                                     part_size=part_size,
                                     num_shards=num_shards,
                                     engine=engine)
        self.sharded = self.engine.backend.supports_sharding
        self.trace_count = 0
        self._uniform_cache = None
        multi = batch > 1
        # reordered plans (DESIGN.md §12): iterate in the plan's
        # internal (relabeled) space — seeds map in at query, ranks
        # map back out, inverse degrees come from the internal graph
        self._perm = self.engine.plan.reorder_perm
        self._inv = (None if self._perm is None
                     else reorder_inverse(self.engine.plan))
        gi = internal_graph(g, self.engine.plan)

        if self.sharded:
            from ..core.distributed import sharded_power_iteration
            layout = self.engine.sharded_layout
            self._n_pad = layout.padded_nodes
            run = sharded_power_iteration(
                layout, self.engine.mesh, self.engine.shard_axis,
                damping=damping, num_iterations=num_iterations, tol=tol,
                check_every=check_every, multi=multi, dangling=dangling)
            self._vec_sharding, mat_sharding, _ = _mesh_shardings(
                self.engine)
            self._state_sharding = (mat_sharding if multi
                                    else self._vec_sharding)
            self._inv_deg = _sharded_inv_degree(gi, self.engine,
                                                self._vec_sharding)
            shape = ((self._n_pad, batch) if multi else (self._n_pad,))
            spec = jax.ShapeDtypeStruct(shape, jnp.float32,
                                        sharding=self._state_sharding)
            inv_spec = jax.ShapeDtypeStruct((self._n_pad,), jnp.float32,
                                            sharding=self._vec_sharding)
        else:
            run = fused_power_iteration(
                self.engine, damping=damping,
                num_iterations=num_iterations, tol=tol,
                check_every=check_every, multi=multi, dangling=dangling)
            self._n_pad = self.n
            self._inv_deg = _inv_degree(gi)
            shape = (self.n, batch) if multi else (self.n,)
            spec = jax.ShapeDtypeStruct(shape, jnp.float32)
            inv_spec = jax.ShapeDtypeStruct((self.n,), jnp.float32)

        def counted(pr, inv_deg, base):
            self.trace_count += 1           # increments only at trace time
            return run.__wrapped__(pr, inv_deg, base)

        self._compiled = (jax.jit(counted, donate_argnums=(0,))
                          .lower(spec, inv_spec, spec).compile())

    def _upload(self, host: np.ndarray):
        if self.sharded:
            return jax.device_put(jnp.asarray(host),
                                  self._state_sharding)
        return jnp.asarray(host)

    def _uniform_batch(self):
        """The uniform-teleport batch, built once: the padded host
        array (the iteration state is donated, so it re-uploads per
        query, but is never re-materialized with ``np.full``) and the
        REUSABLE base device buffer (base is not donated)."""
        if self._uniform_cache is None:
            shape = (self.n, self.batch) if self.batch > 1 else (self.n,)
            host = np.full(shape, 1.0 / self.n, dtype=np.float32)
            if self.sharded:
                pad = self._n_pad - self.n
                host = np.pad(host,
                              ((0, pad),) + ((0, 0),) * (host.ndim - 1))
            base = self._upload((1.0 - self.damping) * host)
            self._uniform_cache = (host, base)
        return self._uniform_cache

    def query(self, seeds: np.ndarray | None = None):
        """Rank one batch.  ``seeds``: (n, batch) per-query teleport
        distributions (columns need not be normalized — they are), or
        None for the uniform-teleport batch.  Returns (ranks, iters,
        residuals) with ranks of shape (n, batch) (or (n,) when
        ``batch == 1``) and residuals as in ``PageRankResult`` (one
        float per convergence check, in iteration order)."""
        shape = (self.n, self.batch) if self.batch > 1 else (self.n,)
        if seeds is None:
            host, base = self._uniform_batch()
            v = self._upload(host)
        else:
            host = _normalize_teleport(
                np.asarray(seeds, dtype=np.float32).reshape(shape))
            if self._perm is not None:
                host = host[self._inv]        # into internal space
            if self.sharded:
                pad = self._n_pad - self.n
                host = np.pad(host,
                              ((0, pad),) + ((0, 0),) * (host.ndim - 1))
            v = self._upload(host)
            base = (1.0 - self.damping) * v
        pr, it, res = self._compiled(v, self._inv_deg, base)
        if self.sharded:
            pr = pr[:self.n]
        if self._perm is not None:            # back to original ids
            perm_dev, _ = reorder_device(self.engine.plan)
            pr = jnp.take(pr, perm_dev, axis=0)
        it = int(it)
        res_host = np.asarray(res)[:it]
        return pr, it, [float(r) for r in res_host if r >= 0.0]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None


class ServeEngine:
    def __init__(self, cfg: LMConfig, params, *, batch_slots: int = 4,
                 max_len: int = 256, eos_id: int = -1,
                 sample: Optional[Callable] = None):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.sample = sample or (lambda logits: jnp.argmax(logits, -1))
        self.cache = tf.init_cache(cfg, batch_slots, max_len)
        self.t = np.zeros(batch_slots, dtype=np.int32)   # next position
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.pending_prompt: list[list[int]] = [[] for _ in range(batch_slots)]
        self._step = jax.jit(
            lambda params, cache, tok, t: tf.decode_step(
                params, cfg, cache, tok, t))

    # ---------------------------------------------------------- admission
    def fits(self, req: Request) -> bool:
        """Whether the request can EVER be admitted: prompt plus token
        budget must stay inside the static per-slot cache region (the
        last KV write for a full generation lands at position
        ``len(prompt) + max_new_tokens - 2``; anything longer would be
        truncated or, for prompts past ``max_len``, corrupt the
        slot)."""
        return len(req.prompt) + req.max_new_tokens <= self.max_len

    def add_request(self, req: Request) -> bool:
        if not self.fits(req):
            return False
        for i in range(self.b):
            if self.slot_req[i] is None:
                self.slot_req[i] = req
                self.pending_prompt[i] = list(req.prompt)
                self.t[i] = 0
                return True
        return False

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    # -------------------------------------------------------------- step
    def step(self):
        """Advance every active slot by one token (prompt feed or
        generation), one batched decode_step."""
        tokens = np.zeros((self.b, 1), dtype=np.int32)
        feeding = [False] * self.b
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.pending_prompt[i]:
                tokens[i, 0] = self.pending_prompt[i].pop(0)
                feeding[i] = True
            elif req.generated:
                tokens[i, 0] = req.generated[-1]
            elif req.prompt:
                tokens[i, 0] = req.prompt[-1]
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.t))
        next_tok = np.asarray(self.sample(logits[:, 0, :]))
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.t[i] += 1
            if feeding[i] and self.pending_prompt[i]:
                continue                         # still prefilling
            if not feeding[i] or not self.pending_prompt[i]:
                tok = int(next_tok[i])
                req.generated.append(tok)
                if (tok == self.eos_id
                        or len(req.generated) >= req.max_new_tokens
                        or self.t[i] >= self.max_len - 1):
                    req.done = True
                    self.slot_req[i] = None      # slot freed

    def run_until_drained(self, requests: list[Request],
                          max_steps: int = 10_000) -> list[Request]:
        queue = []
        for req in requests:
            # never-fitting requests are rejected up front instead of
            # blocking the head of the line forever
            if self.fits(req):
                queue.append(req)
            else:
                req.error = (f"prompt ({len(req.prompt)}) + "
                             f"max_new_tokens ({req.max_new_tokens})"
                             f" exceed max_len={self.max_len}")
                req.done = True
        for _ in range(max_steps):
            # every queued request fits, so admission only waits on a
            # free slot — no per-step queue rescans once the pool fills
            while queue and self.active < self.b:
                self.add_request(queue.pop(0))
            if not queue and self.active == 0:
                break
            if self.active:
                self.step()
        return requests
