"""Batched serving engine with continuous-batching slot management.

A fixed pool of B slots shares one stacked KV cache (static shapes — the
TPU constraint).  Requests are admitted into free slots; their prompts
are prefilled token-by-token into the slot's cache region (per-slot
positions via the vectorized decode path), then all active slots decode
in lockstep.  Finished slots (EOS or max_new_tokens) free immediately
and can be re-admitted without disturbing neighbours — the vLLM-style
schedule reduced to its TPU-static essentials.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..configs.base import LMConfig
from ..models import transformer as tf


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: LMConfig, params, *, batch_slots: int = 4,
                 max_len: int = 256, eos_id: int = -1,
                 sample: Optional[Callable] = None):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.sample = sample or (lambda logits: jnp.argmax(logits, -1))
        self.cache = tf.init_cache(cfg, batch_slots, max_len)
        self.t = np.zeros(batch_slots, dtype=np.int32)   # next position
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.pending_prompt: list[list[int]] = [[] for _ in range(batch_slots)]
        self._step = jax.jit(
            lambda params, cache, tok, t: tf.decode_step(
                params, cfg, cache, tok, t))

    # ---------------------------------------------------------- admission
    def add_request(self, req: Request) -> bool:
        for i in range(self.b):
            if self.slot_req[i] is None:
                self.slot_req[i] = req
                self.pending_prompt[i] = list(req.prompt)
                self.t[i] = 0
                return True
        return False

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    # -------------------------------------------------------------- step
    def step(self):
        """Advance every active slot by one token (prompt feed or
        generation), one batched decode_step."""
        tokens = np.zeros((self.b, 1), dtype=np.int32)
        feeding = [False] * self.b
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.pending_prompt[i]:
                tokens[i, 0] = self.pending_prompt[i].pop(0)
                feeding[i] = True
            elif req.generated:
                tokens[i, 0] = req.generated[-1]
            elif req.prompt:
                tokens[i, 0] = req.prompt[-1]
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.t))
        next_tok = np.asarray(self.sample(logits[:, 0, :]))
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.t[i] += 1
            if feeding[i] and self.pending_prompt[i]:
                continue                         # still prefilling
            if not feeding[i] or not self.pending_prompt[i]:
                tok = int(next_tok[i])
                req.generated.append(tok)
                if (tok == self.eos_id
                        or len(req.generated) >= req.max_new_tokens
                        or self.t[i] >= self.max_len - 1):
                    req.done = True
                    self.slot_req[i] = None      # slot freed

    def run_until_drained(self, requests: list[Request],
                          max_steps: int = 10_000) -> list[Request]:
        queue = list(requests)
        for _ in range(max_steps):
            while queue and self.add_request(queue[0]):
                queue.pop(0)
            if not queue and self.active == 0:
                break
            if self.active:
                self.step()
        return requests
