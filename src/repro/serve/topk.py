"""On-device top-k rank extraction (DESIGN.md §7).

A "top 100 of graph X" query should ship 100 ids + 100 scores over
PCIe/ICI, not the full n-vector.  ``slot_topk`` slices one column out
of the (n, B) slot pool (column index is DATA — no retrace across
slots) and runs ``jax.lax.top_k`` on device; only the (k,) results
cross to the host.  Pad rows of a sharded pool are masked to -1 so
they can never outrank a real vertex (true ranks are >= 0).

``k`` is necessarily a static shape parameter, so the scheduler keeps
one compiled extractor per distinct k (see ``SlotScheduler``); queries
reusing a k hit the cache.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def make_slot_topk(num_nodes: int):
    """Build ``topk(pr, col, k) -> (ids, scores)`` for an (n_pad, B)
    slot pool whose first ``num_nodes`` rows are real vertices."""

    @partial(jax.jit, static_argnames=("k",))
    def topk(pr, col, k):
        column = pr[:, col]                       # traced col: one gather
        if column.shape[0] != num_nodes:          # mask sharding pad rows
            column = jnp.where(jnp.arange(column.shape[0]) < num_nodes,
                               column, -1.0)
        scores, ids = jax.lax.top_k(column, k)
        return ids.astype(jnp.int32), scores

    return topk


slot_topk = make_slot_topk


@partial(jax.jit, static_argnames=("k",))
def topk_ranks(pr, k):
    """Standalone top-k over a single (n,) rank vector."""
    scores, ids = jax.lax.top_k(pr, k)
    return ids.astype(jnp.int32), scores


def host_topk(ranks: np.ndarray, k: int):
    """Host-side top-k over an (n,) numpy estimate — the push query
    path's twin of ``slot_topk`` (push answers live on the host, so
    shipping them to the device just to rank them would re-pay the
    transfer the push path exists to avoid).  Ties break like
    ``jax.lax.top_k``: equal scores order by lower id."""
    ranks = np.asarray(ranks)
    n = ranks.shape[0]
    k = min(int(k), n)
    if k == n:
        idx = np.arange(n)
    else:
        # argpartition picks an ARBITRARY member of a score tie on the
        # k-th boundary; lax.top_k takes the lowest id.  Repair only
        # when a tie actually crosses the boundary — the extra O(n)
        # passes would otherwise dominate this serving hot path.
        idx = np.argpartition(ranks, n - k)[n - k:]
        sel = ranks[idx]
        kth = sel.min()
        if (np.count_nonzero(ranks == kth)
                > np.count_nonzero(sel == kth)):
            strict = idx[sel > kth]
            ties = np.nonzero(ranks == kth)[0]  # ascending id order
            idx = np.concatenate([strict, ties[:k - strict.size]])
    order = np.lexsort((idx, -ranks[idx]))
    ids = idx[order].astype(np.int32)
    return ids, ranks[ids].astype(np.float32)
