from . import pcpm_spmv, embedding_bag, flash_attention

__all__ = ["pcpm_spmv", "embedding_bag", "flash_attention"]
