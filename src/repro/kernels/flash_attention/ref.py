"""Pure-jnp oracle: masked multi-head attention with GQA + sliding window."""
from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
            causal: bool = True, window: int | None = None,
            kv_len: int | None = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). Hq % Hkv == 0.

    window = sliding-window size (Mistral-style: key j visible to query i
    iff i - window < j <= i).  kv_len masks padded kv positions.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)  # align ends (decode)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((1, 1, sq, skv), dtype=bool)
    if causal:
        mask &= (k_pos <= q_pos)[None, None]
    if window is not None:
        mask &= (k_pos > q_pos - window)[None, None]
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len)
        if kv_len.ndim == 0:
            mask &= (k_pos < kv_len)[None, None]
        else:  # per-batch kv lengths (continuous batching)
            mask = mask & (k_pos[None] < kv_len[:, None, None])[:, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
