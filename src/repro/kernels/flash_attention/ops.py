"""Jit'd attention wrapper: (B, S, H, D) layout, padding, GQA, and the
path switch between the Pallas kernel (TPU target) and the XLA reference
(CPU / decode shapes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import mha_ref


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@functools.partial(jax.jit, static_argnames=("causal", "window", "path",
                                             "interpret", "block_q",
                                             "block_k"))
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int | None = None,
              path: str = "xla", interpret: bool = True,
              block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if path == "xla":
        out = mha_ref(qt, kt, vt, causal=causal, window=window)
    else:
        b, hq, sq, d = qt.shape
        skv = kt.shape[2]
        sq_p = _round_up(sq, block_q)
        skv_p = _round_up(skv, block_k)
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        out = flash_attention_pallas(
            qt, kt, vt, causal=causal, window=window, kv_len=skv,
            block_q=block_q, block_k=block_k, interpret=interpret)
        out = out[:, :, :sq, :]
    return out.transpose(0, 2, 1, 3)
