from .kernel import flash_attention_pallas
from .ops import attention
from .ref import mha_ref

__all__ = ["flash_attention_pallas", "attention", "mha_ref"]
