"""FlashAttention (tiled online softmax) Pallas TPU kernel.

Causal + sliding-window (Mistral/Mixtral SWA) masks, GQA via BlockSpec
index_map (kv head = q head // group — no jnp.repeat materialization).

Grid: (batch, q_heads, q_blocks, kv_blocks), kv innermost.  Running
(m, l, acc) state lives in VMEM scratch and is normalized into the
output block at the last kv step.  Fully-masked kv blocks are skipped
with pl.when (the causal/SWA block-diagonal band is the only work done —
this is the FLOP-side win over masked dense attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, kv_len: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip: q rows [q0, q0+Bq), kv cols [k0, k0+Bk)
    q0 = qi * block_q + q_offset          # global key-aligned q position
    k0 = ki * block_k
    run = jnp.bool_(True)
    if causal:
        run &= k0 <= q0 + block_q - 1             # some key <= some query
    if window is not None:
        run &= k0 + block_k - 1 > q0 - window     # inside the band
    run &= k0 < kv_len

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)       # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)       # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q0 + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 0)
        k_pos = k0 + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 1)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                        # (Bq, 1) replicated
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (Bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)   # (Bq, Bk)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "kv_len", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, window: int | None = None,
                           kv_len: int | None = None, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D), Sq % Bq == Skv % Bk == 0."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0 and sq % block_q == 0 and skv % block_k == 0
    group = hq // hkv
    kv_len = skv if kv_len is None else kv_len
    grid = (b, hq, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (d ** 0.5), causal=causal,
        window=window, block_q=block_q, block_k=block_k, kv_len=kv_len,
        q_offset=skv - sq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
