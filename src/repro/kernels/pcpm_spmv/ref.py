"""Pure-jnp oracle for the PCPM gather kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pcpm_gather_ref(bins: jnp.ndarray, edge_upd: jnp.ndarray,
                    edge_dst: jnp.ndarray, *, part_size: int) -> jnp.ndarray:
    """bins: (k, U, d); edge_upd/edge_dst: (k, n_eb, Eb) -> (k, P, d).

    Pad conventions identical to the kernel: edge_upd == U selects a zero
    update; edge_dst == part_size discards the contribution.
    """
    k, num_updates, d = bins.shape
    eu = edge_upd.reshape(k, -1)
    ed = edge_dst.reshape(k, -1)
    bins_z = jnp.concatenate(
        [bins, jnp.zeros((k, 1, d), bins.dtype)], axis=1)
    vals = jnp.take_along_axis(bins_z, eu[:, :, None], axis=1)  # (k, E, d)
    out = jnp.zeros((k, part_size + 1, d), bins.dtype)
    out = out.at[jnp.arange(k)[:, None], ed].add(vals)
    return out[:, :part_size, :]
