from .kernel import pcpm_gather_pallas
from .ops import PackedPNG, pack_blocked, pcpm_spmv_pallas
from .ref import pcpm_gather_ref

__all__ = ["pcpm_gather_pallas", "PackedPNG", "pack_blocked",
           "pcpm_spmv_pallas", "pcpm_gather_ref"]
