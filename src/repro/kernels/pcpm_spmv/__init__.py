from .kernel import (default_interpret, pcpm_gather_pallas, pick_u_tile)
from .ops import PackedPNG, pack_blocked, pcpm_spmv_pallas
from .ref import pcpm_gather_ref

__all__ = ["default_interpret", "pcpm_gather_pallas", "pick_u_tile",
           "PackedPNG", "pack_blocked", "pcpm_spmv_pallas",
           "pcpm_gather_ref"]
