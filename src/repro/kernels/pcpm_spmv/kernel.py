"""PCPM gather phase as a Pallas TPU kernel (v2: tiled update gather).

TPU-native adaptation of paper alg. 5 (see DESIGN.md §2):

- one destination partition's accumulator lives in VMEM for the whole
  pass (the paper's cache-resident partition);
- the update bin for that partition streams through VMEM one lane-sized
  ``u_tile`` slice at a time (v2 — v1 expanded a full (Eb, U) one-hot
  per edge block, which scales VMEM and MXU work with U instead of with
  the tile);
- the per-edge (update_idx, dst_local) streams are consumed in blocks;
- BOTH the update gather and the destination scatter are expressed as
  one-hot matmuls on the MXU — the branch-free replacement for the
  paper's MSB pointer trick (TPU vector lanes have no cheap data-
  dependent branch; redundant MXU FLOPs are free relative to HBM).

Grid: (num_partitions, num_edge_blocks, num_update_tiles); update tiles
iterate innermost, accumulating gathered values for the current edge
block into a VMEM scratch, and the destination scatter fires on the
last tile.  The partition accumulator block is revisited across the two
inner grid axes (Pallas keeps it in VMEM across consecutive grid steps
with the same index_map output).

Shapes (all static, built by core.png.block_png + ops.pack_blocked):
  bins:        (k, U, d)   per-partition compressed update values
  edge_upd:    (k, E_blocks, Eb) int32, pad = U   (one-hot row -> 0)
  edge_dst:    (k, E_blocks, Eb) int32, pad = P   (one-hot row -> 0)
  out:         (k, P, d)   per-partition accumulated values

``interpret=None`` auto-selects the compiled kernel on TPU backends and
the Pallas interpreter everywhere else (CPU CI, tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def default_interpret() -> bool:
    """Interpreter fallback policy: compiled on TPU, interpreted off it."""
    return jax.default_backend() != "tpu"


def pick_u_tile(num_updates: int, *, preferred: int = 512,
                lane: int = 128) -> int:
    """Largest lane-multiple tile <= preferred that divides U."""
    for cand in range(min(preferred, num_updates), lane - 1, -lane):
        if num_updates % cand == 0:
            return cand
    return num_updates


def _gather_kernel(edge_upd_ref, edge_dst_ref, bins_ref, out_ref,
                   vals_ref, *, part_size: int, u_tile: int,
                   num_u_tiles: int):
    e = pl.program_id(1)
    u = pl.program_id(2)

    @pl.when((e == 0) & (u == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(u == 0)
    def _init_vals():
        vals_ref[...] = jnp.zeros_like(vals_ref)

    upd_idx = edge_upd_ref[0, 0, :]                       # (Eb,)
    bins = bins_ref[0]                                    # (u_tile, d)
    eb = upd_idx.shape[0]

    # tiled gather-as-matmul: (Eb, u_tile) @ (u_tile, d) -> (Eb, d).
    # Pad indices (== U) match no tile and contribute zero rows.
    iota_u = (jax.lax.broadcasted_iota(jnp.int32, (eb, u_tile), 1)
              + u * u_tile)
    oh_upd = (upd_idx[:, None] == iota_u).astype(jnp.float32)
    vals_ref[...] += jax.lax.dot(oh_upd, bins.astype(jnp.float32),
                                 preferred_element_type=jnp.float32)

    @pl.when(u == num_u_tiles - 1)
    def _scatter():
        # scatter-as-matmul: (P, Eb) @ (Eb, d) -> (P, d)
        dst_idx = edge_dst_ref[0, 0, :]                   # (Eb,)
        iota_p = jax.lax.broadcasted_iota(jnp.int32, (eb, part_size), 1)
        oh_dst = (dst_idx[:, None] == iota_p).astype(jnp.float32)
        out_ref[0] += jax.lax.dot(
            oh_dst.T, vals_ref[...],
            preferred_element_type=jnp.float32).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("part_size", "u_tile", "interpret"))
def pcpm_gather_pallas(bins: jnp.ndarray, edge_upd: jnp.ndarray,
                       edge_dst: jnp.ndarray, *, part_size: int,
                       u_tile: int | None = None,
                       interpret: bool | None = None) -> jnp.ndarray:
    """bins: (k, U, d); edge_upd/edge_dst: (k, n_eb, Eb) -> (k, P, d)."""
    if interpret is None:
        interpret = default_interpret()
    k, num_updates, d = bins.shape
    _, n_eb, eb = edge_upd.shape
    assert edge_dst.shape == edge_upd.shape
    if u_tile is None:
        u_tile = pick_u_tile(num_updates)
    assert num_updates % u_tile == 0, (num_updates, u_tile)
    n_ut = num_updates // u_tile
    grid = (k, n_eb, n_ut)
    kernel = functools.partial(_gather_kernel, part_size=part_size,
                               u_tile=u_tile, num_u_tiles=n_ut)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, eb), lambda p, e, u: (p, e, 0)),
            pl.BlockSpec((1, 1, eb), lambda p, e, u: (p, e, 0)),
            pl.BlockSpec((1, u_tile, d), lambda p, e, u: (p, u, 0)),
        ],
        out_specs=pl.BlockSpec((1, part_size, d),
                               lambda p, e, u: (p, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, part_size, d), bins.dtype),
        scratch_shapes=[pltpu.VMEM((eb, d), jnp.float32)],
        interpret=interpret,
    )(edge_upd, edge_dst, bins)
