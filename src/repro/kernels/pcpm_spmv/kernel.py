"""PCPM gather phase as a Pallas TPU kernel.

TPU-native adaptation of paper alg. 5 (see DESIGN.md §2):

- one destination partition's accumulator lives in VMEM for the whole
  pass (the paper's cache-resident partition);
- the update bin for that partition is VMEM-resident (paper: bins are
  streamed; here a partition's compressed bin fits VMEM because it is
  m/r-sized);
- the per-edge (update_idx, dst_local) streams are consumed in blocks;
- BOTH the update gather and the destination scatter are expressed as
  one-hot matmuls on the MXU — the branch-free replacement for the
  paper's MSB pointer trick (TPU vector lanes have no cheap data-
  dependent branch; redundant MXU FLOPs are free relative to HBM).

Grid: (num_partitions, num_edge_blocks); edge blocks iterate innermost
so the accumulator block is revisited (Pallas keeps it in VMEM across
consecutive grid steps with the same index_map output).

Shapes (all static, built by core.png.block_png + ops.pack_blocked):
  bins:        (k, U, d)   per-partition compressed update values
  edge_upd:    (k, E_blocks, Eb) int32, pad = U   (one-hot row -> 0)
  edge_dst:    (k, E_blocks, Eb) int32, pad = P   (one-hot row -> 0)
  out:         (k, P, d)   per-partition accumulated values
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(edge_upd_ref, edge_dst_ref, bins_ref, out_ref, *,
                   part_size: int, num_updates: int):
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    upd_idx = edge_upd_ref[0, 0, :]                       # (Eb,)
    dst_idx = edge_dst_ref[0, 0, :]                       # (Eb,)
    bins = bins_ref[0]                                    # (U, d)
    eb = upd_idx.shape[0]

    # gather-as-matmul: (Eb, U) @ (U, d) -> (Eb, d)
    iota_u = jax.lax.broadcasted_iota(jnp.int32, (eb, num_updates), 1)
    oh_upd = (upd_idx[:, None] == iota_u).astype(bins.dtype)
    vals = jax.lax.dot(oh_upd, bins,
                       preferred_element_type=jnp.float32)

    # scatter-as-matmul: (P, Eb) @ (Eb, d) -> (P, d)
    iota_p = jax.lax.broadcasted_iota(jnp.int32, (eb, part_size), 1)
    oh_dst = (dst_idx[:, None] == iota_p).astype(bins.dtype)
    out_ref[0] += jax.lax.dot(oh_dst.T, vals,
                              preferred_element_type=jnp.float32
                              ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("part_size", "edge_block", "interpret"))
def pcpm_gather_pallas(bins: jnp.ndarray, edge_upd: jnp.ndarray,
                       edge_dst: jnp.ndarray, *, part_size: int,
                       edge_block: int = 512,
                       interpret: bool = True) -> jnp.ndarray:
    """bins: (k, U, d); edge_upd/edge_dst: (k, n_eb, Eb) -> (k, P, d)."""
    k, num_updates, d = bins.shape
    _, n_eb, eb = edge_upd.shape
    assert edge_dst.shape == edge_upd.shape
    grid = (k, n_eb)
    kernel = functools.partial(_gather_kernel, part_size=part_size,
                               num_updates=num_updates)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, eb), lambda p, e: (p, e, 0)),
            pl.BlockSpec((1, 1, eb), lambda p, e: (p, e, 0)),
            pl.BlockSpec((1, num_updates, d), lambda p, e: (p, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, part_size, d), lambda p, e: (p, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, part_size, d), bins.dtype),
        interpret=interpret,
    )(edge_upd, edge_dst, bins)
