"""Jit'd wrapper: BlockedPNG + feature matrix -> full PCPM SpMV using the
Pallas gather kernel (scatter phase is an XLA gather producing the bins).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.png import BlockedPNG
from .kernel import pcpm_gather_pallas
from .ref import pcpm_gather_ref


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class PackedPNG:
    """Kernel-ready PNG blocks (device arrays, TPU-aligned padding)."""
    part_size: int
    num_nodes: int
    update_src: jnp.ndarray    # (k, U) int32, pad -> 0 (masked)
    update_valid: jnp.ndarray  # (k, U) bool
    edge_upd: jnp.ndarray      # (k, n_eb, Eb) int32, pad -> U
    edge_dst: jnp.ndarray      # (k, n_eb, Eb) int32, pad -> part_size

    @property
    def num_partitions(self) -> int:
        return self.update_src.shape[0]


def pack_blocked(blocked: BlockedPNG, num_nodes: int, *,
                 edge_block: int = 512, lane: int = 128) -> PackedPNG:
    k, max_u = blocked.update_src.shape
    _, max_e = blocked.edge_update_local.shape
    u_pad = _round_up(max(max_u, lane), lane)
    e_pad = _round_up(max(max_e, edge_block), edge_block)

    upd = np.zeros((k, u_pad), dtype=np.int32)
    valid = np.zeros((k, u_pad), dtype=bool)
    upd[:, :max_u] = np.maximum(blocked.update_src, 0)
    valid[:, :max_u] = blocked.update_src >= 0

    eu = np.full((k, e_pad), u_pad, dtype=np.int32)
    ed = np.full((k, e_pad), blocked.part_size, dtype=np.int32)
    eu[:, :max_e] = np.where(blocked.edge_update_local >= max_u, u_pad,
                             blocked.edge_update_local)
    ed[:, :max_e] = blocked.edge_dst_local

    n_eb = e_pad // edge_block
    return PackedPNG(
        blocked.part_size, num_nodes,
        jnp.asarray(upd), jnp.asarray(valid),
        jnp.asarray(eu.reshape(k, n_eb, edge_block)),
        jnp.asarray(ed.reshape(k, n_eb, edge_block)))


@functools.partial(jax.jit,
                   static_argnames=("interpret", "use_kernel", "u_tile"))
def pcpm_spmv_pallas(packed: PackedPNG, x: jnp.ndarray, *,
                     interpret: bool | None = None,
                     use_kernel: bool = True,
                     u_tile: int | None = None) -> jnp.ndarray:
    """y = A^T x. x: (n,) or (n, d) with any d >= 1 (multi-vector /
    personalized-query batches; d is padded to the 128-lane boundary).

    ``interpret=None`` compiles the kernel on TPU and falls back to the
    Pallas interpreter elsewhere (kernel.default_interpret).
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    n, d = x.shape
    d_pad = _round_up(max(d, 128), 128)
    if d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, d_pad - d)))
    # scatter phase: compressed bins (k, U, d) — one value per
    # (src, dst-partition) pair, the paper's update_bins.
    bins = x[packed.update_src] * packed.update_valid[..., None]
    fn = pcpm_gather_pallas if use_kernel else (
        lambda b, eu, ed, part_size, interpret=None, u_tile=None:
        pcpm_gather_ref(b, eu, ed, part_size=part_size))
    out = fn(bins, packed.edge_upd, packed.edge_dst,
             part_size=packed.part_size, interpret=interpret,
             u_tile=u_tile)
    y = out.reshape(-1, d_pad)[:n, :d]
    return y[:, 0] if squeeze else y


# jax.jit can't take the dataclass directly unless registered as pytree:
jax.tree_util.register_pytree_node(
    PackedPNG,
    lambda p: ((p.update_src, p.update_valid, p.edge_upd, p.edge_dst),
               (p.part_size, p.num_nodes)),
    lambda aux, ch: PackedPNG(aux[0], aux[1], *ch))
