"""Jit'd embedding-bag wrapper with padding + production (XLA) fallback.

``embedding_bag`` picks the execution path:
  - "xla":    take + einsum (best for huge, HBM-resident tables — XLA
              emits a dynamic-gather; this is the production default)
  - "pallas": the MXU one-hot kernel (VMEM-resident table shards; used
              when the table shard fits VMEM, e.g. post-PCPM-dedup
              lookups on a model-parallel shard)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import embedding_bag_pallas
from .ref import embedding_bag_ref


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@functools.partial(jax.jit, static_argnames=("path", "interpret"))
def embedding_bag(table: jnp.ndarray, idx: jnp.ndarray,
                  weights: jnp.ndarray | None = None, *,
                  path: str = "xla", interpret: bool = True) -> jnp.ndarray:
    if path == "xla":
        return embedding_bag_ref(table, idx, weights)
    v, d = table.shape
    b, l = idx.shape
    v_pad = _round_up(v, 512)
    b_pad = _round_up(b, 8)
    d_pad = _round_up(d, 128)
    tbl = jnp.pad(table, ((0, v_pad - v), (0, d_pad - d)))
    # out-of-range pad indices select nothing in every tile
    ix = jnp.pad(idx, ((0, b_pad - b), (0, 0)), constant_values=v_pad)
    ix = jnp.where(ix >= v, v_pad, ix)  # original pads too
    w = None
    if weights is not None:
        w = jnp.pad(weights, ((0, b_pad - b), (0, 0)))
    out = embedding_bag_pallas(tbl, ix, w, interpret=interpret)
    return out[:b, :d]
