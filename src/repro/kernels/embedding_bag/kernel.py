"""EmbeddingBag (sum) as a Pallas TPU kernel.

The recsys hot path (DESIGN.md §4): lookup = SpMV with a 0/1 (or
weighted) selection matrix — the same partition-centric structure as
PCPM.  The vocab axis is tiled (a table tile is the VMEM-resident
"partition"); each bag block builds a one-hot selection matrix against
the resident tile and multiplies on the MXU — gather-as-matmul, the
same adaptation as the PCPM gather (no random access ever leaves VMEM).

Grid: (bag_blocks, vocab_tiles); vocab innermost so the (Bb, d) output
accumulator stays resident in VMEM.

  table: (V, d)     — tiled (Vt, d)
  idx:   (B, L)     — tiled (Bb, L), pad entries >= V
  w:     (B, L)     — per-sample weights
  out:   (B, d)     — tiled (Bb, d)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(idx_ref, w_ref, table_ref, out_ref, *, vocab_tile: int):
    vt = pl.program_id(1)

    @pl.when(vt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]                  # (Bb, L)
    w = w_ref[...]                      # (Bb, L)
    tile = table_ref[...]               # (Vt, d)
    local = idx - vt * vocab_tile       # in-tile position or out of range
    # selection matrix (Bb, Vt): sum_l w[b,l] * onehot(local[b,l])
    iota_v = jax.lax.broadcasted_iota(jnp.int32, (1, 1, vocab_tile), 2)
    oh = (local[:, :, None] == iota_v).astype(tile.dtype)   # (Bb, L, Vt)
    sel = jnp.einsum("bl,blv->bv", w, oh)
    out_ref[...] += jax.lax.dot(sel, tile,
                                preferred_element_type=jnp.float32
                                ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bag_block", "vocab_tile",
                                             "interpret"))
def embedding_bag_pallas(table: jnp.ndarray, idx: jnp.ndarray,
                         weights: jnp.ndarray | None = None, *,
                         bag_block: int = 8, vocab_tile: int = 512,
                         interpret: bool = True) -> jnp.ndarray:
    v, d = table.shape
    b, l = idx.shape
    assert v % vocab_tile == 0, "pad table to vocab_tile multiple"
    assert b % bag_block == 0, "pad batch to bag_block multiple"
    if weights is None:
        weights = jnp.ones_like(idx, dtype=table.dtype)
    # pad idx >= V contributes nothing (never matches an in-tile iota)
    grid = (b // bag_block, v // vocab_tile)
    return pl.pallas_call(
        functools.partial(_bag_kernel, vocab_tile=vocab_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bag_block, l), lambda bb, vt: (bb, 0)),
            pl.BlockSpec((bag_block, l), lambda bb, vt: (bb, 0)),
            pl.BlockSpec((vocab_tile, d), lambda bb, vt: (vt, 0)),
        ],
        out_specs=pl.BlockSpec((bag_block, d), lambda bb, vt: (bb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(idx, weights, table)
