"""Pure-jnp oracle for embedding_bag (sum mode, optional weights).

JAX has no native EmbeddingBag (kernel_taxonomy §RecSys): the reference
is gather + weighted sum; pad slots are signaled by idx >= vocab.
"""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table: jnp.ndarray, idx: jnp.ndarray,
                      weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """table: (V, d); idx: (B, L) int32 (pad = V); weights: (B, L) or None.
    Returns (B, d) = sum_l w[b,l] * table[idx[b,l]]."""
    v, _ = table.shape
    valid = (idx < v).astype(table.dtype)
    w = valid if weights is None else weights * valid
    rows = table[jnp.clip(idx, 0, v - 1)]            # (B, L, d)
    return jnp.einsum("bl,bld->bd", w, rows)
