"""Paper Table VII: pre-processing time.

PDPR needs a CSC sort; BVGAS needs the dst-partition-major sort; PCPM
additionally builds the PNG (compress+transpose).  The paper's claim:
PCPM pre-processing > BVGAS > PDPR(=0 given CSR), and it amortizes
within one PageRank run.
"""
from __future__ import annotations

from repro.core.partition import Partitioning
from repro.core.png import build_png
from repro.core.spmv import DeviceCSC, DeviceBVGAS, DevicePNG
from .common import Csv, Dataset, timeit


def run(datasets: list[Dataset], *, part_size: int = 65536) -> Csv:
    csv = Csv()
    for ds in datasets:
        part = Partitioning(ds.n, part_size)
        t_csc = timeit(lambda: DeviceCSC.build(ds.graph),
                       warmup=1, iters=3)
        t_bv = timeit(lambda: DeviceBVGAS.build(ds.graph, part),
                      warmup=1, iters=3)
        t_png = timeit(lambda: build_png(ds.graph, part),
                       warmup=1, iters=3)
        csv.add(f"table7/{ds.name}/pdpr_csc", t_csc)
        csv.add(f"table7/{ds.name}/bvgas_bins", t_bv)
        csv.add(f"table7/{ds.name}/pcpm_png", t_png)
    return csv
