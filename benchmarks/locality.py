"""Locality relabeling, end to end through the PLAN layer.

table5/table6 measure orderings by relabeling the graph by hand and
rebuilding a PNG layout; this module measures the production path the
ingest subsystem exposes — ``EngineConfig(reorder=...)`` — so the
numbers include everything a user of ``repro.open`` gets: the plan
built on the relabeled graph, the fused solver iterating in internal
space, and the final gather back to original ids.

Rows per dataset and ordering:

- ``locality/<ds>/<ord>/r``     — achieved compression ratio r
  (derived carries r and the gain over the unreordered plan);
- ``locality/<ds>/<ord>/iter``  — WARM per-iteration wall time of the
  fused 20-iteration solve (compile excluded; the honest per-iter
  delta the reordering buys, or costs, at this scale).

Standalone mode merges into BENCH_pagerank.json without disturbing
the rows benchmarks/run.py owns:

    PYTHONPATH=src python -m benchmarks.locality --json \
        BENCH_pagerank.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import repro
from repro.graphs.reorder import available_orderings
from .common import Csv, Dataset, suite, timeit

ITERS = 20


def run(datasets: list[Dataset], *, part_size: int = 65536,
        orderings=None) -> Csv:
    names = list(orderings) if orderings else list(available_orderings())
    if "none" not in names:
        names = ["none"] + names    # the gain baseline is mandatory
    csv = Csv()
    for ds in datasets:
        base_r = None
        for name in names:
            sess = repro.open(ds.graph, repro.EngineConfig(
                method="pcpm", part_size=part_size, reorder=name,
                num_iterations=ITERS, tol=0.0))
            r = sess.plan.compression_ratio
            if name == "none":
                base_r = r

            def once():
                sess.pagerank().ranks.block_until_ready()

            sec_iter = timeit(once, warmup=1, iters=3) / ITERS
            gain = (f",r_gain={r / base_r:.2f}"
                    if base_r else "")
            csv.add(f"locality/{ds.name}/{name}/r", 0.0,
                    f"r={r:.2f}{gain}")
            csv.add(f"locality/{ds.name}/{name}/iter", sec_iter,
                    f"ms_per_iter={sec_iter * 1e3:.2f}")
    return csv


def summarize(rows) -> dict:
    """Fold locality/ rows into the JSON summary block: per dataset,
    per ordering, r / warm per-iter us / r gain over 'none'."""
    summ: dict = {}
    for n, us, derived in rows:
        if not n.startswith("locality/"):
            continue
        _, ds_name, ordering, kind = n.split("/")
        e = summ.setdefault(ds_name, {}).setdefault(ordering, {})
        if kind == "r":
            e["r"] = float(derived.split("r=")[1].split(",")[0])
        else:
            e["iter_us"] = round(us, 1)
    for ords in summ.values():
        base = ords.get("none", {}).get("r")
        if base:
            for e in ords.values():
                e["r_gain"] = round(e["r"] / base, 2)
    return summ


def _merge_json(path: str, rows, meta: dict) -> None:
    """Replace the locality/ rows of an existing benchmark JSON,
    leaving every other module's rows alone (run.py owns the file)."""
    doc = {}
    if os.path.exists(path) and os.path.getsize(path) > 0:
        try:
            with open(path) as f:
                doc = json.load(f)
        except json.JSONDecodeError:
            doc = {}
    kept = [r for r in doc.get("rows", [])
            if not r["name"].startswith("locality/")]
    doc["rows"] = kept + [{"name": n, "us_per_call": round(us, 1),
                           "derived": derived}
                          for n, us, derived in rows]
    doc["locality"] = summarize(rows)
    doc["locality_meta"] = meta
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=16)
    ap.add_argument("--part-size", type=int, default=None)
    ap.add_argument("--reorder", nargs="*", default=None,
                    choices=list(available_orderings()),
                    help="orderings to measure (default: all; 'none' "
                         "is always included as the gain baseline)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="merge locality rows into an existing "
                         "BENCH_pagerank.json (append, not overwrite)")
    args = ap.parse_args(argv)

    t0 = time.time()
    datasets = suite(args.scale)[:2]      # kron + social (rmat regime)
    if args.part_size is None:
        from .common import default_part_size
        args.part_size = default_part_size(1 << args.scale)
    print(f"# locality scale={args.scale} part_size={args.part_size}",
          flush=True)
    print("name,us_per_call,derived")
    out = run(datasets, part_size=args.part_size,
              orderings=args.reorder)
    total_s = time.time() - t0
    print(f"# total {total_s:.0f}s, {len(out.rows)} rows", flush=True)
    if args.json:
        _merge_json(args.json, out.rows, meta={
            "scale": args.scale, "part_size": args.part_size,
            "iters": ITERS, "total_seconds": round(total_s, 1),
        })
        print(f"# merged into {args.json}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
