"""Paper Figs 12-15: partition-size design-space exploration.

Sweeps part_size over powers of two; per point records the compression
ratio r (fig 12), the model DRAM bytes (fig 13), measured per-iteration
time (fig 14) and the scatter/gather split (fig 15, on the largest
dataset only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spmv import SpMVEngine
from .common import Csv, Dataset, timeit
from .table4_runtime import _phase_times


def run(datasets: list[Dataset], sizes=None) -> Csv:
    csv = Csv()
    for ds in datasets:
        x = jnp.asarray(
            np.random.default_rng(0).random(ds.n).astype(np.float32))
        sweep = sizes or [max(256, ds.n // k) for k in
                          (512, 128, 64, 16, 4, 1)]
        for psz in sweep:
            if psz > ds.n:
                continue
            eng = SpMVEngine(ds.graph, method="pcpm", part_size=psz)
            t = timeit(lambda: jax.block_until_ready(eng(x)))
            model = eng.layout.model_bytes()["total"]
            ts, tg = _phase_times(eng, x)
            csv.add(f"fig12/{ds.name}/part{psz}", t,
                    f"r={eng.compression_ratio:.2f}"
                    f",modelGB={model / 1e9:.3f}"
                    f",scatter_us={ts * 1e6:.0f},gather_us={tg * 1e6:.0f}")
    return csv
