"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale N] [--only name ...]

Emits ``name,us_per_call,derived`` CSV on stdout.  Default scale=16
(65K nodes, 1-2M edges per dataset) finishes on the 1-core CPU box in
minutes; the paper's graphs are ~1000x larger and live in the dry-run /
roofline analysis instead (EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from .common import Csv, suite


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=16)
    ap.add_argument("--part-size", type=int, default=None,
                    help="default: n/64 (paper-regime partition count)")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of: table4 fig8 table5 table6 fig12 "
                         "table7 dist e2e sharded serve serve_push "
                         "serve_gateway stream locality comm")
    ap.add_argument("--reorder", default=None,
                    choices=["none", "degree", "bfs", "hybrid"],
                    help="add the plan-layer locality job, measuring "
                         "this ordering against 'none' (compression "
                         "ratio r + warm per-iter time through "
                         "EngineConfig(reorder=...)); --only locality "
                         "without this flag measures every ordering")
    ap.add_argument("--shards", type=int, default=None, metavar="N",
                    help="enable the sharded fused-loop comparison "
                         "with N shards (clamped to visible devices; "
                         "force host devices via XLA_FLAGS)")
    ap.add_argument("--serve", action="store_true",
                    help="add the continuous-batching serving load "
                         "benchmark (queries/sec + p50/p99 latency "
                         "alongside the per-iteration SpMV rows)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write the rows as structured JSON "
                         "(perf-trajectory baseline, e.g. "
                         "BENCH_pagerank.json)")
    args = ap.parse_args(argv)
    if args.json:
        # fail fast on an unwritable path without truncating an
        # existing baseline (a crashed run must not destroy it)
        open(args.json, "a").close()

    t0 = time.time()
    datasets = suite(args.scale)
    from .common import default_part_size
    if args.part_size is None:
        args.part_size = default_part_size(1 << args.scale)
    print(f"# suite scale={args.scale} part_size={args.part_size}: "
          + ", ".join(f"{d.name}(n={d.n},m={d.m})" for d in datasets),
          flush=True)
    print("name,us_per_call,derived")

    from . import (table4_runtime, fig8_comm, table5_locality,
                   table6_comm_locality, fig12_partition_sweep,
                   table7_preproc, dist_wire, pagerank_e2e,
                   sharded_loop, serve_load, serve_push,
                   serve_gateway, stream_updates, locality,
                   comm_live)
    jobs = {
        "table4": lambda: table4_runtime.run(
            datasets, part_size=args.part_size),
        "fig8": lambda: fig8_comm.run(datasets,
                                      part_size=args.part_size),
        "table5": lambda: table5_locality.run(
            datasets, part_size=args.part_size),
        "table6": lambda: table6_comm_locality.run(
            datasets[:3], part_size=args.part_size),
        "fig12": lambda: fig12_partition_sweep.run(datasets[:2]),
        "table7": lambda: table7_preproc.run(
            datasets, part_size=args.part_size),
        "dist": lambda: dist_wire.run(datasets),
        "e2e": lambda: pagerank_e2e.run(datasets[:2],
                                        part_size=args.part_size),
        "sharded": lambda: sharded_loop.run(
            datasets[:2], num_shards=args.shards,
            part_size=args.part_size),
        "serve": lambda: serve_load.run(
            datasets[:2], part_size=args.part_size),
        "serve_push": lambda: serve_push.run(
            datasets[:2], part_size=args.part_size),
        "serve_gateway": lambda: serve_gateway.run(
            datasets[:2], part_size=args.part_size),
        "stream": lambda: stream_updates.run(
            datasets[:1], part_size=args.part_size),
        # --reorder X measures just [none, X]; --only locality with no
        # --reorder sweeps every registered ordering
        "locality": lambda: locality.run(
            datasets[:2], part_size=args.part_size,
            orderings=(["none", args.reorder] if args.reorder
                       else None)),
        # measured-vs-model comm accounting (DESIGN.md §14)
        "comm": lambda: comm_live.run(datasets[:2],
                                      part_size=args.part_size),
    }
    selected = args.only or [j for j in jobs
                             if j not in ("sharded", "serve",
                                          "serve_push", "serve_gateway",
                                          "locality")]
    if args.shards and "sharded" not in selected:
        selected = selected + ["sharded"]
    if args.reorder and "locality" not in selected:
        selected = selected + ["locality"]
    if args.serve:
        selected = selected + [j for j in ("serve", "serve_push",
                                           "serve_gateway")
                               if j not in selected]
    if "sharded" in selected and args.shards is None:
        args.shards = 8          # job default, recorded in the JSON doc
    out = Csv()
    for name in selected:
        print(f"# --- {name} ---", flush=True)
        out.extend(jobs[name]())
    total_s = time.time() - t0
    print(f"# total {total_s:.0f}s, {len(out.rows)} rows", flush=True)
    if args.json:
        doc = {
            "scale": args.scale,
            "part_size": args.part_size,
            "shards": args.shards,
            "only": selected,
            "total_seconds": round(total_s, 1),
            "datasets": [{"name": d.name, "n": d.n, "m": d.m}
                         for d in datasets],
            "rows": [{"name": n, "us_per_call": round(us, 1),
                      "derived": derived}
                     for n, us, derived in out.rows],
        }
        # plan-build vs iterate split (the paper's preprocess-once
        # amortization): aggregated from the e2e */plan and */iterate
        # rows emitted by benchmarks/pagerank_e2e.py.  The fixed-size
        # pallas_smoke rows are excluded — interpret-mode iteration is
        # orders of magnitude slower and would dominate the ratio.
        split_rows = [(n, us) for n, us, _ in out.rows
                      if "pallas_smoke" not in n]
        plan_us = sum(us for n, us in split_rows
                      if n.endswith("/plan"))
        iter_us = sum(us for n, us in split_rows
                      if n.endswith("/iterate"))
        if plan_us or iter_us:
            doc["plan_vs_iterate"] = {
                "plan_build_us": round(plan_us, 1),
                "iterate_us": round(iter_us, 1),
                "plan_frac": round(plan_us / max(plan_us + iter_us, 1e-9),
                                   4),
            }
        # dynamic-graph update split (DESIGN.md §9): per delta size,
        # warm = incremental patch + residual push vs cold = rebuild +
        # full power iteration, from benchmarks/stream_updates.py rows
        stream_tags = sorted({n.rsplit("/", 1)[0] for n, _, _ in out.rows
                              if n.startswith("stream/")
                              and n.endswith("/patch")})
        if stream_tags:
            by_name = {n: us for n, us, _ in out.rows}

            def _entry(tag):
                e = {"delta": tag.split("/", 2)[2],
                     "graph": tag.split("/", 2)[1],
                     "patch_us": round(by_name[f"{tag}/patch"], 1),
                     "rebuild_us": round(by_name[f"{tag}/rebuild"], 1),
                     "push_us": round(by_name[f"{tag}/push20"], 1),
                     "recompute_us": round(
                         by_name[f"{tag}/recompute20"], 1),
                     "speedup": round(
                         (by_name[f"{tag}/rebuild"]
                          + by_name[f"{tag}/recompute20"])
                         / max(by_name[f"{tag}/patch"]
                               + by_name[f"{tag}/push20"], 1e-9), 2)}
                if f"{tag}/push_tol" in by_name:
                    e["speedup_tol"] = round(
                        (by_name[f"{tag}/rebuild"]
                         + by_name[f"{tag}/recompute_tol"])
                        / max(by_name[f"{tag}/patch"]
                              + by_name[f"{tag}/push_tol"], 1e-9), 2)
                return e

            doc["patch_vs_rebuild"] = [_entry(t) for t in stream_tags]
        # plan-layer reordering summary (ISSUE 8): r + warm per-iter
        # per ordering, with the gain over the unreordered plan
        loc = locality.summarize(out.rows)
        if loc:
            doc["locality"] = loc
        comm = comm_live.summarize(out.rows)
        if comm:
            doc["comm"] = comm
        # merge, don't clobber: row FAMILIES (first path component)
        # this run did not regenerate are carried over from the
        # existing baseline, as are their summary sections — so
        # ``--only comm --json BENCH_pagerank.json`` refreshes the
        # comm/ rows without erasing e2e/table4/stream history
        prev = None
        try:
            with open(args.json) as f:
                prev = json.load(f)
        except (OSError, json.JSONDecodeError):
            prev = None
        if prev and prev.get("rows"):
            new_fams = {r["name"].split("/")[0] for r in doc["rows"]}
            kept = [r for r in prev["rows"]
                    if r["name"].split("/")[0] not in new_fams]
            doc["rows"] = kept + doc["rows"]
            doc["only"] = sorted(set(prev.get("only", []))
                                 | set(selected))
            for sect in ("plan_vs_iterate", "patch_vs_rebuild",
                         "locality", "locality_meta", "comm"):
                if sect not in doc and sect in prev:
                    doc[sect] = prev[sect]
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# wrote {args.json}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
