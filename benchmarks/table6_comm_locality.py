"""Paper Table VI: impact of locality-optimized labeling on
communication (and therefore runtime) for PDPR / BVGAS / PCPM.

Per (dataset, labeling, method): the analytic model bytes (with the
measured r of that labeling) and the measured per-iteration time.  The
paper's claims: BVGAS flat under relabeling; PDPR and PCPM improve.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm_model import (ModelParams, pdpr_bytes, bvgas_bytes,
                                   pcpm_bytes)
from repro.core.spmv import SpMVEngine
from repro.graphs import reorder
from .common import Csv, Dataset, timeit


def run(datasets: list[Dataset], *, part_size: int = 65536) -> Csv:
    csv = Csv()
    for ds in datasets:
        for label in ("orig", "hybrid"):
            g = (ds.graph if label == "orig"
                 else ds.graph.relabel(reorder.hybrid_order(ds.graph)))
            x = jnp.asarray(
                np.random.default_rng(0).random(ds.n).astype(np.float32))
            engs = {m: SpMVEngine(g, method=m, part_size=part_size)
                    for m in ("pdpr", "bvgas", "pcpm")}
            r = engs["pcpm"].compression_ratio
            k = engs["pcpm"].partitioning.num_partitions
            pm = ModelParams(ds.n, ds.m, k, r)
            model = {"pdpr": pdpr_bytes(pm), "bvgas": bvgas_bytes(pm),
                     "pcpm": pcpm_bytes(pm)}
            for m, eng in engs.items():
                t = timeit(lambda: jax.block_until_ready(eng(x)))
                csv.add(f"table6/{ds.name}/{label}/{m}", t,
                        f"modelGB={model[m] / 1e9:.3f},r={r:.2f}")
    return csv
