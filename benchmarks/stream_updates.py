"""Dynamic-graph update benchmark (DESIGN.md §9): after an edge delta,
how fast are warm ranks back?

Two paths race from the same starting state (a solved graph with a
built plan — the steady state of a serving deployment):

- **warm**:  incremental plan patch (dirty partitions only)
             + residual-push rank update seeded at the changed edges;
- **cold**:  full plan rebuild on a fresh graph handle
             + full power iteration.

Both sides pay their own trace/compile and device upload — each row is
wall-clock from "delta arrives" to "updated ranks on device".  Two
regimes per delta:

- ``*20`` — the repo's standard benchmark convention (BENCH e2e rows):
  cold runs the fixed 20 iterations; warm pushes to the SAME stopping
  residual cold achieved, so warm accuracy >= cold accuracy (both
  reported against a deep-converged reference).
- ``*_tol`` — deep convergence: both sides run to an L1 stopping
  residual of 1e-6 (identical stopping rule; the push's per-sweep L1
  change is exactly the fused driver's per-step L1 change).

Deltas are half removals / half insertions.  The *localized* deltas
land in a small band of destination partitions (the new-content
arrival pattern incremental patching is built for); the *scattered*
delta sprays uniformly, dirties every partition, and is reported
anyway — it exercises the full-rebuild fallback, so its patch row
honestly costs ~a rebuild while the push still wins.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.pagerank import pagerank
from repro.core.plan import PlanConfig, build_plan, evict_plans
from repro.core.spmv import SpMVEngine
from repro.graphs.formats import Graph
from repro.stream import GraphDelta, apply_delta, patch_plan, update_ranks
from .common import Csv, Dataset
from .pagerank_e2e import _upload_plan

TOL = 1e-6           # deep-convergence regime stopping residual


def _band_delta(g: Graph, frac: float, part_size: int,
                rng: np.random.Generator, *,
                scattered: bool = False) -> GraphDelta:
    """~frac·m changed edges: half removals, half inserts.  Localized
    deltas confine destinations to a band of partitions just big
    enough to supply the removals."""
    n, m = g.num_nodes, g.num_edges
    half = max(1, int(m * frac) // 2)
    if scattered:
        rem_pool = np.arange(m)
        add_dst = rng.integers(0, n, size=half).astype(np.int32)
    else:
        k = -(-n // part_size)
        band = max(1, int(np.ceil(2.0 * half / (m / k))))
        in_band = g.dst < band * part_size
        rem_pool = np.flatnonzero(in_band)
        half = min(half, len(rem_pool))
        add_dst = rng.integers(0, min(band * part_size, n),
                               size=half).astype(np.int32)
    ridx = rng.choice(rem_pool, size=half, replace=False)
    add = np.stack([rng.integers(0, n, size=half).astype(np.int32),
                    add_dst], axis=1)
    rem = np.stack([g.src[ridx], g.dst[ridx]], axis=1)
    return GraphDelta.of(add=add, remove=rem)


def _linf(a, b) -> float:
    return float(np.abs(np.asarray(a) - np.asarray(b)).max())


def _bench_delta(csv: Csv, tag: str, g: Graph, plan0, prev_ranks,
                 delta: GraphDelta, cfg: PlanConfig, *,
                 deep: bool = True) -> None:
    k = plan0.partitioning.num_partitions
    dirty = len(delta.dirty_partitions(cfg.part_size))
    g2 = apply_delta(g, delta)

    # ---- warm: incremental plan patch
    t0 = time.perf_counter()
    p2 = patch_plan(plan0, delta, g2)
    _upload_plan(p2)
    t_patch = time.perf_counter() - t0

    # ---- cold: fresh graph handle, evicted cache, full rebuild
    g2c = Graph(g2.num_nodes, g2.src.copy(), g2.dst.copy())
    evict_plans(g2, chain=False)
    t0 = time.perf_counter()
    p2c = build_plan(g2c, cfg)
    _upload_plan(p2c)
    t_rebuild = time.perf_counter() - t0
    cold_eng = SpMVEngine(g2c, plan=p2c)

    # ---- standard regime: cold runs the fixed 20 iterations, warm
    #      pushes to the residual cold achieved
    t0 = time.perf_counter()
    cold20 = pagerank(g2c, engine=cold_eng, num_iterations=20, tol=0.0)
    cold20.ranks.block_until_ready()
    t_iter20 = time.perf_counter() - t0
    res20 = cold20.residuals[-1]
    t0 = time.perf_counter()
    warm20 = update_ranks(p2, delta, prev_ranks, g_old=g, g_new=g2,
                          tol=res20, max_push=400)
    warm20.ranks.block_until_ready()
    t_push20 = time.perf_counter() - t0

    # deep-converged reference for the accuracy columns (untimed)
    ref = pagerank(g2c, engine=cold_eng, num_iterations=400, tol=1e-8)
    csv.add(f"{tag}/patch", t_patch,
            f"dirty={dirty}/{k},spliced={int(dirty / k <= 0.5)}")
    csv.add(f"{tag}/rebuild", t_rebuild)
    csv.add(f"{tag}/recompute20", t_iter20,
            f"iters=20,res={res20:.1e},err={_linf(cold20.ranks, ref.ranks):.1e}")
    csv.add(f"{tag}/push20", t_push20,
            f"sweeps={warm20.iterations}"
            f",err={_linf(warm20.ranks, ref.ranks):.1e}")
    csv.add(f"{tag}/speedup20", 0.0,
            f"cold_ms={(t_rebuild + t_iter20) * 1e3:.0f}"
            f",warm_ms={(t_patch + t_push20) * 1e3:.0f}"
            f",x={(t_rebuild + t_iter20) / (t_patch + t_push20):.1f}")

    if deep:
        # ---- deep regime: both sides stop at ‖step‖₁ < TOL
        t0 = time.perf_counter()
        cold_t = pagerank(g2c, engine=cold_eng, num_iterations=400,
                          tol=TOL)
        cold_t.ranks.block_until_ready()
        t_iter_t = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm_t = update_ranks(p2, delta, prev_ranks, g_old=g, g_new=g2,
                              tol=TOL, max_push=400)
        warm_t.ranks.block_until_ready()
        t_push_t = time.perf_counter() - t0
        csv.add(f"{tag}/recompute_tol", t_iter_t,
                f"iters={cold_t.iterations}")
        csv.add(f"{tag}/push_tol", t_push_t,
                f"sweeps={warm_t.iterations}"
                f",Linf_vs_cold={_linf(warm_t.ranks, cold_t.ranks):.1e}")
        csv.add(f"{tag}/speedup_tol", 0.0,
                f"cold_ms={(t_rebuild + t_iter_t) * 1e3:.0f}"
                f",warm_ms={(t_patch + t_push_t) * 1e3:.0f}"
                f",x={(t_rebuild + t_iter_t) / (t_patch + t_push_t):.1f}")
    # leave the cache as the warm path expects for the next delta
    evict_plans(g2, chain=False)


def run(datasets: list[Dataset], *, part_size: int = 65536,
        fracs: tuple = (0.001, 0.01), method: str = "pcpm") -> Csv:
    csv = Csv()
    rng = np.random.default_rng(0)
    for ds in datasets:
        g = ds.graph
        cfg = PlanConfig(method=method, part_size=part_size)
        evict_plans(g)
        plan0 = build_plan(g, cfg)
        _upload_plan(plan0)
        # solved steady state: converged ranks + CSR of the solved
        # graph (what the residual seed reads) are warm by definition
        prev = pagerank(g, engine=SpMVEngine(g, plan=plan0),
                        num_iterations=400, tol=TOL / 10)
        prev.ranks.block_until_ready()
        g.csr
        # steady state also includes a compiled push loop: the pcpm
        # push passes its (bucket-padded) streams as arguments, so one
        # executable serves every subsequent delta — warm it with a
        # throwaway 1-edge delta, exactly as a streaming deployment
        # would have long since done.  (The cold side has no analogue:
        # its fused loop closes over each rebuilt plan's constants.)
        wu = GraphDelta.of(
            add=[[int(g.src[0]), int(g.dst[0] + 1) % g.num_nodes]],
            remove=[[int(g.src[0]), int(g.dst[0])]])
        g_wu = apply_delta(g, wu)
        update_ranks(patch_plan(plan0, wu, g_wu), wu, prev.ranks,
                     g_old=g, g_new=g_wu, tol=0.0,
                     max_push=2).ranks.block_until_ready()
        evict_plans(g_wu, chain=False)
        for frac in fracs:
            _bench_delta(csv, f"stream/{ds.name}/f{frac:g}", g, plan0,
                         prev.ranks, _band_delta(g, frac, part_size,
                                                 rng), cfg)
        _bench_delta(csv, f"stream/{ds.name}/scattered{fracs[-1]:g}",
                     g, plan0, prev.ranks,
                     _band_delta(g, fracs[-1], part_size, rng,
                                 scattered=True), cfg, deep=False)
    return csv
